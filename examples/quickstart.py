"""Quickstart: train a tiny qwen3-family model for 30 steps on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_smoke_config
from repro.launch.train import train_loop
from repro.training.optimizer import OptConfig


def main():
    cfg = get_smoke_config("qwen3-32b")
    _, history, _ = train_loop(
        cfg, steps=30, batch=4, seq=64,
        opt=OptConfig(peak_lr=3e-3, warmup_steps=5, total_steps=30))
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f}")
    assert history[-1] < history[0]
    print("quickstart OK")


if __name__ == "__main__":
    main()

"""The paper's application end-to-end: PW advection with the kernel ladder.

Steps a stratus-cloud test case with each kernel variant, checks they agree,
prints the per-variant modelled HBM traffic (the Fig. 3 ladder) including
the v4 temporal-fusion rung, and runs the distributed halo-exchange version
on a 4-way device mesh (subprocess, so this process keeps the single-device
view).

    PYTHONPATH=src python examples/advection_stencil.py
"""
import subprocess
import sys
import textwrap

import jax.numpy as jnp

from repro.stencil.advection import AdvectionDomain


def main():
    X, Y, Z = 12, 64, 128
    results = {}
    for variant in ("reference", "blocked", "dataflow", "wide", "fused"):
        # fuse_T=1 so every variant advances the same single Euler step;
        # the T=4 traffic win is printed separately below
        dom = AdvectionDomain(X, Y, Z, variant=variant, fuse_T=1, dt=0.1)
        u, v, w = dom.init()
        u2, v2, w2 = dom.step(u, v, w)
        results[variant] = u2
        print(f"{variant:10s}: HBM bytes/step (model) = "
              f"{dom.hbm_bytes_per_step()/1e6:8.2f} MB, "
              f"flops/step = {dom.flops_per_step()/1e6:.1f} MF")
    ref = results["reference"]
    for k, r in results.items():
        err = float(jnp.max(jnp.abs(r - ref)))
        assert err < 1e-4, (k, err)
        print(f"{k:10s} matches reference (max err {err:.2e})")

    print("\n-- temporal fusion (v4): T steps per HBM pass, in-grid tiled --")
    fdom = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=4, dt=0.1,
                           y_tile=32)          # tiling="grid" is the default
    u, v, w = fdom.init()
    out = fdom.advance(u, v, w, 4)   # one fused pass = 4 Euler substeps
    base = AdvectionDomain(X, Y, Z, variant="dataflow", dt=0.1)
    per_pass = fdom.hbm_bytes_per_step()
    per_4_steps = 4 * base.hbm_bytes_per_step()
    host = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=4, dt=0.1,
                           y_tile=32, tiling="host")
    print(f"fused T=4 : {per_pass/1e6:8.2f} MB per 4 steps "
          f"(dataflow would move {per_4_steps/1e6:.2f} MB) -> "
          f"{per_4_steps/per_pass:.1f}x amortisation; "
          f"VMEM register {fdom.vmem_register_bytes()/1e3:.0f} kB")
    print(f"            in-grid tiles serve "
          f"{fdom.vmem_halo_bytes_per_step()/1e3:.0f} kB of halo re-reads "
          f"from VMEM (the host-tiled loop restages "
          f"{(host.hbm_bytes_per_step()-per_pass)/1e3:.0f} kB via HBM)")
    assert jnp.all(jnp.isfinite(out[0]))

    print("\n-- fused Euler update in the v1-v3 kernels (fuse_update) --")
    sdom = AdvectionDomain(X, Y, Z, variant="dataflow", fuse_update=True,
                           dt=0.1, y_tile=32)
    su = sdom.step(u, v, w)
    err = float(jnp.max(jnp.abs(su[0] - base.step(u, v, w)[0])))
    print(f"dataflow fuse_update: advanced fields in-kernel, "
          f"{sdom.hbm_bytes_per_step()/1e6:.2f} MB/step vs "
          f"{base.hbm_bytes_per_step()/1e6:.2f} MB unfused (max err {err:.1e})")

    print("\n-- distributed halo exchange (4-way y-decomposition) --")
    code = textwrap.dedent("""
        import os
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, sys
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.stencil.distributed import (make_distributed_advect,
                                               make_distributed_step,
                                               reference_global,
                                               reference_global_step)
        from repro.stencil.advection import stratus_fields
        from repro.kernels.advection.ref import default_params
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((4,), ("data",))
        u, v, w = stratus_fields(8, 32, 16)
        p = default_params(16)
        fn = make_distributed_advect(mesh, p)
        sh = NamedSharding(mesh, P(None, "data", None))
        out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref = reference_global(u, v, w, p)
        err = max(float(jnp.max(jnp.abs(a-b))) for a, b in zip(out, ref))
        print(f"distributed == global oracle, max err {err:.2e}")
        assert err < 1e-5
        step = make_distributed_step(mesh, p, T=4, dt=0.05)
        out4 = step(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref4 = reference_global_step(u, v, w, p, T=4, dt=0.05)
        err4 = max(float(jnp.max(jnp.abs(a-b))) for a, b in zip(out4, ref4))
        print(f"fused distributed step (T=4, one exchange) max err {err4:.2e}")
        assert err4 < 1e-5
        stepk = make_distributed_step(mesh, p, T=4, dt=0.05,
                                      local_kernel="fused", y_tile=4)
        outk = stepk(*(jax.device_put(t, sh) for t in (u, v, w)))
        errk = max(float(jnp.max(jnp.abs(a-b))) for a, b in zip(outk, ref4))
        print(f"  + v4 Pallas local kernel, in-grid y-tiles: err {errk:.2e}")
        assert errk < 1e-5
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin",
                                       "JAX_PLATFORMS": "cpu"})
    print(r.stdout.strip() or r.stderr[-500:])
    assert r.returncode == 0
    print("advection_stencil OK")


if __name__ == "__main__":
    main()

"""The paper's application end-to-end: PW advection with the kernel ladder.

Steps a stratus-cloud test case with each kernel variant, checks they agree,
prints the per-variant modelled HBM traffic (the Fig. 3 ladder), and runs
the distributed halo-exchange version on a 4-way device mesh (subprocess,
so this process keeps the single-device view).

    PYTHONPATH=src python examples/advection_stencil.py
"""
import subprocess
import sys
import textwrap

import jax.numpy as jnp

from repro.stencil.advection import AdvectionDomain


def main():
    X, Y, Z = 12, 64, 128
    results = {}
    for variant in ("reference", "blocked", "dataflow", "wide"):
        dom = AdvectionDomain(X, Y, Z, variant=variant)
        u, v, w = dom.init()
        u2, v2, w2 = dom.step(u, v, w, dt=0.1)
        results[variant] = u2
        print(f"{variant:10s}: HBM bytes/step (model) = "
              f"{dom.hbm_bytes_per_step()/1e6:8.2f} MB, "
              f"flops/step = {dom.flops_per_step()/1e6:.1f} MF")
    ref = results["reference"]
    for k, r in results.items():
        err = float(jnp.max(jnp.abs(r - ref)))
        assert err < 1e-4, (k, err)
        print(f"{k:10s} matches reference (max err {err:.2e})")

    print("\n-- distributed halo exchange (4-way y-decomposition) --")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, sys
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.stencil.distributed import make_distributed_advect, reference_global
        from repro.stencil.advection import stratus_fields
        from repro.kernels.advection.ref import default_params
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        u, v, w = stratus_fields(8, 32, 16)
        p = default_params(16)
        fn = make_distributed_advect(mesh, p)
        sh = NamedSharding(mesh, P(None, "data", None))
        out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref = reference_global(u, v, w, p)
        err = max(float(jnp.max(jnp.abs(a-b))) for a, b in zip(out, ref))
        print(f"distributed == global oracle, max err {err:.2e}")
        assert err < 1e-5
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    print(r.stdout.strip() or r.stderr[-500:])
    assert r.returncode == 0
    print("advection_stencil OK")


if __name__ == "__main__":
    main()

"""Batched serving: continuous-batching engine over a tiny model.

Requests arrive into a fixed decode batch; finished slots are immediately
re-primed with queued requests while other slots keep decoding — the
paper's §IV chunk/kernel-pool overlap, applied to inference serving.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro import pspec
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_smoke_config("qwen3-32b")
    layout = M.make_layout(cfg, tp=1)
    params = pspec.init_params(M.param_specs(cfg, layout), jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_size=3, max_len=96)

    rng = np.random.default_rng(7)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 24)).astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 12)))
            for i in range(8)]
    done = engine.run(reqs)
    assert set(done) == {r.uid for r in reqs}
    for uid in sorted(done):
        print(f"req {uid}: {len(done[uid])} tokens -> {done[uid][:8]}...")

    # determinism: rerunning the same request stream gives identical outputs
    reqs2 = [Request(uid=r.uid, prompt=r.prompt, max_new_tokens=r.max_new_tokens)
             for r in reqs]
    again = ServingEngine(cfg, params, batch_size=3, max_len=96).run(reqs2)
    assert again == done, "greedy decode must be deterministic"
    print("serve_batched OK (deterministic greedy, continuous batching)")


if __name__ == "__main__":
    main()

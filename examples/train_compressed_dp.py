"""Data-parallel training with int8 gradient compression + error feedback.

Demonstrates the distributed-optimisation feature for the DCN (pod) axis:
gradients cross the slow link int8-quantised (4x wire-byte cut), the
quantisation error is fed back next step. Runs on a 4-way device mesh in a
subprocess (shard_map over the DP axis — the explicit-collective trainer).

    PYTHONPATH=src python examples/train_compressed_dp.py
"""
import subprocess
import sys
import textwrap


CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np, functools
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import pspec
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.distributed.compression import (compressed_tree_psum,
                                               init_residuals)
    from repro.training import optimizer as O

    cfg = get_smoke_config("qwen3-32b")
    layout = M.make_layout(cfg, tp=1)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((4,), ("dp",))
    params = pspec.init_params(M.param_specs(cfg, layout), jax.random.PRNGKey(0))
    opt_state = O.init_opt_state(params)
    residuals = init_residuals(params)
    oc = O.OptConfig(peak_lr=3e-3, warmup_steps=2, total_steps=30)

    def local_grads(params, batch):
        loss, _ = M.loss_fn(params, batch, cfg, layout)
        return loss, jax.grad(lambda p: M.loss_fn(p, batch, cfg, layout)[0])(params)

    def dp_step(params, opt_state, residuals, batch, compress):
        def shard_fn(params, batch, residuals):
            loss, grads = local_grads(params, batch)
            if compress:
                grads, residuals = compressed_tree_psum(grads, "dp", residuals)
            else:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, "dp"), grads)
            loss = jax.lax.pmean(loss, "dp")
            return loss, grads, residuals
        pspec_b = jax.tree.map(lambda _: P("dp"), batch)
        loss, grads, residuals = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(), pspec_b, P()),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )(params, batch, residuals)
        params, opt_state, _ = O.adamw_update(params, grads, opt_state, oc)
        return loss, params, opt_state, residuals

    rng = np.random.default_rng(0)
    B, S = 8, 64
    losses = {True: [], False: []}
    for compress in (False, True):
        p, o, r = params, opt_state, residuals
        step = jax.jit(functools.partial(dp_step, compress=compress))
        for i in range(15):
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)))
            batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
            with mesh:
                loss, p, o, r = step(p, o, r, batch)
            losses[compress].append(float(loss))
    print("fp32 DP:", [f"{l:.3f}" for l in losses[False][::5]])
    print("int8+EF:", [f"{l:.3f}" for l in losses[True][::5]])
    gap = abs(losses[True][-1] - losses[False][-1])
    print(f"final-loss gap fp32 vs int8+error-feedback: {gap:.4f}")
    assert losses[True][-1] < losses[True][0], "compressed training must learn"
    assert gap < 0.35, gap
    print("train_compressed_dp OK (4x DCN wire bytes saved)")
""")


def main():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin",
                                       "JAX_PLATFORMS": "cpu"},
                       timeout=900)
    print(r.stdout.strip() or r.stderr[-2000:])
    assert r.returncode == 0, r.stderr[-2000:]


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps with the full substrate — sharded step, chunk-prefetching data
pipeline, async checkpointing, auto-resume, NaN guard.

    PYTHONPATH=src python examples/train_100m.py              # ~200 steps
    PYTHONPATH=src python examples/train_100m.py --quick      # CI-sized
"""
import argparse

from repro.config import ArchConfig
from repro.launch.train import train_loop
from repro.training.optimizer import OptConfig


def make_100m() -> ArchConfig:
    cfg = ArchConfig(
        name="dense-100m",
        family="dense",
        n_layers=10,
        d_model=640,
        n_heads=10,
        n_kv_heads=2,
        d_ff=2560,
        vocab_size=32000,
        head_dim=64,
        mlp="swiglu",
        pos="rope",
        remat="none",
        attn_chunk=256,
    )
    print(f"model: {cfg.param_count()/1e6:.1f}M params")
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()
    steps = args.steps or (20 if args.quick else 200)
    cfg = make_100m()
    _, history, info = train_loop(
        cfg, steps=steps, batch=4, seq=256,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(steps // 4, 10),
        opt=OptConfig(peak_lr=1e-3, warmup_steps=max(steps // 10, 2),
                      total_steps=steps),
        log_every=max(steps // 20, 1))
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f} "
          f"({info['skipped']} skipped steps)")
    assert history[-1] < history[0], "training must reduce loss"
    print("train_100m OK")


if __name__ == "__main__":
    main()

"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free. [arXiv:2410.05355]"""
from repro.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    head_dim=1,
    mlp="swiglu",  # unused (attention-free family has no MLP)
    pos="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=16, conv_k=4, expand=2),
)

SMOKE = CONFIG.replace(
    name="falcon-mamba-7b-smoke",
    n_layers=2, d_model=64, vocab_size=128, scan_chunk=16,
    ssm=SSMConfig(d_state=4, conv_k=4, expand=2, dt_rank=8),
)

"""qwen2.5-14b [dense] — GQA with QKV bias, SwiGLU. [hf:Qwen/Qwen2.5-*]"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    head_dim=128,
    mlp="swiglu",
    qkv_bias=True,
    pos="rope",
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="qwen2.5-14b-smoke",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, head_dim=16,
    d_ff=160, vocab_size=128, attn_chunk=32, scan_chunk=16,
)

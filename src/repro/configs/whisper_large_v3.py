"""whisper-large-v3 [audio] — enc-dec transformer backbone, conv frontend stub.

32 encoder + 32 decoder layers (the assignment's "32L"), MHA (kv == q heads),
GELU MLPs, LayerNorm with bias, sinusoidal encoder positions + learned decoder
positions, tied decoder embeddings. Inputs are precomputed frame embeddings
(the conv frontend is a stub per the assignment). [arXiv:2212.04356]
"""
from repro.config import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=64,  # 32 enc + 32 dec
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    head_dim=64,
    mlp="gelu",
    pos="none",
    tie_embeddings=True,
    norm_eps=1e-5,
    encdec=EncDecConfig(enc_layers=32, dec_layers=32, dec_len=448, max_dec_len=448),
    embeds_input=True,
)

SMOKE = CONFIG.replace(
    name="whisper-large-v3-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=131, attn_chunk=32, scan_chunk=16,
    encdec=EncDecConfig(enc_layers=2, dec_layers=2, dec_len=16, max_dec_len=32),
)

"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern.

Pattern (rec, rec, attn) repeating over 38 layers; local attention window
2048; MQA (kv=1). [arXiv:2402.19427]
"""
from repro.config import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    mlp="swiglu",
    pos="rope",
    scan_layers=False,  # non-uniform pattern: unrolled stack
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), window=2048, conv_k=4),
)

SMOKE = CONFIG.replace(
    name="recurrentgemma-9b-smoke",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=128, attn_chunk=32, scan_chunk=16,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"), window=16, conv_k=4),
)

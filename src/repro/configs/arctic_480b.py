"""arctic-480b [moe] — 128 experts top-2 PLUS a dense residual MLP per layer
(dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base]
"""
from repro.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    mlp="swiglu",
    pos="rope",
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, capacity_factor=1.25),
)

SMOKE = CONFIG.replace(
    name="arctic-480b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=128, attn_chunk=32, scan_chunk=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96,
                  dense_residual=True, capacity_factor=4.0, group_size=64),
)

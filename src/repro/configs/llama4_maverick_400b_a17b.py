"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared expert,
early-fusion multimodal backbone (text path built here). [hf:meta-llama/Llama-4-*]
"""
from repro.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    mlp="swiglu",
    pos="rope",
    rope_theta=500_000.0,
    scan_layers=False,  # interleaved dense/MoE pattern: unrolled stack
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                  shared_expert=True, capacity_factor=1.25, moe_every=2),
)

SMOKE = CONFIG.replace(
    name="llama4-maverick-smoke",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, head_dim=16,
    d_ff=96, vocab_size=128, attn_chunk=32, scan_chunk=16,
    moe=MoEConfig(n_experts=8, top_k=1, d_ff_expert=96,
                  shared_expert=True, capacity_factor=8.0, group_size=64),
)

"""qwen2-vl-72b [vlm] — M-RoPE backbone, dynamic-resolution frontend (stub).

Per the assignment the modality frontend is a stub: inputs are precomputed
patch embeddings at d_model plus 3-component (t,h,w) M-RoPE positions.
[arXiv:2409.12191]
"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    mlp="swiglu",
    qkv_bias=True,
    pos="mrope",
    rope_theta=1_000_000.0,
    embeds_input=True,
)

SMOKE = CONFIG.replace(
    name="qwen2-vl-72b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128, attn_chunk=32, scan_chunk=16,
)

"""Config registry: every assigned architecture + the paper's own kernel.

``get_config(name)`` returns the full published config;
``get_smoke_config(name)`` returns a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ArchConfig

ARCH_IDS: List[str] = [
    "nemotron_4_340b",
    "qwen2_5_14b",
    "qwen3_32b",
    "nemotron_4_15b",
    "qwen2_vl_72b",
    "whisper_large_v3",
    "arctic_480b",
    "llama4_maverick_400b_a17b",
    "falcon_mamba_7b",
    "recurrentgemma_9b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return name


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {i: get_config(i) for i in ARCH_IDS}

"""qwen3-32b [dense] — GQA with qk_norm, SwiGLU. [hf:Qwen/Qwen3-*]"""
from repro.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=128,
    mlp="swiglu",
    qk_norm=True,
    pos="rope",
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    name="qwen3-32b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=128, attn_chunk=32, scan_chunk=16,
)

"""Continuous-batching slot manager, shared by every serving engine.

The paper's §IV kernel-pool/DMA-chunk overlap — new work arriving in
chunks while resident work keeps computing — is continuous batching: a
fixed pool of B slots, each either idle or owned by an in-flight request
with a step budget. `SlotManager` owns exactly that bookkeeping (and
nothing model-specific), so the LLM `ServingEngine` and the stencil
`StencilServingEngine` share one slot lifecycle:

    prime  : `occupy(slot, req, budget)` — a queued request takes an idle
             slot. A budget of 0 means the request is already complete at
             prime time (the engine emits whatever priming produced and
             never occupies the slot) — the budget off-by-one this class
             exists to make unrepresentable.
    step   : `tick(slot)` — one unit of work done; returns True when the
             budget is exhausted and the engine must complete the request.
    finish : `release(slot)` — back to idle, immediately re-primable
             while the other slots keep stepping.
"""
from __future__ import annotations

from typing import Any, List, Optional

import numpy as np


class SlotManager:
    """Host-side lifecycle of a fixed pool of decode/step slots."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self._live = np.zeros((n_slots,), bool)
        self._budget = np.zeros((n_slots,), np.int64)
        self._req: List[Optional[Any]] = [None] * n_slots

    # -- queries -----------------------------------------------------------
    def live_mask(self) -> np.ndarray:
        """Copy of the live flags, index-aligned with the slot axis."""
        return self._live.copy()

    def any_live(self) -> bool:
        return bool(self._live.any())

    def is_live(self, slot: int) -> bool:
        return bool(self._live[slot])

    def idle_slots(self) -> List[int]:
        return [s for s in range(self.n_slots) if not self._live[s]]

    def live_slots(self) -> List[int]:
        return [s for s in range(self.n_slots) if self._live[s]]

    def request(self, slot: int):
        return self._req[slot]

    def budget(self, slot: int) -> int:
        return int(self._budget[slot])

    # -- lifecycle ---------------------------------------------------------
    def occupy(self, slot: int, req, budget: int) -> None:
        """Give `slot` to `req` with `budget` steps of work remaining.
        `budget` must be >= 1: a request whose work is done at prime time
        is complete — completing it is the CALLER's move, not a slot
        state."""
        if self._live[slot]:
            raise ValueError(f"slot {slot} is already live")
        if budget < 1:
            raise ValueError(
                f"budget must be >= 1 to occupy a slot, got {budget}; a "
                "request already complete at prime time never occupies one")
        self._live[slot] = True
        self._budget[slot] = budget
        self._req[slot] = req

    def tick(self, slot: int) -> bool:
        """One unit of work done on `slot`; True when its budget is spent
        (the engine must complete and `release`)."""
        if not self._live[slot]:
            raise ValueError(f"slot {slot} is not live")
        self._budget[slot] -= 1
        return bool(self._budget[slot] <= 0)

    def release(self, slot: int) -> None:
        if not self._live[slot]:
            raise ValueError(f"slot {slot} is not live")
        self._live[slot] = False
        self._budget[slot] = 0
        self._req[slot] = None

"""Forecast-as-a-service: batched multi-domain serving over the fused stencil.

The ROADMAP's "heavy traffic from millions of users" is not one huge
advection domain — it is MANY small ones: a `StencilRequest` is an
``(initial u/v/w fields, AdvectParams, n_steps)`` forecast job, and the
`StencilServingEngine` packs up to `batch_size` of them into ONE padded
mega-launch of the fused kernel (`kernels.advection.advect_fused_batched`
— the batch rides an outer grid dimension, slots streaming back to back
through the shared VMEM rings). This is the paper's §IV kernel-pool/DMA
overlap applied at the product layer: chunked arrival of new forecast
jobs overlaps the resident jobs' compute, with the slot lifecycle shared
with the LLM engine via `serving.slots.SlotManager`.

Contracts (gated by BENCH_serving.json / BENCH_faults.json and
tests/test_stencil_serving.py / tests/test_faults.py):

  * Packing is exact, not approximate: a request SMALLER than the padded
    slot shape is embedded at the origin with per-slot interior masks
    freezing everything outside its own extent and boundary ring, so the
    mega-launch's cropped outputs are BITWISE-equal to per-domain
    sequential `advect_fused` runs on the unpadded fields.
  * Compiled executables are cached keyed on
    ``(shape, T, dtype, n_blocks, exchange, mesh)`` with hit/miss
    counters and a bounded LRU (`max_entries`) — one trace per
    configuration, every later mega-step a hit.
  * Intermediate states stream back per slot (`StencilRequest.states`,
    one cropped (u, v, w) snapshot per fused step).
  * Faults are injected from a deterministic `serving.faults.FaultPlan`
    at mega-step boundaries (the old `lose_device_at` hook is a
    deprecated one-fault alias) and recovery is LAYERED:
      - the mega-step runs the in-graph finite-guard pass
        (`advect_fused_batched(..., guard=True)` — one extra read pass
        over the advanced fields, priced by
        `roofline.guard_bytes_model`), so a poisoned slot is detected
        the step it goes non-finite; the guard is a SEPARATE pallas
        pass over the fused kernel's outputs, so every slot's fields —
        healthy or poisoned — stay bitwise-equal to an unguarded run;
      - periodic snapshots of the full in-flight state (through
        `training/checkpoint`'s atomic-write machinery when
        `snapshot_dir` is set) let ANY fault roll back and replay,
        resume bitwise-equal to an uninterrupted run; a fault that
        re-fires at the same (uid, step) site after a rollback is
        persistent by definition and the slot is QUARANTINED with an
        error status instead of rolled back forever;
      - a stalled exchange is retried with bounded backoff, then walks
        the `DegradationLadder` (`remote_dma` -> `collective` — a new
        cache key, one recorded re-trace) and finally resorts to the
        implicit last rung: reshard down to fewer slots;
      - every action lands in `health()` counters (faults, retries,
        quarantines, rollbacks, degradations, reshards), surfaced by
        `launch/serve.py` and gated by `benchmarks/fault_sweep.py`.
  * A device loss re-shards the engine: live slots are re-packed into a
    smaller batch (a new cache key — the recorded miss), overflow jobs
    resume from their in-flight state when slots free up, and the
    completed outputs stay bitwise-equal to an uninterrupted run.
  * Per-tenant pricing: `AdvectionDomain(batch=...)` scales the
    flops/bytes/wire accounting and `roofline.serving_throughput_model`
    turns it into domains/s.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import vmem as AV
from repro.kernels.advection import advection as K
from repro.kernels.advection.ref import AdvectParams
from repro.serving.faults import (DEFAULT_LADDER, DegradationLadder,
                                  ExchangeStalled, Fault, FaultInjector,
                                  FaultPlan, RecoveryExhausted,
                                  retry_with_backoff)
from repro.serving.slots import SlotManager
from repro.stencil.advection import AdvectionDomain
from repro.training import checkpoint as CKPT


@dataclasses.dataclass
class StencilRequest:
    """One forecast job: initial fields + coefficients + a step budget.

    `n_steps` counts FUSED steps (each advances `domain.fuse_T` Euler
    substeps); 0 means the job is complete at prime time and returns its
    initial fields. `params=None` uses the engine domain's coefficients;
    a per-tenant `AdvectParams` (same Z) rides the slot's batched leaves.
    `status` walks pending -> running -> done, or -> quarantined (with
    `error` set and `out=None`) when the finite guard traps the slot.
    """
    uid: int
    u: np.ndarray                        # (Xr, Yr, Z) initial fields
    v: np.ndarray
    w: np.ndarray
    n_steps: int = 1
    params: Optional[AdvectParams] = None
    out: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
    states: Optional[List[Tuple[np.ndarray, ...]]] = None
    status: str = "pending"
    error: Optional[str] = None


@dataclasses.dataclass
class _InFlight:
    """A live job's full padded slot state, detached for re-sharding."""
    req: StencilRequest
    budget: int
    u: np.ndarray
    v: np.ndarray
    w: np.ndarray
    xm: np.ndarray
    ym: np.ndarray
    params: Tuple[np.ndarray, ...]
    extent: Tuple[int, int]


@dataclasses.dataclass
class _Snapshot:
    """Everything a rollback needs to replay from this boundary: the
    padded batch arrays, the slot assignments, the queue, and the length
    of every reachable request's streamed-state list (so replayed steps
    do not double-append). `disk_step` is set when the arrays were also
    written through `training/checkpoint.save` — the rollback then
    restores them from DISK, exercising the same atomic-write machinery
    the training tier trusts."""
    steps_run: int
    B: int
    arrays: Dict[str, np.ndarray]
    extents: List[Tuple[int, int]]
    live: List[Tuple[int, int, int]]     # (slot, uid, budget)
    reqs: Dict[int, StencilRequest]
    states_len: Dict[int, int]
    queue: List[Any]
    done_uids: set
    disk_step: Optional[int]


class ExecutableCache:
    """Compiled-executable cache: hit/miss/eviction counters + bounded LRU.

    Keys are the full recompilation surface of a mega-step —
    ``(shape, T, dtype, n_blocks, exchange, mesh)`` — so a re-shard (new
    batch in `shape`) or an engine/mesh change records a miss and traces
    once, while every steady-state mega-step is a hit on the same
    executable. `max_entries` bounds the cache under shape-diverse
    traffic: insertion past the bound evicts the least-recently-used
    entry (a later return to that key re-traces — a counted miss, never
    an error). `evict(key)` drops one entry explicitly — the
    `cache_evict` fault kind's hook."""

    def __init__(self, max_entries: Optional[int] = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._fns: "OrderedDict[Any, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, build):
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = self._fns[key] = build()
            if (self.max_entries is not None
                    and len(self._fns) > self.max_entries):
                self._fns.popitem(last=False)
                self.evictions += 1
        else:
            self.hits += 1
            self._fns.move_to_end(key)
        return fn

    def evict(self, key) -> bool:
        """Drop `key` if cached; True when something was evicted."""
        if key in self._fns:
            del self._fns[key]
            self.evictions += 1
            return True
        return False

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._fns), "evictions": self.evictions}


class StencilServingEngine:
    """Continuous-batching forecast server over the batched fused kernel.

    `domain` fixes the padded slot shape ``(X, Y, Z)``, the fusion depth
    `fuse_T`, dt, tiling, and the cache-key mesh/exchange/n_blocks
    configuration (the serving mega-step itself runs the single-shard
    batched kernel; a distributed mega-step would slot into `_build_step`
    under the same key discipline). Requests whose extent is smaller than
    the slot are padded and mask-frozen; Z must match exactly (the z axis
    has no interior mask — it is the vectorised lane dimension).

    Fault tolerance knobs: `fault_plan` (a `FaultPlan`, or a spec string
    for `FaultPlan.parse`) schedules deterministic faults at mega-step
    boundaries; `snapshot_every=k` rolls a recovery point every k
    mega-steps (default 1 — snapshots are host-side array copies, tiny
    next to the launch; None disables rollback and a tripped guard
    quarantines immediately); `snapshot_dir` additionally round-trips
    each snapshot through `training/checkpoint`'s atomic on-disk format;
    `max_retries`/`backoff_s` bound the exchange-stall retry loop;
    `cache_max_entries` bounds the executable cache (LRU).
    """

    def __init__(self, domain: AdvectionDomain, *, batch_size: int = 4,
                 fault_plan: Union[FaultPlan, str, None] = None,
                 snapshot_every: Optional[int] = 1,
                 snapshot_dir: Union[str, Path, None] = None,
                 max_retries: int = 3, backoff_s: float = 0.0,
                 sleeper=time.sleep,
                 cache_max_entries: Optional[int] = None):
        if domain.variant != "fused":
            raise ValueError("the serving tier packs the fused (v4) kernel; "
                             f"got variant={domain.variant!r}")
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if snapshot_every is not None and snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1 or None, got "
                             f"{snapshot_every}")
        self.domain = domain
        self.B = batch_size
        self.cache = ExecutableCache(max_entries=cache_max_entries)
        self.steps_run = 0
        # physical mega-step executions: unlike `steps_run` (the LOGICAL
        # step index, rewound by a rollback so replay is bitwise), this
        # counter is never restored — faulted-minus-clean is the recovery
        # overhead BENCH_faults.json bounds at exactly one replayed
        # snapshot interval per rollback
        self.megasteps_executed = 0
        # the guard is a separate pallas pass over the advanced fields,
        # so it composes with any tiling mode (including host)
        self._guard = True
        if isinstance(fault_plan, str):
            fault_plan = FaultPlan.parse(fault_plan)
        self._injector = FaultInjector(fault_plan)
        self._ladder = self._make_ladder()
        self._snapshot_every = snapshot_every
        self._snapshot_dir = Path(snapshot_dir) if snapshot_dir else None
        self._snap: Optional[_Snapshot] = None
        self._suspects: set = set()
        self._quarantined: set = set()
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self._sleeper = sleeper
        self._last_ok: Optional[np.ndarray] = None
        self._alloc(batch_size)

    def _make_ladder(self) -> DegradationLadder:
        start = self.domain.exchange
        rungs = (DEFAULT_LADDER if start in DEFAULT_LADDER
                 else (start,) + tuple(DEFAULT_LADDER))
        return DegradationLadder(rungs, start=start)

    # -- storage -----------------------------------------------------------
    def _alloc(self, batch_size: int) -> None:
        d = self.domain
        dt = np.dtype(d.dtype)
        # static VMEM budget BEFORE any allocation or compile: the
        # batched slot rings must fit VMEM_PER_CORE (the analysis
        # layer's generalisation of roofline.serving_max_batch — same
        # bound, but the error names the buffer and its sizing)
        AV.serving_ring_plan(d.Y, d.Z, batch=batch_size, T=d.fuse_T,
                             itemsize=dt.itemsize, y_tile=d.y_tile,
                             context="serving engine slot rings").check()
        self.B = batch_size
        self.slots = SlotManager(batch_size)
        shape = (batch_size, d.X, d.Y, d.Z)
        self.u = np.zeros(shape, dt)
        self.v = np.zeros(shape, dt)
        self.w = np.zeros(shape, dt)
        self.xm = np.zeros((batch_size, d.X), np.float32)
        self.ym = np.zeros((batch_size, d.Y), np.float32)
        base = [np.asarray(leaf) for leaf in d.params]
        self._p = [np.stack([leaf] * batch_size) for leaf in base]
        self._extent: List[Tuple[int, int]] = [(0, 0)] * batch_size

    def _step_key(self):
        d = self.domain
        return ((self.B, d.X, d.Y, d.Z), d.fuse_T, d.dtype, d.n_blocks,
                d.exchange, (d.mesh_nx, d.mesh_ny))

    def _build_step(self):
        d = self.domain
        guard = self._guard

        def step(u, v, w, p, xm, ym):
            return K.advect_fused_batched(u, v, w, p, T=d.fuse_T, dt=d.dt,
                                          interpret=d.interpret,
                                          y_tile=d.y_tile, tiling=d.tiling,
                                          x_interior_mask=xm,
                                          y_interior_mask=ym, guard=guard)

        return jax.jit(step)

    # -- slot lifecycle ----------------------------------------------------
    def _pack(self, slot: int, u, v, w, params: Optional[AdvectParams],
              extent: Tuple[int, int]) -> None:
        d = self.domain
        Xr, Yr = extent
        for dst, src in ((self.u, u), (self.v, v), (self.w, w)):
            dst[slot] = 0.0
            dst[slot, :Xr, :Yr] = np.asarray(src, dst.dtype)
        # freeze everything outside the request's own interior: its
        # boundary ring behaves exactly like the unpadded kernel's
        # structural walls, so padding is bitwise-invisible
        self.xm[slot] = 0.0
        self.xm[slot, 1:Xr - 1] = 1.0
        self.ym[slot] = 0.0
        self.ym[slot, 1:Yr - 1] = 1.0
        leaves = (list(params) if params is not None
                  else [np.asarray(leaf) for leaf in d.params])
        for dst, leaf in zip(self._p, leaves):
            dst[slot] = np.asarray(leaf, dst.dtype)
        self._extent[slot] = extent

    def _prime(self, slot: int, req: StencilRequest) -> bool:
        """Pack `req` into `slot`; True when complete at prime time
        (``n_steps == 0`` — the job's output is its initial state and it
        never occupies the slot)."""
        d = self.domain
        if req.n_steps < 0:
            raise ValueError(f"n_steps must be >= 0, got {req.n_steps} "
                             f"(request {req.uid})")
        shp = np.asarray(req.u).shape
        if np.asarray(req.v).shape != shp or np.asarray(req.w).shape != shp:
            raise ValueError(f"request {req.uid} field shapes differ")
        if len(shp) != 3:
            raise ValueError(f"request {req.uid} fields must be (X, Y, Z), "
                             f"got shape {shp}")
        Xr, Yr, Zr = shp
        if Zr != d.Z:
            raise ValueError(
                f"request {req.uid} has Z={Zr} but the engine slot is "
                f"Z={d.Z}: z is the lane dimension and cannot be padded")
        if Xr > d.X or Yr > d.Y:
            raise ValueError(
                f"request {req.uid} extent ({Xr}, {Yr}) exceeds the padded "
                f"slot shape ({d.X}, {d.Y}); domains must fit the slot")
        if Xr < 3 or Yr < 3:
            raise ValueError(
                f"request {req.uid} extent ({Xr}, {Yr}) has no interior "
                "cell; the stencil needs >= 3 points per decomposed axis")
        if req.params is not None and np.asarray(
                req.params.tzc1).shape != (d.Z,):
            raise ValueError(f"request {req.uid} params are not for Z={d.Z}")
        req.states = []
        crop = (np.asarray(req.u, np.dtype(d.dtype)).copy(),
                np.asarray(req.v, np.dtype(d.dtype)).copy(),
                np.asarray(req.w, np.dtype(d.dtype)).copy())
        if req.n_steps == 0:
            req.out = crop
            req.status = "done"
            return True
        self._pack(slot, req.u, req.v, req.w, req.params, (Xr, Yr))
        self.slots.occupy(slot, req, req.n_steps)
        req.status = "running"
        return False

    def _resume(self, slot: int, flight: _InFlight) -> None:
        """Re-pack a job displaced by a re-shard, from its in-flight state."""
        self.u[slot], self.v[slot], self.w[slot] = (flight.u, flight.v,
                                                    flight.w)
        self.xm[slot], self.ym[slot] = flight.xm, flight.ym
        for dst, leaf in zip(self._p, flight.params):
            dst[slot] = leaf
        self._extent[slot] = flight.extent
        self.slots.occupy(slot, flight.req, flight.budget)

    def _clear(self, slot: int) -> None:
        # an idle slot keeps stepping in the mega-launch; all-zero masks
        # freeze it completely so it costs nothing semantically
        self.xm[slot] = 0.0
        self.ym[slot] = 0.0
        self._extent[slot] = (0, 0)

    def _crop(self, slot: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        Xr, Yr = self._extent[slot]
        return (self.u[slot, :Xr, :Yr].copy(),
                self.v[slot, :Xr, :Yr].copy(),
                self.w[slot, :Xr, :Yr].copy())

    # -- the mega-step -----------------------------------------------------
    def _mega_step(self) -> None:
        fn = self.cache.get(self._step_key(), self._build_step)
        p = AdvectParams(*[jnp.asarray(leaf) for leaf in self._p])
        res = fn(jnp.asarray(self.u), jnp.asarray(self.v),
                 jnp.asarray(self.w), p,
                 jnp.asarray(self.xm), jnp.asarray(self.ym))
        if self._guard:
            ou, ov, ow, gf = res
            # a slot is healthy iff every x-slice flag word of its
            # guard pass is 1.0 — the post-kernel isfinite pass
            self._last_ok = np.asarray(gf).min(axis=1) > 0.0
        else:
            ou, ov, ow = res
            self._last_ok = np.ones((self.B,), bool)
        # np.array, not np.asarray: the device result is a read-only view
        # and the next prime writes into these buffers in place
        self.u = np.array(ou)
        self.v = np.array(ov)
        self.w = np.array(ow)
        self.steps_run += 1
        self.megasteps_executed += 1

    def _guarded_mega_step(self, queue: List[Any]) -> None:
        """One mega-step under the retry / degradation discipline: armed
        exchange stalls hang the attempt, the bounded backoff loop
        absorbs transient ones, a persistent stall degrades the ladder
        (new exchange -> new cache key -> one recorded re-trace), and a
        fully exhausted ladder takes the implicit last rung — reshard
        down to fewer slots (the lost transport's devices are gone)."""
        inj, lad = self._injector, self._ladder

        def attempt():
            inj.poll_stall(lad.current)
            self._mega_step()

        while True:
            try:
                retry_with_backoff(
                    attempt, max_retries=self.max_retries,
                    backoff_s=self.backoff_s, sleeper=self._sleeper,
                    on_retry=lambda k, e: inj.record("retries"))
                return
            except ExchangeStalled as e:
                try:
                    rung = lad.degrade(str(e))
                    inj.record("degradations")
                    inj.note(f"step {self.steps_run}: "
                             f"{lad.transitions[-1]}")
                    self.domain = dataclasses.replace(self.domain,
                                                      exchange=rung)
                except RecoveryExhausted:
                    n = max(self.B // 2, 1)
                    inj.record("reshards")
                    inj.note(f"step {self.steps_run}: ladder exhausted "
                             f"-> reshard to {n} slots")
                    inj.clear_stalls()
                    queue[:0] = self.reshard(n)

    # -- fault injection ---------------------------------------------------
    def _apply_faults(self, queue: List[Any]) -> None:
        """Apply the plan's faults due at this mega-step boundary."""
        inj = self._injector
        for idx, f in inj.due(self.steps_run):
            if f.kind == "device_loss":
                n = f.reshard_to if f.reshard_to is not None \
                    else max(self.B // 2, 1)
                inj.mark_fired(idx)
                inj.record("device_losses")
                inj.record("reshards")
                inj.note(f"step {self.steps_run}: device loss -> "
                         f"reshard to {n} slots")
                # displaced jobs resume ahead of queued fresh work
                queue[:0] = self.reshard(n)
            elif f.kind in ("nan_poison", "halo_corruption"):
                if f.slot >= self.B or not self.slots.is_live(f.slot):
                    inj.skip(idx, f"slot {f.slot} not live at step "
                                  f"{self.steps_run}")
                    continue
                arr = {"u": self.u, "v": self.v, "w": self.w}[f.field]
                Xr, Yr = self._extent[f.slot]
                if f.kind == "nan_poison":
                    # one interior cell: the stencil spreads it, the
                    # guard flags the whole slot this same step
                    arr[f.slot, 1, 1, 0] = f.value()
                else:
                    # a corrupted halo band: the mask freezes the
                    # boundary ring, so the poison SITS there (caught by
                    # the guard) but cannot re-enter the interior —
                    # one-shot, rollback + replay is clean
                    arr[f.slot, :min(f.depth, Xr), :Yr, :] = f.value()
                inj.mark_fired(idx)
                inj.note(f"step {self.steps_run}: {f.kind} slot {f.slot} "
                         f"field {f.field} ({f.mode})")
            elif f.kind == "exchange_stall":
                inj.arm_stall(idx, f)
                inj.mark_fired(idx)
                inj.note(f"step {self.steps_run}: exchange stall armed on "
                         f"rung {f.rung!r} ({f.stalls} attempts)")
            elif f.kind == "cache_evict":
                if self.cache.evict(self._step_key()):
                    inj.record("cache_evictions")
                    inj.note(f"step {self.steps_run}: evicted current "
                             f"executable (re-trace on next launch)")
                else:
                    inj.note(f"step {self.steps_run}: cache_evict found "
                             f"no entry for the current key")
                inj.mark_fired(idx)

    # -- snapshots / rollback ----------------------------------------------
    def _reachable(self, queue: List[Any]) -> Dict[int, StencilRequest]:
        out: Dict[int, StencilRequest] = {}
        for s in self.slots.live_slots():
            r = self.slots.request(s)
            out[r.uid] = r
        for item in queue:
            r = item.req if isinstance(item, _InFlight) else item
            out[r.uid] = r
        return out

    def _take_snapshot(self, queue: List[Any], done: Dict[int, Any]) -> None:
        arrays = {"u": self.u.copy(), "v": self.v.copy(),
                  "w": self.w.copy(), "xm": self.xm.copy(),
                  "ym": self.ym.copy()}
        for i, leaf in enumerate(self._p):
            arrays[f"p{i}"] = leaf.copy()
        reqs = self._reachable(queue)
        disk_step = None
        if self._snapshot_dir is not None:
            CKPT.save(self._snapshot_dir, arrays, self.steps_run)
            disk_step = self.steps_run
        self._snap = _Snapshot(
            steps_run=self.steps_run, B=self.B, arrays=arrays,
            extents=list(self._extent),
            live=[(s, self.slots.request(s).uid, self.slots.budget(s))
                  for s in self.slots.live_slots()],
            reqs=reqs,
            states_len={uid: (len(r.states) if r.states is not None else -1)
                        for uid, r in reqs.items()},
            queue=list(queue), done_uids=set(done), disk_step=disk_step)
        self._injector.record("snapshots")

    def _rollback(self, queue: List[Any], done: Dict[int, Any],
                  reason: str) -> None:
        """Restore the last snapshot and replay from it. Quarantined
        jobs stay quarantined (their slot comes back empty); everything
        else — arrays, slot assignments, budgets, streamed states, the
        queue, the step counter — returns to the boundary, so the replay
        is bitwise-indistinguishable from a run that never faulted."""
        snap = self._snap
        assert snap is not None
        arrays = snap.arrays
        if self._snapshot_dir is not None and snap.disk_step is not None:
            # restore through the checkpoint machinery: the atomic
            # on-disk copy is the recovery point, not host memory
            arrays, _ = CKPT.restore(self._snapshot_dir, snap.arrays,
                                     step=snap.disk_step)
        self._alloc(snap.B)
        self.u[:] = arrays["u"]
        self.v[:] = arrays["v"]
        self.w[:] = arrays["w"]
        self.xm[:] = arrays["xm"]
        self.ym[:] = arrays["ym"]
        for i in range(len(self._p)):
            self._p[i][:] = arrays[f"p{i}"]
        self._extent = list(snap.extents)
        for slot, uid, budget in snap.live:
            if uid in self._quarantined:
                self._clear(slot)
                for arr in (self.u, self.v, self.w):
                    arr[slot] = 0.0
                continue
            self.slots.occupy(slot, snap.reqs[uid], budget)
        for uid, req in snap.reqs.items():
            if uid in self._quarantined:
                continue
            n = snap.states_len[uid]
            if n < 0:
                req.states = None
            else:
                del req.states[n:]
            req.out = None
            req.status = "running" if any(u == uid for _, u, _ in snap.live) \
                else "pending"
        for uid in list(done):
            if uid not in snap.done_uids and uid not in self._quarantined:
                del done[uid]
        queue[:] = list(snap.queue)
        self.steps_run = snap.steps_run
        self._injector.record("rollbacks")
        self._injector.note(f"rollback to step {snap.steps_run}: {reason}")

    def _quarantine(self, slot: int, reason: str) -> StencilRequest:
        """Isolate a poisoned slot: error out its job, zero its data (so
        the frozen non-finite cells stop tripping the guard), and free
        the slot for healthy work."""
        req = self.slots.request(slot)
        req.status = "quarantined"
        req.error = reason
        req.out = None
        self._quarantined.add(req.uid)
        self.slots.release(slot)
        self._clear(slot)
        for arr in (self.u, self.v, self.w):
            arr[slot] = 0.0
        self._injector.record("quarantines")
        self._injector.note(f"quarantined uid {req.uid} (slot {slot}): "
                            f"{reason}")
        return req

    # -- fault tolerance ---------------------------------------------------
    def reshard(self, new_batch_size: int) -> List[_InFlight]:
        """Re-shard the engine onto `new_batch_size` slots (a simulated
        device loss took the rest, or devices returned — resharding UP
        works the same way): live jobs are detached with their in-flight
        state, the batch arrays are re-allocated (a NEW cache key — the
        next mega-step records a miss and re-traces), and as many jobs
        as fit are re-packed immediately. Jobs that no longer fit are
        returned for the caller (`run`) to resume — state intact, budget
        intact — when slots free up. Slot independence makes the re-pack
        bitwise-invisible to every job's output."""
        if new_batch_size < 1:
            raise ValueError(f"new_batch_size must be >= 1, got "
                             f"{new_batch_size}")
        flights = [
            _InFlight(req=self.slots.request(s), budget=self.slots.budget(s),
                      u=self.u[s].copy(), v=self.v[s].copy(),
                      w=self.w[s].copy(), xm=self.xm[s].copy(),
                      ym=self.ym[s].copy(),
                      params=tuple(leaf[s].copy() for leaf in self._p),
                      extent=self._extent[s])
            for s in self.slots.live_slots()]
        self._alloc(new_batch_size)
        for slot, flight in enumerate(flights[:new_batch_size]):
            self._resume(slot, flight)
        return flights[new_batch_size:]

    # -- driver ------------------------------------------------------------
    def run(self, requests: List[StencilRequest], *,
            lose_device_at: Optional[int] = None,
            reshard_to: Optional[int] = None,
            fault_plan: Union[FaultPlan, str, None] = None
            ) -> Dict[int, StencilRequest]:
        """Serve `requests` to completion; returns {uid: completed request}
        (each with `out` = final cropped fields and `states` = the
        streamed per-step snapshots; a quarantined request comes back
        with ``status == "quarantined"``, `error` set, and ``out=None``).

        `fault_plan` (a `FaultPlan` or spec string) replaces the
        engine's injector for this run. `lose_device_at=k` is the
        DEPRECATED one-fault alias: it builds a plan with a single
        device-loss fault after the k-th mega-step re-sharding onto
        `reshard_to` slots (default: half, at least 1)."""
        if lose_device_at is not None:
            if fault_plan is not None:
                raise ValueError("pass either fault_plan or the deprecated "
                                 "lose_device_at, not both")
            if lose_device_at < 1:
                raise ValueError(f"lose_device_at must be >= 1, got "
                                 f"{lose_device_at}")
            n = reshard_to if reshard_to is not None else max(self.B // 2, 1)
            fault_plan = FaultPlan((Fault(
                "device_loss", at_step=self.steps_run + lose_device_at,
                reshard_to=n),))
        if fault_plan is not None:
            if isinstance(fault_plan, str):
                fault_plan = FaultPlan.parse(fault_plan)
            self._injector = FaultInjector(fault_plan)
        queue: List[Any] = list(requests)
        done: Dict[int, StencilRequest] = {}
        while queue or self.slots.any_live():
            if (self._snapshot_every is not None
                    and self.steps_run % self._snapshot_every == 0):
                self._take_snapshot(queue, done)
            for s in self.slots.idle_slots():
                if not queue:
                    break
                item = queue.pop(0)
                if isinstance(item, _InFlight):
                    self._resume(s, item)
                elif self._prime(s, item):
                    done[item.uid] = item
            self._apply_faults(queue)
            if not self.slots.any_live():
                continue
            step_idx = self.steps_run
            self._guarded_mega_step(queue)
            bad = [b for b in self.slots.live_slots()
                   if not self._last_ok[b]]
            if bad:
                fresh = [b for b in bad
                         if (self.slots.request(b).uid, step_idx)
                         not in self._suspects]
                if fresh and self._snap is not None:
                    # first sighting at this (uid, step) site: assume a
                    # transient, roll back and replay. A fault that
                    # re-fires on the replay is persistent — the replay
                    # lands here again with the site already suspect and
                    # falls through to quarantine.
                    for b in bad:
                        self._suspects.add(
                            (self.slots.request(b).uid, step_idx))
                    self._rollback(queue, done,
                                   reason=f"non-finite guard at step "
                                          f"{step_idx}, slots {bad}")
                    continue
                for b in bad:
                    req = self._quarantine(
                        b, f"non-finite field detected at step {step_idx}")
                    done[req.uid] = req
            for s in self.slots.live_slots():
                req = self.slots.request(s)
                state = self._crop(s)
                req.states.append(state)
                if self.slots.tick(s):
                    req.out = state
                    req.status = "done"
                    done[req.uid] = req
                    self.slots.release(s)
                    self._clear(s)
        return done

    # -- accounting --------------------------------------------------------
    def cache_stats(self) -> Dict[str, int]:
        return self.cache.stats()

    def health(self) -> Dict[str, Any]:
        """The fault/recovery counters surface: everything the injector
        recorded (faults seen, retries, quarantines, rollbacks,
        degradations, reshards, snapshots) plus the live exchange rung,
        the quarantined uids, and the executable-cache stats. Printed by
        `launch/serve.py` and gated by `benchmarks/fault_sweep.py`."""
        h = self._injector.health()
        h["exchange"] = self._ladder.current
        h["quarantined_uids"] = sorted(self._quarantined)
        h["cache"] = self.cache_stats()
        return h

    def guard_bytes_per_step(self) -> int:
        """Extra HBM bytes the finite-guard pass adds to one mega-launch
        (`roofline.guard_bytes_model` at the current batch size)."""
        return dataclasses.replace(self.domain,
                                   batch=self.B).guard_bytes_per_step()

    def modelled_throughput(self) -> float:
        """Domains/s of this engine's mega-launch per
        `roofline.serving_throughput_model`, at the current batch size."""
        return dataclasses.replace(self.domain,
                                   batch=self.B).serving_throughput()

"""Forecast-as-a-service: batched multi-domain serving over the fused stencil.

The ROADMAP's "heavy traffic from millions of users" is not one huge
advection domain — it is MANY small ones: a `StencilRequest` is an
``(initial u/v/w fields, AdvectParams, n_steps)`` forecast job, and the
`StencilServingEngine` packs up to `batch_size` of them into ONE padded
mega-launch of the fused kernel (`kernels.advection.advect_fused_batched`
— the batch rides an outer grid dimension, slots streaming back to back
through the shared VMEM rings). This is the paper's §IV kernel-pool/DMA
overlap applied at the product layer: chunked arrival of new forecast
jobs overlaps the resident jobs' compute, with the slot lifecycle shared
with the LLM engine via `serving.slots.SlotManager`.

Contracts (gated by BENCH_serving.json and tests/test_stencil_serving.py):

  * Packing is exact, not approximate: a request SMALLER than the padded
    slot shape is embedded at the origin with per-slot interior masks
    freezing everything outside its own extent and boundary ring, so the
    mega-launch's cropped outputs are BITWISE-equal to per-domain
    sequential `advect_fused` runs on the unpadded fields.
  * Compiled executables are cached keyed on
    ``(shape, T, dtype, n_blocks, exchange, mesh)`` with hit/miss
    counters — one trace per configuration, every later mega-step a hit.
  * Intermediate states stream back per slot (`StencilRequest.states`,
    one cropped (u, v, w) snapshot per fused step).
  * A simulated device loss mid-run re-shards the engine: live slots are
    re-packed into a smaller batch (a new cache key — the recorded miss),
    overflow jobs resume from their in-flight state when slots free up,
    and the completed outputs stay bitwise-equal to an uninterrupted run
    (the `tests/test_fault_tolerance.py` resume-equals-uninterrupted
    pattern, on the stencil path).
  * Per-tenant pricing: `AdvectionDomain(batch=...)` scales the
    flops/bytes/wire accounting and `roofline.serving_throughput_model`
    turns it into domains/s.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.advection import advection as K
from repro.kernels.advection.ref import AdvectParams
from repro.serving.slots import SlotManager
from repro.stencil.advection import AdvectionDomain


@dataclasses.dataclass
class StencilRequest:
    """One forecast job: initial fields + coefficients + a step budget.

    `n_steps` counts FUSED steps (each advances `domain.fuse_T` Euler
    substeps); 0 means the job is complete at prime time and returns its
    initial fields. `params=None` uses the engine domain's coefficients;
    a per-tenant `AdvectParams` (same Z) rides the slot's batched leaves.
    """
    uid: int
    u: np.ndarray                        # (Xr, Yr, Z) initial fields
    v: np.ndarray
    w: np.ndarray
    n_steps: int = 1
    params: Optional[AdvectParams] = None
    out: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]] = None
    states: Optional[List[Tuple[np.ndarray, ...]]] = None


@dataclasses.dataclass
class _InFlight:
    """A live job's full padded slot state, detached for re-sharding."""
    req: StencilRequest
    budget: int
    u: np.ndarray
    v: np.ndarray
    w: np.ndarray
    xm: np.ndarray
    ym: np.ndarray
    params: Tuple[np.ndarray, ...]
    extent: Tuple[int, int]


class ExecutableCache:
    """Compiled-executable cache with hit/miss counters.

    Keys are the full recompilation surface of a mega-step —
    ``(shape, T, dtype, n_blocks, exchange, mesh)`` — so a re-shard (new
    batch in `shape`) or an engine/mesh change records a miss and traces
    once, while every steady-state mega-step is a hit on the same
    executable.
    """

    def __init__(self):
        self._fns: Dict[Any, Any] = {}
        self.hits = 0
        self.misses = 0

    def get(self, key, build):
        fn = self._fns.get(key)
        if fn is None:
            self.misses += 1
            fn = self._fns[key] = build()
        else:
            self.hits += 1
        return fn

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._fns)}


class StencilServingEngine:
    """Continuous-batching forecast server over the batched fused kernel.

    `domain` fixes the padded slot shape ``(X, Y, Z)``, the fusion depth
    `fuse_T`, dt, tiling, and the cache-key mesh/exchange/n_blocks
    configuration (the serving mega-step itself runs the single-shard
    batched kernel; a distributed mega-step would slot into `_build_step`
    under the same key discipline). Requests whose extent is smaller than
    the slot are padded and mask-frozen; Z must match exactly (the z axis
    has no interior mask — it is the vectorised lane dimension).
    """

    def __init__(self, domain: AdvectionDomain, *, batch_size: int = 4):
        if domain.variant != "fused":
            raise ValueError("the serving tier packs the fused (v4) kernel; "
                             f"got variant={domain.variant!r}")
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.domain = domain
        self.B = batch_size
        self.cache = ExecutableCache()
        self.steps_run = 0
        self._alloc(batch_size)

    # -- storage -----------------------------------------------------------
    def _alloc(self, batch_size: int) -> None:
        d = self.domain
        dt = np.dtype(d.dtype)
        self.B = batch_size
        self.slots = SlotManager(batch_size)
        shape = (batch_size, d.X, d.Y, d.Z)
        self.u = np.zeros(shape, dt)
        self.v = np.zeros(shape, dt)
        self.w = np.zeros(shape, dt)
        self.xm = np.zeros((batch_size, d.X), np.float32)
        self.ym = np.zeros((batch_size, d.Y), np.float32)
        base = [np.asarray(leaf) for leaf in d.params]
        self._p = [np.stack([leaf] * batch_size) for leaf in base]
        self._extent: List[Tuple[int, int]] = [(0, 0)] * batch_size

    def _step_key(self):
        d = self.domain
        return ((self.B, d.X, d.Y, d.Z), d.fuse_T, d.dtype, d.n_blocks,
                d.exchange, (d.mesh_nx, d.mesh_ny))

    def _build_step(self):
        d = self.domain

        def step(u, v, w, p, xm, ym):
            return K.advect_fused_batched(u, v, w, p, T=d.fuse_T, dt=d.dt,
                                          interpret=d.interpret,
                                          y_tile=d.y_tile, tiling=d.tiling,
                                          x_interior_mask=xm,
                                          y_interior_mask=ym)

        return jax.jit(step)

    # -- slot lifecycle ----------------------------------------------------
    def _pack(self, slot: int, u, v, w, params: Optional[AdvectParams],
              extent: Tuple[int, int]) -> None:
        d = self.domain
        Xr, Yr = extent
        for dst, src in ((self.u, u), (self.v, v), (self.w, w)):
            dst[slot] = 0.0
            dst[slot, :Xr, :Yr] = np.asarray(src, dst.dtype)
        # freeze everything outside the request's own interior: its
        # boundary ring behaves exactly like the unpadded kernel's
        # structural walls, so padding is bitwise-invisible
        self.xm[slot] = 0.0
        self.xm[slot, 1:Xr - 1] = 1.0
        self.ym[slot] = 0.0
        self.ym[slot, 1:Yr - 1] = 1.0
        leaves = (list(params) if params is not None
                  else [np.asarray(leaf) for leaf in d.params])
        for dst, leaf in zip(self._p, leaves):
            dst[slot] = np.asarray(leaf, dst.dtype)
        self._extent[slot] = extent

    def _prime(self, slot: int, req: StencilRequest) -> bool:
        """Pack `req` into `slot`; True when complete at prime time
        (``n_steps == 0`` — the job's output is its initial state and it
        never occupies the slot)."""
        d = self.domain
        if req.n_steps < 0:
            raise ValueError(f"n_steps must be >= 0, got {req.n_steps} "
                             f"(request {req.uid})")
        shp = np.asarray(req.u).shape
        if np.asarray(req.v).shape != shp or np.asarray(req.w).shape != shp:
            raise ValueError(f"request {req.uid} field shapes differ")
        if len(shp) != 3:
            raise ValueError(f"request {req.uid} fields must be (X, Y, Z), "
                             f"got shape {shp}")
        Xr, Yr, Zr = shp
        if Zr != d.Z:
            raise ValueError(
                f"request {req.uid} has Z={Zr} but the engine slot is "
                f"Z={d.Z}: z is the lane dimension and cannot be padded")
        if Xr > d.X or Yr > d.Y:
            raise ValueError(
                f"request {req.uid} extent ({Xr}, {Yr}) exceeds the padded "
                f"slot shape ({d.X}, {d.Y}); domains must fit the slot")
        if Xr < 3 or Yr < 3:
            raise ValueError(
                f"request {req.uid} extent ({Xr}, {Yr}) has no interior "
                "cell; the stencil needs >= 3 points per decomposed axis")
        if req.params is not None and np.asarray(
                req.params.tzc1).shape != (d.Z,):
            raise ValueError(f"request {req.uid} params are not for Z={d.Z}")
        req.states = []
        crop = (np.asarray(req.u, np.dtype(d.dtype)).copy(),
                np.asarray(req.v, np.dtype(d.dtype)).copy(),
                np.asarray(req.w, np.dtype(d.dtype)).copy())
        if req.n_steps == 0:
            req.out = crop
            return True
        self._pack(slot, req.u, req.v, req.w, req.params, (Xr, Yr))
        self.slots.occupy(slot, req, req.n_steps)
        return False

    def _resume(self, slot: int, flight: _InFlight) -> None:
        """Re-pack a job displaced by a re-shard, from its in-flight state."""
        self.u[slot], self.v[slot], self.w[slot] = (flight.u, flight.v,
                                                    flight.w)
        self.xm[slot], self.ym[slot] = flight.xm, flight.ym
        for dst, leaf in zip(self._p, flight.params):
            dst[slot] = leaf
        self._extent[slot] = flight.extent
        self.slots.occupy(slot, flight.req, flight.budget)

    def _clear(self, slot: int) -> None:
        # an idle slot keeps stepping in the mega-launch; all-zero masks
        # freeze it completely so it costs nothing semantically
        self.xm[slot] = 0.0
        self.ym[slot] = 0.0
        self._extent[slot] = (0, 0)

    def _crop(self, slot: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        Xr, Yr = self._extent[slot]
        return (self.u[slot, :Xr, :Yr].copy(),
                self.v[slot, :Xr, :Yr].copy(),
                self.w[slot, :Xr, :Yr].copy())

    # -- the mega-step -----------------------------------------------------
    def _mega_step(self) -> None:
        fn = self.cache.get(self._step_key(), self._build_step)
        p = AdvectParams(*[jnp.asarray(leaf) for leaf in self._p])
        ou, ov, ow = fn(jnp.asarray(self.u), jnp.asarray(self.v),
                        jnp.asarray(self.w), p,
                        jnp.asarray(self.xm), jnp.asarray(self.ym))
        # np.array, not np.asarray: the device result is a read-only view
        # and the next prime writes into these buffers in place
        self.u = np.array(ou)
        self.v = np.array(ov)
        self.w = np.array(ow)
        self.steps_run += 1

    # -- fault tolerance ---------------------------------------------------
    def reshard(self, new_batch_size: int) -> List[_InFlight]:
        """Re-shard the engine onto `new_batch_size` slots (a simulated
        device loss took the rest): live jobs are detached with their
        in-flight state, the batch arrays are re-allocated (a NEW cache
        key — the next mega-step records a miss and re-traces), and as
        many jobs as fit are re-packed immediately. Jobs that no longer
        fit are returned for the caller (`run`) to resume — state intact,
        budget intact — when slots free up. Slot independence makes the
        re-pack bitwise-invisible to every job's output."""
        if new_batch_size < 1:
            raise ValueError(f"new_batch_size must be >= 1, got "
                             f"{new_batch_size}")
        flights = [
            _InFlight(req=self.slots.request(s), budget=self.slots.budget(s),
                      u=self.u[s].copy(), v=self.v[s].copy(),
                      w=self.w[s].copy(), xm=self.xm[s].copy(),
                      ym=self.ym[s].copy(),
                      params=tuple(leaf[s].copy() for leaf in self._p),
                      extent=self._extent[s])
            for s in self.slots.live_slots()]
        self._alloc(new_batch_size)
        for slot, flight in enumerate(flights[:new_batch_size]):
            self._resume(slot, flight)
        return flights[new_batch_size:]

    # -- driver ------------------------------------------------------------
    def run(self, requests: List[StencilRequest], *,
            lose_device_at: Optional[int] = None,
            reshard_to: Optional[int] = None
            ) -> Dict[int, StencilRequest]:
        """Serve `requests` to completion; returns {uid: completed request}
        (each with `out` = final cropped fields and `states` = the
        streamed per-step snapshots).

        `lose_device_at=k` simulates a device loss after the k-th
        mega-step: the engine re-shards onto `reshard_to` slots (default:
        half, at least 1) and keeps serving — the fault-injection hook,
        mirroring `train_loop(inject_nan_at=...)`."""
        if lose_device_at is not None:
            if reshard_to is None:
                reshard_to = max(self.B // 2, 1)
            if lose_device_at < 1:
                raise ValueError(f"lose_device_at must be >= 1, got "
                                 f"{lose_device_at}")
        queue: List[Any] = list(requests)
        done: Dict[int, StencilRequest] = {}
        steps = 0
        while queue or self.slots.any_live():
            for s in self.slots.idle_slots():
                if not queue:
                    break
                item = queue.pop(0)
                if isinstance(item, _InFlight):
                    self._resume(s, item)
                elif self._prime(s, item):
                    done[item.uid] = item
            if not self.slots.any_live():
                continue
            self._mega_step()
            steps += 1
            for s in self.slots.live_slots():
                req = self.slots.request(s)
                state = self._crop(s)
                req.states.append(state)
                if self.slots.tick(s):
                    req.out = state
                    done[req.uid] = req
                    self.slots.release(s)
                    self._clear(s)
            if lose_device_at is not None and steps == lose_device_at:
                # displaced jobs resume ahead of queued fresh work
                queue[:0] = self.reshard(reshard_to)
                lose_device_at = None
        return done

    # -- accounting --------------------------------------------------------
    def cache_stats(self) -> Dict[str, int]:
        return self.cache.stats()

    def modelled_throughput(self) -> float:
        """Domains/s of this engine's mega-launch per
        `roofline.serving_throughput_model`, at the current batch size."""
        return dataclasses.replace(self.domain,
                                   batch=self.B).serving_throughput()

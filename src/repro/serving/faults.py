"""Deterministic fault injection + layered recovery for the serving stack.

The paper's data-movement discipline only pays off in a serving tier that
survives the messy parts of real traffic: a poisoned request, a lost
device, a stalled interconnect. This module is the single place faults
are DESCRIBED and recovery is ORCHESTRATED; detection and repair live in
the layers that own the data:

  * detection  — the fused kernel's in-graph finite guard
                 (`advect_fused(..., guard=True)`): one f32 flag word per
                 (y-tile, x-slice) grid step, priced EXACTLY by
                 `roofline.guard_bytes_model` and counted by
                 `stencil.distributed.count_guard_bytes`.
  * rollback   — `StencilServingEngine` snapshots its `_InFlight` state
                 through `training/checkpoint`'s atomic-write machinery
                 and replays from the last snapshot on any fault; resume
                 is bitwise-equal to an uninterrupted run.
  * isolation  — a slot whose guard flag trips twice at the same step is
                 quarantined with an error status; healthy slots' outputs
                 stay bitwise-equal to an unpoisoned run.
  * degradation— `retry_with_backoff` wraps the exchange engines and a
                 `DegradationLadder` walks `remote_dma` -> `collective`
                 -> reshard-down, each transition recorded.

Everything here is deterministic and seedable: a `FaultPlan` is a frozen
tuple of `Fault`s pinned to mega-step / exchange-block indices, built by
hand, parsed from a `kind@step:key=val,...` spec string, or drawn from
`numpy.random.default_rng(seed)` — the same seed always yields the same
plan (`FaultPlan.random(seed, ...)`), and `describe()` round-trips
through `parse()` so BENCH_faults.json can record exactly what was
injected. `FaultInjector` owns the mutable side (which faults have
fired, how many stall attempts remain) plus the `health()` counters the
launch CLI and the benchmark gates read.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS", "DEFAULT_LADDER", "ELASTIC_LADDER", "MESH_SHRINK",
    "ExchangeStalled", "RecoveryExhausted",
    "Fault", "FaultPlan", "FaultInjector", "DegradationLadder",
    "retry_with_backoff", "resilient_distributed_run",
]

#: every fault kind the plan grammar accepts; each has a tier-1 test
#: exercising injection -> detection -> recovery.
FAULT_KINDS = ("device_loss", "nan_poison", "halo_corruption",
               "exchange_stall", "cache_evict")

#: the graceful-degradation ladder for the exchange engines, fastest
#: first. The serving engine appends an implicit final rung — reshard
#: down to fewer slots — once both transports are exhausted.
DEFAULT_LADDER = ("remote_dma", "collective")

#: the mesh-shrink rung: not an exchange transport but the elastic last
#: resort — gather to host, rebuild a smaller stencil mesh, re-shard,
#: continue. `resilient_distributed_run` takes it when the ladder
#: degrades onto it; a ladder without it (DEFAULT_LADDER) exhausts
#: instead.
MESH_SHRINK = "mesh_shrink"

#: the distributed run's full ladder: both transports, then shrink.
ELASTIC_LADDER = DEFAULT_LADDER + (MESH_SHRINK,)

_FIELDS = ("u", "v", "w")
_MODES = ("nan", "inf")

_COUNTERS = ("faults_injected", "faults_skipped", "device_losses",
             "quarantines", "rollbacks", "retries", "degradations",
             "reshards", "cache_evictions", "snapshots",
             "replayed_blocks")


class ExchangeStalled(RuntimeError):
    """An exchange attempt hung (injected or real); retryable."""


class RecoveryExhausted(RuntimeError):
    """Every rung of the degradation ladder failed."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault. `at_step` is the mega-step (serving engine)
    or exchange-block (distributed run) boundary the fault fires at.

    Kind-specific knobs:
      nan_poison      — `slot`, `field`, `mode` ("nan"/"inf");
                        `persistent` defaults True: the poison source
                        re-fires on replay, so rollback alone cannot
                        clear it and the engine must quarantine.
      halo_corruption — `slot`, `field`, `depth` (band rows poisoned);
                        one-shot by default: rollback + replay is clean.
      device_loss     — `reshard_to` (None -> half the batch).
      exchange_stall  — `stalls` consecutive attempts hang, but only
                        while the engine's CURRENT rung == `rung`;
                        degrading past the faulted transport clears it.
      cache_evict     — evicts the current step's compiled executable
                        (one recorded re-trace miss on the next launch).
    """
    kind: str
    at_step: int
    slot: int = 0
    field: str = "u"
    mode: str = "nan"
    reshard_to: Optional[int] = None
    stalls: int = 1
    rung: str = "remote_dma"
    depth: int = 1
    persistent: Optional[bool] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0, got {self.at_step}")
        if self.field not in _FIELDS:
            raise ValueError(f"field must be one of {_FIELDS}, "
                             f"got {self.field!r}")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, "
                             f"got {self.mode!r}")
        if self.stalls < 1:
            raise ValueError(f"stalls must be >= 1, got {self.stalls}")
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.reshard_to is not None and self.reshard_to < 1:
            raise ValueError(f"reshard_to must be >= 1, "
                             f"got {self.reshard_to}")

    @property
    def is_persistent(self) -> bool:
        """Persistent faults re-fire every time execution re-crosses
        `at_step` (a poisoned SOURCE survives rollback); one-shot faults
        are consumed on first firing (a transient glitch replays clean).
        """
        if self.persistent is not None:
            return self.persistent
        return self.kind == "nan_poison"

    def value(self) -> float:
        """The poison value for nan_poison / halo_corruption."""
        return float("nan") if self.mode == "nan" else float("inf")

    def describe(self) -> str:
        parts = []
        defaults = {f.name: f.default for f in dataclasses.fields(Fault)}
        for name in ("slot", "field", "mode", "reshard_to", "stalls",
                     "rung", "depth", "persistent"):
            val = getattr(self, name)
            if val != defaults[name]:
                parts.append(f"{name}={val}")
        spec = f"{self.kind}@{self.at_step}"
        return spec + (":" + ",".join(parts) if parts else "")


def _parse_value(key: str, raw: str):
    if key in ("field", "mode", "rung"):
        return raw
    if key == "persistent":
        return raw.lower() in ("1", "true", "yes")
    if key == "reshard_to" and raw.lower() == "none":
        return None
    return int(raw)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, seed-reproducible schedule of faults.

    Build directly, `parse()` a spec string
    (``"nan_poison@1:slot=1,mode=inf;device_loss@2:reshard_to=1"``), or
    draw a `random(seed, ...)` plan. `describe()` round-trips through
    `parse()` so artifacts record exactly what ran.
    """
    faults: Tuple[Fault, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse ``kind@step[:key=val,...]`` clauses joined by ";".
        Malformed specs raise ValueError NAMING the offending token —
        the clause, the step, the option item, the key, or the value —
        so a typo'd plan string is diagnosable from the message alone."""
        option_keys = tuple(f.name for f in dataclasses.fields(Fault)
                            if f.name not in ("kind", "at_step"))
        faults = []
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            head, _, tail = clause.partition(":")
            kind, sep, step = head.partition("@")
            if not sep:
                raise ValueError(
                    f"bad fault clause {clause!r}: expected kind@step"
                    f"[:key=val,...]")
            try:
                at_step = int(step)
            except ValueError:
                raise ValueError(f"bad fault step {step!r} in {clause!r}: "
                                 f"expected an integer") from None
            kw = {}
            if tail:
                for item in tail.split(","):
                    key, sep, raw = item.partition("=")
                    if not sep:
                        raise ValueError(f"bad fault option {item!r} in "
                                         f"{clause!r}: expected key=val")
                    key = key.strip()
                    if key not in option_keys:
                        raise ValueError(
                            f"unknown fault option key {key!r} in "
                            f"{clause!r}; expected one of {option_keys}")
                    try:
                        kw[key] = _parse_value(key, raw.strip())
                    except ValueError:
                        raise ValueError(
                            f"bad fault option value {raw.strip()!r} for "
                            f"{key!r} in {clause!r}") from None
            faults.append(Fault(kind=kind.strip(), at_step=at_step, **kw))
        return cls(faults=tuple(faults))

    @classmethod
    def random(cls, seed: int, *, n_steps: int, batch: int,
               n_faults: int = 3,
               kinds: Sequence[str] = FAULT_KINDS) -> "FaultPlan":
        """A reproducible plan: same seed, same faults, always."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            kw = dict(kind=kind,
                      at_step=int(rng.integers(max(1, n_steps))))
            if kind in ("nan_poison", "halo_corruption"):
                kw["slot"] = int(rng.integers(max(1, batch)))
                kw["field"] = _FIELDS[int(rng.integers(3))]
                kw["mode"] = _MODES[int(rng.integers(2))]
            elif kind == "device_loss":
                kw["reshard_to"] = max(1, batch // 2)
            elif kind == "exchange_stall":
                kw["stalls"] = int(rng.integers(1, 3))
            faults.append(Fault(**kw))
        faults.sort(key=lambda f: (f.at_step, f.kind))
        return cls(faults=tuple(faults), seed=seed)

    def at(self, step: int) -> List[Fault]:
        return [f for f in self.faults if f.at_step == step]

    def describe(self) -> str:
        return ";".join(f.describe() for f in self.faults)

    def max_step(self) -> int:
        return max((f.at_step for f in self.faults), default=-1)


class FaultInjector:
    """The mutable runtime side of a `FaultPlan`: which faults have
    fired, how many stall attempts remain, and the `health()` counters
    every recovery action reports into.

    The injection protocol (shared by `StencilServingEngine` and
    `resilient_distributed_run`): at each boundary the driver calls
    `due(step)` and applies the returned faults itself — the injector
    never touches engine state; it only schedules, arms stalls, and
    counts. One-shot faults are consumed by `mark_fired`; persistent
    faults re-fire every time execution re-crosses their step (that is
    what forces the quarantine path — rollback alone cannot out-run a
    poisoned source).
    """

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self.counters: Dict[str, int] = {k: 0 for k in _COUNTERS}
        self.transitions: List[str] = []
        self._consumed: set = set()
        self._stalls: Dict[int, List] = {}   # fault idx -> [rung, left]

    # -- scheduling --------------------------------------------------------
    def due(self, step: int) -> List[Tuple[int, Fault]]:
        """Faults firing at this boundary (one-shot faults already
        consumed are skipped). The caller applies them, then
        `mark_fired(idx)`s each."""
        out = []
        for idx, f in enumerate(self.plan.faults):
            if f.at_step == step and idx not in self._consumed:
                out.append((idx, f))
        return out

    def mark_fired(self, idx: int) -> None:
        f = self.plan.faults[idx]
        self.counters["faults_injected"] += 1
        if not f.is_persistent:
            self._consumed.add(idx)

    def skip(self, idx: int, reason: str) -> None:
        """A due fault the driver cannot apply (e.g. a poison aimed at
        an empty slot) — consumed and counted, never silently dropped."""
        self._consumed.add(idx)
        self.counters["faults_skipped"] += 1
        self.transitions.append(f"skipped[{idx}]: {reason}")

    # -- stalls ------------------------------------------------------------
    def arm_stall(self, idx: int, fault: Fault) -> None:
        """Register an exchange_stall: the next `fault.stalls` attempts
        on rung `fault.rung` raise `ExchangeStalled`."""
        self._stalls[idx] = [fault.rung, fault.stalls]

    def poll_stall(self, rung: str) -> None:
        """Called immediately before each exchange attempt. Raises
        `ExchangeStalled` while an armed stall matches the CURRENT rung;
        an armed stall whose rung was degraded past is cleared — the
        whole point of the ladder is that the fallback transport does
        not share the faulted engine's failure."""
        for idx in list(self._stalls):
            srung, left = self._stalls[idx]
            if left <= 0:
                del self._stalls[idx]
                continue
            if srung == rung:
                self._stalls[idx][1] -= 1
                raise ExchangeStalled(
                    f"injected stall on rung {rung!r} "
                    f"({self._stalls[idx][1]} more)")
            del self._stalls[idx]

    def clear_stalls(self) -> None:
        """Drop every armed stall — the reshard path's reset (the lost
        devices took the stalled transport with them)."""
        self._stalls.clear()

    # -- counters ----------------------------------------------------------
    def record(self, counter: str, n: int = 1) -> None:
        if counter not in self.counters:
            raise KeyError(f"unknown health counter {counter!r}; "
                           f"expected one of {_COUNTERS}")
        self.counters[counter] += n

    def note(self, event: str) -> None:
        self.transitions.append(event)

    def health(self) -> Dict[str, object]:
        """The counters surface the launch CLI prints and the
        BENCH_faults gates assert on."""
        out: Dict[str, object] = dict(self.counters)
        out["transitions"] = list(self.transitions)
        out["plan"] = self.plan.describe()
        return out


class DegradationLadder:
    """Walks the exchange transports fastest-first, recording every
    transition. `degrade()` past the last rung raises
    `RecoveryExhausted` — the serving engine catches that and takes the
    implicit final rung (reshard down); the raw distributed run
    propagates it."""

    def __init__(self, rungs: Sequence[str] = DEFAULT_LADDER,
                 start: Optional[str] = None):
        self.rungs = tuple(rungs)
        if not self.rungs:
            raise ValueError("ladder needs at least one rung")
        if start is None:
            self._i = 0
        else:
            if start not in self.rungs:
                raise ValueError(f"start rung {start!r} not in "
                                 f"{self.rungs}")
            self._i = self.rungs.index(start)
        self.transitions: List[str] = []

    @property
    def current(self) -> str:
        return self.rungs[self._i]

    def degrade(self, reason: str = "") -> str:
        was = self.current
        if self._i + 1 >= len(self.rungs):
            self.transitions.append(f"{was} -> EXHAUSTED ({reason})")
            raise RecoveryExhausted(
                f"degradation ladder exhausted at {was!r}: {reason}")
        self._i += 1
        self.transitions.append(f"{was} -> {self.current} ({reason})")
        return self.current


def retry_with_backoff(attempt: Callable[[], object], *,
                       max_retries: int = 3, backoff_s: float = 0.0,
                       max_backoff_s: Optional[float] = None,
                       jitter_seed: Optional[int] = None,
                       sleeper: Callable[[float], None] = time.sleep,
                       on_retry: Optional[Callable[[int, Exception],
                                                   None]] = None):
    """One initial try plus up to `max_retries` retries of `attempt`,
    sleeping `min(backoff_s * 2**k, max_backoff_s)` before retry k —
    the ceiling keeps a deep retry budget from sleeping for `2**k`-ever
    (`max_backoff_s=None` preserves the uncapped legacy behaviour).
    `jitter_seed` draws a DETERMINISTIC jitter factor in [0.5, 1.0) per
    retry from `numpy.random.default_rng(jitter_seed)` — seeded, so the
    de-synchronised sleep schedule is still reproducible (same seed,
    same sleeps; the tests pin the sequence through the injected
    `sleeper`). Only `ExchangeStalled` is retryable — anything else
    propagates immediately. Re-raises the last stall when the budget is
    spent (the caller degrades the ladder)."""
    if max_retries < 0:
        raise ValueError(f"max_retries must be >= 0, got {max_retries}")
    if max_backoff_s is not None and max_backoff_s < 0:
        raise ValueError(f"max_backoff_s must be >= 0, got {max_backoff_s}")
    rng = (None if jitter_seed is None
           else np.random.default_rng(jitter_seed))
    err: Optional[ExchangeStalled] = None
    for k in range(max_retries + 1):
        try:
            return attempt()
        except ExchangeStalled as e:
            err = e
            if k == max_retries:
                break
            if on_retry is not None:
                on_retry(k, e)
            if backoff_s > 0:
                delay = backoff_s * (2 ** k)
                if max_backoff_s is not None:
                    delay = min(delay, max_backoff_s)
                if rng is not None:
                    delay *= 0.5 + 0.5 * float(rng.random())
                sleeper(delay)
    assert err is not None
    raise err


def resilient_distributed_run(mesh, params, u, v, w, *, n_blocks: int,
                              T: int = 1, dt: float = 1.0,
                              axis: str = "data",
                              x_axis: Optional[str] = None,
                              local_kernel: str = "reference",
                              y_tile: Optional[int] = None,
                              interpret: bool = True,
                              injector: Optional[FaultInjector] = None,
                              ladder: Optional[DegradationLadder] = None,
                              max_retries: int = 3,
                              backoff_s: float = 0.0,
                              max_backoff_s: Optional[float] = None,
                              jitter_seed: Optional[int] = None,
                              sleeper: Callable[[float], None] = time.sleep,
                              checkpoint_every: int = 1,
                              checkpoint_dir=None,
                              keep_last: int = 3,
                              max_replays: int = 2,
                              verify_integrity: Optional[bool] = None,
                              guard: bool = True):
    """`make_distributed_step` driven block-by-block with EVERY
    `FaultPlan` kind injectable at the exchange layer, recovering
    through the full resilience stack:

      * exchange_stall   — armed stalls hang the attempt; the bounded
        retry loop (capped/jittered backoff) absorbs transients; a
        persistent stall degrades the ladder, rebuilding the step on the
        fallback transport and replaying the block — sound because both
        engines assemble bitwise-identical extended slabs (the
        BENCH_overlap gate). The ELASTIC_LADDER's final `mesh_shrink`
        rung halves the y-shard count instead of exhausting.
      * halo_corruption  — a band of the faulted field is damaged ON THE
        WIRE for that block (`corrupt_halo` in the emulated engines);
        the checksummed exchange (`verify_integrity`, default on in
        interpret mode) flags it and the driver rolls back to the last
        checkpoint and replays — bounded: `replayed_blocks` <=
        `rollbacks * checkpoint_every`. On a 1-shard mesh there is no
        wire, so the damage lands on the slab edge rows the band would
        have been (still injected, never skipped).
      * nan_poison       — a shard's owned rows of the faulted field are
        poisoned before the block; the finite guard (`guard=True`,
        host-side `isfinite` over the advanced fields — the priced
        in-graph guard kernel belongs to the serving engine) detects it
        after the block, and rollback + replay recovers. A PERSISTENT
        poison re-fires on every replay; after `max_replays` replays of
        the same block the driver raises `RecoveryExhausted` (rollback
        cannot out-run a poisoned source — quarantining is the serving
        tier's job).
      * device_loss      — gather to host, rebuild a smaller mesh via
        `launch.mesh.resize_stencil_mesh` (ny -> `reshard_to`, default
        half), re-shard, continue; a later device_loss with a LARGER
        `reshard_to` models device return and re-shards up. Sound
        because the fused tiled kernel's per-tile arithmetic is
        shard-shape independent (BENCH_recovery.json gates the
        shrink/regrow run BITWISE against the uninterrupted one on the
        original mesh; the jnp reference kernel re-fuses per shape and
        only tracks to ~1 ulp).
      * cache_evict      — drops the compiled step cache; the next block
        re-traces (counted, bitwise-invisible).

    Snapshots are taken every `checkpoint_every` blocks — in host memory
    by default, through `training.checkpoint`'s atomic on-disk writes
    when `checkpoint_dir` is given. Ladder exhaustion and unclearable
    faults raise `RecoveryExhausted`. On a clean plan the result is
    BITWISE what `make_distributed_run` produces (the regression gate:
    the step parity alternates with the block index, it is never pinned
    to slot 0). Returns ``(u, v, w), injector`` so callers can assert on
    `health()`.
    """
    import jax.numpy as jnp

    from repro.launch import mesh as LM
    from repro.stencil import distributed as D
    from repro.training import checkpoint as CKPT

    injector = injector or FaultInjector()
    ladder = ladder or DegradationLadder(ELASTIC_LADDER)
    if ladder.current not in D.EXCHANGES:
        raise ValueError(f"ladder must start on an exchange rung "
                         f"{D.EXCHANGES}, got {ladder.current!r}")
    if checkpoint_every < 1:
        raise ValueError(f"checkpoint_every must be >= 1, "
                         f"got {checkpoint_every}")
    if max_replays < 0:
        raise ValueError(f"max_replays must be >= 0, got {max_replays}")
    verify = interpret if verify_integrity is None else verify_integrity

    X, Y, _ = np.shape(u)
    n_y = mesh.shape[axis]
    n_x = mesh.shape[x_axis] if x_axis is not None else 1
    cur_mesh = mesh
    fields = tuple(jnp.asarray(np.asarray(f)) for f in (u, v, w))
    rung = ladder.current
    steps: Dict[Tuple[str, int], Callable] = {}

    def build_step(rng_, parity, corrupt):
        return D.make_distributed_step(
            cur_mesh, params, axis=axis, x_axis=x_axis, T=T, dt=dt,
            local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
            exchange=rng_, dma_block_index=parity,
            verify_integrity=verify, corrupt_halo=corrupt)

    def get_step(parity, corrupt):
        if corrupt is not None:           # one-off, never cached
            return build_step(rung, parity, corrupt)
        key = (rung, parity)
        if key not in steps:
            steps[key] = build_step(rung, parity, None)
        return steps[key]

    # -- snapshot / rollback (in-memory, optionally disk-backed) ----------
    snap: Dict[str, np.ndarray] = {}
    snap_block = 0

    def take_snapshot(b):
        nonlocal snap, snap_block
        snap = {"u": np.asarray(fields[0]), "v": np.asarray(fields[1]),
                "w": np.asarray(fields[2]),
                "block": np.int64(b), "parity": np.int64(b % 2)}
        snap_block = b
        if checkpoint_dir is not None:
            CKPT.save(checkpoint_dir, snap, b, keep_last=keep_last)
        injector.record("snapshots")

    def rollback(b, reason):
        nonlocal fields
        arrays = snap
        if checkpoint_dir is not None:
            arrays, _ = CKPT.restore(checkpoint_dir, snap, step=snap_block)
        fields = tuple(jnp.asarray(arrays[k]) for k in ("u", "v", "w"))
        injector.record("rollbacks")
        if b > snap_block:
            injector.record("replayed_blocks", b - snap_block)
        injector.note(f"block {b}: rollback to block {snap_block} "
                      f"({reason})")
        return snap_block

    # -- fault applicators -------------------------------------------------
    def poison_rows(flds, fi, row_lo, rows, value):
        arr = np.array(np.asarray(flds[fi]))
        arr[:, row_lo:row_lo + rows, :] = value
        return tuple(jnp.asarray(arr) if j == fi else flds[j]
                     for j in range(3))

    def do_reshard(target, b, why):
        nonlocal cur_mesh, n_y, fields
        if Y % target:
            raise ValueError(f"cannot re-shard to ny={target}: global "
                             f"Y={Y} is not divisible")
        host = tuple(np.asarray(f) for f in fields)   # gather off the mesh
        dummy_x = x_axis if x_axis is not None else (
            "x" if axis != "x" else "x_")
        cur_mesh = LM.resize_stencil_mesh(n_x, target, x_axis=dummy_x,
                                          y_axis=axis)
        old, n_y = n_y, target
        fields = tuple(jnp.asarray(h) for h in host)
        steps.clear()
        injector.clear_stalls()   # the lost transport died with the mesh
        injector.record("reshards")
        injector.note(f"block {b}: {why}: re-shard ny {old} -> {target}")

    take_snapshot(0)
    replays: Dict[int, int] = {}
    block = 0
    while block < n_blocks:
        corrupt = None
        for idx, f in injector.due(block):
            if f.kind == "exchange_stall":
                injector.arm_stall(idx, f)
                injector.note(f"block {block}: armed stall on "
                              f"{f.rung} x{f.stalls}")
            elif f.kind == "cache_evict":
                steps.clear()
                injector.record("cache_evictions")
                injector.note(f"block {block}: evicted the compiled "
                              f"step cache")
            elif f.kind == "nan_poison":
                fi = _FIELDS.index(f.field)
                Yl = Y // n_y
                fields = poison_rows(fields, fi, (f.slot % n_y) * Yl, 1,
                                     f.value())
                injector.note(f"block {block}: poisoned {f.field} on "
                              f"shard {f.slot % n_y} ({f.mode})")
            elif f.kind == "halo_corruption":
                if n_y > 1 or n_x > 1:
                    corrupt = (_FIELDS.index(f.field), f.depth, f.value())
                    injector.note(f"block {block}: corrupting {f.field} "
                                  f"halo band on the wire (depth "
                                  f"{f.depth}, {f.mode})")
                else:
                    # 1-shard mesh: no wire — the band IS the slab edge
                    fields = poison_rows(fields, _FIELDS.index(f.field),
                                         0, f.depth, f.value())
                    injector.note(f"block {block}: 1-shard mesh, "
                                  f"corrupted the {f.field} edge rows "
                                  f"the band would have carried")
            elif f.kind == "device_loss":
                injector.record("device_losses")
                do_reshard(f.reshard_to or max(1, n_y // 2), block,
                           "device loss" if (f.reshard_to or 0) <= n_y
                           else "device return")
            injector.mark_fired(idx)

        while True:                       # stall/degrade loop
            step = get_step(block % 2, corrupt)

            def attempt():
                injector.poll_stall(rung)
                return step(*fields)

            try:
                out = retry_with_backoff(
                    attempt, max_retries=max_retries, backoff_s=backoff_s,
                    max_backoff_s=max_backoff_s, jitter_seed=jitter_seed,
                    sleeper=sleeper,
                    on_retry=lambda k, e: injector.record("retries"))
                break
            except ExchangeStalled as e:
                nxt = ladder.degrade(str(e))    # RecoveryExhausted up
                injector.record("degradations")
                injector.note(f"block {block}: {ladder.transitions[-1]}")
                if nxt == MESH_SHRINK:
                    if n_y <= 1:
                        raise RecoveryExhausted(
                            f"mesh-shrink rung reached with ny={n_y}: "
                            f"nothing left to shrink") from e
                    do_reshard(max(1, n_y // 2), block, "mesh shrink")
                    exch = [r for r in ladder.rungs if r in D.EXCHANGES]
                    rung = exch[-1] if exch else "collective"
                else:
                    rung = nxt

        if verify:
            cand, flags = out[:3], out[3]
        else:
            cand, flags = out, None

        bad = None
        if flags is not None and int(np.sum(np.asarray(flags))) > 0:
            bad = "halo corruption detected by band checksums"
        elif guard and not all(bool(np.all(np.isfinite(np.asarray(f))))
                               for f in cand):
            bad = "non-finite field values detected"
        if bad is not None:
            n_rep = replays.get(block, 0) + 1
            replays[block] = n_rep
            if n_rep > max_replays:
                raise RecoveryExhausted(
                    f"block {block}: {bad} persists after {max_replays} "
                    f"replay(s) — a persistent fault source rollback "
                    f"cannot clear")
            block = rollback(block, bad)
            continue

        fields = cand
        block += 1
        if block % checkpoint_every == 0 or block == n_blocks:
            take_snapshot(block)
    return tuple(fields), injector

"""Deterministic fault injection + layered recovery for the serving stack.

The paper's data-movement discipline only pays off in a serving tier that
survives the messy parts of real traffic: a poisoned request, a lost
device, a stalled interconnect. This module is the single place faults
are DESCRIBED and recovery is ORCHESTRATED; detection and repair live in
the layers that own the data:

  * detection  — the fused kernel's in-graph finite guard
                 (`advect_fused(..., guard=True)`): one f32 flag word per
                 (y-tile, x-slice) grid step, priced EXACTLY by
                 `roofline.guard_bytes_model` and counted by
                 `stencil.distributed.count_guard_bytes`.
  * rollback   — `StencilServingEngine` snapshots its `_InFlight` state
                 through `training/checkpoint`'s atomic-write machinery
                 and replays from the last snapshot on any fault; resume
                 is bitwise-equal to an uninterrupted run.
  * isolation  — a slot whose guard flag trips twice at the same step is
                 quarantined with an error status; healthy slots' outputs
                 stay bitwise-equal to an unpoisoned run.
  * degradation— `retry_with_backoff` wraps the exchange engines and a
                 `DegradationLadder` walks `remote_dma` -> `collective`
                 -> reshard-down, each transition recorded.

Everything here is deterministic and seedable: a `FaultPlan` is a frozen
tuple of `Fault`s pinned to mega-step / exchange-block indices, built by
hand, parsed from a `kind@step:key=val,...` spec string, or drawn from
`numpy.random.default_rng(seed)` — the same seed always yields the same
plan (`FaultPlan.random(seed, ...)`), and `describe()` round-trips
through `parse()` so BENCH_faults.json can record exactly what was
injected. `FaultInjector` owns the mutable side (which faults have
fired, how many stall attempts remain) plus the `health()` counters the
launch CLI and the benchmark gates read.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS", "DEFAULT_LADDER", "ExchangeStalled", "RecoveryExhausted",
    "Fault", "FaultPlan", "FaultInjector", "DegradationLadder",
    "retry_with_backoff", "resilient_distributed_run",
]

#: every fault kind the plan grammar accepts; each has a tier-1 test
#: exercising injection -> detection -> recovery.
FAULT_KINDS = ("device_loss", "nan_poison", "halo_corruption",
               "exchange_stall", "cache_evict")

#: the graceful-degradation ladder for the exchange engines, fastest
#: first. The serving engine appends an implicit final rung — reshard
#: down to fewer slots — once both transports are exhausted.
DEFAULT_LADDER = ("remote_dma", "collective")

_FIELDS = ("u", "v", "w")
_MODES = ("nan", "inf")

_COUNTERS = ("faults_injected", "faults_skipped", "device_losses",
             "quarantines", "rollbacks", "retries", "degradations",
             "reshards", "cache_evictions", "snapshots")


class ExchangeStalled(RuntimeError):
    """An exchange attempt hung (injected or real); retryable."""


class RecoveryExhausted(RuntimeError):
    """Every rung of the degradation ladder failed."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault. `at_step` is the mega-step (serving engine)
    or exchange-block (distributed run) boundary the fault fires at.

    Kind-specific knobs:
      nan_poison      — `slot`, `field`, `mode` ("nan"/"inf");
                        `persistent` defaults True: the poison source
                        re-fires on replay, so rollback alone cannot
                        clear it and the engine must quarantine.
      halo_corruption — `slot`, `field`, `depth` (band rows poisoned);
                        one-shot by default: rollback + replay is clean.
      device_loss     — `reshard_to` (None -> half the batch).
      exchange_stall  — `stalls` consecutive attempts hang, but only
                        while the engine's CURRENT rung == `rung`;
                        degrading past the faulted transport clears it.
      cache_evict     — evicts the current step's compiled executable
                        (one recorded re-trace miss on the next launch).
    """
    kind: str
    at_step: int
    slot: int = 0
    field: str = "u"
    mode: str = "nan"
    reshard_to: Optional[int] = None
    stalls: int = 1
    rung: str = "remote_dma"
    depth: int = 1
    persistent: Optional[bool] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.at_step < 0:
            raise ValueError(f"at_step must be >= 0, got {self.at_step}")
        if self.field not in _FIELDS:
            raise ValueError(f"field must be one of {_FIELDS}, "
                             f"got {self.field!r}")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, "
                             f"got {self.mode!r}")
        if self.stalls < 1:
            raise ValueError(f"stalls must be >= 1, got {self.stalls}")
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.reshard_to is not None and self.reshard_to < 1:
            raise ValueError(f"reshard_to must be >= 1, "
                             f"got {self.reshard_to}")

    @property
    def is_persistent(self) -> bool:
        """Persistent faults re-fire every time execution re-crosses
        `at_step` (a poisoned SOURCE survives rollback); one-shot faults
        are consumed on first firing (a transient glitch replays clean).
        """
        if self.persistent is not None:
            return self.persistent
        return self.kind == "nan_poison"

    def value(self) -> float:
        """The poison value for nan_poison / halo_corruption."""
        return float("nan") if self.mode == "nan" else float("inf")

    def describe(self) -> str:
        parts = []
        defaults = {f.name: f.default for f in dataclasses.fields(Fault)}
        for name in ("slot", "field", "mode", "reshard_to", "stalls",
                     "rung", "depth", "persistent"):
            val = getattr(self, name)
            if val != defaults[name]:
                parts.append(f"{name}={val}")
        spec = f"{self.kind}@{self.at_step}"
        return spec + (":" + ",".join(parts) if parts else "")


def _parse_value(key: str, raw: str):
    if key in ("field", "mode", "rung"):
        return raw
    if key == "persistent":
        return raw.lower() in ("1", "true", "yes")
    if key == "reshard_to" and raw.lower() == "none":
        return None
    return int(raw)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, seed-reproducible schedule of faults.

    Build directly, `parse()` a spec string
    (``"nan_poison@1:slot=1,mode=inf;device_loss@2:reshard_to=1"``), or
    draw a `random(seed, ...)` plan. `describe()` round-trips through
    `parse()` so artifacts record exactly what ran.
    """
    faults: Tuple[Fault, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        faults = []
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            head, _, tail = clause.partition(":")
            kind, sep, step = head.partition("@")
            if not sep:
                raise ValueError(
                    f"bad fault clause {clause!r}: expected kind@step"
                    f"[:key=val,...]")
            kw = {}
            if tail:
                for item in tail.split(","):
                    key, sep, raw = item.partition("=")
                    if not sep:
                        raise ValueError(f"bad fault option {item!r} in "
                                         f"{clause!r}: expected key=val")
                    kw[key.strip()] = _parse_value(key.strip(), raw.strip())
            faults.append(Fault(kind=kind.strip(), at_step=int(step), **kw))
        return cls(faults=tuple(faults))

    @classmethod
    def random(cls, seed: int, *, n_steps: int, batch: int,
               n_faults: int = 3,
               kinds: Sequence[str] = FAULT_KINDS) -> "FaultPlan":
        """A reproducible plan: same seed, same faults, always."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            kw = dict(kind=kind,
                      at_step=int(rng.integers(max(1, n_steps))))
            if kind in ("nan_poison", "halo_corruption"):
                kw["slot"] = int(rng.integers(max(1, batch)))
                kw["field"] = _FIELDS[int(rng.integers(3))]
                kw["mode"] = _MODES[int(rng.integers(2))]
            elif kind == "device_loss":
                kw["reshard_to"] = max(1, batch // 2)
            elif kind == "exchange_stall":
                kw["stalls"] = int(rng.integers(1, 3))
            faults.append(Fault(**kw))
        faults.sort(key=lambda f: (f.at_step, f.kind))
        return cls(faults=tuple(faults), seed=seed)

    def at(self, step: int) -> List[Fault]:
        return [f for f in self.faults if f.at_step == step]

    def describe(self) -> str:
        return ";".join(f.describe() for f in self.faults)

    def max_step(self) -> int:
        return max((f.at_step for f in self.faults), default=-1)


class FaultInjector:
    """The mutable runtime side of a `FaultPlan`: which faults have
    fired, how many stall attempts remain, and the `health()` counters
    every recovery action reports into.

    The injection protocol (shared by `StencilServingEngine` and
    `resilient_distributed_run`): at each boundary the driver calls
    `due(step)` and applies the returned faults itself — the injector
    never touches engine state; it only schedules, arms stalls, and
    counts. One-shot faults are consumed by `mark_fired`; persistent
    faults re-fire every time execution re-crosses their step (that is
    what forces the quarantine path — rollback alone cannot out-run a
    poisoned source).
    """

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self.counters: Dict[str, int] = {k: 0 for k in _COUNTERS}
        self.transitions: List[str] = []
        self._consumed: set = set()
        self._stalls: Dict[int, List] = {}   # fault idx -> [rung, left]

    # -- scheduling --------------------------------------------------------
    def due(self, step: int) -> List[Tuple[int, Fault]]:
        """Faults firing at this boundary (one-shot faults already
        consumed are skipped). The caller applies them, then
        `mark_fired(idx)`s each."""
        out = []
        for idx, f in enumerate(self.plan.faults):
            if f.at_step == step and idx not in self._consumed:
                out.append((idx, f))
        return out

    def mark_fired(self, idx: int) -> None:
        f = self.plan.faults[idx]
        self.counters["faults_injected"] += 1
        if not f.is_persistent:
            self._consumed.add(idx)

    def skip(self, idx: int, reason: str) -> None:
        """A due fault the driver cannot apply (e.g. a poison aimed at
        an empty slot) — consumed and counted, never silently dropped."""
        self._consumed.add(idx)
        self.counters["faults_skipped"] += 1
        self.transitions.append(f"skipped[{idx}]: {reason}")

    # -- stalls ------------------------------------------------------------
    def arm_stall(self, idx: int, fault: Fault) -> None:
        """Register an exchange_stall: the next `fault.stalls` attempts
        on rung `fault.rung` raise `ExchangeStalled`."""
        self._stalls[idx] = [fault.rung, fault.stalls]

    def poll_stall(self, rung: str) -> None:
        """Called immediately before each exchange attempt. Raises
        `ExchangeStalled` while an armed stall matches the CURRENT rung;
        an armed stall whose rung was degraded past is cleared — the
        whole point of the ladder is that the fallback transport does
        not share the faulted engine's failure."""
        for idx in list(self._stalls):
            srung, left = self._stalls[idx]
            if left <= 0:
                del self._stalls[idx]
                continue
            if srung == rung:
                self._stalls[idx][1] -= 1
                raise ExchangeStalled(
                    f"injected stall on rung {rung!r} "
                    f"({self._stalls[idx][1]} more)")
            del self._stalls[idx]

    def clear_stalls(self) -> None:
        """Drop every armed stall — the reshard path's reset (the lost
        devices took the stalled transport with them)."""
        self._stalls.clear()

    # -- counters ----------------------------------------------------------
    def record(self, counter: str, n: int = 1) -> None:
        if counter not in self.counters:
            raise KeyError(f"unknown health counter {counter!r}; "
                           f"expected one of {_COUNTERS}")
        self.counters[counter] += n

    def note(self, event: str) -> None:
        self.transitions.append(event)

    def health(self) -> Dict[str, object]:
        """The counters surface the launch CLI prints and the
        BENCH_faults gates assert on."""
        out: Dict[str, object] = dict(self.counters)
        out["transitions"] = list(self.transitions)
        out["plan"] = self.plan.describe()
        return out


class DegradationLadder:
    """Walks the exchange transports fastest-first, recording every
    transition. `degrade()` past the last rung raises
    `RecoveryExhausted` — the serving engine catches that and takes the
    implicit final rung (reshard down); the raw distributed run
    propagates it."""

    def __init__(self, rungs: Sequence[str] = DEFAULT_LADDER,
                 start: Optional[str] = None):
        self.rungs = tuple(rungs)
        if not self.rungs:
            raise ValueError("ladder needs at least one rung")
        if start is None:
            self._i = 0
        else:
            if start not in self.rungs:
                raise ValueError(f"start rung {start!r} not in "
                                 f"{self.rungs}")
            self._i = self.rungs.index(start)
        self.transitions: List[str] = []

    @property
    def current(self) -> str:
        return self.rungs[self._i]

    def degrade(self, reason: str = "") -> str:
        was = self.current
        if self._i + 1 >= len(self.rungs):
            self.transitions.append(f"{was} -> EXHAUSTED ({reason})")
            raise RecoveryExhausted(
                f"degradation ladder exhausted at {was!r}: {reason}")
        self._i += 1
        self.transitions.append(f"{was} -> {self.current} ({reason})")
        return self.current


def retry_with_backoff(attempt: Callable[[], object], *,
                       max_retries: int = 3, backoff_s: float = 0.0,
                       sleeper: Callable[[float], None] = time.sleep,
                       on_retry: Optional[Callable[[int, Exception],
                                                   None]] = None):
    """One initial try plus up to `max_retries` retries of `attempt`,
    sleeping `backoff_s * 2**k` before retry k. Only `ExchangeStalled`
    is retryable — anything else propagates immediately. Re-raises the
    last stall when the budget is spent (the caller degrades the
    ladder)."""
    if max_retries < 0:
        raise ValueError(f"max_retries must be >= 0, got {max_retries}")
    err: Optional[ExchangeStalled] = None
    for k in range(max_retries + 1):
        try:
            return attempt()
        except ExchangeStalled as e:
            err = e
            if k == max_retries:
                break
            if on_retry is not None:
                on_retry(k, e)
            if backoff_s > 0:
                sleeper(backoff_s * (2 ** k))
    assert err is not None
    raise err


def resilient_distributed_run(mesh, params, u, v, w, *, n_blocks: int,
                              T: int = 1, dt: float = 1.0,
                              axis: str = "data",
                              x_axis: Optional[str] = None,
                              local_kernel: str = "reference",
                              y_tile: Optional[int] = None,
                              interpret: bool = True,
                              injector: Optional[FaultInjector] = None,
                              ladder: Optional[DegradationLadder] = None,
                              max_retries: int = 3,
                              backoff_s: float = 0.0,
                              sleeper: Callable[[float], None] = time.sleep):
    """`make_distributed_step` driven block-by-block under the retry /
    degradation discipline: at each exchange-block boundary the due
    faults are polled, armed stalls hang the attempt, the bounded
    retry loop absorbs transient stalls, and a persistent stall degrades
    the ladder (`remote_dma` -> `collective`) — the step is rebuilt on
    the fallback transport and the block REPLAYED on it, which is sound
    because the two engines assemble bitwise-identical extended slabs
    (the BENCH_overlap gate). Ladder exhaustion raises
    `RecoveryExhausted`.

    Non-stall fault kinds in the plan are recorded as skipped — this
    driver owns only the exchange layer; slot-level faults belong to the
    serving engine. Returns ``(u, v, w), injector`` so callers can
    assert on `health()`.
    """
    from repro.stencil.distributed import make_distributed_step

    injector = injector or FaultInjector()
    ladder = ladder or DegradationLadder()

    def build(rung):
        return make_distributed_step(
            mesh, params, axis=axis, x_axis=x_axis, T=T, dt=dt,
            local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
            exchange=rung, dma_block_index=0)

    step = build(ladder.current)
    for block in range(n_blocks):
        for idx, f in injector.due(block):
            if f.kind == "exchange_stall":
                injector.arm_stall(idx, f)
                injector.mark_fired(idx)
            else:
                injector.skip(idx, f"{f.kind} not injectable at the "
                                   f"exchange layer")
        while True:
            def attempt():
                injector.poll_stall(ladder.current)
                return step(u, v, w)

            try:
                u, v, w = retry_with_backoff(
                    attempt, max_retries=max_retries, backoff_s=backoff_s,
                    sleeper=sleeper,
                    on_retry=lambda k, e: injector.record("retries"))
                break
            except ExchangeStalled as e:
                rung = ladder.degrade(str(e))       # RecoveryExhausted up
                injector.record("degradations")
                injector.note(f"block {block}: {ladder.transitions[-1]}")
                step = build(rung)
    return (u, v, w), injector

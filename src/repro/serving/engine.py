"""Serving: prefill -> decode cache management + a batched request engine.

Decode caches:
  * full-attention archs: (B, max_len, Ks, D) linear buffers, write at `pos`
  * hybrid local-attention layers: (B, W, Ks, D) ring buffers (slot = pos % W)
  * ssm / rec layers: O(1) conv window + recurrent state

`prefill_to_decode_cache` converts the prefill-produced caches (length = prompt)
into decode buffers of the serving length. The chunk-by-chunk arrival of
requests into the running batch mirrors the paper's §IV DMA chunk/kernel-pool
overlap: prefill (transfer) of one request overlaps decode (compute) of others.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.distributed.sharding import HeadLayout
from repro.models import model as M
from repro.serving.slots import SlotManager


def _to_linear(k: jax.Array, max_len: int) -> jax.Array:
    """([L,] B, S, Ks, D) prefill cache -> ([L,] B, max_len, Ks, D)."""
    ax = k.ndim - 3  # the sequence axis
    pad = [(0, 0)] * k.ndim
    pad[ax] = (0, max_len - k.shape[ax])
    return jnp.pad(k, pad)


def _to_ring(k: jax.Array, window: int) -> jax.Array:
    """([L,] B, S, ...) -> ([L,] B, W, ...) ring: last W tokens at slot t % W."""
    ax = k.ndim - 3
    S, W = k.shape[ax], window
    shape = list(k.shape)
    shape[ax] = W
    out = jnp.zeros(tuple(shape), k.dtype)
    idx = (slice(None),) * ax
    if S <= W:
        return out.at[idx + (slice(0, S),)].set(k)
    last = k[idx + (slice(S - W, S),)]        # tokens S-W .. S-1
    tpos = (jnp.arange(S - W, S)) % W
    return out.at[idx + (tpos,)].set(jnp.moveaxis(last, ax, ax))


def prefill_to_decode_cache(cfg: ArchConfig, caches, prompt_len: int,
                            max_len: int):
    """Convert prefill caches into decode buffers."""
    if caches is None:
        return None
    if cfg.family == "encdec":
        return caches  # already padded to max_dec_len by _forward_encdec

    def convert_layer(c):
        if "state" in c:          # mamba / rg-lru: O(1) state, pass through
            return c
        if cfg.family == "hybrid":
            W = cfg.hybrid.window
            return {"k": _to_ring(c["k"], W), "v": _to_ring(c["v"], W)}
        return {"k": _to_linear(c["k"], max_len), "v": _to_linear(c["v"], max_len)}

    if isinstance(caches, list):
        return [convert_layer(c) for c in caches]
    return convert_layer(caches) if isinstance(caches, dict) and (
        "k" in caches or "state" in caches) else jax.tree.map(lambda x: x, caches)


def init_decode_cache(cfg: ArchConfig, layout: HeadLayout, batch: int,
                      max_len: int, rules=None, mesh=None):
    from repro import pspec
    specs = M.cache_specs(cfg, layout, batch, max_len)
    zeros = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), specs,
        is_leaf=lambda x: hasattr(x, "axes"))
    return zeros


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32
    out: Optional[List[int]] = None


class ServingEngine:
    """Minimal batched greedy-decode engine over the functional model API.

    Slots of a fixed decode batch are filled as requests arrive (kernel-pool
    analogue of the paper's §IV): a finished slot is immediately re-primed
    with the next queued request while the other slots keep decoding. The
    slot lifecycle (live flags, step budgets, completion) lives in the
    shared `SlotManager`, which the stencil serving tier
    (`repro.serving.stencil_engine`) reuses unchanged.
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 4,
                 max_len: int = 256, tp: int = 1):
        self.cfg = cfg
        self.layout = M.make_layout(cfg, tp)
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.caches = init_decode_cache(cfg, self.layout, batch_size, max_len)
        self.pos = np.zeros((batch_size,), np.int32)
        self.next_token = np.zeros((batch_size,), np.int32)
        self.slots = SlotManager(batch_size)
        self._decode = jax.jit(functools.partial(
            self._decode_impl, cfg=cfg, layout=self.layout))

    @staticmethod
    def _decode_impl(params, caches, tokens, pos, *, cfg, layout):
        logits, caches = M.decode_step(params, caches, {"token": tokens, "pos": pos},
                                       cfg, layout)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, caches

    # -- slot management ---------------------------------------------------
    def _prime(self, slot: int, req: Request) -> bool:
        """Prefill `req` into `slot`. Prime time already emits the first
        new token (the prefill logits' argmax), so a request arrives with
        `max_new_tokens - 1` decode steps of budget — and one with
        ``max_new_tokens == 1`` is COMPLETE here: it never occupies the
        slot, and the caller must collect it instead of decoding an extra
        token past the budget. Returns True in that complete-at-prime
        case."""
        cfg, layout = self.cfg, self.layout
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {req.max_new_tokens} "
                f"(request {req.uid})")
        if len(req.prompt) >= self.max_len:
            raise ValueError(
                f"prompt of request {req.uid} has {len(req.prompt)} tokens "
                f"but max_len is {self.max_len}: the prompt must be shorter "
                "than max_len (the decode-cache scatter would clip the "
                "out-of-bounds tail and corrupt decode)")
        prompt = jnp.asarray(req.prompt)[None]
        batch = {"inputs": prompt}
        logits, _, caches = M.forward(self.params, batch, cfg, layout,
                                      mode="prefill")
        caches = prefill_to_decode_cache(cfg, caches, prompt.shape[1], self.max_len)
        # write this request's cache into the batch slot
        def put(dst, src):
            return dst.at[slot].set(src[0].astype(dst.dtype))
        if isinstance(self.caches, list):
            self.caches = [jax.tree.map(put, d, s)
                           for d, s in zip(self.caches, caches)]
        else:
            self.caches = jax.tree.map(put, self.caches, caches)
        self.pos[slot] = len(req.prompt) - 1  # next decode writes at prompt_len
        nxt = int(jnp.argmax(logits[0, -1]))
        req.out = [nxt]
        self.next_token[slot] = nxt
        if req.max_new_tokens == 1:
            return True
        self.slots.occupy(slot, req, req.max_new_tokens - 1)
        return False

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        queue = list(requests)
        self.next_token = np.zeros((self.B,), np.int32)
        done: Dict[int, List[int]] = {}
        while queue or self.slots.any_live():
            # fill idle slots (chunk arrival overlapping busy slots)
            for s in self.slots.idle_slots():
                if not queue:
                    break
                req = queue.pop(0)
                if self._prime(s, req):
                    done[req.uid] = req.out
            if not self.slots.any_live():
                continue  # everything primed this round completed at prime
            # dead slots are masked to a fixed (token 0, pos 0) feed: they
            # must not replay their previous occupant's stale state through
            # the decoder (their logits are discarded and a re-prime
            # overwrites the whole cache slot, so the masked write is inert)
            live = self.slots.live_mask()
            toks = jnp.asarray(np.where(live, self.next_token, 0)
                               .astype(np.int32))
            pos = jnp.asarray(np.where(live, self.pos + 1, 0)
                              .astype(np.int32))
            nxt, self.caches = self._decode(self.params, self.caches, toks, pos)
            nxt = np.asarray(nxt)
            for s in self.slots.live_slots():
                self.pos[s] += 1
                req = self.slots.request(s)
                req.out.append(int(nxt[s]))
                self.next_token[s] = nxt[s]
                if self.slots.tick(s) or self.pos[s] + 2 >= self.max_len:
                    done[req.uid] = req.out
                    self.slots.release(s)
        return done

"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

The production mesh's ``pod`` axis can run as a pipeline dimension: layer
stacks are split into S contiguous stages (one per pod), microbatches stream
through, and stage boundaries move activations with `ppermute` — point-to-
point DCN traffic instead of the all-reduce a pure-DP pod axis needs. The
bubble fraction is the usual (S-1)/(T+S-1).

`pipeline_apply` is schedule-exact GPipe: at step t, stage s computes
microbatch (t-s); results equal the sequential layer stack bit-for-bit
(tests/test_pipeline_parallel.py). Works with any per-layer block fn
(the LM blocks in repro.models plug in directly).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stacked_params, xs, block_fn: Callable, mesh: Mesh,
                   axis: str = "pod"):
    """Run a layer stack as a pipeline over `axis`.

    stacked_params: pytree with leading dim L (layers), sharded over `axis`
                    (L % n_stages == 0; each stage owns L/S contiguous layers)
    xs:             (n_micro, micro_batch, ...) microbatched activations
    block_fn:       (layer_params, x) -> x
    Returns (n_micro, micro_batch, ...) outputs, replicated over `axis`.
    """
    n_stage = mesh.shape[axis]
    n_micro = xs.shape[0]

    def local_stack(local_params, x):
        def body(c, p):
            return block_fn(p, c), ()
        y, _ = jax.lax.scan(body, x, local_params)
        return y

    def stage_fn(local_params, xs_local):
        s = jax.lax.axis_index(axis)
        T = n_micro + n_stage - 1
        buf = jnp.zeros_like(xs_local[0])          # incoming activation
        outs = jnp.zeros_like(xs_local)

        def step(t, carry):
            buf, outs = carry
            inject = xs_local[jnp.clip(t, 0, n_micro - 1)]
            x_in = jnp.where(s == 0, inject, buf)
            y = local_stack(local_params, x_in)
            # forward the activation to the next stage (ring permute; the
            # wrap-around edge's payload is never consumed)
            y_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stage) for i in range(n_stage)])
            idx = t - (n_stage - 1)
            valid = (s == n_stage - 1) & (idx >= 0) & (idx < n_micro)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[jnp.clip(idx, 0, n_micro - 1)].set(y),
                lambda o: o, outs)
            return (y_next, outs)

        _, outs = jax.lax.fori_loop(0, T, step, (buf, outs))
        # broadcast the last stage's collected outputs to every stage
        last = (s == n_stage - 1).astype(outs.dtype)
        return jax.lax.psum(outs * last, axis)

    fn = shard_map(
        stage_fn, mesh=mesh,
        in_specs=(P(axis), P()),      # params split by stage; xs replicated
        out_specs=P(),
        check_rep=False,
    )
    return fn(stacked_params, xs)


def bubble_fraction(n_stage: int, n_micro: int) -> float:
    return (n_stage - 1) / (n_micro + n_stage - 1)

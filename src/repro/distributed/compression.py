"""Gradient compression for the data-parallel reduce (int8 + error feedback).

At 1000+ nodes the DP gradient all-reduce crosses DCN and dominates the
collective term (see EXPERIMENTS.md §Roofline for the multi-pod cells). The
classic mitigation is quantised reduction with error feedback: each worker
all-reduces an int8-quantised gradient and locally accumulates what the
quantisation dropped, feeding it back next step — bias-free in the long run.

`compressed_psum` is the collective (runs under `shard_map` over the DP/pod
axis); `CompressionState` carries the per-leaf error-feedback residual.
GSPMD's implicit backward all-reduces can't be intercepted, so the trainer
that uses this runs grads through an explicit shard_map reduction over the
`pod` axis (see examples/train_compressed_dp.py).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantisation. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis: str,
                    residual: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8 all-reduce with error feedback. Returns (mean_grad, new_residual).

    Wire cost: 1 byte/element + one f32 scale per tensor vs 4 bytes/element —
    a 4x cut on the DCN term.
    """
    n = jax.lax.psum(1, axis)
    xf = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(xf)
    new_residual = xf - dequantize_int8(q, scale)
    # int8 payloads sum without overflow in int32; scales are averaged.
    # (Homogeneous-scale approximation: max|x| is near-identical across DP
    # replicas of the same gradient; the residual absorbs the difference.)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis)
    ssum = jax.lax.psum(scale, axis)
    mean = qsum.astype(jnp.float32) * (ssum / n) / n
    return mean.astype(x.dtype), new_residual


def init_residuals(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_tree_psum(grads, axis: str, residuals):
    """Apply compressed_psum leaf-wise over a gradient pytree."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    outs = [compressed_psum(g, axis, r) for g, r in zip(flat_g, flat_r)]
    means = tdef.unflatten([o[0] for o in outs])
    new_res = tdef.unflatten([o[1] for o in outs])
    return means, new_res


def wire_bytes_saved(grads) -> Dict[str, float]:
    total = sum(g.size for g in jax.tree.leaves(grads))
    return {"fp32_bytes": 4.0 * total, "int8_bytes": 1.0 * total,
            "ratio": 4.0}

"""Logical-axis sharding rules + TP-aware GQA head layout.

The production mesh is fixed by the assignment: ``(data=16, model=16)`` per
pod, optionally with a leading ``pod`` axis. Parameters and activations are
annotated with *logical* axes which these rules map onto mesh axes:

  * DP / FSDP : batch and parameter "embed-ish" dims over ``data`` (+ ``pod``)
  * TP        : heads / ffn / vocab / experts over ``model``
  * EP        : MoE experts over ``model``
  * SP        : long sequences over ``data`` where the op allows it

jit *inputs* must be evenly divisible by the axes they shard over
(GSPMD restriction verified empirically), so:

  * dims that do not divide are dropped from the spec (`_divisible` guard);
  * attention heads use a group-aligned stored layout (`HeadLayout`) that
    pads/replicates q and kv heads so that the head dim always divides TP —
    this is the same layout trick production TP serving engines use, and the
    resulting dead-head fraction is charged to the roofline "useful FLOPs"
    ratio rather than hidden.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ArchConfig, RunShape

# ---------------------------------------------------------------------------
# Head layout under tensor parallelism
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HeadLayout:
    """Stored (possibly padded/replicated) attention-head layout for a TP degree.

    q weights are stored as (embed, n_kv_stored * q_per_group, head_dim) and
    kv weights as (embed, n_kv_stored, head_dim). Stored group g corresponds
    to original kv head ``g // kv_repeat`` (or a dead pad group). Dead q heads
    are masked after attention so semantics match the unpadded model exactly.
    """

    n_q: int            # logical q heads
    n_kv: int           # logical kv heads
    tp: int
    n_kv_stored: int
    kv_repeat: int      # each original kv head stored this many times
    q_per_group: int    # stored q heads per stored kv group
    n_kv_dead: int      # trailing dead kv groups (pad case only)

    @property
    def n_q_stored(self) -> int:
        return self.n_kv_stored * self.q_per_group

    @property
    def q_live_fraction(self) -> float:
        return self.n_q / self.n_q_stored

    def q_head_mask(self) -> np.ndarray:
        """(n_q_stored,) 1.0 for live stored q heads, 0.0 for padding."""
        mask = np.zeros((self.n_q_stored,), np.float32)
        q_per_kv = self.n_q // self.n_kv
        for g in range(self.n_kv_stored - self.n_kv_dead):
            orig = g // self.kv_repeat
            slot = g % self.kv_repeat
            start = slot * self.q_per_group
            live = min(max(q_per_kv - start, 0), self.q_per_group)
            mask[g * self.q_per_group : g * self.q_per_group + live] = 1.0
        assert int(mask.sum()) == self.n_q, (mask.sum(), self.n_q)
        return mask

    def q_gather_index(self) -> np.ndarray:
        """(n_q_stored,) original q-head index feeding each stored slot (0 for dead)."""
        idx = np.zeros((self.n_q_stored,), np.int64)
        q_per_kv = self.n_q // self.n_kv
        for g in range(self.n_kv_stored - self.n_kv_dead):
            orig = g // self.kv_repeat
            slot = g % self.kv_repeat
            for j in range(self.q_per_group):
                src = slot * self.q_per_group + j
                if src < q_per_kv:
                    idx[g * self.q_per_group + j] = orig * q_per_kv + src
        return idx

    def kv_gather_index(self) -> np.ndarray:
        """(n_kv_stored,) original kv head stored in each group (0 for dead)."""
        idx = np.zeros((self.n_kv_stored,), np.int64)
        for g in range(self.n_kv_stored - self.n_kv_dead):
            idx[g] = g // self.kv_repeat
        return idx


def make_head_layout(n_q: int, n_kv: int, tp: int) -> HeadLayout:
    q_per_kv = n_q // n_kv
    assert n_q % n_kv == 0, "q heads must be a multiple of kv heads"
    if tp <= 1 or n_kv % tp == 0:
        # clean case: kv groups shard directly
        return HeadLayout(n_q, n_kv, tp, n_kv, 1, q_per_kv, 0)
    if tp % n_kv == 0:
        # replicate each kv head tp/n_kv times; split its q heads over copies
        rep = tp // n_kv
        qpg = math.ceil(q_per_kv / rep)
        return HeadLayout(n_q, n_kv, tp, tp, rep, qpg, 0)
    # pad kv heads up to a multiple of tp (e.g. MHA 20 heads on tp=16 -> 32)
    n_kv_stored = math.ceil(n_kv / tp) * tp
    return HeadLayout(n_q, n_kv, tp, n_kv_stored, 1, q_per_kv, n_kv_stored - n_kv)


# ---------------------------------------------------------------------------
# Logical axis -> mesh axis rules
# ---------------------------------------------------------------------------

# parameter / activation logical axes
Rules = Dict[str, Tuple[str, ...]]


def make_rules(*, multi_pod: bool, shape_kind: str = "train",
               fsdp_over_pod: bool = False,
               seq_shard: bool = False,
               seq_parallel: bool = False) -> Rules:
    """Sharding rules for the production mesh.

    data-parallel batch spans (pod, data); FSDP parameter sharding spans
    ``data`` (optionally pod too); TP spans ``model``. ``seq_parallel``
    shards the residual-stream sequence dim over ``model`` between blocks
    (Megatron-SP; GSPMD inserts the boundary gathers/scatters).
    """
    batch: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    fsdp: Tuple[str, ...] = (("pod", "data") if (multi_pod and fsdp_over_pod)
                             else ("data",))
    rules: Rules = {
        # parameters
        "embed": fsdp,
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": (),
        "ffn": ("model",),
        "vocab": ("model",),
        "expert": ("model",),
        "expert_ffn": (),
        "expert_embed": (),            # EP-resident expert weights: no FSDP
        "opt_expert_embed": ("data",),  # ...but ZeRO-1 moments shard over data
        "state": (),
        "lowrank": (),
        "conv": (),
        "layers": (),
        "norm": (),
        # activations
        "batch": batch,
        "seq": ("data",) if seq_shard else (),
        "res_seq": ("model",) if seq_parallel else (),  # Megatron-SP boundary
        "act_embed": (),
        "act_heads": ("model",),
        "act_kv_heads": ("model",),
        "act_ffn": ("model",),
        "act_expert": ("model",),
        "act_vocab": ("model",),
    }
    if shape_kind == "decode":
        # decode batch may be 1 (long_500k); channel dims carry the parallelism
        pass
    return rules


def _divisible(dim: int, axes: Tuple[str, ...], mesh: Mesh) -> Tuple[str, ...]:
    """Drop mesh axes that don't divide the dim (jit inputs must divide)."""
    kept = []
    prod = 1
    for a in axes:
        if a not in mesh.shape:
            continue
        size = mesh.shape[a]
        if dim % (prod * size) == 0:
            kept.append(a)
            prod *= size
    return tuple(kept)


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             rules: Rules, mesh: Mesh) -> P:
    """Build a PartitionSpec for an array with the given logical axes."""
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        if name is None:
            parts.append(None)
            continue
        axes = rules.get(name, ())
        axes = tuple(a for a in axes if a not in used)
        axes = _divisible(dim, axes, mesh)
        used.update(axes)
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(tuple(axes))
    return P(*parts)


def sharding_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
                 rules: Rules, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical_axes, rules, mesh))


def constrain(x, logical_axes: Sequence[Optional[str]], rules: Rules,
              mesh: Optional[Mesh]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for(x.shape, logical_axes, rules, mesh)
    )

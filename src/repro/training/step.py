"""Train / prefill / serve step builders with explicit shardings.

``build_step(cfg, shape_kind, ...)`` returns the jittable step function plus
abstract inputs and in/out shardings — exactly what both the real launcher and
the multi-pod dry-run need.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import pspec
from repro.config import ArchConfig, RunShape
from repro.distributed.sharding import Rules, sharding_for, spec_for
from repro.models import model as M
from repro.training import optimizer as O


def make_train_step(cfg: ArchConfig, layout, rules: Optional[Rules] = None,
                    mesh=None, opt: O.OptConfig = O.OptConfig(),
                    unroll: bool = False):
    """state = {"params", "opt"}; batch per input_specs. Returns (state, metrics)."""

    def loss_of(params, batch):
        return M.loss_fn(params, batch, cfg, layout, rules=rules, mesh=mesh,
                         unroll=unroll)

    def step(state, batch):
        accum = cfg.grad_accum
        if accum > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(state["params"], mb)
                gsum = jax.tree.map(jnp.add, gsum, grads)
                return (gsum, lsum + loss), ()
            mb0 = jax.tree.map(
                lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]),
                batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state["params"])
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), mb0)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {"loss": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(state["params"], batch)
        new_params, new_opt, om = O.adamw_update(
            state["params"], grads, state["opt"], opt)
        metrics = {**metrics, **om}
        # in-graph NaN guard: a poisoned batch/step must not corrupt the
        # state (donation makes host-side rollback impossible on device)
        good = jnp.isfinite(metrics["loss"]) & jnp.isfinite(om["grad_norm"])
        sel = lambda n, o: jnp.where(good, n, o.astype(n.dtype))
        new_params = jax.tree.map(sel, new_params, state["params"])
        new_opt = jax.tree.map(sel, new_opt, state["opt"])
        metrics["good"] = good
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def make_prefill_step(cfg: ArchConfig, layout, rules=None, mesh=None,
                      unroll: bool = False):
    def step(params, batch):
        logits, aux, caches = M.forward(params, batch, cfg, layout, rules=rules,
                                        mesh=mesh, mode="prefill", unroll=unroll)
        return logits[:, -1], caches
    return step


def make_serve_step(cfg: ArchConfig, layout, rules=None, mesh=None):
    def step(params, caches, batch):
        logits, caches = M.decode_step(params, caches, batch, cfg, layout,
                                       rules=rules, mesh=mesh)
        return logits, caches
    return step


# ---------------------------------------------------------------------------
# State construction / shardings
# ---------------------------------------------------------------------------


def state_specs(cfg: ArchConfig, layout) -> Dict[str, Any]:
    """ParamSpec tree for the full train state (params + AdamW moments).

    EP-resident expert weights (`expert_embed` axis) are replicated over
    `data`, but their AdamW moments still ZeRO-1-shard over `data` via the
    `opt_expert_embed` rule (the update's delta is gathered once per step).
    """
    ps = M.param_specs(cfg, layout)

    def moment(s):
        axes = tuple("opt_expert_embed" if a == "expert_embed" else a
                     for a in s.axes)
        return pspec.ParamSpec(s.shape, axes, cfg.opt_dtype, "zeros")
    return {
        "params": ps,
        "opt": {
            "m": jax.tree.map(moment, ps, is_leaf=pspec.is_spec),
            "v": jax.tree.map(moment, ps, is_leaf=pspec.is_spec),
            "step": pspec.ParamSpec((), (), "int32", "zeros"),
        },
    }


def init_state(cfg: ArchConfig, layout, rng) -> Dict[str, Any]:
    params = pspec.init_params(M.param_specs(cfg, layout), rng)
    return {"params": params, "opt": O.init_opt_state(params, cfg.opt_dtype)}


def tree_shardings(specs, rules: Rules, mesh):
    return pspec.param_shardings(specs, rules, mesh)


def tree_abstract(specs):
    return pspec.abstract_params(specs)

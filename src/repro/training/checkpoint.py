"""Checkpoint / restore with atomic writes and elastic (mesh-agnostic) restore.

Layout on disk:
    <dir>/step_000123/
        manifest.json      # step, arch, tp layout, tree structure, shapes
        arrays.npz         # one entry per flattened leaf (host-gathered)
    <dir>/LATEST           # atomic pointer file

Design points for the 1000-node regime (documented, exercised at CPU scale):
  * atomic rename: a crashed save can never corrupt LATEST;
  * params are stored in the *logical* (tp=1) head layout, so a restart on a
    different mesh/TP degree re-lays-out on load (elastic restarts);
  * `keep_last` bounds disk usage; `save_async` overlaps serialisation with
    the next training step (the paper's transfer/compute overlap, applied to
    checkpoint I/O);
  * at real multi-host scale each host would write its own array shards —
    the manifest format already records per-leaf shapes to support that.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.config import ArchConfig
from repro.models import model as M
from repro.models import relayout as R


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str | Path, state: Dict[str, Any], step: int, *,
         cfg: Optional[ArchConfig] = None, layout=None,
         keep_last: int = 3) -> Path:
    """Synchronous atomic checkpoint save."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    if cfg is not None and layout is not None:
        params = R.to_logical(state["params"], cfg, layout)
        state = {**state, "params": params}
    tmp = ckpt_dir / f".tmp_step_{step:09d}_{os.getpid()}"
    final = ckpt_dir / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "arch": cfg.name if cfg else None,
        "keys": [k for k, _ in leaves],
        "shapes": {k: list(np.shape(v)) for k, v in leaves},
        "dtypes": {k: str(np.asarray(v).dtype) for k, v in leaves},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic on POSIX
    latest_tmp = ckpt_dir / ".LATEST.tmp"
    latest_tmp.write_text(final.name)
    latest_tmp.rename(ckpt_dir / "LATEST")  # atomic pointer update
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: Path, keep_last: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


class AsyncCheckpointer:
    """Overlap checkpoint serialisation with training (one in flight)."""

    def __init__(self, ckpt_dir: str | Path, keep_last: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, state, step: int, *, cfg=None, layout=None):
        self.wait()
        # snapshot to host memory synchronously (cheap vs serialisation)
        host_state = jax.tree.map(np.asarray, state)

        def work():
            try:
                save(self.ckpt_dir, host_state, step, cfg=cfg, layout=layout,
                     keep_last=self.keep_last)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
            if self.last_error is not None:
                raise self.last_error


class CheckpointCorrupted(RuntimeError):
    """A checkpoint on disk is unreadable (truncated write, damaged
    archive, missing file). The message always names the offending path;
    callers fall back to an earlier step or fail loudly — never a raw
    unpickling traceback."""


def _is_complete(d: Path) -> bool:
    """A checkpoint directory is complete once BOTH files the atomic
    rename published exist; anything else (a partial copy, a crashed
    foreign writer) is ignored by `latest_step`."""
    return (d / "manifest.json").exists() and (d / "arrays.npz").exists()


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    """Newest COMPLETE checkpoint step, or None. Prefers the LATEST
    pointer; a stale/partial target (e.g. a directory some other writer
    left without its arrays.npz) falls back to scanning the complete
    `step_*` directories — `.tmp_*` staging dirs are never candidates."""
    ckpt_dir = Path(ckpt_dir)
    p = ckpt_dir / "LATEST"
    if p.exists():
        name = p.read_text().strip()
        if _is_complete(ckpt_dir / name):
            return int(name.split("_")[1])
    steps = sorted(d for d in ckpt_dir.glob("step_*")
                   if d.is_dir() and _is_complete(d))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore(ckpt_dir: str | Path, like_state: Dict[str, Any], *,
            step: Optional[int] = None, cfg: Optional[ArchConfig] = None,
            layout=None) -> Tuple[Dict[str, Any], int]:
    """Restore into the structure of `like_state` (elastic: any TP layout).

    A truncated or otherwise damaged checkpoint raises
    `CheckpointCorrupted` naming the path (np.load on a torn npz throws
    anything from BadZipFile to EOFError depending on where the write
    died — all normalised here); a checkpoint that simply is not there
    raises FileNotFoundError.
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:09d}"
    npz = d / "arrays.npz"
    if not npz.exists():
        raise FileNotFoundError(f"checkpoint step {step}: no arrays file "
                                f"at {npz}")
    keys = [k for k, _ in _flatten_with_paths(like_state)]
    flat_like, tdef = jax.tree_util.tree_flatten(like_state)
    vals = []
    try:
        data = np.load(npz, allow_pickle=False)
        stored_keys = set(data.files)
        for k, leaf in zip(keys, flat_like):
            if k not in stored_keys:
                raise KeyError(f"checkpoint missing leaf {k}")
            vals.append(np.asarray(data[k]))
    except (KeyError, FileNotFoundError):
        raise
    except Exception as e:   # torn npz: BadZipFile / EOFError / OSError / ...
        raise CheckpointCorrupted(
            f"checkpoint archive {npz} is unreadable "
            f"({type(e).__name__}: {e}); the write was likely truncated — "
            f"restore an earlier step") from e
    state = jax.tree_util.tree_unflatten(tdef, vals)
    if cfg is not None and layout is not None:
        state = {**state, "params": R.from_logical(state["params"], cfg, layout)}
        # coerce dtypes/shapes to the live layout
        state = jax.tree.map(
            lambda a, l: np.asarray(a, dtype=l.dtype) if hasattr(l, "dtype") else a,
            state, like_state)
    return state, step

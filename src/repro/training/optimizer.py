"""AdamW with warmup+cosine schedule, global-norm clipping.

Implemented from scratch (no optax in this environment). Moments are stored
in ``cfg.opt_dtype`` (f32 default; bf16 is the documented low-memory option
for the >400B dry-run cells).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step, oc: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = oc.peak_lr * (step + 1) / max(oc.warmup_steps, 1)
    t = jnp.clip((step - oc.warmup_steps)
                 / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.peak_lr * (oc.min_lr_frac
                        + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < oc.warmup_steps, warm, cos)


def init_opt_state(params, opt_dtype: str = "float32") -> Dict[str, Any]:
    dt = jnp.dtype(opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, clip: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, opt_state, oc: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    lr = lr_at(step, oc)
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    b1, b2 = oc.b1, oc.b2
    c1 = 1.0 - b1 ** (step.astype(jnp.float32) + 1)
    c2 = 1.0 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}

"""Architecture + run-shape configuration system.

Every assigned architecture is a frozen `ArchConfig`; run shapes
(train_4k / prefill_32k / decode_32k / long_500k) are `RunShape`s.
`src/repro/configs/<id>.py` instantiates the exact published numbers and a
reduced smoke config of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # arctic: dense residual MLP running in parallel with the routed experts
    dense_residual: bool = False
    # llama4: one always-on shared expert added to the routed output
    shared_expert: bool = False
    # route tokens within groups of this size (GShard-style grouping bounds
    # the dispatch tensor); 0 = pick automatically
    group_size: int = 0
    # MoE on every k-th layer (llama4 interleaves MoE with dense layers)
    moe_every: int = 1
    # True: expert weights ZeRO-3 FSDP-sharded over `data` (baseline; weight
    # all-gather per layer). False: EP-resident — experts sharded over
    # `model` only, replicated across `data`, optimizer moments ZeRO-1
    # sharded over `data`; tokens move (all-to-all), weights don't.
    expert_fsdp: bool = True
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    conv_k: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> d_model // 16


@dataclass(frozen=True)
class HybridConfig:
    # recurrentgemma: repeating block pattern, e.g. ("rec", "rec", "attn")
    pattern: Tuple[str, ...] = ()
    window: int = 2048          # local attention window
    d_rnn: int = 0              # RG-LRU width (0 -> d_model)
    conv_k: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 0
    dec_layers: int = 0
    dec_len: int = 512          # decoder text length used for train/prefill shapes
    max_dec_len: int = 512      # decoder self-attention cache length at decode


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    mlp: str = "swiglu"         # swiglu | sq_relu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    pos: str = "rope"           # rope | mrope | none | sincos
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)
    encdec: EncDecConfig = field(default_factory=EncDecConfig)

    # modality frontends are stubs per the assignment: inputs are precomputed
    # frame/patch embeddings rather than raw pixels/audio
    embeds_input: bool = False

    # ---- execution knobs (not part of the published architecture) ----
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"   # AdamW moment dtype
    scan_layers: bool = True
    scan_group: int = 0          # 0 = flat scan; g>1 = sqrt-remat group scan
    seq_parallel: bool = False   # shard residual-stream seq dim over `model`
    remat: str = "full"          # none | dots | full
    attention_impl: str = "chunked"  # dense | chunked | local | pallas
    attn_chunk: int = 1024
    scan_chunk: int = 256        # ssm/hybrid sequence-chunk size
    grad_accum: int = 1

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "ssm" and self.ssm.dt_rank == 0:
            object.__setattr__(
                self, "ssm", dataclasses.replace(self.ssm, dt_rank=self.d_model // 16)
            )
        if self.family == "hybrid" and self.hybrid.d_rnn == 0:
            object.__setattr__(
                self, "hybrid", dataclasses.replace(self.hybrid, d_rnn=self.d_model)
            )

    # -- convenience ----------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode is supported (SSM / local-attention)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (logical / unpadded)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hq, hk, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * hq * hd + 2 * d * hk * hd + hq * hd * d
        if self.mlp == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        per_layer = 0
        if self.family == "ssm":
            di, st, dtr = self.d_inner, self.ssm.d_state, self.ssm.dt_rank
            per_layer = (2 * d * di + di * self.ssm.conv_k + di * (dtr + 2 * st)
                         + dtr * di + di * st + di + di * d)
        elif self.family == "hybrid":
            n_attn = sum(1 for p in self._pattern_full() if p == "attn")
            n_rec = self.n_layers - n_attn
            dr = self.hybrid.d_rnn
            rec = 2 * d * dr + dr * self.hybrid.conv_k + 3 * dr + dr * d
            per_layer = 0  # handled below (non-uniform)
            total = n_attn * (attn + mlp) + n_rec * (rec + mlp)
            emb = v * d + (0 if self.tie_embeddings else d * v)
            return total + emb + L * 2 * d
        elif self.family == "moe":
            m = self.moe
            n_moe = self.n_layers // m.moe_every
            n_dense = self.n_layers - n_moe
            routed = m.n_experts * 3 * d * m.d_ff_expert
            extra = (3 * d * self.d_ff if m.dense_residual else 0)
            extra += (3 * d * m.d_ff_expert if m.shared_expert else 0)
            total = (self.n_layers * attn
                     + n_moe * (routed + extra + d * m.n_experts)
                     + n_dense * 3 * d * self.d_ff)
            emb = v * d + (0 if self.tie_embeddings else d * v)
            return total + emb + L * 2 * d
        elif self.family == "encdec":
            e = self.encdec
            enc = e.enc_layers * (attn + mlp)
            dec = e.dec_layers * (2 * attn + mlp)  # self + cross
            emb = v * d + (0 if self.tie_embeddings else d * v)
            return enc + dec + emb
        else:
            per_layer = attn + mlp
        emb = v * d + (0 if self.tie_embeddings else d * v)
        return L * per_layer + emb + L * 2 * d

    def active_param_count(self) -> int:
        """Params touched per token (MoE active; equals param_count for dense)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        m = self.moe
        n_moe = L // m.moe_every
        n_dense = L - n_moe
        hq, hk, hd = self.n_heads, self.n_kv_heads, self.head_dim
        attn = d * hq * hd + 2 * d * hk * hd + hq * hd * d
        routed_active = m.top_k * 3 * d * m.d_ff_expert
        extra = (3 * d * self.d_ff if m.dense_residual else 0)
        extra += (3 * d * m.d_ff_expert if m.shared_expert else 0)
        emb = self.vocab_size * d * 2
        return (L * attn + n_moe * (routed_active + extra + d * m.n_experts)
                + n_dense * 3 * d * self.d_ff + emb)

    def _pattern_full(self) -> Tuple[str, ...]:
        if self.family != "hybrid":
            return ()
        pat = self.hybrid.pattern or ("rec", "rec", "attn")
        out = []
        while len(out) < self.n_layers:
            out.extend(pat)
        return tuple(out[: self.n_layers])


@dataclass(frozen=True)
class RunShape:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = RunShape("train_4k", "train", 4_096, 256)
PREFILL_32K = RunShape("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = RunShape("decode_32k", "decode", 32_768, 128)
LONG_500K = RunShape("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def supports(cfg: ArchConfig, shape: RunShape) -> bool:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True

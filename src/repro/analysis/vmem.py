"""VMEM budget pass: statically sum every named on-chip buffer a config
will allocate and refuse to build past `roofline.VMEM_PER_CORE` —
BEFORE anything compiles.

The serving tier already had this discipline for ONE buffer class
(`roofline.serving_max_batch` bounds the batched slot rings); this pass
generalises it to every rung of the ladder: the fused kernel's
shift-register ring (`kernels.advection.fused_register_bytes`, spec
geometry included), the remote-DMA engine's staged-send slabs and
double-buffered recv slabs (`kernels.advection.dma_slab_bytes`, the
exact scratch/out shapes `halo_band_exchange_dma` declares), and the
serving engine's per-slot rings. A `VmemPlan` is a list of named
buffers plus the budget; `check()` raises `VmemBudgetExceeded` NAMING
the largest offender, so an over-budget config fails at build/trace
time with the buffer to shrink instead of at compile time with a Mosaic
allocation error (or, worse, on hardware).

Builders return plans; the distributed drivers and the serving engine
call `check()` on them at trace/alloc time, and `scripts/lint_movement.py`
audits representative ladder configs without building anything.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.core import roofline as R
from repro.kernels.advection import advection as K

__all__ = [
    "VmemBudgetExceeded", "VmemBuffer", "VmemPlan", "fused_ring_plan",
    "distributed_block_plan", "serving_ring_plan", "plan_max_batch",
]


class VmemBudgetExceeded(ValueError):
    """A statically-planned VMEM footprint exceeds the per-core budget.
    The message names every buffer and the largest offender — the knob
    to shrink (y_tile, T, batch, depth) is always one of the named
    buffers' parameters."""


@dataclass(frozen=True)
class VmemBuffer:
    """One named on-chip allocation: `name` is what the error reports,
    `note` records the sizing formula's inputs for the audit trail."""
    name: str
    nbytes: int
    note: str = ""


@dataclass(frozen=True)
class VmemPlan:
    """A static VMEM plan: named buffers vs the per-core budget."""
    buffers: Tuple[VmemBuffer, ...]
    budget: int = R.VMEM_PER_CORE
    context: str = ""

    def total(self) -> int:
        return sum(b.nbytes for b in self.buffers)

    def headroom(self) -> int:
        return self.budget - self.total()

    def fits(self) -> bool:
        return self.total() <= self.budget

    def table(self) -> str:
        lines = [f"  {b.nbytes:>12d} B  {b.name}"
                 + (f"  ({b.note})" if b.note else "")
                 for b in self.buffers]
        lines.append(f"  {self.total():>12d} B  TOTAL"
                     f"  (budget {self.budget} B)")
        return "\n".join(lines)

    def check(self) -> "VmemPlan":
        if not self.fits():
            worst = max(self.buffers, key=lambda b: b.nbytes)
            where = f" [{self.context}]" if self.context else ""
            raise VmemBudgetExceeded(
                f"static VMEM plan{where} needs {self.total()} B, budget "
                f"is {self.budget} B ({R.VMEM_PER_CORE} per core); "
                f"largest buffer: {worst.name!r} at {worst.nbytes} B"
                + (f" ({worst.note})" if worst.note else "")
                + f"\n{self.table()}")
        return self


# ---- builders ----------------------------------------------------------

def fused_ring_plan(y_rows: int, Z: int, *, T: int, itemsize: int = 4,
                    y_tile: Optional[int] = None,
                    halo: Optional[int] = None, n_fields: int = 3,
                    n_slots: int = 3, n_levels: Optional[int] = None,
                    batch: int = 1, budget: int = R.VMEM_PER_CORE,
                    context: str = "") -> VmemPlan:
    """The fused kernel's shift-register ring (`fused_register_bytes`,
    spec geometry via n_fields/n_slots/n_levels/halo), `batch` slots of
    it for the batched mega-launch."""
    per_slot = K.fused_register_bytes(
        T, y_rows, Z, itemsize, y_tile, halo,
        n_fields=n_fields, n_slots=n_slots, n_levels=n_levels)
    name = ("fused shift-register ring" if batch == 1
            else f"batched slot rings (batch={batch})")
    note = (f"{per_slot} B/slot: {n_fields} fields x "
            f"{n_slots}x{T if n_levels is None else n_levels} slices, "
            f"y_tile={y_tile}, Z={Z}")
    buf = VmemBuffer(name, batch * per_slot, note)
    return VmemPlan((buf,), budget=budget, context=context)


def distributed_block_plan(shard_shape: Tuple[int, int, int], *, T: int,
                           itemsize: int = 4, local_kernel: str,
                           exchange: str, interpret: bool,
                           y_tile: Optional[int] = None, nx: int = 1,
                           ny: int = 1, spec=None,
                           budget: int = R.VMEM_PER_CORE,
                           context: str = "") -> VmemPlan:
    """Static per-shard VMEM plan of one distributed substep block:
    the fused ring over the halo-EXTENDED slab (when
    `local_kernel="fused"`) plus the compiled remote-DMA engine's
    staged-send and recv slabs for both exchange phases (when
    `exchange="remote_dma"` and not interpreting — the emulation stages
    nothing in VMEM). `spec` switches the ring to the generalised
    `stencil_fused` geometry and the exchange depth to `spec.halo(T)`.
    """
    Xl, Yl, Z = shard_shape
    depth = spec.halo(T) if spec is not None else T
    n_fields = spec.n_fields if spec is not None else 3
    dx = depth if nx > 1 else 0
    dy = depth if ny > 1 else 0
    buffers = []
    if local_kernel == "fused":
        ring_kw = {}
        if spec is not None:
            ring_kw = dict(n_fields=spec.n_fields,
                           n_slots=2 * spec.radius + 1,
                           n_levels=spec.stages * T, halo=depth)
        per = K.fused_register_bytes(T, Yl + 2 * dy, Z, itemsize, y_tile,
                                     **ring_kw)
        buffers.append(VmemBuffer(
            "fused shift-register ring (halo-extended shard slab)", per,
            f"slab {(Xl + 2 * dx, Yl + 2 * dy, Z)}, y_tile={y_tile}, "
            f"T={T}, depth={depth}"))
    if exchange == "remote_dma" and not interpret:
        if dx:
            stage, recv = K.dma_slab_bytes((Xl, Yl, Z), dx, 0, itemsize,
                                           n_fields=n_fields)
            buffers.append(VmemBuffer(
                "remote-DMA staged-send slabs (x phase)", stage,
                f"depth={dx} planes of {(Xl, Yl, Z)}"))
            buffers.append(VmemBuffer(
                "remote-DMA double-buffered recv slabs (x phase)", recv,
                "2 slots x 2 sides"))
        if dy:
            x_ext = Xl + 2 * dx
            stage, recv = K.dma_slab_bytes((x_ext, Yl, Z), dy, 1, itemsize,
                                           n_fields=n_fields)
            buffers.append(VmemBuffer(
                "remote-DMA staged-send slabs (y phase, x-extended)",
                stage, f"depth={dy} rows of {(x_ext, Yl, Z)}"))
            buffers.append(VmemBuffer(
                "remote-DMA double-buffered recv slabs (y phase)", recv,
                "2 slots x 2 sides"))
    return VmemPlan(tuple(buffers), budget=budget, context=context)


def serving_ring_plan(Y: int, Z: int, *, batch: int, T: int,
                      itemsize: int = 4, y_tile: Optional[int] = None,
                      n_fields: int = 3, budget: int = R.VMEM_PER_CORE,
                      context: str = "") -> VmemPlan:
    """The serving engine's batched slot rings — the buffer class
    `roofline.serving_max_batch` bounds; `plan_max_batch` proves the two
    agree."""
    return fused_ring_plan(Y, Z, T=T, itemsize=itemsize, y_tile=y_tile,
                           n_fields=n_fields, batch=batch, budget=budget,
                           context=context)


def plan_max_batch(Y: int, Z: int, *, T: int, itemsize: int = 4,
                   y_tile: Optional[int] = None, n_fields: int = 3,
                   budget: int = R.VMEM_PER_CORE) -> int:
    """Largest batch whose `serving_ring_plan` fits: defined THROUGH
    `roofline.serving_max_batch` so the serving-only check and the
    generalised pass can never drift apart (a test pins the
    equivalence)."""
    per_slot = K.fused_register_bytes(T, Y, Z, itemsize, y_tile,
                                      n_fields=n_fields)
    return R.serving_max_batch(per_slot, vmem_budget=budget)

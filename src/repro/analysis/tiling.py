"""Tiling-contract linter: check every `pallas_call` in a traced program
against the TPU tiling contract — statically, from the grid mapping the
trace already carries.

Three checks per block mapping:

  lane / sublane  (warn) the last two block-shape dims should be
                  multiples of the (8, 128) sublane/lane tile
                  (`roofline` and every kernel docstring's contract).
                  Misaligned blocks LOWER correctly but pad each
                  vregister — the ladder's lane-efficiency penalty
                  (`hbm_bytes_model`'s Z % 128 discount) made that cost
                  visible; the linter makes it enumerable. Warnings,
                  not errors: the interpret-mode compute grids are
                  deliberately tiny and misaligned.
  unblocked-oob   (error) for `pl.Unblocked` mappings the index map
                  returns ELEMENT offsets with no XLA clamp semantics:
                  the linter evaluates the index-map jaxpr over the
                  launch grid (every point up to `max_grid_points`,
                  corners beyond) and flags any block reaching outside
                  the operand extent — the out-of-bounds read/write a
                  wrong `_slab_lo` clip would cause, caught before
                  anything runs.
  alias-*         (error) `input_output_aliases` pairs update a buffer
                  in place: operand/result extents must match
                  (alias-shape) and, when both sides are Unblocked,
                  their index maps must address the same window at
                  every grid point (alias-window) — otherwise the
                  in-place write lands somewhere the aliased read
                  didn't come from.

`lint_tiling(fn, *args)` walks the whole traced program (pjit /
shard_map / loop bodies included) and returns a `TilingReport`;
`scripts/lint_movement.py` gates errors == 0 over the ladder configs
and pins the warning census in BENCH_analysis.json.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Tuple

import jax
import numpy as np

from repro.analysis.jaxpr import walk_jaxpr

__all__ = ["TilingIssue", "TilingReport", "lint_tiling",
           "SUBLANE", "LANE"]

SUBLANE, LANE = 8, 128


@dataclass(frozen=True)
class TilingIssue:
    severity: str      # "error" | "warn"
    kind: str          # "lane" | "sublane" | "unblocked-oob" | "alias-*"
    kernel: str
    operand: str
    detail: str

    def __str__(self) -> str:
        return (f"{self.severity.upper()} [{self.kind}] {self.kernel}"
                f" / {self.operand}: {self.detail}")


@dataclass
class TilingReport:
    issues: Tuple[TilingIssue, ...]
    kernels: int

    @property
    def errors(self) -> Tuple[TilingIssue, ...]:
        return tuple(i for i in self.issues if i.severity == "error")

    @property
    def warnings(self) -> Tuple[TilingIssue, ...]:
        return tuple(i for i in self.issues if i.severity == "warn")

    def raise_if_errors(self) -> None:
        if self.errors:
            lines = "\n  ".join(str(i) for i in self.errors)
            raise AssertionError(
                f"tiling contract violated ({len(self.errors)} "
                f"error(s)):\n  {lines}")


def _kernel_name(eqn) -> str:
    nsi = eqn.params.get("name_and_src_info")
    return str(getattr(nsi, "name", nsi or "pallas_call"))


def _grid_points(grid, max_grid_points):
    """Every launch-grid point when the grid is small, corners beyond —
    index maps in this repo are affine in each grid index, so corners
    bound the extrema; exhaustive evaluation below the cap keeps the
    check assumption-free where it is cheap."""
    sizes = [int(g) for g in grid]
    if not sizes:
        return [()]
    total = int(np.prod(sizes))
    if total <= max_grid_points:
        return list(itertools.product(*(range(s) for s in sizes)))
    return list(itertools.product(*(sorted({0, s - 1}) for s in sizes)))


def _eval_index_map(index_map_jaxpr, point):
    vals = jax.core.eval_jaxpr(index_map_jaxpr.jaxpr, index_map_jaxpr.consts,
                               *[np.int32(p) for p in point])
    return tuple(int(v) for v in vals)


def _block_dims(block_shape):
    """Block shape entries that are concrete ints (squeezed/mapped dims
    are pallas-internal sentinels — skipped)."""
    return [(d, int(b)) for d, b in enumerate(block_shape)
            if isinstance(b, (int, np.integer))]


def _check_mapping(bm, *, kernel, operand, grid, max_grid_points, issues,
                   sublane, lane):
    block = list(getattr(bm, "block_shape", ()) or ())
    dims = _block_dims(block)
    arr = getattr(getattr(bm, "array_shape_dtype", None), "shape", None)
    # ---- (8, 128) contract: warn on misaligned trailing dims
    if dims:
        last_d, last_b = dims[-1]
        if last_b % lane:
            issues.append(TilingIssue(
                "warn", "lane", kernel, operand,
                f"block shape {tuple(block)} last dim {last_b} is not a "
                f"multiple of the {lane}-lane tile — every vregister is "
                f"padded (the hbm model's lane_eff penalty)"))
        if len(dims) >= 2:
            sub_d, sub_b = dims[-2]
            if sub_b % sublane:
                issues.append(TilingIssue(
                    "warn", "sublane", kernel, operand,
                    f"block shape {tuple(block)} dim {sub_d} ({sub_b} "
                    f"rows) is not a multiple of the {sublane}-sublane "
                    f"tile"))
    # ---- Unblocked bounds vs operand extent
    mode = type(getattr(bm, "indexing_mode", None)).__name__
    if mode != "Unblocked" or arr is None:
        return
    padding = getattr(bm.indexing_mode, "padding", None)
    if padding and any(int(lo) or int(hi) for lo, hi in padding):
        return  # padded refs extend the addressable window by design
    imap = getattr(bm, "index_map_jaxpr", None)
    if imap is None:
        return
    try:
        starts_per_point = [(_eval_index_map(imap, pt), pt)
                            for pt in _grid_points(grid, max_grid_points)]
    except Exception as e:  # unevaluable map: surface, don't crash
        issues.append(TilingIssue(
            "warn", "index-map-uneval", kernel, operand,
            f"could not evaluate Unblocked index map statically: {e!r}"))
        return
    for starts, pt in starts_per_point:
        # starts align 1:1 with block dims for Unblocked mappings;
        # squeezed dims carry a sentinel block entry and span 1 element
        for d, start in enumerate(starts):
            if d >= len(arr) or d >= len(block):
                continue
            size = (int(block[d])
                    if isinstance(block[d], (int, np.integer)) else 1)
            extent = int(arr[d])
            if start < 0 or start + size > extent:
                issues.append(TilingIssue(
                    "error", "unblocked-oob", kernel, operand,
                    f"grid point {pt}: Unblocked window "
                    f"[{start}, {start + size}) exceeds operand extent "
                    f"{extent} in dim {d} (operand shape {tuple(arr)})"))
                return  # one witness per operand is enough


def _lint_pallas_eqn(eqn, *, max_grid_points, sublane, lane, issues):
    gm = eqn.params.get("grid_mapping")
    if gm is None:
        return
    kernel = _kernel_name(eqn)
    grid = tuple(getattr(gm, "grid", ()) or ())
    if any(not isinstance(g, (int, np.integer)) for g in grid):
        return  # dynamic grids cannot be swept statically
    mappings = list(getattr(gm, "block_mappings", ()) or ())
    n_in = int(getattr(gm, "num_inputs", len(mappings)))
    for i, bm in enumerate(mappings):
        operand = (f"in[{i}]" if i < n_in else f"out[{i - n_in}]")
        origin = getattr(bm, "origin", "")
        if origin:
            operand += f" ({origin})"
        _check_mapping(bm, kernel=kernel, operand=operand, grid=grid,
                       max_grid_points=max_grid_points, issues=issues,
                       sublane=sublane, lane=lane)
    # ---- in-place aliasing: operand/result windows must coincide
    aliases = eqn.params.get("input_output_aliases") or ()
    for in_idx, out_idx in aliases:
        if in_idx >= len(mappings) or n_in + out_idx >= len(mappings):
            continue
        bm_in, bm_out = mappings[in_idx], mappings[n_in + out_idx]
        shp_in = getattr(getattr(bm_in, "array_shape_dtype", None),
                         "shape", None)
        shp_out = getattr(getattr(bm_out, "array_shape_dtype", None),
                          "shape", None)
        pair = f"in[{in_idx}]<->out[{out_idx}]"
        if shp_in != shp_out:
            issues.append(TilingIssue(
                "error", "alias-shape", kernel, pair,
                f"aliased operand/result extents differ: {shp_in} vs "
                f"{shp_out} — the in-place update writes outside the "
                f"buffer it reads"))
            continue
        modes = {type(getattr(b, "indexing_mode", None)).__name__
                 for b in (bm_in, bm_out)}
        if modes == {"Unblocked"}:
            try:
                for pt in _grid_points(grid, max_grid_points):
                    si = _eval_index_map(bm_in.index_map_jaxpr, pt)
                    so = _eval_index_map(bm_out.index_map_jaxpr, pt)
                    if si != so:
                        issues.append(TilingIssue(
                            "error", "alias-window", kernel, pair,
                            f"grid point {pt}: aliased windows diverge "
                            f"(read at {si}, write at {so}) — the "
                            f"in-place write lands where the read did "
                            f"not come from"))
                        break
            except Exception as e:
                issues.append(TilingIssue(
                    "warn", "index-map-uneval", kernel, pair,
                    f"could not compare aliased index maps: {e!r}"))


def lint_tiling(fn, *args, sublane: int = SUBLANE, lane: int = LANE,
                max_grid_points: int = 4096) -> TilingReport:
    """Trace `fn(*args)` (never executing it) and lint every
    `pallas_call` — including those inside pjit / shard_map / loop
    bodies — against the tiling contract. Returns a `TilingReport`;
    `raise_if_errors()` is the gate."""
    closed = jax.make_jaxpr(fn)(*args)
    issues: list = []
    kernels = [0]

    def visit(eqn):
        if eqn.primitive.name == "pallas_call":
            kernels[0] += 1
            _lint_pallas_eqn(eqn, max_grid_points=max_grid_points,
                             sublane=sublane, lane=lane, issues=issues)

    walk_jaxpr(closed.jaxpr, visit)
    return TilingReport(issues=tuple(issues), kernels=kernels[0])

"""Retrace detector: flag config knobs that leak static Python values
into a traced driver.

The PR 5 bug class: `make_distributed_run`'s recv-slot parity was once
selected with static Python `block_index % 2`, so every block baked a
DIFFERENT trace — a silent recompile per config that no test saw until
the pipeline gate counted K× the wire bytes. The fix threads the index
as a traced `lax.fori_loop` induction variable (`lax.rem` + dynamic
indexing); this pass is the regression gate for the whole class.

Mechanism: trace the driver a factory builds at each value of a config
knob and compare `structural_fingerprint`s. Literal operand VALUES are
abstracted (they are cache-compatible when passed as arguments), so two
configs fingerprint equal exactly when the knob stayed out of the trace
structure. Each perturbation declares what it expects:

  expect="shared"    the knob must NOT change the trace (block parity,
                     n_blocks): divergence == a leaked static value,
                     reported with the first structurally differing
                     equation — kind "leak".
  expect="distinct"  the knob MUST change the trace (y_tile changes the
                     Pallas grid): identical fingerprints mean the knob
                     is silently ignored — kind "inert".

Both verdicts are bugs; `detect_retrace` returns a report naming knob,
values and the diverging equation, and `RetraceReport.ok` is the gate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr import fingerprint_parts, structural_fingerprint

__all__ = [
    "Perturbation", "RetraceFinding", "RetraceReport", "detect_retrace",
    "driver_fingerprint", "make_static_parity_driver",
    "make_traced_parity_driver",
]


@dataclass(frozen=True)
class Perturbation:
    """Sweep `knob` over `values`; `expect` declares whether the traces
    must be shared (retrace-free) or distinct (the knob must matter)."""
    knob: str
    values: Tuple
    expect: str = "shared"

    def __post_init__(self):
        if self.expect not in ("shared", "distinct"):
            raise ValueError(f"expect must be 'shared' or 'distinct', "
                             f"got {self.expect!r}")
        if len(self.values) < 2:
            raise ValueError(f"perturbation {self.knob!r} needs >= 2 "
                             f"values to compare")


@dataclass(frozen=True)
class RetraceFinding:
    knob: str
    kind: str          # "leak" | "inert"
    values: Tuple
    detail: str

    def __str__(self) -> str:
        return f"[{self.kind}] knob {self.knob!r} over {self.values}: " \
               f"{self.detail}"


@dataclass
class RetraceReport:
    ok: bool
    findings: Tuple[RetraceFinding, ...]
    fingerprints: Dict[Tuple[str, object], str] = field(default_factory=dict)

    def raise_if_failed(self) -> None:
        if not self.ok:
            lines = "\n  ".join(str(f) for f in self.findings)
            raise AssertionError(
                f"retrace detector failed ({len(self.findings)} "
                f"finding(s)):\n  {lines}")


def driver_fingerprint(fn, *args) -> str:
    """Structural fingerprint of `fn(*args)`'s trace (never executed)."""
    return structural_fingerprint(jax.make_jaxpr(fn)(*args))


def _first_divergence(parts_a: Sequence[str], parts_b: Sequence[str]) -> str:
    for i, (a, b) in enumerate(zip(parts_a, parts_b)):
        if a != b:
            return (f"first divergence at equation #{i}: "
                    f"{a.strip()!r} vs {b.strip()!r}")
    return (f"traces differ in length: {len(parts_a)} vs {len(parts_b)} "
            f"equations")


def detect_retrace(factory: Callable,
                   perturbations: Sequence[Perturbation]) -> RetraceReport:
    """`factory(**{knob: value}) -> (fn, args)` builds the driver under
    one config override; each perturbation's values are traced and the
    fingerprints compared against its expectation. All traces happen
    under `jax.make_jaxpr` — nothing executes, nothing compiles."""
    findings = []
    fingerprints: Dict[Tuple[str, object], str] = {}
    for pert in perturbations:
        traces = []
        for value in pert.values:
            fn, args = factory(**{pert.knob: value})
            closed = jax.make_jaxpr(fn)(*args)
            parts = fingerprint_parts(closed.jaxpr)
            fp = structural_fingerprint(closed)
            fingerprints[(pert.knob, value)] = fp
            traces.append((value, fp, parts))
        base_value, base_fp, base_parts = traces[0]
        for value, fp, parts in traces[1:]:
            if pert.expect == "shared" and fp != base_fp:
                findings.append(RetraceFinding(
                    pert.knob, "leak", (base_value, value),
                    "a static Python value leaked into the trace — jit "
                    "retraces per config; "
                    + _first_divergence(base_parts, parts)))
            elif pert.expect == "distinct" and fp == base_fp:
                findings.append(RetraceFinding(
                    pert.knob, "inert", (base_value, value),
                    "expected the knob to change the traced program but "
                    "the fingerprints are identical — the config is "
                    "silently ignored"))
    return RetraceReport(ok=not findings, findings=tuple(findings),
                         fingerprints=fingerprints)


# ---- fixtures ----------------------------------------------------------

def make_static_parity_driver(block_index: int = 0,
                              shape: Tuple[int, int, int] = (4, 6, 8)):
    """Deliberately-BROKEN fixture reintroducing the PR 5 bug class: the
    double-buffered recv slot is selected with static Python parity
    (`slots[block_index % 2]` resolved at trace time), so even and odd
    blocks bake different slice params into the trace and every parity
    flip retraces. The detector must flag this as a "leak" — the red
    half of its acceptance gate. Returns `(fn, args)` for
    `detect_retrace`'s factory protocol."""
    slot = int(block_index) % 2   # the bug: parity resolved in Python

    def step(u):
        slots = jnp.stack([u, jnp.roll(u, 1, axis=1)])
        return slots[slot] * 0.5

    return step, (jnp.zeros(shape, jnp.float32),)


def make_traced_parity_driver(block_index: int = 0,
                              shape: Tuple[int, int, int] = (4, 6, 8)):
    """The FIXED counterpart of `make_static_parity_driver`: the parity
    is computed from a traced operand (`lax.rem` + dynamic indexing, the
    PR 5 fix), so every block index shares one trace. The detector must
    report it retrace-free — the green half of the fixture pair."""
    def step(u, k):
        slots = jnp.stack([u, jnp.roll(u, 1, axis=1)])
        parity = jax.lax.rem(k, jnp.int32(2))
        return jax.lax.dynamic_index_in_dim(
            slots, parity, axis=0, keepdims=False) * 0.5

    return step, (jnp.zeros(shape, jnp.float32), jnp.int32(block_index))

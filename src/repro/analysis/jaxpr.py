"""Shared jaxpr traversal for the static-analysis passes.

Everything in `repro.analysis` works on the TRACED program — the jaxpr
`jax.make_jaxpr` returns — never on a running computation. The walkers
here are the substrate: `iter_jaxprs` flattens any sub-jaxpr an equation
carries in its params (pjit / shard_map / scan / while / pallas_call /
custom-derivative bodies all stash their bodies differently),
`walk_jaxpr` applies a visitor to every equation recursively, and
`structural_fingerprint` hashes the trace STRUCTURE so the retrace
detector can tell "jit would reuse this trace" from "a static Python
value leaked in and forced a new one".

`iter_jaxprs` is the single source of truth moved out of
`stencil/distributed.py` (which re-exports it as `_iter_jaxprs` for
backward compatibility): the four legacy `count_*` byte counters and all
four analysis passes recurse through exactly the same param traversal,
so a control-flow primitive none of them knew about fails everywhere at
once instead of silently in one counter.
"""
from __future__ import annotations

import hashlib
import re

import jax
import numpy as np

__all__ = [
    "iter_jaxprs", "walk_jaxpr", "aval_bytes", "fingerprint_parts",
    "structural_fingerprint",
]


def iter_jaxprs(val):
    """Yield every `jax.core.Jaxpr` reachable from an eqn param value:
    a ClosedJaxpr, a bare Jaxpr, or any list/tuple nesting of them.
    (Dict-valued params carry no jaxprs on the pinned jax; mirroring the
    legacy counters, they are not descended into.)"""
    core = jax.core
    if isinstance(val, core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from iter_jaxprs(v)


def walk_jaxpr(jaxpr, visit) -> None:
    """Depth-first visitor over every equation of `jaxpr` and of every
    sub-jaxpr carried in equation params. `visit(eqn)` runs on the
    equation BEFORE its children — the traversal order the legacy
    `count_*` walkers used, preserved so the refactor is byte-identical.
    """
    for eqn in jaxpr.eqns:
        visit(eqn)
        for pval in eqn.params.values():
            for sub in iter_jaxprs(pval):
                walk_jaxpr(sub, visit)


def aval_bytes(aval) -> int:
    """Size in bytes of an abstract value (0 for shapeless avals)."""
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape)) * np.dtype(dtype).itemsize


def _aval_str(aval) -> str:
    return f"{getattr(aval, 'shape', '?')}:{getattr(aval, 'dtype', '?')}"


def _var_str(var) -> str:
    # Literal VALUES are abstracted away ("lit" + aval): arguments jit
    # receives at call time key the trace cache by aval only, so two
    # traces differing in nothing but literal operand values are
    # cache-compatible when those values arrive as arguments. Static
    # leaks of the PR 5 class resolve at trace time into eqn params or
    # structure (slice starts, unrolled bodies) and stay visible.
    if isinstance(var, jax.core.Literal):
        return "lit" + _aval_str(var.aval)
    return _aval_str(var.aval)


# reprs of params may embed object addresses (wrapped functions, trace
# debug info); scrub them so the fingerprint depends on structure only.
_ADDR = re.compile(r"0x[0-9a-fA-F]+")


def fingerprint_parts(jaxpr, _depth: int = 0) -> list:
    """One line per equation (recursing into sub-jaxprs) capturing the
    primitive, operand/result avals, literal layout and param values —
    the retrace detector diffs two of these lists to NAME the first
    structurally diverging equation."""
    pad = "  " * _depth
    parts = []
    for eqn in jaxpr.eqns:
        sub_parts = []
        param_bits = []
        for key in sorted(eqn.params):
            pval = eqn.params[key]
            subs = list(iter_jaxprs(pval))
            if subs:
                param_bits.append(f"{key}=<jaxpr>")
                for s in subs:
                    sub_parts.extend(fingerprint_parts(s, _depth + 1))
            else:
                param_bits.append(f"{key}={_ADDR.sub('0x', repr(pval))}")
        parts.append(pad + "|".join((
            eqn.primitive.name,
            ",".join(_var_str(v) for v in eqn.invars),
            ",".join(_aval_str(v.aval) for v in eqn.outvars),
            ";".join(param_bits))))
        parts.extend(sub_parts)
    return parts


def structural_fingerprint(traced) -> str:
    """Hex digest of the trace structure of `traced` (a ClosedJaxpr or
    Jaxpr). Two drivers with equal fingerprints lower to the same
    program modulo argument values; unequal fingerprints mean a config
    knob changed the TRACE itself — either legitimately (shapes, depth)
    or because a static Python value leaked in (the retrace detector's
    quarry)."""
    jaxpr = traced.jaxpr if isinstance(traced, jax.core.ClosedJaxpr) else traced
    digest = hashlib.sha256(
        "\n".join(fingerprint_parts(jaxpr)).encode()).hexdigest()
    return digest[:16]

"""Static data-movement analysis: audit any traced driver WITHOUT
executing it.

The repo's whole discipline is "counted == modelled EXACTLY" — every
BENCH gate prices moved bytes against an analytic model. This package
is that discipline turned into a reusable subsystem: one shared jaxpr
walker (`jaxpr`), a byte-attribution ledger plus model-coverage gate
(`ledger`), a static-value-leak / retrace detector (`retrace`), a
build-time VMEM budget (`vmem`) and a Pallas tiling-contract linter
(`tiling`), all registered in `passes` and driven over the ladder's
representative configs by `scripts/lint_movement.py` (emitting
BENCH_analysis.json). See docs/static-analysis.md for the pass
catalogue and how the ledger categories map to the paper's profiling
table.
"""
from repro.analysis.jaxpr import (aval_bytes, fingerprint_parts,
                                  iter_jaxprs, structural_fingerprint,
                                  walk_jaxpr)
from repro.analysis.ledger import (CATEGORIES, CoverageFailure,
                                   CoverageReport, ModelCoverageError,
                                   MovementLedger, MovementRecord,
                                   audit_movement, check_model_coverage,
                                   count_ppermute_bytes)
from repro.analysis.passes import (PASSES, AnalysisPass, available,
                                   get_pass, register_pass)
from repro.analysis.retrace import (Perturbation, RetraceFinding,
                                    RetraceReport, detect_retrace,
                                    driver_fingerprint,
                                    make_static_parity_driver,
                                    make_traced_parity_driver)
from repro.analysis.tiling import (LANE, SUBLANE, TilingIssue,
                                   TilingReport, lint_tiling)
from repro.analysis.vmem import (VmemBudgetExceeded, VmemBuffer, VmemPlan,
                                 distributed_block_plan, fused_ring_plan,
                                 plan_max_batch, serving_ring_plan)

__all__ = [
    "iter_jaxprs", "walk_jaxpr", "aval_bytes", "fingerprint_parts",
    "structural_fingerprint",
    "CATEGORIES", "MovementRecord", "MovementLedger", "audit_movement",
    "count_ppermute_bytes",
    "CoverageFailure", "CoverageReport", "ModelCoverageError",
    "check_model_coverage",
    "Perturbation", "RetraceFinding", "RetraceReport", "detect_retrace",
    "driver_fingerprint", "make_static_parity_driver",
    "make_traced_parity_driver",
    "VmemBudgetExceeded", "VmemBuffer", "VmemPlan", "fused_ring_plan",
    "distributed_block_plan", "serving_ring_plan", "plan_max_batch",
    "TilingIssue", "TilingReport", "lint_tiling", "SUBLANE", "LANE",
    "AnalysisPass", "PASSES", "register_pass", "available", "get_pass",
]

"""MovementLedger: ONE jaxpr walk attributing every moved byte to a
category — the pass that subsumes the four copy-pasted `count_*`
counters in `stencil/distributed.py` (now thin wrappers over this).

Categories (the paper's profiling-table rows, trace-time edition):

  ppermute_wire      rank >= 3 ppermute operands — the halo band
                     payloads both exchange engines put on the wire
                     (priced by `roofline.halo_wire_bytes_model`).
  integrity_words    rank < 3 ppermute operands — the uint32
                     `band_checksum` words a verified exchange rides on
                     each band (`roofline.integrity_bytes_model`).
  pallas_hbm         rank >= 3 operands/results of field-moving
                     `pallas_call`s — the HBM streams
                     (`kernels.advection.hbm_bytes_model`).
  guard_field_reads  rank >= 3 operands of guard-pass `pallas_call`s
                     (every result rank < 3 — the guard signature): the
                     detection re-read of the fields.
  guard_flag_words   rank < 3 operands/results of guard-pass calls: the
                     flag words. guard_field_reads + guard_flag_words
                     is `roofline.guard_bytes_model`'s quantity.
  pallas_control     rank < 3 operands/results of field-moving
                     `pallas_call`s — packed coefficient vectors and
                     interior masks, scalar-pipeline traffic the
                     analytic models deliberately never charged (the
                     documented exclusion in `count_pallas_hbm_bytes`);
                     the coverage pass treats it as unpriced-by-design.
  all_gather         operands of `all_gather` — NEW visibility: the
  psum               elastic regather / reduction traffic no legacy
  all_to_all         counter saw. No model term prices these yet, so
                     any nonzero total FAILS the coverage pass until a
                     model claims it — "anything uncounted is an
                     error".
  host_transfer      operands of `device_put` — explicit host/device
                     traffic inside a traced program.

The model-coverage pass (`check_model_coverage`) closes the loop: given
the ledger and a dict of analytic claims {category: exact bytes}, it
fails on (a) counted bytes no claim covers, (b) a claim the count
contradicts, and (c) a claim for bytes the trace never moves. The
legacy gates checked only the bytes they knew about; this makes new
movement a PR introduces break the gate instead of sliding past it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax

from repro.analysis.jaxpr import aval_bytes, walk_jaxpr

__all__ = [
    "CATEGORIES", "MovementRecord", "MovementLedger", "audit_movement",
    "count_ppermute_bytes",
    "CoverageFailure", "CoverageReport", "check_model_coverage",
    "ModelCoverageError",
]

CATEGORIES = (
    "ppermute_wire", "integrity_words", "pallas_hbm",
    "guard_field_reads", "guard_flag_words", "pallas_control",
    "all_gather", "psum", "all_to_all", "host_transfer",
)

# collectives recorded under their own primitive name
_COLLECTIVES = ("all_gather", "psum", "all_to_all")


@dataclass(frozen=True)
class MovementRecord:
    """One attributed operand: `nbytes` of `category` traffic moved by
    `primitive` (with the Pallas kernel name when there is one)."""
    category: str
    primitive: str
    nbytes: int
    shape: Tuple[int, ...]
    dtype: str
    kernel: str = ""


def _kernel_name(eqn) -> str:
    nsi = eqn.params.get("name_and_src_info")
    return str(getattr(nsi, "name", nsi or ""))


class MovementLedger:
    """The attributed byte records of one traced program."""

    def __init__(self, records=()):
        self.records: list = list(records)

    # ---- construction -------------------------------------------------
    @classmethod
    def of(cls, fn, *args) -> "MovementLedger":
        """Trace `fn(*args)` (never executing it) and attribute every
        byte its jaxpr moves. Inside `shard_map` shapes are per-shard,
        so on a distributed driver the totals are per-shard — the same
        convention every legacy counter and analytic model uses."""
        return cls.from_traced(jax.make_jaxpr(fn)(*args))

    @classmethod
    def from_traced(cls, traced) -> "MovementLedger":
        jaxpr = (traced.jaxpr
                 if isinstance(traced, jax.core.ClosedJaxpr) else traced)
        records = []

        def add(category, eqn, var, kernel=""):
            aval = var.aval
            records.append(MovementRecord(
                category=category, primitive=eqn.primitive.name,
                nbytes=aval_bytes(aval),
                shape=tuple(getattr(aval, "shape", ())),
                dtype=str(getattr(aval, "dtype", "?")), kernel=kernel))

        def visit(eqn):
            name = eqn.primitive.name
            if name == "ppermute":
                for var in eqn.invars:
                    ndim = getattr(var.aval, "ndim", 0)
                    add("ppermute_wire" if ndim >= 3 else "integrity_words",
                        eqn, var)
            elif name == "pallas_call":
                kernel = _kernel_name(eqn)
                # the guard signature: EVERY result rank < 3 (flags are
                # (X,) / vmapped (B, X); field kernels emit rank >= 3)
                guard = all(getattr(v.aval, "ndim", 3) < 3
                            for v in eqn.outvars)
                for var in list(eqn.invars) + list(eqn.outvars):
                    ndim = getattr(var.aval, "ndim", 0)
                    if guard:
                        cat = ("guard_field_reads" if ndim >= 3
                               else "guard_flag_words")
                    else:
                        cat = "pallas_hbm" if ndim >= 3 else "pallas_control"
                    add(cat, eqn, var, kernel)
            elif name in _COLLECTIVES:
                for var in eqn.invars:
                    add(name, eqn, var)
            elif name == "device_put":
                for var in eqn.invars:
                    add("host_transfer", eqn, var)

        walk_jaxpr(jaxpr, visit)
        return cls(records)

    # ---- queries ------------------------------------------------------
    def total(self, *categories: str) -> int:
        for c in categories:
            if c not in CATEGORIES:
                raise KeyError(f"unknown movement category {c!r}; "
                               f"one of {CATEGORIES}")
        return sum(r.nbytes for r in self.records if r.category in categories)

    def totals(self) -> Dict[str, int]:
        """Per-category byte totals — every category, zeros included."""
        out = {c: 0 for c in CATEGORIES}
        for r in self.records:
            out[r.category] += r.nbytes
        return out

    def grand_total(self) -> int:
        return sum(r.nbytes for r in self.records)

    def __len__(self) -> int:
        return len(self.records)

    def __repr__(self) -> str:
        nz = {c: b for c, b in self.totals().items() if b}
        return f"MovementLedger({len(self.records)} records, {nz})"


def audit_movement(fn, *args) -> MovementLedger:
    """Convenience alias: `MovementLedger.of(fn, *args)`."""
    return MovementLedger.of(fn, *args)


def count_ppermute_bytes(fn, args, keep) -> int:
    """Summed sizes of the ppermute operands selected by `keep(aval)` in
    `fn`'s recursively walked jaxpr — the generic form the wire and
    integrity counters in `stencil.distributed` partition by rank
    (moved here from that module; it re-exports this as
    `_count_ppermute_bytes` for backward compatibility)."""
    closed = jax.make_jaxpr(fn)(*args)
    total = [0]

    def visit(eqn):
        if eqn.primitive.name == "ppermute":
            for var in eqn.invars:
                if keep(var.aval):
                    total[0] += aval_bytes(var.aval)

    walk_jaxpr(closed.jaxpr, visit)
    return total[0]


# ---- model-coverage pass ----------------------------------------------

class ModelCoverageError(AssertionError):
    """The traced program moves bytes the analytic models do not claim
    (or a model claims bytes the trace contradicts). Raised by
    `CoverageReport.raise_if_failed`."""


@dataclass(frozen=True)
class CoverageFailure:
    category: str
    counted: int
    claimed: Optional[int]
    reason: str

    def __str__(self) -> str:
        return (f"[{self.category}] counted={self.counted} "
                f"claimed={self.claimed}: {self.reason}")


@dataclass
class CoverageReport:
    ok: bool
    failures: Tuple[CoverageFailure, ...]
    counted: Dict[str, int] = field(default_factory=dict)
    claims: Dict[str, int] = field(default_factory=dict)
    unpriced: Tuple[str, ...] = ()

    def raise_if_failed(self) -> None:
        if not self.ok:
            lines = "\n  ".join(str(f) for f in self.failures)
            raise ModelCoverageError(
                f"model coverage failed ({len(self.failures)} "
                f"failure(s)):\n  {lines}")


def check_model_coverage(ledger: MovementLedger,
                         claims: Dict[str, int], *,
                         unpriced: Tuple[str, ...] = ("pallas_control",),
                         ) -> CoverageReport:
    """Every counted byte must be claimed EXACTLY by an analytic model
    term, or appear in `unpriced` (categories documented as
    deliberately unpriced — default: the scalar-pipeline `pallas_control`
    traffic `count_pallas_hbm_bytes` always excluded). Conversely every
    claim must match the count exactly — a model pricing movement the
    trace does not perform is as wrong as unpriced movement."""
    counted = ledger.totals()
    failures = []
    for cat in CATEGORIES:
        if cat in unpriced:
            if cat in claims:
                failures.append(CoverageFailure(
                    cat, counted[cat], claims[cat],
                    "category is both claimed and declared unpriced — "
                    "pick one"))
            continue
        have = counted[cat]
        if cat in claims:
            want = int(claims[cat])
            if have != want:
                reason = ("model claims bytes the trace never moves"
                          if have == 0 else
                          "counted bytes contradict the model claim")
                failures.append(CoverageFailure(cat, have, want, reason))
        elif have:
            failures.append(CoverageFailure(
                cat, have, None,
                "unclaimed movement: no analytic model term prices these "
                "bytes (add a model claim or an explicit unpriced entry)"))
    unknown = sorted(set(claims) - set(CATEGORIES))
    for cat in unknown:
        failures.append(CoverageFailure(
            cat, 0, claims[cat],
            f"claim names no ledger category (one of {CATEGORIES})"))
    return CoverageReport(ok=not failures, failures=tuple(failures),
                          counted=counted, claims=dict(claims),
                          unpriced=tuple(unpriced))

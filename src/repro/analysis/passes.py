"""The pass registry: the four analysis passes behind one discoverable
surface.

Each pass is a callable registered under a stable name with a one-line
summary — `available()` is what `scripts/lint_movement.py --list` and
`docs/static-analysis.md` enumerate, and adding a pass is one
`@register_pass` away (the doc's "how to add a pass" recipe). The
registry deliberately does NOT normalise signatures: the passes take
what their problem needs (a traced fn, a driver factory, a static
config) and the registry's job is discovery and documentation, not
dispatch gymnastics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

from repro.analysis.ledger import (MovementLedger, check_model_coverage)
from repro.analysis.retrace import detect_retrace
from repro.analysis.tiling import lint_tiling
from repro.analysis.vmem import VmemPlan

__all__ = ["AnalysisPass", "PASSES", "register_pass", "available",
           "get_pass"]


@dataclass(frozen=True)
class AnalysisPass:
    name: str
    summary: str
    run: Callable


PASSES: Dict[str, AnalysisPass] = {}


def register_pass(name: str, summary: str):
    """Register `fn` as the analysis pass `name`. Names are unique —
    re-registering is a bug, not an override."""
    def deco(fn):
        if name in PASSES:
            raise ValueError(f"analysis pass {name!r} already registered")
        PASSES[name] = AnalysisPass(name=name, summary=summary, run=fn)
        return fn
    return deco


def available() -> Tuple[Tuple[str, str], ...]:
    """(name, summary) of every registered pass, registration order."""
    return tuple((p.name, p.summary) for p in PASSES.values())


def get_pass(name: str) -> AnalysisPass:
    if name not in PASSES:
        known = ", ".join(PASSES)
        raise KeyError(f"no analysis pass {name!r}; registered: {known}")
    return PASSES[name]


# ---- the four shipped passes -------------------------------------------

@register_pass(
    "movement-ledger",
    "attribute every byte a traced program moves to a category "
    "(wire / HBM / integrity / guard / collective / host)")
def movement_ledger_pass(fn, *args) -> MovementLedger:
    return MovementLedger.of(fn, *args)


@register_pass(
    "model-coverage",
    "fail when the ledger holds bytes no analytic model term claims "
    "(or a claim the count contradicts)")
def model_coverage_pass(fn, *args, claims, unpriced=("pallas_control",)):
    return check_model_coverage(MovementLedger.of(fn, *args), claims,
                                unpriced=unpriced)


@register_pass(
    "retrace",
    "flag config knobs whose static Python values leak into the trace "
    "(the PR 5 dma_block_index bug class)")
def retrace_pass(factory, perturbations):
    return detect_retrace(factory, perturbations)


@register_pass(
    "vmem-budget",
    "statically sum named on-chip buffers against VMEM_PER_CORE and "
    "refuse over-budget configs before compile")
def vmem_budget_pass(plan: VmemPlan) -> VmemPlan:
    return plan.check()


@register_pass(
    "tiling-contract",
    "lint every pallas_call's block shapes against the (8, 128) tile, "
    "Unblocked bounds and in-place aliasing windows")
def tiling_contract_pass(fn, *args, **kw):
    return lint_tiling(fn, *args, **kw)

"""Distributed PW advection: halo exchange overlapped with interior compute.

The paper's §IV overlap (DMA chunks vs kernel pool) maps chip-to-chip on TPU:
the y-decomposed domain needs depth-1 halos, exchanged with
`lax.ppermute` while the *interior* — which needs no halo — computes.
The data dependence is structured so XLA can schedule the collective-permute
concurrently with the interior stencil (interior result does not consume the
permuted edges), then the two boundary y-rows are patched.

Temporal fusion (the v4 kernel) makes the halo depth T-dependent:
`make_distributed_step(..., T=...)` exchanges T rows per side ONCE, then
advances T Euler substeps on the halo'd slab before trimming — amortising
both the HBM pass *and* the collective over T steps (each step contaminates
one more halo row, so depth-T halos are exactly consumed after T substeps).

`local_kernel="fused"` runs that per-shard slab update through the v4
Pallas kernel instead of the jnp reference loop, composing the depth-T
exchange with the kernel's in-grid `(y_tile, x)` tiling: the shard's slab
streams through ONE kernel launch whose VMEM register is bounded by
`y_tile` while the wrapped (periodic-ppermute) rows are frozen via the
kernel's `y_interior_mask` — the same global-interior mask the reference
loop applies per substep.

Runs under `shard_map` over the `data` axis of any mesh (smoke-tested on the
host mesh; the production mesh shards y 16-way per pod).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.advection import advection as K
from repro.kernels.advection.ref import (AdvectParams, pw_advect_ref,
                                         pw_step_ref)


def _exchange_halos(f, axis: str, n: int, depth: int = 1):
    """Send my edge y-rows to neighbours; receive theirs. Returns (lo, hi).

    lo = neighbour's last `depth` rows (go below my slab), hi = their first.
    `n` is the static axis size (jax.lax.axis_size is not available on the
    pinned jax, and ppermute's pair table must be static anyway).
    """
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    hi_from_prev = jax.lax.ppermute(f[:, -depth:, :], axis, fwd)  # top -> next
    lo_from_next = jax.lax.ppermute(f[:, :depth, :], axis, bwd)   # bottom -> prev
    return hi_from_prev, lo_from_next


def make_distributed_advect(mesh: Mesh, params: AdvectParams,
                            axis: str = "data"):
    """Returns jit(advect) over fields sharded (None, axis, None) in y."""

    n_shards = mesh.shape[axis]

    def local(u, v, w):
        """Per-shard: exchange halos, compute interior meanwhile, patch edges."""
        # 1) launch halo exchange (6 edge planes, tiny vs the slab)
        halos = [_exchange_halos(f, axis, n_shards) for f in (u, v, w)]
        # 2) interior compute — no dependence on `halos`, so XLA overlaps the
        #    collective-permutes with this stencil (the §IV overlap on ICI)
        interior = pw_advect_ref(u, v, w, params)
        # 3) boundary patch: rebuild the two edge y-bands with halo rows
        n = n_shards
        idx = jax.lax.axis_index(axis)

        def with_halo(f, h):
            prev_hi, next_lo = h
            return jnp.concatenate([prev_hi, f, next_lo], axis=1)

        uh, vh, wh = (with_halo(f, h) for f, h in zip((u, v, w), halos))
        full = pw_advect_ref(uh, vh, wh, params)
        band = [s[:, 1:-1, :] for s in full]   # drop halo rows back off
        # interior rows are identical; edge rows (y=0 / y=-1 of the slab) come
        # from the halo'd compute. For edge shards the global boundary stays 0.
        Y = u.shape[1]
        rows = jnp.arange(Y)
        is_edge_row = (rows < 1) | (rows >= Y - 1)
        gl = (idx == 0)
        gh = (idx == n - 1)
        glob_lo = gl & (rows < 1)
        glob_hi = gh & (rows >= Y - 1)
        keep_band = is_edge_row & ~(glob_lo | glob_hi)
        sel = keep_band[None, :, None]
        out = [jnp.where(sel, b, i) for b, i in zip(band, interior)]
        return tuple(out)

    spec = P(None, axis, None)
    fn = shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                   out_specs=(spec, spec, spec))
    return jax.jit(fn)


def make_distributed_step(mesh: Mesh, params: AdvectParams, *,
                          axis: str = "data", T: int = 1, dt: float = 1.0,
                          local_kernel: str = "reference",
                          y_tile: Optional[int] = None,
                          interpret: bool = True):
    """Returns jit(step): T Euler substeps per ONE depth-T halo exchange.

    The wrapped ppermute is periodic, so the first/last shard's outer halo
    rows carry wrapped (wrong) data — but every substep masks the source to
    zero outside the *global* interior, and a depth-1 stencil cannot carry
    values past an unchanging row: the global-boundary row is a wall, the
    wrapped rows never contaminate the trimmed result.

    `local_kernel` selects the per-shard slab update: "reference" is the
    jnp T-substep loop; "fused" streams the slab through the v4 Pallas
    kernel (one HBM pass for all T substeps), passing the global-interior
    mask as the kernel's `y_interior_mask` and composing with the kernel's
    in-grid `(y_tile, x)` tiling via `y_tile` — the shard slab keeps a
    VMEM-bounded register no matter how wide the shard is.

    Wire cost: T rows per neighbour per exchange, so bytes-on-wire per
    substep are flat in T while the exchange *count* falls as 1/T —
    latency-bound small halos amortise T×.
    """
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    if local_kernel not in ("reference", "fused"):
        raise ValueError(f"local_kernel must be 'reference' or 'fused', "
                         f"got {local_kernel!r}")

    n_shards = mesh.shape[axis]

    def local(u, v, w):
        n = n_shards
        idx = jax.lax.axis_index(axis)
        if T > u.shape[1]:
            raise ValueError(
                f"halo depth T={T} exceeds the local shard width "
                f"{u.shape[1]} (single-hop exchange); lower T or use "
                "fewer shards")
        halos = [_exchange_halos(f, axis, n, depth=T) for f in (u, v, w)]

        def slab(f, h):
            prev_hi, next_lo = h
            return jnp.concatenate([prev_hi, f, next_lo], axis=1)

        us, vs, ws = (slab(f, h) for f, h in zip((u, v, w), halos))
        Yl = u.shape[1]
        gy = idx * Yl - T + jnp.arange(Yl + 2 * T)   # global row per slab row
        interior_y = (gy >= 1) & (gy <= n * Yl - 2)
        if local_kernel == "fused":
            us, vs, ws = K.advect_fused(
                us, vs, ws, params, T=T, dt=dt, interpret=interpret,
                y_tile=y_tile,
                y_interior_mask=interior_y.astype(jnp.float32))
        else:
            m = interior_y[None, :, None]
            for _ in range(T):
                su, sv, sw = pw_advect_ref(us, vs, ws, params)
                us = us + dt * jnp.where(m, su, 0.0)
                vs = vs + dt * jnp.where(m, sv, 0.0)
                ws = ws + dt * jnp.where(m, sw, 0.0)
        return tuple(f[:, T:T + Yl, :] for f in (us, vs, ws))

    spec = P(None, axis, None)
    # pallas_call has no shard_map replication rule on this jax; the fused
    # local kernel therefore needs check_rep=False (outputs are fully
    # sharded along `axis` anyway, so nothing is lost)
    fn = shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                   out_specs=(spec, spec, spec),
                   check_rep=local_kernel != "fused")
    return jax.jit(fn)


def reference_global(u, v, w, params: AdvectParams):
    """Single-device oracle for the distributed version."""
    return pw_advect_ref(u, v, w, params)


def reference_global_step(u, v, w, params: AdvectParams, *, T: int = 1,
                          dt: float = 1.0):
    """Single-device T-substep oracle for `make_distributed_step`."""
    for _ in range(T):
        u, v, w = pw_step_ref(u, v, w, params, dt)
    return u, v, w

"""Distributed PW advection: the 2D-decomposed depth-T halo exchange, with
two interchangeable exchange engines and optional compute overlap.

Each shard of the (nx, ny) mesh owns an (X/nx, Y/ny, Z) slab
(`make_distributed_step(axis="y", x_axis="x")`; an axis of size 1 exchanges
nothing). ONE depth-T exchange serves T Euler substeps: each substep
contaminates one more halo row/plane, so depth-T halos are exactly consumed
after T substeps — the collective is amortised over T exactly like the HBM
pass the v4 fused kernel amortises. The exchange is two-phase, X-THEN-Y:
phase 1 trades depth-T x-planes of the raw shard along the x ring; phase 2
trades depth-T y-rows of the x-EXTENDED slab along the y ring. The corner
contract lives entirely in that ordering — a y-neighbour's x-extended rows
already contain its x-halo columns, so the four (T, T, Z) corner blocks
ride phase 2 and no diagonal (8-neighbour) communication is ever issued.
Reordering the phases (or exchanging y on the unextended slab) silently
zeroes the corners; the scaling2d benchmark's counted-vs-modelled wire-byte
gate and the corner regression test pin the contract. The wrapped ring is
periodic: halo data that wraps past the global edge is wrong by
construction and is frozen by the global-interior masks every engine
shares.

`exchange=` selects the transport for those bands (both engines move
byte-identical bands through byte-identical phases, so
`roofline.halo_wire_bytes_model` prices either):

  * ``"collective"`` — `lax.ppermute`, scheduled by XLA. Multi-hop: when T
    exceeds a shard's local extent, hop k is a distance-k ppermute fetching
    the k-away neighbour's share directly, so ceil(T/local) permutes per
    side move exactly T rows total. With `overlap=True` the interior pass
    has no data dependence on the permutes, so XLA *may* hide the exchange
    behind it — an opportunity, not a guarantee
    (`roofline.XLA_OVERLAP_DISCOUNT`).
  * ``"remote_dma"`` — the paper-faithful §IV endgame: the bands move by
    `pltpu.make_async_remote_copy` issued from INSIDE a Pallas kernel
    (`kernels.advection.advection.halo_band_exchange_dma`) into
    double-buffered recv slabs (slot = substep-block k % 2, so block k+1's
    bands land while block k computes). The kernel owns its issue/wait
    schedule instead of trusting XLA. Multi-hop like the collective
    engine: one `make_async_remote_copy` per `_band_schedule` hop, each
    landing at its recv-slab offset, so T beyond the local extent moves
    without an engine fallback. Compiled mode requires a TPU backend
    (Mosaic semaphores have no CPU lowering); in interpret mode the
    engine runs a schedule-faithful emulation — the same per-hop band
    messages and recv-slab assembly offsets (`_band_schedule`),
    transported by ppermute — which the tests and BENCH_overlap.json /
    BENCH_pipeline.json gate BITWISE-equal to the collective engine.

The slot parity is exploited by the pipelined multi-block driver
`make_distributed_run(n_blocks=K)`: ONE jitted program runs K
substep-blocks (K*T substeps) with the block counter threaded as a TRACED
`lax.fori_loop` induction variable into the engine's recv-slot selection,
so the step body is traced exactly once for any K and alternating parity
gives block k+1's bands a vacant recv slot to land in while block k's
interior pass computes. `roofline.pipeline_efficiency_model` prices that
INTENDED steady-state schedule; the traced body today still orders
exchange before compute within each block, so realising the cross-block
landing needs the boundary-first async continuation the ROADMAP lists —
the gates here are trace-once and bitwise equivalence, not measured
overlap.

`local_kernel="fused"` runs the per-shard slab update through the v4
Pallas kernel instead of the jnp reference loop, composing the depth-T
exchange with the kernel's in-grid `(y_tile, x)` tiling: the shard's slab
streams through ONE kernel launch whose VMEM register is bounded by
`y_tile` while the wrapped (periodic) halo rows/planes are frozen via the
kernel's `(x_interior_mask, y_interior_mask)` — the same global-interior
masks the reference loop applies per substep. Because `pallas_call` has no
shard_map replication rule on the pinned jax, any step using a Pallas
kernel per shard is built with ``check_rep=False``: outputs are fully
sharded along the mesh axes anyway so no replication information is lost,
but shard_map will no longer error if a future edit accidentally consumes
an unreduced value — the distributed equivalence tests are the guard.

`overlap=True` splits each shard's update into an interior pass (owned
slab only — no data dependence on any exchange, the §IV DMA/compute
overlap chip-to-chip) and a boundary pass on the halo'd slab; the T-deep
bands adjacent to a cut are then selected from the boundary pass,
everything else from the interior pass.
`roofline.overlap_efficiency_model` prices how much of the exchange each
engine hides behind that interior pass, and
`RooflineTerms.collective_exposed_s` is the wire time left on the critical
path — the quantity BENCH_overlap.json sweeps.

Runs under `shard_map` over any mesh axes (smoke-tested on the host mesh;
`launch.mesh.make_stencil_mesh` builds the (nx, ny) production shape).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.advection import advection as K
from repro.kernels.advection.ref import (AdvectParams, pw_advect_ref,
                                         pw_step_ref)
from repro.stencil import spec as SP

EXCHANGES = ("collective", "remote_dma")

# The per-hop band schedule lives in the kernels layer (`_kernel_band_dma`
# issues one `make_async_remote_copy` per entry); re-exported here because
# the ppermute emulation, the wire pricing and the tests all address recv
# slabs through it.
_band_schedule = K._band_schedule


class HaloCorrupted(RuntimeError):
    """A verified exchange received a band whose checksum mismatched: the
    moved bytes are not the sent bytes, so the fields downstream of the
    exchange are untrustworthy. Raised host-side by `check_integrity`
    (and the resilient driver) from the per-shard mismatch flags a
    `verify_integrity=True` step/run returns; the recovery contract is
    roll back to the last checkpoint and replay."""


def check_integrity(flags) -> None:
    """Raise `HaloCorrupted` if any shard's verified exchange counted a
    band checksum mismatch. `flags` is the uint32 mismatch-count array a
    `verify_integrity=True` step or run returns as its last output (one
    entry per shard; a run accumulates over its blocks)."""
    bad = int(np.sum(np.asarray(flags), dtype=np.uint64))
    if bad:
        raise HaloCorrupted(
            f"{bad} halo band checksum mismatch(es) across shards; the "
            f"exchanged fields are not trustworthy — roll back to the "
            f"last checkpoint and replay")


def _corrupt_band(g, dim: int, rows: int, value: float):
    """Fault-injection hook: overwrite the leading `rows` planes/rows of a
    RECEIVED band with `value` — damage on the wire, after the sender's
    checksum was computed, so a verified exchange must detect it."""
    idx = [slice(None)] * g.ndim
    idx[dim] = slice(0, rows)
    return g.at[tuple(idx)].set(value)


def _exchange_halos(f, axis: str, n: int, depth: int = 1, dim: int = 1,
                    *, integrity_out=None, corrupt=None):
    """Fetch `depth` rows (dim=1) or planes (dim=0) per side from the ring
    of shards on mesh axis `axis`. Returns (hi_from_prev, lo_from_next):
    hi = the `depth` rows just below my slab (tails of my predecessors),
    lo = the `depth` rows just above it (heads of my successors).

    Multi-hop: when `depth` exceeds the local extent L, hop k (a
    distance-k ppermute with a static pair table — `n` is passed in
    because `jax.lax.axis_size` does not exist on the pinned jax) fetches
    the k-away neighbour's share directly: hop 1 moves min(L, depth) rows,
    hop k moves min(L, depth-(k-1)L), so ceil(depth/L) permutes per side
    carry exactly `depth` rows total — bytes-on-wire are hop-count
    independent. The ring is periodic; rows that wrap past the global
    domain carry wrong data by construction and MUST be frozen by the
    caller's global-interior mask.

    Integrity (`integrity_out` a list): every band message additionally
    carries its `kernels.advection.band_checksum` word through the SAME
    permutation, the receiver recomputes the word over the received band,
    and one uint32 mismatch indicator per band is appended to the list —
    4 extra wire bytes per band (`roofline.integrity_bytes_model`),
    fields bit-untouched. `corrupt=(rows, value)` is the fault hook:
    damage the hop-1 received hi band AFTER the send-side checksum, as
    wire corruption would.
    """
    L = f.shape[dim]
    hops = -(-depth // L)

    def part(g, lo, hi):
        idx = [slice(None)] * g.ndim
        idx[dim] = slice(lo, hi)
        return g[tuple(idx)]

    hi_parts, lo_parts = [], []
    for k in range(1, hops + 1):
        cnt = min(L, depth - (k - 1) * L)
        fwd = [(i, (i + k) % n) for i in range(n)]
        bwd = [(i, (i - k) % n) for i in range(n)]
        # tail of the k-away predecessor -> me; head of the k-away successor
        hi_band, lo_band = part(f, L - cnt, L), part(f, 0, cnt)
        hi_recv = jax.lax.ppermute(hi_band, axis, fwd)
        lo_recv = jax.lax.ppermute(lo_band, axis, bwd)
        if integrity_out is not None:
            hi_ck = jax.lax.ppermute(K.band_checksum(hi_band), axis, fwd)
            lo_ck = jax.lax.ppermute(K.band_checksum(lo_band), axis, bwd)
        if corrupt is not None and k == 1:
            hi_recv = _corrupt_band(hi_recv, dim, min(corrupt[0], cnt),
                                    corrupt[1])
        if integrity_out is not None:
            integrity_out.append(
                (K.band_checksum(hi_recv) != hi_ck).astype(jnp.uint32))
            integrity_out.append(
                (K.band_checksum(lo_recv) != lo_ck).astype(jnp.uint32))
        hi_parts.append(hi_recv)
        lo_parts.append(lo_recv)
    if hops == 1:
        return hi_parts[0], lo_parts[0]
    # hi: farthest predecessor first so global coordinates stay ascending
    return (jnp.concatenate(hi_parts[::-1], axis=dim),
            jnp.concatenate(lo_parts, axis=dim))


def _exchange_remote_dma_emulated(f, axis: str, n: int, depth: int,
                                  dim: int, *, integrity_out=None,
                                  corrupt=None):
    """Interpret-mode transport for the `remote_dma` engine: the DMA
    kernel's exact schedule — one contiguous band message per (side, hop),
    each landing at its `_band_schedule` recv-slab offset in a
    zero-initialised extended slab — with `lax.ppermute` standing in for
    `make_async_remote_copy` (Mosaic semaphores have no CPU path). Wire
    accounting is unchanged: one ppermute operand per band message, so
    `count_exchange_wire_bytes` prices this engine identically to the
    collective one. Returns the extended slab directly (the engine owns
    its assembly, unlike `_exchange_halos`' (hi, lo) contract); the tests
    gate it bitwise-equal against the collective concatenation.

    `integrity_out` / `corrupt` mean what they mean on `_exchange_halos`:
    one checksum word rides each band message, the receiver verifies it
    after the (optional) injected wire damage to the hop-1 hi band.
    """
    L = f.shape[dim]

    def band(g, lo, hi):
        idx = [slice(None)] * g.ndim
        idx[dim] = slice(lo, hi)
        return g[tuple(idx)]

    ext_shape = list(f.shape)
    ext_shape[dim] += 2 * depth
    ext = jnp.zeros(tuple(ext_shape), f.dtype)

    def place(acc, buf, off):
        idx = [slice(None)] * acc.ndim
        idx[dim] = slice(off, off + buf.shape[dim])
        return acc.at[tuple(idx)].set(buf)

    ext = place(ext, f, depth)   # owned block
    for k, cnt, hi_off, lo_off in _band_schedule(L, depth):
        fwd = [(i, (i + k) % n) for i in range(n)]
        bwd = [(i, (i - k) % n) for i in range(n)]
        hi_band, lo_band = band(f, L - cnt, L), band(f, 0, cnt)
        hi_recv = jax.lax.ppermute(hi_band, axis, fwd)
        lo_recv = jax.lax.ppermute(lo_band, axis, bwd)
        if integrity_out is not None:
            hi_ck = jax.lax.ppermute(K.band_checksum(hi_band), axis, fwd)
            lo_ck = jax.lax.ppermute(K.band_checksum(lo_band), axis, bwd)
        if corrupt is not None and k == 1:
            hi_recv = _corrupt_band(hi_recv, dim, min(corrupt[0], cnt),
                                    corrupt[1])
        if integrity_out is not None:
            integrity_out.append(
                (K.band_checksum(hi_recv) != hi_ck).astype(jnp.uint32))
            integrity_out.append(
                (K.band_checksum(lo_recv) != lo_ck).astype(jnp.uint32))
        ext = place(ext, hi_recv, hi_off)
        ext = place(ext, lo_recv, lo_off)
    return ext


def remote_dma_schedule_wire_bytes(Xl: int, Yl: int, Z: int, itemsize: int,
                                   *, nx: int = 1, ny: int = 1,
                                   T: int = 1, n_fields: int = 3) -> int:
    """Per-shard sent bytes of the remote-DMA engine's actual schedule:
    the summed `_band_schedule` message sizes over both sides of the
    two-phase x-then-y exchange (phase 2 operands are x-EXTENDED — the
    corner blocks). Computed from the messages the engine issues, NOT from
    `roofline.halo_wire_bytes_model`'s closed form; the overlap tests and
    BENCH_overlap.json gate the two EXACTLY equal, pinning the DMA
    schedule to the priced model."""
    total = 0
    if nx > 1:
        total += sum(2 * cnt * Yl * Z
                     for _, cnt, _, _ in _band_schedule(Xl, T))
    x_ext = Xl + (2 * T if nx > 1 else 0)
    if ny > 1:
        total += sum(2 * cnt * x_ext * Z
                     for _, cnt, _, _ in _band_schedule(Yl, T))
    return total * n_fields * itemsize


def make_distributed_advect(mesh: Mesh, params: AdvectParams,
                            axis: str = "data"):
    """Returns jit(advect) over fields sharded (None, axis, None) in y.

    LEGACY rung: the original 1D depth-1 source-only exchange, kept as the
    minimal overlap exemplar. New work composes depth-T halos, the 2D
    x-then-y phases and the exchange engines via `make_distributed_step`.
    """

    n_shards = mesh.shape[axis]

    def local(u, v, w):
        """Per-shard: exchange halos, compute interior meanwhile, patch edges."""
        # 1) launch halo exchange (6 edge planes, tiny vs the slab)
        halos = [_exchange_halos(f, axis, n_shards) for f in (u, v, w)]
        # 2) interior compute — no dependence on `halos`, so XLA overlaps the
        #    collective-permutes with this stencil (the §IV overlap on ICI)
        interior = pw_advect_ref(u, v, w, params)
        # 3) boundary patch: rebuild the two edge y-bands with halo rows
        n = n_shards
        idx = jax.lax.axis_index(axis)

        def with_halo(f, h):
            prev_hi, next_lo = h
            return jnp.concatenate([prev_hi, f, next_lo], axis=1)

        uh, vh, wh = (with_halo(f, h) for f, h in zip((u, v, w), halos))
        full = pw_advect_ref(uh, vh, wh, params)
        band = [s[:, 1:-1, :] for s in full]   # drop halo rows back off
        # interior rows are identical; edge rows (y=0 / y=-1 of the slab) come
        # from the halo'd compute. For edge shards the global boundary stays 0.
        Y = u.shape[1]
        rows = jnp.arange(Y)
        is_edge_row = (rows < 1) | (rows >= Y - 1)
        gl = (idx == 0)
        gh = (idx == n - 1)
        glob_lo = gl & (rows < 1)
        glob_hi = gh & (rows >= Y - 1)
        keep_band = is_edge_row & ~(glob_lo | glob_hi)
        sel = keep_band[None, :, None]
        out = [jnp.where(sel, b, i) for b, i in zip(band, interior)]
        return tuple(out)

    spec = P(None, axis, None)
    fn = shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                   out_specs=(spec, spec, spec))
    return jax.jit(fn)


def _check_step_config(T: int, local_kernel: str, exchange: str,
                       interpret: bool) -> None:
    """Shared build-time validation for the step and run drivers."""
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    if local_kernel not in ("reference", "fused"):
        raise ValueError(f"local_kernel must be 'reference' or 'fused', "
                         f"got {local_kernel!r}")
    if exchange not in EXCHANGES:
        raise ValueError(f"exchange must be one of {EXCHANGES}, "
                         f"got {exchange!r}")
    if exchange == "remote_dma" and not interpret:
        backend = jax.default_backend()
        if backend != "tpu":
            raise RuntimeError(
                f"exchange='remote_dma' in compiled mode issues "
                f"pltpu.make_async_remote_copy from inside a Pallas kernel "
                f"and needs a TPU backend (Mosaic); this process is running "
                f"{backend!r}. Use exchange='collective', or interpret=True "
                "for the schedule-faithful emulation.")


def _check_integrity_config(verify_integrity: bool, corrupt_halo,
                            exchange: str, interpret: bool,
                            n_fields: int = 3) -> None:
    """Build-time validation of the integrity layer's knobs. `n_fields`
    bounds `corrupt_halo`'s field index — 3 (u, v, w) on the legacy
    path, `spec.n_fields` on a spec-driven build."""
    if exchange == "remote_dma" and not interpret:
        if verify_integrity:
            raise RuntimeError(
                "verify_integrity=True rides checksum words on the "
                "ppermute transports (collective engine and the "
                "remote-DMA emulation); the compiled Mosaic DMA kernel "
                "carries no checksum channel yet. Use interpret=True or "
                "exchange='collective'.")
        if corrupt_halo is not None:
            raise RuntimeError(
                "corrupt_halo injects wire damage in the ppermute "
                "transports; the compiled Mosaic DMA kernel has no "
                "injection hook. Use interpret=True.")
    if corrupt_halo is not None:
        fi, depth, _ = corrupt_halo
        if not (0 <= int(fi) < n_fields):
            raise ValueError(f"corrupt_halo field index must be "
                             f"0..{n_fields - 1}, got {fi}")
        if int(depth) < 1:
            raise ValueError(f"corrupt_halo depth must be >= 1, "
                             f"got {depth}")


def _flag_shape(x_axis: Optional[str]):
    """Per-shard shape of the integrity mismatch count (out_spec puts one
    entry per shard in the global array)."""
    return (1,) if x_axis is None else (1, 1)


def _build_local_block(mesh: Mesh, params: AdvectParams, *, axis: str,
                       x_axis: Optional[str], T: int, dt: float,
                       local_kernel: str, y_tile: Optional[int],
                       interpret: bool, overlap: bool, exchange: str,
                       verify_integrity: bool = False,
                       corrupt_halo=None):
    """The per-shard substep-block body shared by `make_distributed_step`
    (one block, static `dma_block_index`) and `make_distributed_run`
    (K blocks, the block counter a traced `fori_loop` induction variable
    feeding the remote-DMA engine's recv-slot parity). Returns
    ``local_block(u, v, w, block_index) -> (u, v, w)``, or with
    `verify_integrity` ``-> (u, v, w, mismatch)`` where `mismatch` is the
    shard's uint32 count of band-checksum mismatches this block
    (`_flag_shape`-shaped so `_wrap_shard_map` can lay one per shard).
    `corrupt_halo=(field_idx, rows, value)` injects wire damage into that
    field's hop-1 hi band on the LAST exchanged phase (y when y is
    decomposed, else x) — the detection path's fault hook.
    """
    n_y = mesh.shape[axis]
    n_x = mesh.shape[x_axis] if x_axis is not None else 1

    def _substeps(us, vs, ws, x_int, y_int, tile):
        """T masked Euler substeps on a (halo'd) slab; None mask = all-interior
        (the slab edge is then the true boundary, walled structurally)."""
        if local_kernel == "fused":
            return K.advect_fused(
                us, vs, ws, params, T=T, dt=dt, interpret=interpret,
                y_tile=tile,
                x_interior_mask=(None if x_int is None
                                 else x_int.astype(jnp.float32)),
                y_interior_mask=(None if y_int is None
                                 else y_int.astype(jnp.float32)))
        m = jnp.ones((), jnp.bool_)
        if x_int is not None:
            m = m & x_int[:, None, None]
        if y_int is not None:
            m = m & y_int[None, :, None]
        for _ in range(T):
            su, sv, sw = pw_advect_ref(us, vs, ws, params)
            us = us + dt * jnp.where(m, su, 0.0)
            vs = vs + dt * jnp.where(m, sv, 0.0)
            ws = ws + dt * jnp.where(m, sw, 0.0)
        return us, vs, ws

    def local_block(u, v, w, block_index):
        Xl, Yl, Z = u.shape
        X_g, Y_g = n_x * Xl, n_y * Yl
        dx = T if n_x > 1 else 0
        dy = T if n_y > 1 else 0
        if dy and T > Y_g - 2:
            raise ValueError(
                f"halo depth T={T} exceeds the decomposable global Y "
                f"extent ({Y_g} rows, interior {Y_g - 2}); lower T")
        if dx and T > X_g - 2:
            raise ValueError(
                f"halo depth T={T} exceeds the decomposable global X "
                f"extent ({X_g} planes, interior {X_g - 2}); lower T")
        if local_kernel == "fused" or (exchange == "remote_dma"
                                       and not interpret):
            # static VMEM budget: ring registers + DMA slabs summed
            # against VMEM_PER_CORE at trace time, so an over-budget
            # config fails BEFORE compile with the buffer named
            # (the analysis layer's vmem pass, generalising the
            # serving-only serving_max_batch check to every rung)
            from repro.analysis import vmem as _vmem
            _vmem.distributed_block_plan(
                (Xl, Yl, Z), T=T, itemsize=u.dtype.itemsize,
                local_kernel=local_kernel, exchange=exchange,
                interpret=interpret, y_tile=y_tile, nx=n_x, ny=n_y,
                context="distributed block").check()
        iy = jax.lax.axis_index(axis)
        ix = jax.lax.axis_index(x_axis) if dx else None

        # ---- integrity / fault-injection plumbing: one mismatch word per
        # verified band collects in `integrity_out`; `corrupt_halo` damage
        # lands on the LAST exchanged phase so it survives into the slab.
        integrity_out = [] if verify_integrity else None
        corrupt_dim = None
        if corrupt_halo is not None and (dx or dy):
            corrupt_dim = 1 if dy else 0

        # ---- two-phase exchange: x first, then y on the x-extended slab
        # (phase 2's rows carry phase 1's corner columns — see module doc).
        # `_extend` is the engine dispatch; every engine returns the same
        # extended slab, so the corner contract is engine-independent.
        def _extend(fields, ax_name, n, dim, cid):
            def _corrupt_for(fi):
                if corrupt_dim != dim or fi != int(corrupt_halo[0]):
                    return None
                return (int(corrupt_halo[1]), corrupt_halo[2])

            if exchange == "remote_dma":
                if interpret:
                    return tuple(
                        _exchange_remote_dma_emulated(
                            f, ax_name, n, T, dim,
                            integrity_out=integrity_out,
                            corrupt=(_corrupt_for(fi)
                                     if corrupt_halo is not None else None))
                        for fi, f in enumerate(fields))
                bands = K.halo_band_exchange_dma(
                    *fields, axis=ax_name, mesh_axes=mesh.axis_names,
                    n=n, depth=T, dim=dim, block_index=block_index,
                    collective_id=cid)
                return tuple(jnp.concatenate([hi, f, lo], axis=dim)
                             for f, (hi, lo) in zip(fields, bands))
            hs = [_exchange_halos(f, ax_name, n, depth=T, dim=dim,
                                  integrity_out=integrity_out,
                                  corrupt=(_corrupt_for(fi)
                                           if corrupt_halo is not None
                                           else None))
                  for fi, f in enumerate(fields)]
            return tuple(jnp.concatenate([h[0], f, h[1]], axis=dim)
                         for f, h in zip(fields, hs))

        def _with_flag(out):
            if not verify_integrity:
                return out
            mismatch = jnp.zeros((), jnp.uint32)
            for m in (integrity_out or []):
                mismatch = mismatch + m.reshape(())
            return out + (mismatch.reshape(_flag_shape(x_axis)),)

        fields = (u, v, w)
        if dx:
            fields = _extend(fields, x_axis, n_x, 0, 0)
        if dy:
            fields = _extend(fields, axis, n_y, 1, 1)

        # ---- global-interior masks over the slab coordinates
        x_int = y_int = None
        if dx:
            gx = ix * Xl - dx + jnp.arange(Xl + 2 * dx)
            x_int = (gx >= 1) & (gx <= X_g - 2)
        if dy:
            gy = iy * Yl - dy + jnp.arange(Yl + 2 * dy)
            y_int = (gy >= 1) & (gy <= Y_g - 2)

        # ---- boundary pass (consumes the exchange), trimmed to owned rows
        us, vs, ws = _substeps(*fields, x_int, y_int, y_tile)
        out = tuple(f[dx:dx + Xl, dy:dy + Yl, :] for f in (us, vs, ws))
        if not (overlap and (dx or dy)):
            return _with_flag(out)

        # ---- interior pass: owned slab only, no exchange dependence.
        # Shard-cut edges act as walls contaminating < T cells inward; the
        # select below discards exactly those bands.
        ox_int = oy_int = None
        if dx:
            ogx = ix * Xl + jnp.arange(Xl)
            ox_int = (ogx >= 1) & (ogx <= X_g - 2)
        if dy:
            ogy = iy * Yl + jnp.arange(Yl)
            oy_int = (ogy >= 1) & (ogy <= Y_g - 2)
        inner = _substeps(u, v, w, ox_int, oy_int, y_tile)
        sx = jnp.arange(Xl)
        ok_x = jnp.ones((Xl,), jnp.bool_) if not dx else (
            ((ix == 0) | (sx >= T)) & ((ix == n_x - 1) | (sx < Xl - T)))
        sy = jnp.arange(Yl)
        ok_y = jnp.ones((Yl,), jnp.bool_) if not dy else (
            ((iy == 0) | (sy >= T)) & ((iy == n_y - 1) | (sy < Yl - T)))
        sel = (ok_x[:, None] & ok_y[None, :])[:, :, None]
        return _with_flag(tuple(jnp.where(sel, i, b)
                                for i, b in zip(inner, out)))

    return local_block


def _wrap_shard_map(local, mesh: Mesh, axis: str, x_axis: Optional[str],
                    local_kernel: str, exchange: str, interpret: bool,
                    *, integrity: bool = False, n_scalars: int = 0,
                    check_rep_off: bool = False):
    """jit(shard_map(local)) with the repo's spec/check_rep conventions.

    `integrity` appends the per-shard mismatch flag to the out_specs
    (one `_flag_shape` entry per shard, laid out along the mesh axes);
    `n_scalars` appends replicated scalar inputs (the run core's traced
    block bounds); `check_rep_off` forces check_rep=False — the traced-
    bounds `fori_loop` lowers to `while`, which has no shard_map
    replication rule on the pinned jax.
    """
    spec = (P(None, axis, None) if x_axis is None
            else P(x_axis, axis, None))
    flag_spec = P(axis) if x_axis is None else P(x_axis, axis)
    # check_rep=False whenever a Pallas kernel runs per shard (the fused
    # local kernel, or the compiled remote-DMA exchange) — rationale in the
    # module docstring, documented once there.
    uses_pallas = (local_kernel == "fused"
                   or (exchange == "remote_dma" and not interpret))
    out_specs = (spec, spec, spec)
    if integrity:
        out_specs = out_specs + (flag_spec,)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(spec, spec, spec) + (P(),) * n_scalars,
                   out_specs=out_specs,
                   check_rep=not (uses_pallas or check_rep_off))
    return jax.jit(fn)


def _check_spec_step_config(spec, T: int, local_kernel: str, exchange: str,
                            interpret: bool, verify_integrity: bool = False,
                            corrupt_halo=None) -> None:
    """Build-time validation of the spec-driven distributed path."""
    _check_step_config(T, local_kernel, exchange, interpret)
    if not isinstance(spec, SP.StencilSpec):
        raise ValueError(f"spec must be a StencilSpec, got {type(spec)!r}")
    if exchange == "remote_dma" and not interpret:
        raise RuntimeError(
            "spec-driven steps have no compiled Mosaic DMA kernel yet (the "
            "hand-written halo_band_exchange_dma is 3-field advection-"
            "specific); use exchange='collective', or interpret=True for "
            "the schedule-faithful emulation.")
    # verify_integrity / corrupt_halo ride the ppermute transports, which
    # are field-count-generic (`band_checksum` works on any band) — the
    # knobs plumb straight through; only the field-index bound changes.
    _check_integrity_config(verify_integrity, corrupt_halo, exchange,
                            interpret, n_fields=spec.n_fields)


def _build_spec_local_block(mesh: Mesh, spec, spec_params, *, axis: str,
                            x_axis: Optional[str], T: int, dt: float,
                            local_kernel: str, y_tile: Optional[int],
                            interpret: bool, overlap: bool, exchange: str,
                            verify_integrity: bool = False,
                            corrupt_halo=None):
    """Spec-generalised per-shard substep-block body: `spec.n_fields`
    fields exchanged ONCE at depth `D = spec.halo(T) = radius*stages*T`
    per T integrator steps — `_build_local_block` with every halo=T and
    every 3-field literal replaced by the spec's radius, stage count and
    field tuple. Both ppermute transports are already field-count- and
    depth-generic, so the engines are reused unchanged; only the compiled
    Mosaic DMA kernel (3-field, advection-specific) is rejected at build
    time. Returns ``local_block(fields, block_index) -> fields``, or
    with `verify_integrity` ``-> fields + (mismatch,)`` — the
    checksummed exchange of `_build_local_block` at the spec's field
    count and depth (`corrupt_halo=(field_idx, rows, value)` is the
    matching fault hook; `integrity_bytes_model(n_fields=spec.n_fields,
    depth=spec.halo(T))` prices the extra words).
    """
    n_y = mesh.shape[axis]
    n_x = mesh.shape[x_axis] if x_axis is not None else 1
    r = spec.radius
    D = spec.halo(T)

    def _substeps(fields, x_int, y_int, tile):
        """T masked integrator steps on a (halo'd) slab; None mask =
        all-interior (slab edge then walls structurally, zero_source)."""
        if local_kernel == "fused":
            return K.stencil_fused(
                fields, spec_params, spec, T=T, dt=dt, interpret=interpret,
                y_tile=tile,
                x_interior_mask=(None if x_int is None
                                 else x_int.astype(jnp.float32)),
                y_interior_mask=(None if y_int is None
                                 else y_int.astype(jnp.float32)))
        m = jnp.ones((), jnp.bool_)
        if x_int is not None:
            m = m & x_int[:, None, None]
        if y_int is not None:
            m = m & y_int[None, :, None]
        half = 0.5 * dt
        for _ in range(T):
            if spec.integrator == "rk2":
                s0 = SP.spec_sources(fields, spec_params, spec)
                g = tuple(f + half * jnp.where(m, s, 0.0)
                          for f, s in zip(fields, s0))
                s1 = SP.spec_sources(g, spec_params, spec)
                fields = tuple(f + dt * jnp.where(m, s, 0.0)
                               for f, s in zip(fields, s1))
            else:
                srcs = SP.spec_sources(fields, spec_params, spec)
                fields = tuple(f + dt * jnp.where(m, s, 0.0)
                               for f, s in zip(fields, srcs))
        return fields

    def local_block(fields, block_index):
        del block_index  # no double-buffered DMA slots on the spec path yet
        Xl, Yl, Z = fields[0].shape
        X_g, Y_g = n_x * Xl, n_y * Yl
        dx = D if n_x > 1 else 0
        dy = D if n_y > 1 else 0
        if local_kernel == "fused":
            # static VMEM budget: refuse an over-budget ring at trace
            # time, naming the buffer (analysis layer's vmem pass)
            from repro.analysis import vmem as _vmem
            _vmem.distributed_block_plan(
                (Xl, Yl, Z), T=T, itemsize=fields[0].dtype.itemsize,
                local_kernel=local_kernel, exchange=exchange,
                interpret=interpret, y_tile=y_tile, nx=n_x, ny=n_y,
                spec=spec, context="spec-driven distributed block"
            ).check()
        if dy and D > Y_g - 2 * r:
            raise ValueError(
                f"halo depth spec.halo(T)={D} exceeds the decomposable "
                f"global Y extent ({Y_g} rows, interior {Y_g - 2 * r}); "
                f"lower T")
        if dx and D > X_g - 2 * r:
            raise ValueError(
                f"halo depth spec.halo(T)={D} exceeds the decomposable "
                f"global X extent ({X_g} planes, interior {X_g - 2 * r}); "
                f"lower T")
        iy = jax.lax.axis_index(axis)
        ix = jax.lax.axis_index(x_axis) if dx else None

        # ---- integrity / fault-injection plumbing (as in
        # `_build_local_block`): one mismatch word per verified band,
        # injected damage on the LAST exchanged phase.
        integrity_out = [] if verify_integrity else None
        corrupt_dim = None
        if corrupt_halo is not None and (dx or dy):
            corrupt_dim = 1 if dy else 0

        # ---- two-phase x-then-y exchange at depth D; same engine dispatch
        # and corner contract as `_build_local_block` (module docstring).
        def _extend(fs, ax_name, n, dim):
            def _corrupt_for(fi):
                if corrupt_dim != dim or fi != int(corrupt_halo[0]):
                    return None
                return (int(corrupt_halo[1]), corrupt_halo[2])

            if exchange == "remote_dma":
                return tuple(
                    _exchange_remote_dma_emulated(
                        f, ax_name, n, D, dim,
                        integrity_out=integrity_out,
                        corrupt=(_corrupt_for(fi)
                                 if corrupt_halo is not None else None))
                    for fi, f in enumerate(fs))
            hs = [_exchange_halos(f, ax_name, n, depth=D, dim=dim,
                                  integrity_out=integrity_out,
                                  corrupt=(_corrupt_for(fi)
                                           if corrupt_halo is not None
                                           else None))
                  for fi, f in enumerate(fs)]
            return tuple(jnp.concatenate([h[0], f, h[1]], axis=dim)
                         for f, h in zip(fs, hs))

        def _with_flag(out):
            if not verify_integrity:
                return out
            mismatch = jnp.zeros((), jnp.uint32)
            for m in (integrity_out or []):
                mismatch = mismatch + m.reshape(())
            return tuple(out) + (mismatch.reshape(_flag_shape(x_axis)),)

        ext = tuple(fields)
        if dx:
            ext = _extend(ext, x_axis, n_x, 0)
        if dy:
            ext = _extend(ext, axis, n_y, 1)

        # ---- global-interior masks: the wall is `radius` cells wide (a
        # radius-r stencil cannot carry values past r frozen cells).
        x_int = y_int = None
        if dx:
            gx = ix * Xl - dx + jnp.arange(Xl + 2 * dx)
            x_int = (gx >= r) & (gx <= X_g - 1 - r)
        if dy:
            gy = iy * Yl - dy + jnp.arange(Yl + 2 * dy)
            y_int = (gy >= r) & (gy <= Y_g - 1 - r)

        outs = _substeps(ext, x_int, y_int, y_tile)
        out = tuple(f[dx:dx + Xl, dy:dy + Yl, :] for f in outs)
        if not (overlap and (dx or dy)):
            return _with_flag(out)

        # ---- interior pass (no exchange dependence); shard-cut walls
        # contaminate < D cells inward, the select discards those bands.
        ox_int = oy_int = None
        if dx:
            ogx = ix * Xl + jnp.arange(Xl)
            ox_int = (ogx >= r) & (ogx <= X_g - 1 - r)
        if dy:
            ogy = iy * Yl + jnp.arange(Yl)
            oy_int = (ogy >= r) & (ogy <= Y_g - 1 - r)
        inner = _substeps(tuple(fields), ox_int, oy_int, y_tile)
        sx = jnp.arange(Xl)
        ok_x = jnp.ones((Xl,), jnp.bool_) if not dx else (
            ((ix == 0) | (sx >= D)) & ((ix == n_x - 1) | (sx < Xl - D)))
        sy = jnp.arange(Yl)
        ok_y = jnp.ones((Yl,), jnp.bool_) if not dy else (
            ((iy == 0) | (sy >= D)) & ((iy == n_y - 1) | (sy < Yl - D)))
        sel = (ok_x[:, None] & ok_y[None, :])[:, :, None]
        return _with_flag(tuple(jnp.where(sel, i, b)
                                for i, b in zip(inner, out)))

    return local_block


def _wrap_spec_shard_map(local, mesh: Mesh, axis: str,
                         x_axis: Optional[str], local_kernel: str,
                         n_fields: int, *, integrity: bool = False,
                         n_scalars: int = 0,
                         check_rep_off: bool = False):
    """`_wrap_shard_map` for an n-field spec program. `integrity`
    appends the per-shard mismatch flag to the out_specs — the same
    `_flag_shape` layout as the legacy path."""
    p = (P(None, axis, None) if x_axis is None else P(x_axis, axis, None))
    flag_spec = P(axis) if x_axis is None else P(x_axis, axis)
    uses_pallas = local_kernel == "fused"
    out_specs = (p,) * n_fields
    if integrity:
        out_specs = out_specs + (flag_spec,)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(p,) * n_fields + (P(),) * n_scalars,
                   out_specs=out_specs,
                   check_rep=not (uses_pallas or check_rep_off))
    return jax.jit(fn)


def make_distributed_step(mesh: Mesh, params: AdvectParams, *,
                          axis: str = "data", x_axis: Optional[str] = None,
                          T: int = 1, dt: float = 1.0,
                          local_kernel: str = "reference",
                          y_tile: Optional[int] = None,
                          interpret: bool = True,
                          overlap: bool = False,
                          exchange: str = "collective",
                          dma_block_index: int = 0,
                          verify_integrity: bool = False,
                          corrupt_halo=None,
                          spec=None, spec_params=None):
    """Returns jit(step): T Euler substeps per ONE depth-T halo exchange.

    `spec=` (a `stencil.spec.StencilSpec`, with `spec_params=` whatever its
    `pack_params` consumes) generalises the step beyond PW advection: the
    returned jit takes `spec.n_fields` slabs and the ONE exchange runs at
    depth `spec.halo(T) = radius * stages * T` — deeper stencils and the
    RK2 integrator simply exchange deeper, through the same two-phase
    engines (`params` is ignored; pass the spec's params via
    `spec_params`). The spec path rejects the compiled Mosaic DMA kernel
    and the integrity knobs at build time (`_check_spec_step_config`).

    `axis` is the mesh axis decomposing y. With `x_axis` the step runs on a
    2D (x, y) device mesh — each shard owns an (X/nx, Y/ny, Z) slab and the
    exchange is the two-phase x-then-y ordering described in the module
    docstring (corners ride phase 2; no diagonal sends). An axis of size 1
    exchanges nothing along that direction.

    Every exchange engine's wrapped ring is periodic, so shards at the
    global edges receive wrapped (wrong) halo data — but every substep
    masks the source to zero outside the *global* interior, and a depth-1
    stencil cannot carry values past an unchanging row: the global-boundary
    row is a wall, the wrapped rows never contaminate the trimmed result.
    The same mask argument lifts the old T <= local-extent restriction on
    BOTH engines: multi-hop `_exchange_halos` / `halo_band_exchange_dma`
    fetch arbitrarily deep halos, so the only hard bound left is
    T <= global extent - 2 along each decomposed axis (beyond that no
    interior cell exists whose depth-T cone the ring can serve).

    `exchange` selects the band transport (module docstring): "collective"
    is XLA-scheduled ppermute; "remote_dma" issues the bands from inside a
    Pallas kernel via `pltpu.make_async_remote_copy` in compiled mode
    (TPU-only — any other backend raises RuntimeError at build time;
    multi-hop via one remote copy per `_band_schedule` hop, so T is
    bounded only by the global extent like the collective engine) and
    runs the schedule-faithful ppermute emulation in interpret mode
    (bitwise-equal to "collective" — the gate CI runs). `dma_block_index`
    is the substep block number k, selecting the engine's double-buffered
    recv slot (k % 2) DYNAMICALLY — alternating parity never retraces;
    `make_distributed_run` threads a traced counter through K blocks in
    one program so block k+1's bands land beside block k's.

    `local_kernel` selects the per-shard slab update: "reference" is the
    jnp T-substep loop; "fused" streams the slab through the v4 Pallas
    kernel (one HBM pass for all T substeps), passing the global-interior
    masks as the kernel's `(x_interior_mask, y_interior_mask)` and
    composing with the kernel's in-grid `(y_tile, x)` tiling via `y_tile`
    — the shard slab keeps a VMEM-bounded register no matter how wide the
    shard is.

    `overlap=True` additionally computes the halo-independent interior of
    each shard in a pass that consumes NO exchange output, so it can run
    concurrently with both exchange phases (the paper's §IV DMA/compute
    overlap, chip-to-chip); only the T-deep boundary bands then wait on
    the exchange. The boundary pass covers the whole slab (the repo's
    established overlap idiom, cf. `make_distributed_advect`) — the cost
    is one extra local pass, the win is that the exchange latency is
    hidden behind a full interior update; how much is hidden per engine is
    `roofline.overlap_efficiency_model`'s business.

    Wire cost: T rows per neighbour per exchange (per `roofline.
    halo_wire_bytes_model`, identical for both engines), so bytes-on-wire
    per substep are flat in T while the exchange *count* falls as 1/T —
    latency-bound small halos amortise T×.

    `verify_integrity=True` rides a `kernels.advection.band_checksum`
    uint32 word on every band message of both ppermute transports and
    returns a FOURTH output: the per-shard mismatch count (pass to
    `check_integrity` to raise `HaloCorrupted`). The fields are
    bit-untouched — the verified step is BITWISE-equal to the unchecked
    one on clean wires, and the extra bytes are priced by
    `roofline.integrity_bytes_model` / counted by
    `count_integrity_bytes` (both gated in BENCH_recovery.json).
    `corrupt_halo=(field_idx, rows, value)` is the matching fault hook:
    wire damage to one received band, injected after the send-side
    checksum so a verified step MUST flag it. Both knobs need the
    ppermute transports (interpret mode or the collective engine); the
    compiled Mosaic DMA path rejects them at build time.
    """
    if spec is not None:
        _check_spec_step_config(spec, T, local_kernel, exchange, interpret,
                                verify_integrity, corrupt_halo)
        spec_block = _build_spec_local_block(
            mesh, spec, spec_params, axis=axis, x_axis=x_axis, T=T, dt=dt,
            local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
            overlap=overlap, exchange=exchange,
            verify_integrity=verify_integrity, corrupt_halo=corrupt_halo)

        def spec_local(*fields):
            return spec_block(fields, dma_block_index)

        return _wrap_spec_shard_map(spec_local, mesh, axis, x_axis,
                                    local_kernel, spec.n_fields,
                                    integrity=verify_integrity)
    _check_integrity_config(verify_integrity, corrupt_halo, exchange,
                            interpret)
    _check_step_config(T, local_kernel, exchange, interpret)
    local_block = _build_local_block(
        mesh, params, axis=axis, x_axis=x_axis, T=T, dt=dt,
        local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
        overlap=overlap, exchange=exchange,
        verify_integrity=verify_integrity, corrupt_halo=corrupt_halo)

    def local(u, v, w):
        return local_block(u, v, w, dma_block_index)

    return _wrap_shard_map(local, mesh, axis, x_axis, local_kernel,
                           exchange, interpret, integrity=verify_integrity)


def _make_run_core(mesh: Mesh, params: AdvectParams, *, axis: str,
                   x_axis: Optional[str], T: int, dt: float,
                   local_kernel: str, y_tile: Optional[int],
                   interpret: bool, overlap: bool, exchange: str,
                   verify_integrity: bool):
    """The span-generic run program: ``core(u, v, w, start, end)`` runs
    blocks [start, end) with BOTH bounds traced, so one trace serves the
    full run, every checkpoint interval, and every resume continuation —
    interval boundaries never retrace and the per-block wire/integrity
    counts stay span-independent (the trace-once gate). Traced bounds
    lower `fori_loop` to `while`, hence `check_rep_off` (see
    `_wrap_shard_map`). With `verify_integrity` the core returns a fourth
    output: per-shard mismatch counts ACCUMULATED over the span.
    """
    _check_integrity_config(verify_integrity, None, exchange, interpret)
    _check_step_config(T, local_kernel, exchange, interpret)
    local_block = _build_local_block(
        mesh, params, axis=axis, x_axis=x_axis, T=T, dt=dt,
        local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
        overlap=overlap, exchange=exchange,
        verify_integrity=verify_integrity)

    def local(u, v, w, start, end):
        if verify_integrity:
            def body(k, carry):
                uu, vv, ww, m = local_block(carry[0], carry[1], carry[2], k)
                return (uu, vv, ww, carry[3] + m)
            init = (u, v, w, jnp.zeros(_flag_shape(x_axis), jnp.uint32))
        else:
            def body(k, carry):
                return local_block(*carry, k)
            init = (u, v, w)
        return jax.lax.fori_loop(start, end, body, init)

    return _wrap_shard_map(local, mesh, axis, x_axis, local_kernel,
                           exchange, interpret, integrity=verify_integrity,
                           n_scalars=2, check_rep_off=True)


def _run_state(u, v, w, block: int, flags) -> dict:
    """The checkpoint leaf dict: sharded fields host-gathered, plus the
    logical block index and the recv-slot parity the remote-DMA engine's
    double buffering depends on (stored redundantly — `resume` refuses a
    checkpoint whose parity disagrees with its block index)."""
    state = {"u": np.asarray(u), "v": np.asarray(v), "w": np.asarray(w),
             "block": np.int64(block), "parity": np.int64(block % 2)}
    if flags is not None:
        state["mismatches"] = np.asarray(flags, dtype=np.uint32)
    return state


def _checkpointed_segments(core, checkpoint_dir, u, v, w, *, start: int,
                           n_blocks: int, every: int, verify: bool,
                           flags, keep_last: int, save_initial: bool):
    """Drive `core` over [start, n_blocks) in `every`-block segments,
    checkpointing at each boundary (and the final block) through
    `training.checkpoint`'s atomic writes. `flags` carries the mismatch
    counts accumulated BEFORE `start` (restored on resume) so the
    resumed run's flag output equals the uninterrupted run's."""
    from repro.training import checkpoint as CKPT

    if verify and flags is None:
        raise ValueError("verify requires restored-or-zero flags")
    if save_initial:
        CKPT.save(checkpoint_dir, _run_state(u, v, w, start, flags),
                  start, keep_last=keep_last)
    b = start
    while b < n_blocks:
        e = min(b + every, n_blocks)
        out = core(u, v, w, b, e)
        if verify:
            u, v, w, fl = out
            flags = np.asarray(flags + np.asarray(fl), dtype=np.uint32)
        else:
            u, v, w = out
        b = e
        CKPT.save(checkpoint_dir, _run_state(u, v, w, b, flags), b,
                  keep_last=keep_last)
    if verify:
        return u, v, w, jnp.asarray(flags)
    return u, v, w


def make_distributed_run(mesh: Mesh, params: AdvectParams, *,
                         n_blocks: int, axis: str = "data",
                         x_axis: Optional[str] = None,
                         T: int = 1, dt: float = 1.0,
                         local_kernel: str = "reference",
                         y_tile: Optional[int] = None,
                         interpret: bool = True,
                         overlap: bool = False,
                         exchange: str = "collective",
                         verify_integrity: bool = False,
                         checkpoint_every: Optional[int] = None,
                         checkpoint_dir=None,
                         keep_last: int = 3,
                         spec=None, spec_params=None):
    """Returns run(u, v, w): `n_blocks` substep-blocks (n_blocks * T Euler
    substeps, ONE depth-T exchange per block) in ONE traced program — the
    pipelined multi-block driver the remote-DMA engine's double-buffered
    recv slabs exist for.

    The block counter is a `lax.fori_loop` induction variable threaded —
    TRACED — into the exchange engine (`dma_block_index` in the one-block
    `make_distributed_step`): the remote-DMA engine's recv-slot parity is
    selected dynamically per block (`lax.rem`-indexed, SMEM `step_ref` in
    the kernel), so alternating parity across blocks costs NO retrace or
    recompile — the step body appears exactly once in the jaxpr for any
    `n_blocks`, and block k+1's bands always have a vacant recv slot to
    land in while block k's interior pass computes. The loop BOUNDS are
    traced too (`_make_run_core`), so the checkpointing driver below runs
    every interval through the same single trace.
    `roofline.pipeline_efficiency_model` prices that INTENDED schedule
    (one fill block, steady-state hidden fraction); scope honesty: the
    traced body still orders exchange before compute within a block, so
    the cross-block landing is what the parity/slots make POSSIBLE, not
    yet what XLA is forced to do — the boundary-first async continuation
    is the ROADMAPped follow-on, and `benchmarks/pipeline_sweep.py` gates
    what IS delivered: one trace for all K blocks and bitwise
    equivalence. Semantics are exactly K sequential
    `make_distributed_step` calls with `dma_block_index = 0..K-1` —
    bitwise, the acceptance gate.

    `verify_integrity` adds the checksummed exchange of
    `make_distributed_step` to every block; the run returns a fourth
    output accumulating the per-shard mismatch counts over all blocks.

    `checkpoint_every=k` with `checkpoint_dir=` turns the returned run
    into a host-side driver that snapshots the sharded (u, v, w) plus the
    logical block index and recv-slot parity through
    `training.checkpoint`'s atomic writes at every k-block boundary (and
    block 0 and the final block), `keep_last` bounding disk. A run killed
    mid-way resumes via `resume_distributed_run` BITWISE-equal to the
    uninterrupted run (the BENCH_recovery.json gate) because every
    segment replays through the same traced core with the restored block
    index feeding the recv-slot parity. Without checkpointing the
    returned run is a pure jitted program (traceable — the byte-counting
    gates `jax.make_jaxpr` it).

    All other arguments mean what they mean on `make_distributed_step`.
    """
    if n_blocks < 1:
        raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
    if (checkpoint_every is None) != (checkpoint_dir is None):
        raise ValueError("checkpoint_every and checkpoint_dir come "
                         "together: both or neither")
    if spec is not None:
        if checkpoint_every is not None:
            raise ValueError(
                "checkpointing is not wired to the spec-driven run yet "
                "(the snapshot leaf dict is (u, v, w)-specific); run "
                "without spec= or without checkpoint_every=")
        _check_spec_step_config(spec, T, local_kernel, exchange, interpret,
                                verify_integrity, None)
        spec_block = _build_spec_local_block(
            mesh, spec, spec_params, axis=axis, x_axis=x_axis, T=T, dt=dt,
            local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
            overlap=overlap, exchange=exchange,
            verify_integrity=verify_integrity)

        def spec_local(*args):
            fields, start, end = args[:-2], args[-2], args[-1]

            if verify_integrity:
                def body(k, carry):
                    out = spec_block(carry[:-1], k)
                    return out[:-1] + (carry[-1] + out[-1],)
                init = tuple(fields) + (
                    jnp.zeros(_flag_shape(x_axis), jnp.uint32),)
            else:
                def body(k, carry):
                    return spec_block(carry, k)
                init = tuple(fields)
            return jax.lax.fori_loop(start, end, body, init)

        spec_core = _wrap_spec_shard_map(
            spec_local, mesh, axis, x_axis, local_kernel, spec.n_fields,
            integrity=verify_integrity, n_scalars=2, check_rep_off=True)

        def spec_run(*fields):
            return spec_core(*fields, 0, n_blocks)
        return spec_run
    core = _make_run_core(
        mesh, params, axis=axis, x_axis=x_axis, T=T, dt=dt,
        local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
        overlap=overlap, exchange=exchange,
        verify_integrity=verify_integrity)

    if checkpoint_every is None:
        def run(u, v, w):
            return core(u, v, w, 0, n_blocks)
        return run

    if checkpoint_every < 1:
        raise ValueError(f"checkpoint_every must be >= 1, "
                         f"got {checkpoint_every}")
    flag0 = (np.zeros(_global_flag_shape(mesh, axis, x_axis), np.uint32)
             if verify_integrity else None)

    def run_ck(u, v, w):
        return _checkpointed_segments(
            core, checkpoint_dir, u, v, w, start=0, n_blocks=n_blocks,
            every=checkpoint_every, verify=verify_integrity, flags=flag0,
            keep_last=keep_last, save_initial=True)
    return run_ck


def _global_flag_shape(mesh: Mesh, axis: str, x_axis: Optional[str]):
    return ((mesh.shape[axis],) if x_axis is None
            else (mesh.shape[x_axis], mesh.shape[axis]))


def resume_distributed_run(mesh: Mesh, params: AdvectParams, u, v, w, *,
                           n_blocks: int, checkpoint_dir,
                           checkpoint_every: Optional[int] = None,
                           step: Optional[int] = None,
                           axis: str = "data",
                           x_axis: Optional[str] = None,
                           T: int = 1, dt: float = 1.0,
                           local_kernel: str = "reference",
                           y_tile: Optional[int] = None,
                           interpret: bool = True,
                           overlap: bool = False,
                           exchange: str = "collective",
                           verify_integrity: bool = False,
                           keep_last: int = 3):
    """Restore the latest (or `step=`) checkpoint a checkpointing
    `make_distributed_run` wrote under `checkpoint_dir` and continue to
    `n_blocks`, returning what the uninterrupted run would have —
    BITWISE (the BENCH_recovery.json gate): the restored block index
    feeds the recv-slot parity through the same traced core, so replayed
    intervals are the intervals the dead run would have executed.

    (u, v, w) are templates for structure/dtype only — their VALUES are
    replaced by the restored snapshot (restoring from the block-0
    checkpoint replays the whole run). `checkpoint_every=None` continues
    in one segment, still writing the final checkpoint. A checkpoint
    whose stored recv-slot parity disagrees with its block index (or
    whose manifest step disagrees with the stored block) is refused with
    a ValueError naming the inconsistency rather than resumed into a
    silently wrong parity. Build arguments must match the original run's.
    """
    from repro.training import checkpoint as CKPT

    if n_blocks < 1:
        raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
    core = _make_run_core(
        mesh, params, axis=axis, x_axis=x_axis, T=T, dt=dt,
        local_kernel=local_kernel, y_tile=y_tile, interpret=interpret,
        overlap=overlap, exchange=exchange,
        verify_integrity=verify_integrity)
    like = _run_state(u, v, w, 0,
                      np.zeros(_global_flag_shape(mesh, axis, x_axis),
                               np.uint32) if verify_integrity else None)
    state, disk_step = CKPT.restore(checkpoint_dir, like, step=step)
    block = int(state["block"])
    parity = int(state["parity"])
    if parity != block % 2:
        raise ValueError(
            f"checkpoint step {disk_step} under {checkpoint_dir} is "
            f"inconsistent: stored recv-slot parity {parity} != block "
            f"{block} % 2; refusing to resume into a wrong DMA slot")
    if disk_step != block:
        raise ValueError(
            f"checkpoint step {disk_step} under {checkpoint_dir} stores "
            f"block index {block}; refusing to resume an inconsistent "
            f"snapshot")
    u, v, w = (jnp.asarray(state["u"]), jnp.asarray(state["v"]),
               jnp.asarray(state["w"]))
    flags = (np.asarray(state["mismatches"], dtype=np.uint32)
             if verify_integrity else None)
    if block >= n_blocks:
        if verify_integrity:
            return u, v, w, jnp.asarray(flags)
        return u, v, w
    every = checkpoint_every if checkpoint_every else n_blocks - block
    if every < 1:
        raise ValueError(f"checkpoint_every must be >= 1, got {every}")
    return _checkpointed_segments(
        core, checkpoint_dir, u, v, w, start=block, n_blocks=n_blocks,
        every=every, verify=verify_integrity, flags=flags,
        keep_last=keep_last, save_initial=False)


# The jaxpr traversal and byte attribution live in `repro.analysis` now
# (ONE walker instead of four copies); re-exported under the old private
# names so existing callers and tests need no edits, and the four
# counters below are thin wrappers whose values are byte-identical to
# the pre-refactor implementations (the BENCH gates are the regression
# test; tests/test_analysis_ledger.py pins the equivalence directly).
from repro.analysis.jaxpr import iter_jaxprs as _iter_jaxprs  # noqa: E402
from repro.analysis.ledger import (  # noqa: E402
    MovementLedger as _MovementLedger,
    count_ppermute_bytes as _count_ppermute_bytes)


def count_exchange_wire_bytes(fn, *args) -> int:
    """Per-shard FIELD bytes `fn` puts on the wire: the summed sizes of
    every rank >= 3 `ppermute` operand in its (recursively walked) jaxpr.

    Inside `shard_map` tracing shapes are per-shard, so each ppermute
    operand is exactly one shard's send buffer. This covers BOTH interpret
    engines — the collective exchange and the remote-DMA emulation, whose
    band messages are one ppermute operand each. Rank >= 3 selects
    exactly the (x, y, z) band payloads; the rank-1 uint32 checksum words
    a `verify_integrity=True` program additionally permutes are counted
    by `count_integrity_bytes` instead, so THIS count is identical with
    verification on or off — itself a BENCH_recovery.json gate (the
    integrity layer may not change what the band model prices). The
    compiled remote-DMA kernel's transfers live inside a `pallas_call`
    and are priced instead by `remote_dma_schedule_wire_bytes` (the same
    `_band_schedule` message sizes the kernel issues), which the overlap
    tests pin to `roofline.halo_wire_bytes_model` exactly. This function
    is the measured counterpart of that model; the scaling2d and overlap
    benchmarks gate the two against each other exactly.

    On a `make_distributed_run` program the `fori_loop` body jaxpr is
    walked ONCE, so the count is the PER-BLOCK wire bytes independent of
    `n_blocks` — which is itself the pipeline benchmark's trace-once
    gate: a driver that unrolled or retraced per block would count K
    times the model.
    """
    return _MovementLedger.of(fn, *args).total("ppermute_wire")


def count_integrity_bytes(fn, *args) -> int:
    """Per-shard CHECKSUM bytes `fn` puts on the wire: the summed sizes
    of every rank < 3 `ppermute` operand in its (recursively walked)
    jaxpr — the `(1,)`-shaped uint32 `band_checksum` words the verified
    exchange rides on each band message, and nothing else (field bands
    are rank 3; `count_exchange_wire_bytes` owns them). Zero on an
    unverified program. The measured counterpart of
    `roofline.integrity_bytes_model`; BENCH_recovery.json gates the two
    equal EXACTLY, per block even on a `make_distributed_run` program
    (the fori body is walked once — same trace-once argument as the wire
    count)."""
    return _MovementLedger.of(fn, *args).total("integrity_words")


def count_pallas_hbm_bytes(fn, *args) -> int:
    """HBM bytes `fn`'s Pallas kernels stream: the summed sizes of every
    rank->=3 operand and result of each `pallas_call` in its (recursively
    walked) jaxpr.

    Rank >= 3 selects exactly the field arrays — the (X, Y, Z) /
    slot-stacked (B, X, Y, Z) inputs the kernel reads once and the outputs
    it writes once. The O(X + Y + Z) control operands (the packed
    coefficient vectors and the interior masks) are deliberately excluded:
    they are scalar-pipeline traffic the analytic model never charged.
    For the fused kernel on lane-aligned Z this count equals
    ``kernels.advection.hbm_bytes_model(..., "fused", grid_tiled=True)``
    EXACTLY (and the batched mega-launch counts B times that) — the
    measured counterpart of the model, gated in BENCH_serving.json the
    way `count_exchange_wire_bytes` is gated in BENCH_scaling2d.json.

    The ledger splits the guard pass's field re-read into its own
    category; this counter keeps the legacy semantics (EVERY
    pallas_call's rank >= 3 operands, guard included), so it sums the
    `pallas_hbm` and `guard_field_reads` categories.
    """
    return _MovementLedger.of(fn, *args).total(
        "pallas_hbm", "guard_field_reads")


def count_guard_bytes(fn, *args) -> int:
    """HBM bytes of the finite-guard pass: for every `pallas_call` in
    `fn`'s (recursively walked) jaxpr whose results are ALL rank < 3 —
    the guard kernel's signature; flags are (X,) / vmapped (B, X) while
    every field-moving kernel emits rank >= 3 results — sum the sizes of
    its operands AND results: the field re-read plus the flag words.

    The advection kernels proper are never miscounted (their field
    results are rank >= 3, counted by `count_pallas_hbm_bytes` and
    untouched by guarding), so this isolates exactly the detection
    traffic. Gated in BENCH_faults.json against
    `roofline.guard_bytes_model` EXACTLY — the recovery tier's detection
    traffic priced under the same model-equals-counted discipline as the
    field and wire bytes.
    """
    return _MovementLedger.of(fn, *args).total(
        "guard_field_reads", "guard_flag_words")


def reference_global(u, v, w, params: AdvectParams):
    """Single-device oracle for the distributed version."""
    return pw_advect_ref(u, v, w, params)


def reference_global_step(u, v, w, params: AdvectParams, *, T: int = 1,
                          dt: float = 1.0):
    """Single-device T-substep oracle for `make_distributed_step`."""
    for _ in range(T):
        u, v, w = pw_step_ref(u, v, w, params, dt)
    return u, v, w


def reference_global_spec_step(fields, spec_params, spec, *, T: int = 1,
                               dt: float = 1.0):
    """Single-device T-step oracle for the spec-driven distributed step:
    `spec_multistep`'s zero_source wall is exactly the global-interior
    mask every shard applies, so the sharded program must reproduce this
    BITWISE for any mesh shape."""
    return SP.spec_multistep(fields, spec_params, spec, T, dt)

"""Distributed PW advection: halo exchange overlapped with interior compute.

The paper's §IV overlap (DMA chunks vs kernel pool) maps chip-to-chip on TPU:
the y-decomposed domain needs depth-1 halos, exchanged with
`lax.ppermute` while the *interior* — which needs no halo — computes.
The data dependence is structured so XLA can schedule the collective-permute
concurrently with the interior stencil (interior result does not consume the
permuted edges), then the two boundary y-rows are patched.

Runs under `shard_map` over the `data` axis of any mesh (smoke-tested on the
host mesh; the production mesh shards y 16-way per pod).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.advection.ref import AdvectParams, pw_advect_ref


def _exchange_halos(f, axis: str):
    """Send my edge y-rows to neighbours; receive theirs. Returns (lo, hi).

    lo = neighbour's last row (goes below my slab), hi = neighbour's first.
    """
    n = jax.lax.axis_size(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    hi_from_prev = jax.lax.ppermute(f[:, -1:, :], axis, fwd)   # my top -> next
    lo_from_next = jax.lax.ppermute(f[:, :1, :], axis, bwd)    # my bottom -> prev
    return hi_from_prev, lo_from_next


def make_distributed_advect(mesh: Mesh, params: AdvectParams,
                            axis: str = "data"):
    """Returns jit(advect) over fields sharded (None, axis, None) in y."""

    def local(u, v, w):
        """Per-shard: exchange halos, compute interior meanwhile, patch edges."""
        # 1) launch halo exchange (6 edge planes, tiny vs the slab)
        halos = [_exchange_halos(f, axis) for f in (u, v, w)]
        # 2) interior compute — no dependence on `halos`, so XLA overlaps the
        #    collective-permutes with this stencil (the §IV overlap on ICI)
        interior = pw_advect_ref(u, v, w, params)
        # 3) boundary patch: rebuild the two edge y-bands with halo rows
        n = jax.lax.axis_size(axis)
        idx = jax.lax.axis_index(axis)

        def with_halo(f, h):
            prev_hi, next_lo = h
            return jnp.concatenate([prev_hi, f, next_lo], axis=1)

        uh, vh, wh = (with_halo(f, h) for f, h in zip((u, v, w), halos))
        full = pw_advect_ref(uh, vh, wh, params)
        band = [s[:, 1:-1, :] for s in full]   # drop halo rows back off
        # interior rows are identical; edge rows (y=0 / y=-1 of the slab) come
        # from the halo'd compute. For edge shards the global boundary stays 0.
        Y = u.shape[1]
        rows = jnp.arange(Y)
        is_edge_row = (rows < 1) | (rows >= Y - 1)
        gl = (idx == 0)
        gh = (idx == n - 1)
        glob_lo = gl & (rows < 1)
        glob_hi = gh & (rows >= Y - 1)
        keep_band = is_edge_row & ~(glob_lo | glob_hi)
        sel = keep_band[None, :, None]
        out = [jnp.where(sel, b, i) for b, i in zip(band, interior)]
        return tuple(out)

    spec = P(None, axis, None)
    fn = shard_map(local, mesh=mesh, in_specs=(spec, spec, spec),
                   out_specs=(spec, spec, spec))
    return jax.jit(fn)


def reference_global(u, v, w, params: AdvectParams):
    """Single-device oracle for the distributed version."""
    return pw_advect_ref(u, v, w, params)

"""Stencil-spec frontend: the operator-parameterised temporal-blocking engine.

The fused ring kernel, band schedule, masks and exchange engines were all
hard-wired to one Piacsek-Williams advection operator; the MONC port
(arXiv:2010.01545) shows advection was only the first and hottest of many
cloud-model kernels needing the same data-movement machinery, and the
follow-up study (arXiv:2107.13500) confirms the dataflow recast transfers
when the operator is parameterised. `StencilSpec` is that parameterisation:

  - per-field stencil offsets (the dependence star; `radius` = max |offset|
    component bounds the ring width and the halo growth per substep),
  - a boundary condition (``zero_source``: the outermost `radius` cells
    never receive a source — exactly the wall behaviour of the hand-written
    ladder),
  - a source-term callback `source(sh, pv)` written against an abstract
    accessor `sh(field_index, dx, dy, dz)`, so the SAME arithmetic runs on
    3-D array views (the jnp reference below) and on the fused kernel's
    2-D VMEM ring slices (`kernels.advection.stencil_fused`),
  - an integrator (`euler` or midpoint `rk2` — RK2 runs INSIDE the ring:
    two ring levels per substep, so the halo deepens at `radius * 2` per
    step and `spec.halo(T) = radius * stages * T` is the single number the
    kernel ring depth, the analytic byte models and the distributed
    exchange depth all consume).

The Piacsek-Williams spec (`pw_advection_spec`) reproduces the hand-written
`advect_fused` BITWISE (gated in tests/test_stencil_spec.py and
benchmarks/stencil_sweep.py): its callback mirrors `_source_slices`
term-by-term, so the spec frontend is a generalisation, not a fork.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.advection.ref import AdvectParams

INTEGRATORS = ("euler", "rk2")
BOUNDARIES = ("zero_source",)


def _check_offset(field: str, off) -> Tuple[int, int, int]:
    if not (isinstance(off, tuple) and len(off) == 3):
        raise ValueError(
            f"field {field!r}: offset {off!r} must be a 3-tuple of ints")
    for c in off:
        # bools are ints in Python; reject them (an offset of True is a bug)
        if not isinstance(c, int) or isinstance(c, bool):
            raise ValueError(
                f"field {field!r}: offset {off!r} must be a 3-tuple of ints "
                f"(component {c!r} is {type(c).__name__})")
    return off


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """One stencil operator: what the temporal-blocking engine needs to know.

    `source(sh, pv)` returns one interior source slab per field, where
    `sh(fi, dx, dy, dz)` yields field `fi` shifted by the offset (views
    trimmed by `radius` on every axis — 3-D in the reference, (rows, Z)
    2-D ring slices in the kernel) and `pv` is `pack_params(params)`: a
    tuple of 1-D vectors broadcast along the LAST (z) axis only, so the
    identical callback traces in both worlds. Offsets are declarative
    metadata validated here; the accessor re-checks every `sh` call stays
    within the declared radius.
    """
    name: str
    fields: Tuple[str, ...]
    offsets: Mapping[str, Tuple[Tuple[int, int, int], ...]]
    source: Callable
    pack_params: Callable
    boundary: str = "zero_source"
    integrator: str = "euler"

    def __post_init__(self):
        if not self.fields or not isinstance(self.fields, tuple):
            raise ValueError(
                f"fields must be a non-empty tuple of names, "
                f"got {self.fields!r}")
        seen = set()
        for f in self.fields:
            if not isinstance(f, str) or not f:
                raise ValueError(f"field name {f!r} must be a non-empty str")
            if f in seen:
                raise ValueError(f"duplicate field name {f!r}")
            seen.add(f)
        for f in self.fields:
            if f not in self.offsets:
                raise ValueError(f"field {f!r} has no stencil offsets")
        for f in self.offsets:
            if f not in seen:
                raise ValueError(
                    f"offsets name unknown field {f!r} "
                    f"(declared fields: {self.fields})")
        for f, offs in self.offsets.items():
            if not offs:
                raise ValueError(f"field {f!r}: offsets must be non-empty")
            for off in offs:
                _check_offset(f, off)
        if self.boundary not in BOUNDARIES:
            raise ValueError(
                f"boundary must be one of {BOUNDARIES}, "
                f"got {self.boundary!r}")
        if self.integrator not in INTEGRATORS:
            raise ValueError(
                f"integrator must be one of {INTEGRATORS}, "
                f"got {self.integrator!r}")
        if not callable(self.source):
            raise ValueError("source must be callable")
        if not callable(self.pack_params):
            raise ValueError("pack_params must be callable")
        if self.radius < 1:
            raise ValueError(
                "spec must have at least one nonzero offset (radius >= 1); "
                "a pointwise operator needs no ring")

    @property
    def n_fields(self) -> int:
        return len(self.fields)

    @property
    def radius(self) -> int:
        """Max |offset component| over every field — the ring half-width."""
        return max(abs(c) for offs in self.offsets.values()
                   for off in offs for c in off)

    @property
    def stages(self) -> int:
        """Ring levels consumed per substep (1 euler, 2 rk2)."""
        return 2 if self.integrator == "rk2" else 1

    def halo(self, T: int) -> int:
        """Halo/exchange/contamination depth of T fused substeps.

        Each ring level advances the dependence cone by `radius`; the
        integrator spends `stages` levels per substep — so T substeps
        need `radius * stages * T` halo cells, the single depth that the
        fused kernel's startup masks, `_band_schedule`'s exchange bands
        and the analytic byte models all share.
        """
        if T < 1:
            raise ValueError(f"T must be >= 1, got {T}")
        return self.radius * self.stages * T


def checked_accessor(spec: StencilSpec, raw_sh: Callable) -> Callable:
    """Wrap an `sh` accessor with the spec's declared-radius contract:
    a callback reaching past `spec.radius` on any axis is a spec bug, and
    the error names the field and the offending offset."""
    r = spec.radius

    def sh(fi, dx, dy, dz):
        if max(abs(dx), abs(dy), abs(dz)) > r:
            raise ValueError(
                f"field {spec.fields[fi]!r}: source reads offset "
                f"({dx}, {dy}, {dz}) beyond the declared radius {r}")
        return raw_sh(fi, dx, dy, dz)

    return sh


# ---------------------------------------------------------------------------
# full-array jnp reference (the oracle the kernels are differenced against)
# ---------------------------------------------------------------------------


def spec_sources(fields, params, spec: StencilSpec):
    """Full-array source terms: one (X, Y, Z) array per field, interior
    computed, outermost `radius` cells zero (the ``zero_source`` wall)."""
    fields = tuple(fields)
    if len(fields) != spec.n_fields:
        raise ValueError(
            f"spec {spec.name!r} has {spec.n_fields} fields "
            f"({spec.fields}), got {len(fields)} arrays")
    r = spec.radius
    X, Y, Z = fields[0].shape

    def raw_sh(fi, dx, dy, dz):
        f = fields[fi]
        return f[r + dx:X - r + dx, r + dy:Y - r + dy, r + dz:Z - r + dz]

    pv = spec.pack_params(params)
    srcs = spec.source(checked_accessor(spec, raw_sh), pv)
    if len(srcs) != spec.n_fields:
        raise ValueError(
            f"spec {spec.name!r} source returned {len(srcs)} slabs for "
            f"{spec.n_fields} fields")
    return tuple(jnp.pad(s, ((r, r), (r, r), (r, r))) for s in srcs)


def spec_step(fields, params, spec: StencilSpec, dt: float = 1.0):
    """One integrator step of the spec: euler `f + dt*S(f)` or midpoint
    rk2 `f + dt*S(f + (dt/2)*S(f))`, sources walled to zero at the
    boundary ring exactly as the fused kernel's masks do."""
    fields = tuple(fields)
    if spec.integrator == "euler":
        srcs = spec_sources(fields, params, spec)
        return tuple(f + dt * s for f, s in zip(fields, srcs))
    half = 0.5 * dt
    g = tuple(f + half * s for f, s in
              zip(fields, spec_sources(fields, params, spec)))
    srcs = spec_sources(g, params, spec)
    return tuple(f + dt * s for f, s in zip(fields, srcs))


def spec_multistep(fields, params, spec: StencilSpec, T: int,
                   dt: float = 1.0):
    fields = tuple(fields)
    for _ in range(T):
        fields = spec_step(fields, params, spec, dt)
    return fields


def spec_multistep_ref_f64(fields, params, spec: StencilSpec, T: int,
                           dt: float = 1.0):
    """T spec steps in genuine float64 — the oracle bounding every lower
    dtype's accumulated error (the jnp.asarray conversions happen INSIDE
    enable_x64; outside they silently downcast, cf. ref._with_f64)."""
    f_np = [np.asarray(t, np.float64) for t in fields]
    p_np = jax.tree_util.tree_map(lambda t: np.asarray(t, np.float64),
                                  params)
    with jax.experimental.enable_x64():
        f64 = tuple(jnp.asarray(t) for t in f_np)
        p64 = jax.tree_util.tree_map(jnp.asarray, p_np)
        out = spec_multistep(f64, p64, spec, T, dt)
        return tuple(np.asarray(t, np.float64) for t in out)


def spec_flops_per_cell(spec: StencilSpec, params) -> int:
    """Jaxpr-measured add/sub/mul per interior cell of one source pass
    (all ops are per-cell elementwise by construction; `params` must be
    built for the probe Z below)."""
    import collections
    n = _PROBE_N
    args = [jnp.zeros((n, n, n), jnp.float32)] * spec.n_fields
    jaxpr = jax.make_jaxpr(
        lambda *fs: spec_sources(fs, params, spec))(*args)
    counts = collections.Counter(str(e.primitive) for e in jaxpr.jaxpr.eqns)
    return sum(counts[k] for k in ("add", "sub", "mul"))


_PROBE_N = 4  # probe grid edge for spec_flops_per_cell (>= 2*radius + 2)


# ---------------------------------------------------------------------------
# operator specs
# ---------------------------------------------------------------------------

_STAR = ((0, 0, 0), (-1, 0, 0), (1, 0, 0),
         (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1))


def _pw_pack(p: AdvectParams):
    """Pack scalars + z-metrics into (Z+2,) vectors — the exact layout the
    hand-written kernels stream, so the spec path's param traffic and
    arithmetic are identical to theirs."""
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    return (t1, t2)


def _pw_flux_source(sh, pv, n_out: int):
    """PW flux-form sources for fields 0..n_out-1 advected by the velocity
    fields 0/1/2 — mirrors `_source_slices` term-by-term (operand order
    included) so the spec-driven kernel is BITWISE-equal to the
    hand-written one for the 3-velocity case."""
    t1, t2 = pv
    tcx = 0.0 + t1[0]
    tcy = t1[1]
    tzc1 = t1[2:][1:-1]
    tzc2 = t2[2:][1:-1]

    def source(fi):
        fx = tcx * (sh(0, -1, 0, 0) * (sh(fi, 0, 0, 0) + sh(fi, -1, 0, 0))
                    - sh(0, 1, 0, 0) * (sh(fi, 0, 0, 0) + sh(fi, 1, 0, 0)))
        fy = tcy * (sh(1, 0, -1, 0) * (sh(fi, 0, 0, 0) + sh(fi, 0, -1, 0))
                    - sh(1, 0, 1, 0) * (sh(fi, 0, 0, 0) + sh(fi, 0, 1, 0)))
        fz = (tzc1 * sh(2, 0, 0, -1) * (sh(fi, 0, 0, 0) + sh(fi, 0, 0, -1))
              - tzc2 * sh(2, 0, 0, 1) * (sh(fi, 0, 0, 0) + sh(fi, 0, 0, 1)))
        return fx + fy + fz

    return tuple(source(fi) for fi in range(n_out))


def _pw_source(sh, pv):
    return _pw_flux_source(sh, pv, 3)


def _tracer_source(sh, pv):
    return _pw_flux_source(sh, pv, 4)


def pw_advection_spec(integrator: str = "euler") -> StencilSpec:
    """The Piacsek-Williams momentum advection operator — the paper's
    kernel, as a spec. With `integrator="euler"` the fused spec kernel is
    gated bitwise-equal to the hand-written `advect_fused`."""
    return StencilSpec(
        name="pw_advection" if integrator == "euler"
        else f"pw_advection_{integrator}",
        fields=("u", "v", "w"),
        offsets={"u": _STAR, "v": _STAR, "w": _STAR},
        source=_pw_source, pack_params=_pw_pack,
        integrator=integrator)


def tracer_advection_spec(integrator: str = "euler") -> StencilSpec:
    """Scalar-tracer advection riding the velocity rings: a fourth field
    `q` advected by (u, v, w) in the same PW flux form — the MONC
    multi-kernel amortisation story's first extra passenger (one exchange
    and one HBM pass now serve FOUR fields)."""
    return StencilSpec(
        name="tracer_advection" if integrator == "euler"
        else f"tracer_advection_{integrator}",
        fields=("u", "v", "w", "q"),
        offsets={"u": _STAR, "v": _STAR, "w": _STAR, "q": _STAR},
        source=_tracer_source, pack_params=_pw_pack,
        integrator=integrator)


class DiffusionParams(NamedTuple):
    kx: jax.Array   # scalar: nu / dx^2
    ky: jax.Array   # scalar: nu / dy^2
    kz: jax.Array   # (Z,): per-level nu / dz(k)^2 (stretched grid)


def default_diffusion_params(Z: int, dx: float = 100.0, dy: float = 100.0,
                             dz: float = 40.0, nu: float = 50.0,
                             dtype=jnp.float32) -> DiffusionParams:
    k = np.arange(Z, dtype=np.float64)
    dzk = dz * (1.0 + 0.001 * k)
    return DiffusionParams(
        jnp.asarray(nu / dx ** 2, dtype), jnp.asarray(nu / dy ** 2, dtype),
        jnp.asarray(nu / dzk ** 2, dtype))


def _diff_pack(p: DiffusionParams):
    return (jnp.concatenate([p.kx[None], p.ky[None], p.kz]),)


def _diff_source(sh, pv):
    (t,) = pv
    kx = t[0]
    ky = t[1]
    kz = t[2:][1:-1]
    c = sh(0, 0, 0, 0)
    lap = (kx * (sh(0, -1, 0, 0) - 2.0 * c + sh(0, 1, 0, 0))
           + ky * (sh(0, 0, -1, 0) - 2.0 * c + sh(0, 0, 1, 0))
           + kz * (sh(0, 0, 0, -1) - 2.0 * c + sh(0, 0, 0, 1)))
    return (lap,)


def diffusion_spec(integrator: str = "euler") -> StencilSpec:
    """3D diffusion (7-point Laplacian, per-level z metric): one field —
    the n_fields=1 end of the frontier the engine must span."""
    return StencilSpec(
        name="diffusion3d" if integrator == "euler"
        else f"diffusion3d_{integrator}",
        fields=("phi",),
        offsets={"phi": _STAR},
        source=_diff_source, pack_params=_diff_pack,
        integrator=integrator)


# ---------------------------------------------------------------------------
# deterministic initial fields for the new operators (hash-pinned in tests)
# ---------------------------------------------------------------------------


def tracer_field(X: int, Y: int, Z: int, seed: int = 3,
                 dtype=jnp.float32):
    """Deterministic smooth tracer blob + seeded noise (the q companion to
    `stratus_fields`; content-hash pinned by tests/test_seed_determinism)."""
    rng = np.random.default_rng(seed)
    kx = np.linspace(0, 2 * np.pi, X)[:, None, None]
    ky = np.linspace(0, 2 * np.pi, Y)[None, :, None]
    kz = np.linspace(0, np.pi, Z)[None, None, :]
    q = 1.0 + 0.5 * np.sin(kx) * np.sin(ky + 0.2) * np.cos(kz)
    q += 0.01 * rng.normal(size=q.shape)
    return jnp.asarray(q, dtype)


def diffusion_field(X: int, Y: int, Z: int, seed: int = 7,
                    dtype=jnp.float32):
    """Deterministic initial temperature-like field for the diffusion
    operator (content-hash pinned by tests/test_seed_determinism)."""
    rng = np.random.default_rng(seed)
    kx = np.linspace(0, 2 * np.pi, X)[:, None, None]
    ky = np.linspace(0, 2 * np.pi, Y)[None, :, None]
    kz = np.linspace(0, np.pi, Z)[None, None, :]
    phi = 300.0 + 2.0 * np.cos(kx + 0.1) * np.sin(ky) * np.sin(kz + 0.3)
    phi += 0.01 * rng.normal(size=phi.shape)
    return jnp.asarray(phi, dtype)

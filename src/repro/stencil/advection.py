"""Domain-level PW advection: the paper's application, end to end.

`AdvectionDomain` owns the (X, Y, Z) wind fields and steps them with any of
the kernel-ladder variants (jnp reference = the paper's CPU baseline;
Pallas blocked/dataflow/wide = the FPGA kernel stages). The stratus-cloud
test-case initialisation mirrors the paper's standard MONC case sizes
(Fig. 8: 1M .. 268M grid points at z=64).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.advection import advection as K
from repro.kernels.advection import ref as REF

VARIANTS = ("reference", "blocked", "dataflow", "wide")

# the paper's experiment grid sizes (Fig. 8), (x, y, z)
PAPER_GRIDS = {
    "1M": (16, 1024, 64),
    "4M": (64, 1024, 64),
    "16M": (256, 1024, 64),   # Fig. 3/5 use 512x512x64 = 16.7M
    "67M": (1024, 1024, 64),
    "268M": (4096, 1024, 64),
}


def stratus_fields(X: int, Y: int, Z: int, seed: int = 0,
                   dtype=jnp.float32) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Smooth, divergence-ish wind fields standing in for the stratus case."""
    rng = np.random.default_rng(seed)
    kx = np.linspace(0, 2 * np.pi, X)[:, None, None]
    ky = np.linspace(0, 2 * np.pi, Y)[None, :, None]
    kz = np.linspace(0, np.pi, Z)[None, None, :]
    u = 5.0 * np.sin(kx + 0.5) * np.cos(ky) * np.sin(kz + 0.1)
    v = 4.0 * np.cos(kx) * np.sin(ky + 0.3) * np.sin(kz)
    w = 0.5 * np.sin(kx) * np.sin(ky) * np.cos(kz)
    for f in (u, v, w):
        f += 0.01 * rng.normal(size=f.shape)
    return tuple(jnp.asarray(f, dtype) for f in (u, v, w))


@dataclasses.dataclass
class AdvectionDomain:
    X: int
    Y: int
    Z: int
    variant: str = "dataflow"
    interpret: bool = True
    dtype: str = "float32"

    def __post_init__(self):
        self.params = REF.default_params(self.Z, dtype=jnp.dtype(self.dtype))

    def kernel(self) -> Callable:
        p = self.params
        v = self.variant
        if v == "reference":
            fn = lambda u, vv, w: REF.pw_advect_ref(u, vv, w, p)
        elif v == "blocked":
            fn = lambda u, vv, w: K.advect_blocked(u, vv, w, p,
                                                   interpret=self.interpret)
        elif v == "dataflow":
            fn = lambda u, vv, w: K.advect_dataflow(u, vv, w, p,
                                                    interpret=self.interpret)
        elif v == "wide":
            fn = lambda u, vv, w: K.advect_wide(u, vv, w, p,
                                                interpret=self.interpret)
        else:
            raise ValueError(v)
        return jax.jit(fn)

    def init(self, seed: int = 0):
        return stratus_fields(self.X, self.Y, self.Z, seed,
                              jnp.dtype(self.dtype))

    def sources(self, u, v, w):
        return self.kernel()(u, v, w)

    def step(self, u, v, w, dt: float = 1.0):
        """One explicit-Euler advection update (the model timestep's kernel)."""
        su, sv, sw = self.sources(u, v, w)
        return u + dt * su, v + dt * sv, w + dt * sw

    def flops_per_step(self) -> int:
        cells = (self.X - 2) * (self.Y - 2) * (self.Z - 2)
        return cells * REF.flops_per_cell()

    def hbm_bytes_per_step(self) -> int:
        return K.hbm_bytes_model(self.X, self.Y, self.Z,
                                 jnp.dtype(self.dtype).itemsize,
                                 self.variant if self.variant != "reference"
                                 else "pointwise")

"""Domain-level PW advection: the paper's application, end to end.

`AdvectionDomain` owns the (X, Y, Z) wind fields and steps them with any of
the kernel-ladder variants (jnp reference = the paper's CPU baseline;
Pallas blocked/dataflow/wide/fused = the FPGA kernel stages v1-v4). The
stratus-cloud test-case initialisation mirrors the paper's standard MONC
case sizes (Fig. 8: 1M .. 268M grid points at z=64). A (mesh_nx, mesh_ny)
configuration additionally prices the 2D-decomposed distributed step —
per-shard HBM pass, depth-T exchange wire bytes, and (via `exchange` /
`overlap`) how much of that exchange the configured engine hides behind
the interior pass (`roofline_terms().collective_exposed_s`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import roofline as R
from repro.kernels.advection import advection as K
from repro.kernels.advection import ref as REF

VARIANTS = ("reference", "blocked", "dataflow", "wide", "fused")

# the paper's experiment grid sizes (Fig. 8), (x, y, z)
PAPER_GRIDS = {
    "1M": (16, 1024, 64),
    "4M": (64, 1024, 64),
    "16M": (256, 1024, 64),   # Fig. 3/5 use 512x512x64 = 16.7M
    "67M": (1024, 1024, 64),
    "268M": (4096, 1024, 64),
}


def stratus_fields(X: int, Y: int, Z: int, seed: int = 0,
                   dtype=jnp.float32) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Smooth, divergence-ish wind fields standing in for the stratus case."""
    rng = np.random.default_rng(seed)
    kx = np.linspace(0, 2 * np.pi, X)[:, None, None]
    ky = np.linspace(0, 2 * np.pi, Y)[None, :, None]
    kz = np.linspace(0, np.pi, Z)[None, None, :]
    u = 5.0 * np.sin(kx + 0.5) * np.cos(ky) * np.sin(kz + 0.1)
    v = 4.0 * np.cos(kx) * np.sin(ky + 0.3) * np.sin(kz)
    w = 0.5 * np.sin(kx) * np.sin(ky) * np.cos(kz)
    for f in (u, v, w):
        f += 0.01 * rng.normal(size=f.shape)
    return tuple(jnp.asarray(f, dtype) for f in (u, v, w))


@dataclasses.dataclass(frozen=True)
class AdvectionDomain:
    """Frozen: the jitted kernel is memoized on first use, so mutable config
    would silently run a stale kernel. Use dataclasses.replace to vary."""
    X: int
    Y: int
    Z: int
    variant: str = "dataflow"
    interpret: bool = True
    dtype: str = "float32"
    fuse_T: int = 4                   # fused (v4): Euler steps per HBM pass
    y_tile: Optional[int] = None      # y-tiles (VMEM-bounded register)
    tiling: str = "grid"              # "grid": in-grid (y_tile, x) 2D grid;
                                      # "host": retained per-block loop
    fuse_update: bool = False         # v1-v3: fold f + dt*s into the kernel
    dt: float = 1.0
    mesh_nx: int = 1                  # 2D (x, y) mesh decomposition shape,
    mesh_ny: int = 1                  # for the per-shard accounting below
                                      # (step() itself stays single-shard;
                                      # make_distributed_step runs the mesh)
    exchange: str = "collective"      # halo-band transport engine and
    overlap: bool = False             # interior/boundary split, for the
                                      # overlap-efficiency accounting below
    n_blocks: int = 1                 # substep-blocks per pipelined
                                      # make_distributed_run program
                                      # (1 = the one-block step)
    batch: int = 1                    # serving-tier slots: independent
                                      # domains of this shape packed into
                                      # one mega-launch. Pure per-tenant
                                      # ACCOUNTING — step() stays
                                      # single-domain; the flops/bytes/wire
                                      # methods and vmem_register_bytes
                                      # scale by it, and
                                      # serving_throughput() prices the
                                      # packed launch in domains/s

    def __post_init__(self):
        if self.exchange not in ("collective", "remote_dma"):
            raise ValueError(f"exchange must be 'collective' or "
                             f"'remote_dma', got {self.exchange!r}")
        if self.n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {self.n_blocks}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        object.__setattr__(self, "params",
                           REF.default_params(self.Z,
                                              dtype=jnp.dtype(self.dtype)))
        object.__setattr__(self, "_kernel", None)

    def kernel(self) -> Callable:
        """Jitted kernel for the configured variant, built once: jit caches
        by function identity, so rebuilding per call would retrace (and
        re-lower the Pallas kernel) on every step."""
        if self._kernel is not None:
            return self._kernel
        p = self.params
        v = self.variant
        if v == "reference":
            if self.fuse_update:
                fn = lambda u, vv, w: REF.pw_step_ref(u, vv, w, p, self.dt)
            else:
                fn = lambda u, vv, w: REF.pw_advect_ref(u, vv, w, p)
        elif v in ("blocked", "dataflow", "wide"):
            kern = {"blocked": K.advect_blocked, "dataflow": K.advect_dataflow,
                    "wide": K.advect_wide}[v]
            fn = lambda u, vv, w: kern(u, vv, w, p,
                                       interpret=self.interpret,
                                       y_tile=self.y_tile,
                                       tiling=self.tiling,
                                       fuse_update=self.fuse_update,
                                       dt=self.dt)
        elif v == "fused":
            fn = lambda u, vv, w: K.advect_fused(u, vv, w, p, T=self.fuse_T,
                                                 dt=self.dt,
                                                 interpret=self.interpret,
                                                 y_tile=self.y_tile,
                                                 tiling=self.tiling)
        else:
            raise ValueError(v)
        object.__setattr__(self, "_kernel", jax.jit(fn))
        return self._kernel

    def init(self, seed: int = 0):
        return stratus_fields(self.X, self.Y, self.Z, seed,
                              jnp.dtype(self.dtype))

    def sources(self, u, v, w):
        if self.variant == "fused":
            raise ValueError("fused advances fields in-kernel; use step()")
        if self.fuse_update:
            raise ValueError("fuse_update kernels advance fields in-kernel; "
                             "use step()")
        return self.kernel()(u, v, w)

    def step(self, u, v, w, dt: Optional[float] = None):
        """One advection update. For `fused` (and the v1-v3 rungs with
        `fuse_update=True`) this is the fast path: the kernel advances the
        fields in a single HBM pass with dt baked in (dt override is
        rejected there), instead of writing sources and paying an extra
        full-field read at update time."""
        if self.variant == "fused" or self.fuse_update:
            if dt is not None and dt != self.dt:
                raise ValueError("the fused-update kernel bakes dt in; set "
                                 "AdvectionDomain(dt=...) instead")
            return self.kernel()(u, v, w)
        dt = self.dt if dt is None else dt
        su, sv, sw = self.sources(u, v, w)
        return u + dt * su, v + dt * sv, w + dt * sw

    def substeps_per_step(self) -> int:
        """Euler substeps one step() call advances (T for fused, else 1)."""
        return self.fuse_T if self.variant == "fused" else 1

    def advance(self, u, v, w, n_substeps: int):
        """Run `n_substeps` Euler substeps, using the fused fast path in
        chunks of `fuse_T` when the variant supports it."""
        per = self.substeps_per_step()
        if n_substeps % per:
            raise ValueError(f"n_substeps={n_substeps} not a multiple of "
                             f"fuse_T={per}")
        for _ in range(n_substeps // per):
            u, v, w = self.step(u, v, w)
        return u, v, w

    def flops_per_step(self) -> int:
        cells = (self.X - 2) * (self.Y - 2) * (self.Z - 2)
        return (cells * REF.flops_per_cell() * self.substeps_per_step()
                * self.batch)

    def _hbm_bytes_pass(self, X: int, Y: int) -> int:
        """One kernel pass over an (X, Y, Z) extent on the configured
        execution path — the single pricing point `hbm_bytes_per_step`
        (global) and `hbm_bytes_per_shard_step` (halo'd shard slab) share,
        so the two can never desynchronise."""
        fused_upd = self.variant == "fused" or self.fuse_update
        return K.hbm_bytes_model(X, Y, self.Z,
                                 jnp.dtype(self.dtype).itemsize,
                                 self.variant if self.variant != "reference"
                                 else "pointwise",
                                 T=self.substeps_per_step(),
                                 y_tile=self.y_tile,
                                 grid_tiled=self.tiling == "grid",
                                 fuse_update=fused_upd)

    def hbm_bytes_per_step(self) -> int:
        """Modelled HBM bytes per step() call (fused: per T-step pass).

        Prices the configured execution path: in-grid vs host tiling, and
        whether the Euler update is fused in-kernel or paid as a separate
        full-field pass (always separate for `reference`). A `batch` > 1
        charges every packed slot's pass — slots share nothing.
        """
        return self._hbm_bytes_pass(self.X, self.Y) * self.batch

    def vmem_halo_bytes_per_step(self) -> int:
        """Halo re-read bytes served from VMEM by the in-grid tiled path."""
        if self.tiling != "grid":
            return 0
        return K.vmem_halo_bytes_model(self.X, self.Y, self.Z,
                                       jnp.dtype(self.dtype).itemsize,
                                       self.variant
                                       if self.variant != "reference"
                                       else "pointwise",
                                       T=self.substeps_per_step(),
                                       y_tile=self.y_tile) * self.batch

    def shard_shape(self) -> Tuple[int, int]:
        """Owned (Xl, Yl) per-shard dims on the (mesh_nx, mesh_ny) mesh."""
        if self.mesh_nx < 1 or self.mesh_ny < 1:
            raise ValueError(f"mesh shape must be >= 1, got "
                             f"({self.mesh_nx}, {self.mesh_ny})")
        if self.X % self.mesh_nx or self.Y % self.mesh_ny:
            raise ValueError(
                f"grid ({self.X}, {self.Y}) not divisible by mesh "
                f"({self.mesh_nx}, {self.mesh_ny}); shard_map requires "
                "even shards")
        return self.X // self.mesh_nx, self.Y // self.mesh_ny

    def hbm_bytes_per_shard_step(self) -> int:
        """Per-shard HBM bytes per step(): the kernel pass over the halo'd
        (Xl+2T, Yl+2T, Z) shard slab `make_distributed_step` streams — the
        quantity that must FALL as the mesh grows for the 268M grid to
        become per-device feasible (the scaling2d gate)."""
        Xl, Yl = self.shard_shape()
        T = self.substeps_per_step()
        Xs = Xl + (2 * T if self.mesh_nx > 1 else 0)
        Ys = Yl + (2 * T if self.mesh_ny > 1 else 0)
        return self._hbm_bytes_pass(Xs, Ys) * self.batch

    def halo_wire_bytes_per_step(self) -> int:
        """Per-shard wire bytes for the ONE depth-T exchange a distributed
        step() performs (zero on a 1x1 mesh), per packed batch slot."""
        return R.halo_wire_bytes_model(self.X, self.Y, self.Z,
                                       jnp.dtype(self.dtype).itemsize,
                                       nx=self.mesh_nx, ny=self.mesh_ny,
                                       T=self.substeps_per_step()) * self.batch

    def overlap_efficiency(self) -> float:
        """Modelled fraction of the depth-T exchange the configured engine
        hides behind the halo-independent interior pass
        (`roofline.overlap_efficiency_model` over this domain's shard
        geometry). 0.0 on a 1x1 mesh or with overlap=False."""
        if self.mesh_nx * self.mesh_ny == 1:
            return 0.0
        Xl, Yl = self.shard_shape()
        frac = R.interior_compute_fraction(Xl, Yl, self.substeps_per_step(),
                                           nx=self.mesh_nx, ny=self.mesh_ny)
        return R.overlap_efficiency_model(overlap=self.overlap,
                                          exchange=self.exchange,
                                          interior_fraction=frac)

    def pipeline_efficiency(self) -> float:
        """Per-block hidden fraction over an `n_blocks`-block pipelined
        run (`roofline.pipeline_efficiency_model` over this domain's
        shard geometry): the remote-DMA engine's cross-block
        double-buffered hiding pays one pipeline-fill block, the
        collective engine's within-block figure is K-independent. Equals
        `overlap_efficiency()` for the collective engine; 0.0 on a 1x1
        mesh, with overlap=False, or for an isolated remote-DMA block
        (n_blocks=1 — its kernel serialises its own waits)."""
        if self.mesh_nx * self.mesh_ny == 1:
            return 0.0
        Xl, Yl = self.shard_shape()
        frac = R.interior_compute_fraction(Xl, Yl, self.substeps_per_step(),
                                           nx=self.mesh_nx, ny=self.mesh_ny)
        return R.pipeline_efficiency_model(n_blocks=self.n_blocks,
                                           overlap=self.overlap,
                                           exchange=self.exchange,
                                           interior_fraction=frac)

    def roofline_terms(self) -> R.RooflineTerms:
        """Three-term roofline of one distributed step() on the configured
        (mesh_nx, mesh_ny) mesh, with the exchange bytes feeding
        ``collective_s`` and the engine's overlap efficiency splitting it
        into hidden vs exposed seconds. With `n_blocks > 1` the split uses
        the pipelined per-block efficiency (`pipeline_efficiency`) — the
        terms then price one block of the `make_distributed_run` program;
        `n_blocks=1` keeps the single-block `overlap_efficiency` figure
        (back-compat: BENCH_overlap's ladder)."""
        n_dev = self.mesh_nx * self.mesh_ny
        eff = (self.pipeline_efficiency() if self.n_blocks > 1
               else self.overlap_efficiency())
        return R.RooflineTerms(
            flops_per_dev=self.flops_per_step() / n_dev,
            hbm_bytes_per_dev=self.hbm_bytes_per_shard_step(),
            ici_wire_bytes=self.halo_wire_bytes_per_step(),
            dcn_wire_bytes=0.0,
            n_chips=n_dev,
            overlap_efficiency=eff)

    def vmem_register_bytes(self) -> int:
        """VMEM shift-register footprint of the current configuration —
        one ring per packed batch slot (the batched-grid layout keeps
        every resident slot's ring on chip so the batch dimension can
        pipeline; `serving_throughput` binds on this)."""
        depth = self.fuse_T if self.variant == "fused" else 1
        itemsize = jnp.dtype(self.dtype).itemsize
        # wide's grid-tiled slab carries the sublane-rounded fetch halo
        halo = K._WIDE_HALO if (self.variant == "wide"
                                and self.tiling == "grid"
                                and self.y_tile is not None) else None
        return K.fused_register_bytes(depth, self.Y, self.Z, itemsize,
                                      y_tile=self.y_tile, halo=halo
                                      ) * self.batch

    def guard_bytes_per_step(self) -> int:
        """Extra HBM bytes per mega-launch of the finite-guard pass
        (`roofline.guard_bytes_model`): one read pass over the three
        advanced fields plus X flag words per packed slot. The serving
        tier's fault detection priced next to the field bytes it watches
        — half the fused six-array pass, amortised over the fuse_T Euler
        steps each pass carries, and gated counted == modelled EXACTLY
        in BENCH_faults.json."""
        if self.variant != "fused":
            raise ValueError("the finite guard rides the fused kernel; "
                             f"variant={self.variant!r} has no guard path")
        return R.guard_bytes_model(self.X, self.Y, self.Z,
                                   batch=self.batch)

    def serving_throughput(self) -> float:
        """Modelled domains/s of serving `batch` independent copies of
        this domain per mega-launch (`roofline.serving_throughput_model`):
        the fixed launch overhead amortised over the packed slots against
        each slot's HBM pass and exposed wire seconds. Strictly rises in
        `batch` until the per-slot rings exceed the VMEM budget
        (`roofline.serving_max_batch`), where the model refuses — the
        BENCH_serving gate pair."""
        t = self.roofline_terms()
        return R.serving_throughput_model(
            self.batch,
            hbm_bytes_per_domain=t.hbm_bytes_per_dev / self.batch,
            ring_bytes_per_slot=self.vmem_register_bytes() // self.batch,
            exposed_wire_s_per_domain=t.collective_exposed_s / self.batch)

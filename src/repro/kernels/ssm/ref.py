"""Pure-jnp oracle for the selective-scan (Mamba-1) chunk kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(xc, dt, Bmat, Cmat, A, h0):
    """Sequential oracle.

    xc   (B, S, D)   post-conv activations
    dt   (B, S, D)   softplus'd timestep
    Bmat (B, S, N)   input projection
    Cmat (B, S, N)   output projection
    A    (D, N)      negative state matrix
    h0   (B, D, N)   initial state
    Returns (y (B, S, D), h_final (B, D, N)), all f32.
    """
    xc, dt, Bmat, Cmat, A, h0 = (t.astype(jnp.float32)
                                 for t in (xc, dt, Bmat, Cmat, A, h0))
    B, S, D = xc.shape

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a = jnp.exp(dt_t[..., None] * A)                 # (B, D, N)
        bu = (dt_t * x_t)[..., None] * b_t[:, None, :]   # (B, D, N)
        h = a * h + bu
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    inputs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dt, 1, 0),
              jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    h, ys = jax.lax.scan(step, h0, inputs)
    return jnp.moveaxis(ys, 0, 1), h

"""Jit'd wrapper for the selective-scan kernel + block-size guidance."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssm.ssm import selective_scan, vmem_bytes


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(xc, dt, Bmat, Cmat, A, h0, *, chunk: int = 128,
               interpret: bool = True):
    return selective_scan(xc, dt, Bmat, Cmat, A, h0, chunk=chunk,
                          interpret=interpret)


def pick_chunk(D: int, N: int, budget: int = 12 * 2**20) -> int:
    """Largest power-of-two chunk whose working set fits the VMEM budget."""
    c = 1024
    while c > 8 and vmem_bytes(c, D, N) > budget:
        c //= 2
    return c

"""Selective-scan (Mamba-1) as a Pallas TPU kernel.

The paper's BRAM slice window in SSM form: the (chunk, D, N) discretised
state tensors never leave VMEM — only the chunk inputs (x, dt, B, C) stream
in and (y, inter-chunk state) stream out. Grid = (batch, n_chunks); the
chunk axis is sequential with the running state h carried in VMEM scratch,
exactly like the advection kernel's slice shift-register.

Inside a chunk the recurrence is evaluated with an associative (Blelloch)
scan over log2(chunk) rounds — MXU/VPU-friendly tree form rather than a
length-`chunk` sequential loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _chunk_body(x, dt, b, c, A, h0):
    """One chunk, fully in registers/VMEM. Shapes: x/dt (C,D); b/c (C,N);
    A (D,N); h0 (D,N). Returns (y (C,D), h_final (D,N))."""
    C = x.shape[0]
    a = jnp.exp(dt[..., None] * A)                  # (C, D, N)
    bu = (dt * x)[..., None] * b[:, None, :]        # (C, D, N)

    # associative scan (prefix composition of h -> a*h + bu), log2(C) rounds
    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])
    pa, pb = jax.lax.associative_scan(combine, (a, bu), axis=0)
    h_all = pa * h0[None] + pb                      # (C, D, N)
    y = jnp.einsum("cdn,cn->cd", h_all, c)
    return y, h_all[-1]


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
            y_ref, hout_ref, h_sc, *, n_chunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_sc[...] = h0_ref[0].astype(jnp.float32)

    y, h = _chunk_body(x_ref[0].astype(jnp.float32),
                       dt_ref[0].astype(jnp.float32),
                       b_ref[0].astype(jnp.float32),
                       c_ref[0].astype(jnp.float32),
                       a_ref[...].astype(jnp.float32),
                       h_sc[...])
    h_sc[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == n_chunks - 1)
    def _final():
        hout_ref[0] = h_sc[...].astype(hout_ref.dtype)


def selective_scan(xc, dt, Bmat, Cmat, A, h0, *, chunk: int = 128,
                   interpret: bool = True):
    """xc/dt (B,S,D); Bmat/Cmat (B,S,N); A (D,N); h0 (B,D,N).

    Returns (y (B,S,D) f32, h_final (B,D,N) f32)."""
    B, S, D = xc.shape
    N = Bmat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    grid = (B, n)
    seq_spec = lambda width: pl.BlockSpec((1, chunk, width),
                                          lambda b, j: (b, j, 0))
    a_spec = pl.BlockSpec((D, N), lambda b, j: (0, 0))
    h_spec = pl.BlockSpec((1, D, N), lambda b, j: (b, 0, 0))

    fn = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n),
        grid=grid,
        in_specs=[seq_spec(D), seq_spec(D), seq_spec(N), seq_spec(N),
                  a_spec, h_spec],
        out_specs=[seq_spec(D), h_spec],
        out_shape=[jax.ShapeDtypeStruct((B, S, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, D, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((D, N), jnp.float32)],
        interpret=interpret,
    )
    return tuple(fn(xc, dt, Bmat, Cmat, A, h0))


def vmem_bytes(chunk: int, D: int, N: int, itemsize: int = 2) -> int:
    """Working set of one program: chunk IO + (chunk, D, N) scan tensors."""
    io = (2 * chunk * D + 2 * chunk * N) * itemsize + chunk * D * 4
    scan = 2 * chunk * D * N * 4          # a, bu in f32
    state = D * N * 4
    return 2 * io + scan + state

"""Pure-jnp oracle for flash attention (GQA-aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q (B,H,Sq,D); k,v (B,Hkv,Skv,D) with H % Hkv == 0. f32 softmax."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale or D ** -0.5
    qg = q.reshape(B, Hkv, g, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), Skv - Sq)
        s = jnp.where(mask, s, -2.0 ** 30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)

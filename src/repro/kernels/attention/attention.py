"""Flash attention as a Pallas TPU kernel — the paper's dataflow discipline
applied to the transformer hot spot.

The KV stream through VMEM is the direct analogue of the paper's z-y slice
window through BRAM: Q tiles stay resident (the paper's "current slices"),
K/V tiles stream in HBM-burst-sized, lane-aligned blocks, and the online
softmax statistics (m, l) play the role of the FIFO-decoupled accumulators.
The S^2 logits never touch HBM — that is the entire point (cf. the dry-run
roofline, where XLA-level attention charges dominate the memory term).

GQA is handled in the *index map*: kv block index = q_head // group, so
shared KV heads are fetched once per group rather than expanded in HBM.

Grid: (batch, q_heads, q_blocks, kv_blocks), kv innermost (sequential
accumulation in VMEM scratch; Pallas double-buffers the next KV block
against the current tile's compute — load/compute overlap, Fig. 4 style).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0].astype(jnp.float32)          # (Bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (Bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (Bq, Bk)
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot(p, v)
    m_sc[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finish():
        o_ref[0, 0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q (B,H,Sq,D); k,v (B,Hkv,Skv,D), H % Hkv == 0. Returns (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k
    scale = scale or D ** -0.5

    grid = (B, H, nq, nk)
    q_spec = pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, D),
                           lambda b, h, iq, ik: (b, h // group, ik, 0))
    o_spec = pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0))

    fn = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, n_kv=nk),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )
    return fn(q, k, v)


def vmem_bytes(block_q: int, block_k: int, D: int, itemsize: int = 2) -> int:
    """VMEM working set of one program (for BlockSpec tuning)."""
    io = (block_q * D + 2 * block_k * D) * itemsize + block_q * D * itemsize
    scratch = (2 * block_q + block_q * D) * 4
    logits = block_q * block_k * 4
    return 2 * io + scratch + logits  # x2: double-buffered pipeline

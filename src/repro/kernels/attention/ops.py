"""Jit'd public wrapper for the flash-attention Pallas kernel.

`mha(q, k, v, ...)` takes (B, H, S, D)/(B, Hkv, S, D) tensors;
`gqa_layout_attention` adapts the model's (B, S, K, G, D) layout so the
kernel drops into `attention_apply` when `attention_impl="pallas"` on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention.attention import flash_attention
from repro.kernels.attention.ref import mha_ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def mha(q, k, v, *, causal: bool = True, block_q: int = 128,
        block_k: int = 128, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)


def gqa_layout_attention(q5, k4, v4, *, causal: bool = True,
                         interpret: bool = True):
    """(B,S,K,G,D) q / (B,S,K,D) kv -> (B,S,K,G,D), via the Pallas kernel."""
    B, S, K, G, D = q5.shape
    q = q5.transpose(0, 2, 3, 1, 4).reshape(B, K * G, S, D)
    k = k4.transpose(0, 2, 1, 3)
    v = v4.transpose(0, 2, 1, 3)
    o = mha(q, k, v, causal=causal, interpret=interpret)
    return o.reshape(B, K, G, S, D).transpose(0, 3, 1, 2, 4)


__all__ = ["mha", "gqa_layout_attention", "mha_ref"]

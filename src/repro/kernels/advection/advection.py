"""Pallas TPU kernels for PW advection — the paper's Fig. 3 ladder on TPU.

FPGA -> TPU mapping of the paper's stages:

  v1 `blocked`   : grid over x; each step fetches the (x-1, x, x+1) z-y slices
                   of all three fields from HBM into VMEM (three index-mapped
                   views per field). This is the paper's *initial* BRAM-blocked
                   kernel: correct, pipelined by Pallas, but each slice is
                   fetched three times — the "pipeline drains / re-reads"
                   regime.

  v2 `dataflow`  : grid over x with a persistent VMEM shift-register
                   (3, Y, Z) per field. Each step fetches exactly ONE new
                   slice and rotates the register — the paper's "shift the
                   current slices down by one, retrieve x+1" (Listing 1 lines
                   9-13) fused with its dataflow pipeline (Fig. 4): the Pallas
                   grid pipeline double-buffers the incoming slice against
                   compute, so load/compute/store overlap structurally.
                   HBM traffic drops 3x vs v1 — the Fig. 3 rows 3-5 move.
                   `fuse_update=True` additionally folds the explicit-Euler
                   update into the kernel (advanced fields out, not sources),
                   dropping the separate full-field read+write the host-side
                   `f + dt*s` pass would pay.

  v3 `wide`      : v2 with lane-aligned slices (Z a multiple of 128, f32
                   (8,128) tiling). One HBM->VMEM transaction carries 128
                   lanes — the 64->256-bit port widening of Fig. 3 rows 6-7.
                   Kernel body is identical; alignment is a contract on the
                   data layout (checked), and the benchmark charges misaligned
                   grids the measured lane-efficiency penalty.

  v4 `fused`     : temporal blocking — T explicit-Euler steps per HBM pass.
                   The shift register widens to T stacked 3-slice rings, one
                   per time level: as input slice x=i streams in (level 0),
                   level k produces its slice x=i-k from level k-1's ring, so
                   the step-T field leaves the chip the only time it touches
                   HBM. Per T steps the kernel reads 3·X and writes 3·X
                   slices where v2/v3 read+write 6·T·X — HBM traffic drops
                   ~T× (the on-chip-reuse endgame of the paper's Fig. 3
                   progression; cf. Brown 2020/2021 on amortising MONC
                   advection transfers across reuse). Register cost is
                   3 fields × 3T slices; with Y-tiling (halo T per side)
                   it is VMEM-bounded at (3T, TY+2T, Z) per field for any Y.

Grid-tiled execution contract (the `y_tile` path, `tiling="grid"`):

  `blocked`/`dataflow`/`wide`/`fused` accept `y_tile` and run the whole
  domain in ONE kernel launch over a 2D `(y_tile, x)` grid — the y-tile
  index is the outer (slow) grid dimension, x the inner streaming one.
  Element-indexed (`pl.Unblocked`) block specs select each tile's slab
  (`y_tile + 2*halo` rows, clipped flush into the domain at the edges) and
  write each tile's owned rows in place, so there is no host-side restitch
  (`jnp.concatenate`) and no per-tile dispatch. The ring register is sized
  to the slab, `(3, y_tile+2*halo, Z)` / `(T, 3, y_tile+2*T, Z)`, keeping
  VMEM bounded irrespective of Y; it is never cleared between tiles — the
  same startup masking that walls off x<0 slices walls off the stale ring
  content at each tile switch. The stencil's halo re-reads hit the
  VMEM-resident slab rather than issuing per-tile host restaging: the
  write side and the per-tile dispatch/concat are eliminated outright,
  and `hbm_bytes_model(..., grid_tiled=True)` charges the read side at
  compulsory traffic (zero halo overlap), with `vmem_halo_bytes_model`
  carrying the relocated bytes — an idealisation of slab residency: the
  interpret-mode reference still materialises each slab window per grid
  step, so the analytic model (not a measured counter) is the contract
  here, as everywhere in this repo's Fig. 3/8/9 tables. Slab edges behave
  as walls (zero source), exactly like global boundaries; every owned row
  keeps >= halo true rows of margin to a cut edge, so grid-tiled outputs
  are bitwise equal to the untiled kernel. Tiles whose slab would not fit
  (`y_tile + 2*halo > Y`) fall back to the untiled path.

  `wide` grid-tiles with a sublane-rounded fetch halo of 8 rows, so every
  slab keeps the (8,128) layout contract per tile — large-Y grids finally
  get a lane-aligned tiled path (`y_tile` must be a multiple of 8).

  The old host-side loop is retained as `tiling="host"` (`_y_tiled_host`):
  one `pallas_call` per halo-overlapped block plus a host restitch — kept
  as the measurable anti-pattern baseline (the paper's "data movement
  overhead" regime) for BENCH_tiling.json. `wide` still rejects host
  tiling (tile+halo rows cannot satisfy its sublane contract there).

Distributed composition (the 2D (x, y) mesh decomposition of PR 3 and the
exchange engines of PR 4): `stencil.distributed.make_distributed_step`
streams each (X/nx, Y/ny, Z) shard's halo'd slab through the v4 kernel with
ONE depth-T two-phase x-then-y exchange per T substeps (corners ride the y
phase on the x-extended slab), freezing wrapped periodic halo planes/rows
via `(x_interior_mask, y_interior_mask)`. `halo_band_exchange_dma` (below)
is the in-kernel transport for that exchange: the T-deep boundary bands
move by `pltpu.make_async_remote_copy` issued from inside a Pallas kernel
— one copy per `_band_schedule` hop, multi-hop for T beyond the local
extent — into double-buffered recv slabs whose slot parity is selected by
a TRACED block counter, so `stencil.distributed.make_distributed_run` can
alternate slots across K substep-blocks inside one traced program instead
of trusting XLA to schedule a `ppermute` — the paper's §IV "do the data
movement yourself" lesson at the chip-to-chip level.

Validated with interpret=True against ref.pw_advect_ref, the f64 oracle, and
the multi-step f64 oracle (fused) across shape/dtype/T/y_tile sweeps in
tests/test_advection_kernels.py, tests/test_advection_fused.py and
tests/test_advection_grid_tiled.py; the remote-DMA band kernel is
compiled-TPU-only and rides tests/test_compiled_smoke.py.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.advection.ref import AdvectParams
from repro.launch.mesh import dma_neighbor_coords

TILINGS = ("grid", "host")
_WIDE_HALO = 8   # sublane-rounded fetch halo: keeps wide's (8,128) contract


def _source_slices(um, uc, up, vm, vc, vp, wm, wc, wp, tcx, tcy, tzc1, tzc2):
    """PW source terms for one x-slice. Inputs (rows, Z) f32 views."""
    def inner(f):
        return f[1:-1, 1:-1]

    def sh(f_m, f_c, f_p, di, dj, dk):
        f = {-1: f_m, 0: f_c, 1: f_p}[di]
        Y, Z = f.shape
        return f[1 + dj:Y - 1 + dj, 1 + dk:Z - 1 + dk]

    t1 = tzc1[1:-1]
    t2 = tzc2[1:-1]

    def source(fm, fc, fp):
        fx = tcx * (sh(um, uc, up, -1, 0, 0) * (inner(fc) + inner(fm))
                    - sh(um, uc, up, 1, 0, 0) * (inner(fc) + inner(fp)))
        fy = tcy * (sh(vm, vc, vp, 0, -1, 0) * (inner(fc) + fc[0:-2, 1:-1])
                    - sh(vm, vc, vp, 0, 1, 0) * (inner(fc) + fc[2:, 1:-1]))
        fz = (t1 * sh(wm, wc, wp, 0, 0, -1) * (inner(fc) + fc[1:-1, 0:-2])
              - t2 * sh(wm, wc, wp, 0, 0, 1) * (inner(fc) + fc[1:-1, 2:]))
        return fx + fy + fz

    return (source(um, uc, up), source(vm, vc, vp), source(wm, wc, wp))


def _pad_edges(s):
    return jnp.pad(s, ((1, 1), (1, 1)))


# ---------------------------------------------------------------------------
# in-grid (y_tile, x) tiling geometry
# ---------------------------------------------------------------------------


def _check_tiling(tiling: str) -> None:
    if tiling not in TILINGS:
        raise ValueError(f"tiling must be one of {TILINGS}, got {tiling!r}")


def _check_y_tile(y_tile: Optional[int]) -> None:
    if y_tile is not None and y_tile < 1:
        raise ValueError(f"y_tile must be >= 1, got {y_tile}")


def _grid_geometry(Y: int, y_tile: Optional[int],
                   halo: int) -> Tuple[int, int, int]:
    """(TY, S, n_ty): owned rows per tile, static slab rows, tile count.

    Untiled (or a slab that would not fit the domain) degenerates to one
    full-domain tile (Y, Y, 1) — the 2D grid with n_ty=1 IS the untiled
    kernel, so there is a single code path.
    """
    if y_tile is None or y_tile >= Y or y_tile + 2 * halo > Y:
        return Y, Y, 1
    return y_tile, y_tile + 2 * halo, -(-Y // y_tile)


def _slab_lo(t, Y: int, TY: int, S: int, H: int):
    """Global row of slab row 0 for tile t, clipped flush into the domain."""
    return jnp.clip(t * TY - H, 0, Y - S)


def _out_lo(t, Y: int, TY: int):
    """Global row of the tile's (1, TY, Z) output block; the remainder tile
    slides down so its static-shaped block stays in bounds — its extra rows
    overlap the previous tile's and are rewritten with identical values
    (every row it emits has >= halo rows of slab margin)."""
    return jnp.minimum(t * TY, Y - TY)


def _own_start(t, Y: int, TY: int, S: int, H: int):
    """Slab-local row where the tile's owned output rows begin."""
    return _out_lo(t, Y, TY) - _slab_lo(t, Y, TY, S, H)


def _emit_tile_outputs(refs, sources, cens, interior, start, fuse, dt):
    """Shared v1/v2 epilogue: mask each slab source to the x-interior,
    optionally fold the Euler update in (`fuse`: advanced fields out), and
    write the tile's owned rows — the (1, TY, Z) output block — from slab
    row `start`."""
    for ref, s, cen in zip(refs, sources, cens):
        if fuse:
            src = jnp.where(interior, _pad_edges(s), 0.0).astype(cen.dtype)
            val = cen + dt * src
        else:
            val = jnp.where(interior, _pad_edges(s), 0.0).astype(ref.dtype)
        ref[0] = jax.lax.dynamic_slice(val, (start, 0),
                                       (ref.shape[1], val.shape[1]))


# ---------------------------------------------------------------------------
# v1: blocked — three slice views per field, 3x HBM traffic
# ---------------------------------------------------------------------------


def _kernel_blocked(t1_ref, t2_ref,
                    um_ref, uc_ref, up_ref, vm_ref, vc_ref, vp_ref,
                    wm_ref, wc_ref, wp_ref,
                    su_ref, sv_ref, sw_ref, *, X, Y, TY, S, H, fuse, dt):
    t = pl.program_id(0)
    i = pl.program_id(1)
    args = [r[0] for r in (um_ref, uc_ref, up_ref, vm_ref, vc_ref, vp_ref,
                           wm_ref, wc_ref, wp_ref)]
    su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                t1_ref[2:], t2_ref[2:])
    interior = (i >= 1) & (i <= X - 2)
    _emit_tile_outputs((su_ref, sv_ref, sw_ref), (su, sv, sw),
                       (args[1], args[4], args[7]), interior,
                       _own_start(t, Y, TY, S, H), fuse, dt)


def advect_blocked(u, v, w, p: AdvectParams, *, interpret: bool = True,
                   y_tile: int | None = None, tiling: str = "grid",
                   fuse_update: bool = False, dt: float = 1.0):
    _check_tiling(tiling)
    _check_y_tile(y_tile)
    X, Y, Z = u.shape
    if tiling == "host" and y_tile is not None and y_tile < Y:
        fn = lambda a, b, c: advect_blocked(a, b, c, p, interpret=interpret,
                                            fuse_update=fuse_update, dt=dt)
        return _y_tiled_host(fn, u, v, w, y_tile=y_tile, halo=1)
    TY, S, n_ty = _grid_geometry(Y, y_tile, 1)
    slice_spec = lambda off: pl.BlockSpec(
        (1, S, Z),
        lambda t, i, off=off: (jnp.clip(i + off, 0, X - 1),
                               _slab_lo(t, Y, TY, S, 1), 0),
        indexing_mode=pl.Unblocked())
    # pack scalars+z-metrics into one (Z+2,) vector per metric for simplicity
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda t, i: (0,))
    out_spec = pl.BlockSpec((1, TY, Z),
                            lambda t, i: (i, _out_lo(t, Y, TY), 0),
                            indexing_mode=pl.Unblocked())
    out_shape = [jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3
    fn = pl.pallas_call(
        functools.partial(_kernel_blocked, X=X, Y=Y, TY=TY, S=S, H=1,
                          fuse=fuse_update, dt=dt),
        grid=(n_ty, X),
        in_specs=[tz_spec, tz_spec] + [slice_spec(o) for _ in range(3)
                                       for o in (-1, 0, 1)],
        out_specs=[out_spec] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(t1, t2, u, u, u, v, v, v, w, w, w)


# ---------------------------------------------------------------------------
# v2: dataflow — persistent VMEM shift register, 1x HBM traffic
# ---------------------------------------------------------------------------


def _kernel_dataflow(t1_ref, t2_ref, u_ref, v_ref, w_ref,
                     su_ref, sv_ref, sw_ref,
                     ubuf, vbuf, wbuf, *, X, Y, TY, S, H, fuse, dt):
    t = pl.program_id(0)
    i = pl.program_id(1)
    # 1) shift register: store the newly-arrived slice at ring position i%3.
    #    At a tile switch the ring holds the previous tile's slices; the
    #    interior mask below keeps them out of every unmasked output, so no
    #    explicit per-tile reset is needed.
    slot = jax.lax.rem(i, 3)
    load = i <= X - 1
    for buf, ref in ((ubuf, u_ref), (vbuf, v_ref), (wbuf, w_ref)):
        cur = buf[slot]
        buf[slot] = jnp.where(load, ref[0], cur)
    # 2) compute x = i-1 from ring slots (i-2, i-1, i)
    m, c, pslot = (jax.lax.rem(i + 1, 3), jax.lax.rem(i + 2, 3),
                   jax.lax.rem(i, 3))
    args = [ubuf[m], ubuf[c], ubuf[pslot],
            vbuf[m], vbuf[c], vbuf[pslot],
            wbuf[m], wbuf[c], wbuf[pslot]]
    su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                t1_ref[2:], t2_ref[2:])
    interior = (i >= 2) & (i <= X - 1)
    _emit_tile_outputs((su_ref, sv_ref, sw_ref), (su, sv, sw),
                       (args[1], args[4], args[7]), interior,
                       _own_start(t, Y, TY, S, H), fuse, dt)


def _y_tiled_host(fn, u, v, w, *, y_tile: int, halo: int):
    """HOST-side tiling (the retained anti-pattern baseline, `tiling="host"`):
    run a slice kernel over halo-overlapped y-blocks and restitch.

    Each block sees `halo` extra rows per interior side; the kernel treats
    block edges as boundaries (zero source), which contaminates at most
    `halo` rows per side after `halo` update sweeps — exactly the rows we
    trim. Global-edge blocks get no extra rows, so the true boundary
    condition lands on the block edge. Every halo row is restaged from HBM
    per block, and the restitch is a host `jnp.concatenate` — the cost
    `hbm_bytes_model(..., grid_tiled=False)` charges and the in-grid path
    eliminates.
    """
    Y = u.shape[1]
    outs = ([], [], [])
    for y0 in range(0, Y, y_tile):
        y1 = min(y0 + y_tile, Y)
        lo, hi = max(y0 - halo, 0), min(y1 + halo, Y)
        tile = fn(u[:, lo:hi], v[:, lo:hi], w[:, lo:hi])
        for acc, t in zip(outs, tile):
            acc.append(t[:, y0 - lo:y0 - lo + (y1 - y0)])
    return tuple(jnp.concatenate(a, axis=1) for a in outs)


def advect_dataflow(u, v, w, p: AdvectParams, *, interpret: bool = True,
                    y_tile: int | None = None, tiling: str = "grid",
                    fuse_update: bool = False, dt: float = 1.0,
                    _fetch_halo: int = 1):
    _check_tiling(tiling)
    _check_y_tile(y_tile)
    X, Y, Z = u.shape
    if tiling == "host" and y_tile is not None and y_tile < Y:
        fn = lambda a, b, c: advect_dataflow(a, b, c, p, interpret=interpret,
                                             fuse_update=fuse_update, dt=dt)
        return _y_tiled_host(fn, u, v, w, y_tile=y_tile, halo=1)
    H = _fetch_halo
    TY, S, n_ty = _grid_geometry(Y, y_tile, H)
    in_spec = pl.BlockSpec((1, S, Z),
                           lambda t, i: (jnp.minimum(i, X - 1),
                                         _slab_lo(t, Y, TY, S, H), 0),
                           indexing_mode=pl.Unblocked())
    out_spec = pl.BlockSpec((1, TY, Z),
                            lambda t, i: (jnp.clip(i - 1, 0, X - 1),
                                          _out_lo(t, Y, TY), 0),
                            indexing_mode=pl.Unblocked())
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda t, i: (0,))
    out_shape = [jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3
    fn = pl.pallas_call(
        functools.partial(_kernel_dataflow, X=X, Y=Y, TY=TY, S=S, H=H,
                          fuse=fuse_update, dt=dt),
        grid=(n_ty, X + 1),
        in_specs=[tz_spec, tz_spec, in_spec, in_spec, in_spec],
        out_specs=[out_spec] * 3,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((3, S, Z), u.dtype) for _ in range(3)],
        interpret=interpret,
    )
    return fn(t1, t2, u, v, w)


# ---------------------------------------------------------------------------
# v3: wide — v2 with lane-aligned layout (Z % 128 == 0)
# ---------------------------------------------------------------------------


def advect_wide(u, v, w, p: AdvectParams, *, interpret: bool = True,
                y_tile: int | None = None, tiling: str = "grid",
                fuse_update: bool = False, dt: float = 1.0):
    _check_tiling(tiling)
    _check_y_tile(y_tile)
    Z = u.shape[2]
    if Z % 128:
        raise ValueError(
            f"advect_wide requires lane-aligned Z (multiple of 128), got {Z}; "
            "use advect_dataflow and accept the lane-efficiency penalty")
    if u.shape[1] % 8:
        raise ValueError(f"Y must be a multiple of 8 (sublane), got {u.shape[1]}")
    if y_tile is not None and y_tile < u.shape[1]:
        if tiling == "host":
            # halo'd host blocks are y_tile+2 (edge: +1) rows — never a
            # sublane multiple, so host tiling would silently break the
            # layout contract this variant exists to enforce
            raise ValueError(
                "advect_wide cannot Y-tile host-side (tile+halo rows break "
                "the (8,128) sublane contract); use tiling='grid' (default), "
                "advect_dataflow(y_tile=...) or advect_fused")
        if y_tile % 8:
            raise ValueError(
                f"wide y_tile must be a multiple of 8 (sublane), got {y_tile}")
    # grid tiling keeps the contract per-tile: the fetch halo is rounded up
    # to a full sublane (8 rows), so slab row counts and element offsets all
    # stay multiples of 8 while the stencil only needs 1 halo row.
    return advect_dataflow(u, v, w, p, interpret=interpret, y_tile=y_tile,
                           tiling="grid", fuse_update=fuse_update, dt=dt,
                           _fetch_halo=_WIDE_HALO)


# ---------------------------------------------------------------------------
# v4: fused — temporal blocking, T Euler steps per HBM pass
# ---------------------------------------------------------------------------


def _kernel_fused(t1_ref, t2_ref, xm_ref, ym_ref, u_ref, v_ref, w_ref,
                  *refs, X, Y, TY, S, T, dt):
    """T stacked 3-slice rings: level k holds the step-k fields.

    At grid step (t, i) the newly-arrived input slice x=i of tile t's slab
    lands in level 0's ring; level k (k=1..T) then computes its slice x=i-k
    from level k-1's ring. Level k-1's slice x=j is stored at grid step
    j+k-1, so for every level the (x-1, x, x+1) operands sit at ring slots
    ((i+1)%3, (i+2)%3, i%3) and every level writes slot i%3 — the same
    rotation as v2, T-deep.

    Startup/tail slices (x<0 or x>X-1) are garbage but provably walled off:
    a level's x=0 / x=X-1 output is a masked copy of its centre operand, and
    the depth-1 stencil cannot carry values past an unchanging slice. The
    same wall swallows the previous tile's stale ring content at each tile
    switch, so the ring needs no explicit per-tile reset.

    `ym_ref` is the slab's row-interior mask (1.0 = the row's source may be
    applied); all-ones reproduces the plain boundary behaviour, while the
    distributed depth-T halo exchange passes its global-interior mask so
    wrapped ppermute rows stay frozen walls. `xm_ref` is the per-slice
    analogue for the x dimension: slice j's sources are applied only when
    xm[j] is nonzero, so a 2D (x, y) decomposition can freeze wrapped
    x-halo planes the same way (the slab-edge wall at j=0 / j=X-1 stays
    structural either way).

    The finite guard deliberately does NOT live in this kernel: probing
    the output slice with `isfinite` inside the loop body changes the
    body's codegen enough to perturb float contraction by one ulp at
    most shapes. Detection is a separate pass — `_kernel_finite_guard`
    below — so this kernel's outputs stay bitwise-identical whether or
    not the caller asked for guarding.
    """
    ou_ref, ov_ref, ow_ref, ubuf, vbuf, wbuf = refs
    t = pl.program_id(0)
    i = pl.program_id(1)
    slot = jax.lax.rem(i, 3)
    m, c = jax.lax.rem(i + 1, 3), jax.lax.rem(i + 2, 3)
    row_ok = (ym_ref[...] > 0.0)[:, None]
    for buf, ref in ((ubuf, u_ref), (vbuf, v_ref), (wbuf, w_ref)):
        buf[0, slot] = ref[0]
    outs = None
    for k in range(1, T + 1):
        j = i - k
        args = [ubuf[k - 1, m], ubuf[k - 1, c], ubuf[k - 1, slot],
                vbuf[k - 1, m], vbuf[k - 1, c], vbuf[k - 1, slot],
                wbuf[k - 1, m], wbuf[k - 1, c], wbuf[k - 1, slot]]
        su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                    t1_ref[2:], t2_ref[2:])
        x_ok = xm_ref[pl.ds(jnp.clip(j, 0, X - 1), 1)][0] > 0.0
        interior = (j >= 1) & (j <= X - 2) & x_ok
        new = []
        for cen, s in ((args[1], su), (args[4], sv), (args[7], sw)):
            src = jnp.where(interior & row_ok, _pad_edges(s),
                            0.0).astype(cen.dtype)
            new.append(cen + dt * src)
        if k < T:
            ubuf[k, slot], vbuf[k, slot], wbuf[k, slot] = new
        else:
            outs = new
    start = _own_start(t, Y, TY, S, T)
    for ref, val in zip((ou_ref, ov_ref, ow_ref), outs):
        ref[0] = jax.lax.dynamic_slice(val, (start, 0), (TY, val.shape[1]))


def _kernel_finite_guard(u_ref, v_ref, w_ref, gf_ref):
    """Per-x-slice finite-guard: flag = 1.0 iff the (Y, Z) slice of all
    three fields is entirely finite. One grid step per x-slice keeps the
    VMEM working set at 3*Y*Z words regardless of X."""
    ok = jnp.float32(1.0)
    for ref in (u_ref, v_ref, w_ref):
        ok = ok * jnp.all(jnp.isfinite(ref[0])).astype(jnp.float32)
    gf_ref[0] = ok


def finite_guard(u, v, w, *, interpret: bool = True):
    """Scan the three fields for non-finite cells in ONE extra read pass.

    Returns f32 flags of shape ``(X,)``: ``flags[i] == 1.0`` iff x-slice
    i of `u`, `v` and `w` is entirely finite, so ``flags.min() > 0`` iff
    the whole state is. This is the serving tier's poisoned-slot
    detector, kept OUTSIDE the fused advection kernel on purpose: an
    in-loop `isfinite` probe perturbs the fused kernel's float
    contraction by one ulp, while a separate pass over the already-
    written outputs leaves them bitwise intact. The price is honest and
    exactly modelled: the pass re-reads all three fields and writes X
    flag words — `roofline.guard_bytes_model` bytes, which
    `stencil.distributed.count_guard_bytes` recounts from the jaxpr and
    BENCH_faults.json gates equal EXACTLY.
    """
    X, Y, Z = u.shape
    return pl.pallas_call(
        _kernel_finite_guard,
        grid=(X,),
        in_specs=[pl.BlockSpec((1, Y, Z), lambda i: (i, 0, 0))] * 3,
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((X,), jnp.float32),
        interpret=interpret,
    )(u, v, w)


def advect_fused(u, v, w, p: AdvectParams, *, T: int = 4, dt: float = 1.0,
                 interpret: bool = True, y_tile: int | None = None,
                 tiling: str = "grid", y_interior_mask=None,
                 x_interior_mask=None, guard: bool = False):
    """v4: advance the fields T explicit-Euler steps in ONE HBM pass.

    Returns the advanced `(u, v, w)` (not sources — the step is fused into
    the kernel). With `y_tile`, each in-grid tile's slab carries a T-deep
    halo so the register is VMEM-bounded at ``fused_register_bytes``
    irrespective of Y. `y_interior_mask` (shape (Y,), nonzero = source may
    be applied) lets callers freeze extra rows beyond the domain edges —
    the distributed depth-T halo exchange uses it to wall off wrapped
    ppermute rows while composing with in-grid tiles. `x_interior_mask`
    (shape (X,)) is the x-plane analogue, used by the 2D (x, y) mesh
    decomposition to freeze wrapped x-halo planes.

    `guard=True` returns ``(u, v, w, flags)`` where `flags` is the
    `finite_guard` pass over the three ADVANCED fields — f32 shape
    ``(X,)``, 1.0 iff that x-slice is finite across all three, so
    ``flags.min() > 0`` iff the whole advanced state is finite. The
    guard is a separate pallas pass over the outputs (NOT fused into
    the advection loop — an in-loop probe costs one ulp of drift), so
    the field outputs are bitwise-identical to a `guard=False` call.
    Its extra HBM bytes (one read pass + X flag words) are priced by
    `roofline.guard_bytes_model` and counted by
    `stencil.distributed.count_guard_bytes` — gated equal EXACTLY.
    """
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    _check_tiling(tiling)
    _check_y_tile(y_tile)
    X, Y, Z = u.shape
    if tiling == "host" and y_tile is not None and y_tile < Y:
        if y_interior_mask is not None or x_interior_mask is not None:
            raise ValueError("interior masks require the grid-tiled path "
                             "(tiling='grid')")
        fn = lambda a, b, c: advect_fused(a, b, c, p, T=T, dt=dt,
                                          interpret=interpret)
        ou, ov, ow = _y_tiled_host(fn, u, v, w, y_tile=y_tile, halo=T)
        if guard:
            return ou, ov, ow, finite_guard(ou, ov, ow, interpret=interpret)
        return ou, ov, ow
    TY, S, n_ty = _grid_geometry(Y, y_tile, T)
    ym = (jnp.ones((Y,), jnp.float32) if y_interior_mask is None
          else jnp.asarray(y_interior_mask, jnp.float32))
    if ym.shape != (Y,):
        raise ValueError(f"y_interior_mask must have shape ({Y},), "
                         f"got {ym.shape}")
    xm = (jnp.ones((X,), jnp.float32) if x_interior_mask is None
          else jnp.asarray(x_interior_mask, jnp.float32))
    if xm.shape != (X,):
        raise ValueError(f"x_interior_mask must have shape ({X},), "
                         f"got {xm.shape}")
    in_spec = pl.BlockSpec((1, S, Z),
                           lambda t, i: (jnp.minimum(i, X - 1),
                                         _slab_lo(t, Y, TY, S, T), 0),
                           indexing_mode=pl.Unblocked())
    out_spec = pl.BlockSpec((1, TY, Z),
                            lambda t, i: (jnp.clip(i - T, 0, X - 1),
                                          _out_lo(t, Y, TY), 0),
                            indexing_mode=pl.Unblocked())
    ym_spec = pl.BlockSpec((S,), lambda t, i: (_slab_lo(t, Y, TY, S, T),),
                           indexing_mode=pl.Unblocked())
    xm_spec = pl.BlockSpec((X,), lambda t, i: (0,))
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda t, i: (0,))
    fn = pl.pallas_call(
        functools.partial(_kernel_fused, X=X, Y=Y, TY=TY, S=S, T=T, dt=dt),
        grid=(n_ty, X + T),
        in_specs=[tz_spec, tz_spec, xm_spec, ym_spec,
                  in_spec, in_spec, in_spec],
        out_specs=[out_spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3,
        scratch_shapes=[pltpu.VMEM((T, 3, S, Z), u.dtype) for _ in range(3)],
        interpret=interpret,
    )
    ou, ov, ow = fn(t1, t2, xm, ym, u, v, w)
    if guard:
        return ou, ov, ow, finite_guard(ou, ov, ow, interpret=interpret)
    return ou, ov, ow


def _batch_axis(leaf, base_ndim: int):
    """vmap in_axis for an optionally slot-batched operand: a leading batch
    dimension on top of the unbatched rank maps (axis 0), anything else is
    shared across slots (axis None)."""
    nd = getattr(leaf, "ndim", 0)
    if nd == base_ndim:
        return None
    if nd == base_ndim + 1:
        return 0
    raise ValueError(
        f"operand rank {nd} is neither the unbatched rank {base_ndim} nor "
        f"batched rank {base_ndim + 1}")


def advect_fused_batched(u, v, w, p, *, T: int = 4, dt: float = 1.0,
                         interpret: bool = True, y_tile: int | None = None,
                         tiling: str = "grid", y_interior_mask=None,
                         x_interior_mask=None, guard: bool = False):
    """Batched mega-launch: advance B independent (X, Y, Z) domains with
    ONE fused-kernel dispatch — the serving tier's packing move.

    `u`, `v`, `w` are slot-stacked ``(B, X, Y, Z)`` fields. The batch rides
    an outer grid dimension via `jax.vmap` of the fused pallas_call (the
    vmap-with-shared-ring layout): Pallas's batching rule prepends the
    slot index to the `(n_ty, X + T)` grid, so slots stream through the
    SAME VMEM shift-register rings back to back — slot b+1's startup
    masking walls off slot b's stale ring content exactly as a y-tile
    switch does, and per-slot outputs are bitwise-identical to B
    sequential `advect_fused` calls (the BENCH_serving gate).

    `p` is an `AdvectParams` whose leaves are either shared (unbatched) or
    slot-stacked with a leading B — per-tenant advection coefficients.
    `x_interior_mask` / `y_interior_mask` may likewise be shared ``(X,)`` /
    ``(Y,)`` or per-slot ``(B, X)`` / ``(B, Y)``: a request SMALLER than
    the padded slot shape freezes everything outside its own extent (and
    its own boundary ring) with zeros in the mask, so the padded run
    reproduces the unpadded domain bitwise — the serving engine's
    pack-small-domains contract.

    HBM traffic is exactly B times the per-domain model
    (``hbm_bytes_model``): the batched pallas_call's field operands and
    results are the only rank->=3 arrays it touches, which is what
    `stencil.distributed.count_pallas_hbm_bytes` counts and
    BENCH_serving.json gates EXACTLY (lane-aligned Z).

    `guard=True` additionally returns slot-stacked finite-guard flags
    ``(B, X)`` (see `finite_guard`): ``flags[b].min() > 0`` iff slot b's
    advanced fields are entirely finite — the serving engine's per-slot
    quarantine signal, one extra vmapped guard pass over the mega-
    launch's outputs that leaves the field outputs bitwise-identical to
    an unguarded call. The flag output is rank 2, so the main kernel's
    `count_pallas_hbm_bytes` is unchanged; `count_guard_bytes` isolates
    the guard pass's traffic, == `guard_bytes_model(batch=B)`.
    """
    for name, f in (("u", u), ("v", v), ("w", w)):
        if f.ndim != 4:
            raise ValueError(f"{name} must be slot-stacked (B, X, Y, Z), "
                             f"got rank {f.ndim}")
    if not (u.shape == v.shape == w.shape):
        raise ValueError(f"field shapes differ: {u.shape} {v.shape} "
                         f"{w.shape}")
    B, X, Y, Z = u.shape
    p_axes = AdvectParams(_batch_axis(p.tcx, 0), _batch_axis(p.tcy, 0),
                          _batch_axis(p.tzc1, 1), _batch_axis(p.tzc2, 1))
    xm = (jnp.ones((X,), jnp.float32) if x_interior_mask is None
          else jnp.asarray(x_interior_mask, jnp.float32))
    ym = (jnp.ones((Y,), jnp.float32) if y_interior_mask is None
          else jnp.asarray(y_interior_mask, jnp.float32))
    xm_ax, ym_ax = _batch_axis(xm, 1), _batch_axis(ym, 1)

    def one(uu, vv, ww, pp, xmm, ymm):
        return advect_fused(uu, vv, ww, pp, T=T, dt=dt, interpret=interpret,
                            y_tile=y_tile, tiling=tiling,
                            y_interior_mask=ymm, x_interior_mask=xmm,
                            guard=guard)

    return jax.vmap(one, in_axes=(0, 0, 0, p_axes, xm_ax, ym_ax))(
        u, v, w, p, xm, ym)


# ---------------------------------------------------------------------------
# spec-driven generalised fused kernel (the stencil-spec frontend's engine)
# ---------------------------------------------------------------------------


def _pad_r(s, r: int):
    return jnp.pad(s, ((r, r), (r, r)))


def _kernel_stencil_fused(*refs, X, Y, TY, S, T, dt, n_fields, n_params,
                          radius, stages, source):
    """Generalised temporal-blocking ring: `stages*T` stacked levels of
    `2*radius+1` slots per field, driven by a StencilSpec's source callback.

    Geometry (reduces EXACTLY to `_kernel_fused` at radius=1, stages=1):
    level 0 stores the arriving input slice x=i at slot i % W (W=2r+1);
    level k (k=1..L, L=stages*T) computes its slice j = i - k*r from level
    k-1's ring — level k-1's slice j+dx (|dx| <= r) was written at grid
    step j+dx+(k-1)*r = i-r+dx, i.e. slot (i-r+dx) % W, still resident in
    the W-deep rotation. Each level writes slot i % W; the output level L
    emits slice j = i - D (D = r*L, the spec halo depth).

    Integrators: euler spends one level per substep (new = cen + dt*src).
    Midpoint rk2 spends two — odd levels hold the half-step state
    g = cen + (dt/2)*src, even levels complete f_new = base + dt*src(g)
    where `base` is the PREVIOUS FULL level's (k-2) slice j, written at
    grid step i-2r and therefore the oldest still-resident slot
    (i-2r) % W. Masked slices copy through unchanged at every level
    (g=cen, f_new=base), so the startup/tail/tile-switch wall argument of
    `_kernel_fused` carries over for any radius and either integrator.
    """
    P, F = n_params, n_fields
    p_refs = refs[:P]
    xm_ref, ym_ref = refs[P], refs[P + 1]
    f_refs = refs[P + 2:P + 2 + F]
    out_refs = refs[P + 2 + F:P + 2 + 2 * F]
    bufs = refs[P + 2 + 2 * F:]
    r = radius
    W = 2 * r + 1
    L = stages * T
    D = r * L
    t = pl.program_id(0)
    i = pl.program_id(1)
    pv = tuple(pr[...] for pr in p_refs)
    row_ok = (ym_ref[...] > 0.0)[:, None]
    slot = jax.lax.rem(i, W)
    for buf, ref in zip(bufs, f_refs):
        buf[0, slot] = ref[0]
    outs = None
    for k in range(1, L + 1):
        lvl = k - 1
        j = i - k * r

        def sh(fi, dx, dj, dk, _lvl=lvl):
            # (i + (W - r) + dx) % W == (i - r + dx) % W, kept non-negative
            sl = jax.lax.rem(i + (W - r) + dx, W)
            v = bufs[fi][_lvl, sl]
            return v[r + dj:v.shape[0] - r + dj, r + dk:v.shape[1] - r + dk]

        srcs = source(sh, pv)
        x_ok = xm_ref[pl.ds(jnp.clip(j, 0, X - 1), 1)][0] > 0.0
        interior = (j >= r) & (j <= X - 1 - r) & x_ok
        cslot = jax.lax.rem(i + (W - r), W)
        half_level = stages == 2 and k % 2 == 1
        step_dt = 0.5 * dt if half_level else dt
        new = []
        for fi, s in enumerate(srcs):
            if stages == 2 and k % 2 == 0:
                base = bufs[fi][k - 2, jax.lax.rem(i + (W - 2 * r), W)]
            else:
                base = bufs[fi][lvl, cslot]
            src = jnp.where(interior & row_ok, _pad_r(s, r),
                            0.0).astype(base.dtype)
            new.append(base + step_dt * src)
        if k < L:
            for fi, val in enumerate(new):
                bufs[fi][k, slot] = val
        else:
            outs = new
    start = _own_start(t, Y, TY, S, D)
    for ref, val in zip(out_refs, outs):
        ref[0] = jax.lax.dynamic_slice(val, (start, 0), (TY, val.shape[1]))


def stencil_fused(fields, params, spec, *, T: int = 4, dt: float = 1.0,
                  interpret: bool = True, y_tile: int | None = None,
                  y_interior_mask=None, x_interior_mask=None):
    """Spec-driven v4: advance a StencilSpec's fields T integrator steps in
    ONE HBM pass — the generalisation of `advect_fused` to any operator.

    `fields` is a tuple of `spec.n_fields` (X, Y, Z) arrays; `params` is
    whatever `spec.pack_params` consumes. Ring depth, startup masks, slab
    halo and the output lag are ALL derived from `spec.halo(T) =
    radius * stages * T` instead of the hand kernel's hard-coded halo=1
    per substep, so deeper stencils and multi-stage integrators ride the
    identical grid-tiled execution contract (`y_tile`, interior masks —
    same semantics as `advect_fused`). For the Piacsek-Williams spec this
    function is gated BITWISE-equal to `advect_fused`: the ring rotation,
    block specs and update arithmetic reduce exactly to `_kernel_fused`
    at radius=1, stages=1. VMEM cost is `fused_register_bytes(...,
    n_fields, n_slots=2r+1, n_levels=stages*T)`.
    """
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    _check_y_tile(y_tile)
    fields = tuple(fields)
    if len(fields) != spec.n_fields:
        raise ValueError(
            f"spec {spec.name!r} has {spec.n_fields} fields "
            f"({spec.fields}), got {len(fields)} arrays")
    shape = fields[0].shape
    for name, f in zip(spec.fields, fields):
        if f.shape != shape:
            raise ValueError(f"field {name!r} shape {f.shape} != {shape}")
    X, Y, Z = shape
    r = spec.radius
    D = spec.halo(T)
    L = spec.stages * T
    TY, S, n_ty = _grid_geometry(Y, y_tile, D)
    ym = (jnp.ones((Y,), jnp.float32) if y_interior_mask is None
          else jnp.asarray(y_interior_mask, jnp.float32))
    if ym.shape != (Y,):
        raise ValueError(f"y_interior_mask must have shape ({Y},), "
                         f"got {ym.shape}")
    xm = (jnp.ones((X,), jnp.float32) if x_interior_mask is None
          else jnp.asarray(x_interior_mask, jnp.float32))
    if xm.shape != (X,):
        raise ValueError(f"x_interior_mask must have shape ({X},), "
                         f"got {xm.shape}")
    pv = tuple(spec.pack_params(params))
    for p in pv:
        if p.ndim != 1:
            raise ValueError(
                f"spec {spec.name!r}: pack_params must return 1-D vectors, "
                f"got shape {p.shape}")
    p_specs = [pl.BlockSpec(p.shape, lambda t, i: (0,)) for p in pv]
    in_spec = pl.BlockSpec((1, S, Z),
                           lambda t, i: (jnp.minimum(i, X - 1),
                                         _slab_lo(t, Y, TY, S, D), 0),
                           indexing_mode=pl.Unblocked())
    out_spec = pl.BlockSpec((1, TY, Z),
                            lambda t, i: (jnp.clip(i - D, 0, X - 1),
                                          _out_lo(t, Y, TY), 0),
                            indexing_mode=pl.Unblocked())
    ym_spec = pl.BlockSpec((S,), lambda t, i: (_slab_lo(t, Y, TY, S, D),),
                           indexing_mode=pl.Unblocked())
    xm_spec = pl.BlockSpec((X,), lambda t, i: (0,))
    fn = pl.pallas_call(
        functools.partial(_kernel_stencil_fused, X=X, Y=Y, TY=TY, S=S, T=T,
                          dt=dt, n_fields=spec.n_fields, n_params=len(pv),
                          radius=r, stages=spec.stages, source=spec.source),
        grid=(n_ty, X + D),
        in_specs=p_specs + [xm_spec, ym_spec] + [in_spec] * spec.n_fields,
        out_specs=[out_spec] * spec.n_fields,
        out_shape=[jax.ShapeDtypeStruct((X, Y, Z), fields[0].dtype)
                   ] * spec.n_fields,
        scratch_shapes=[pltpu.VMEM((L, 2 * r + 1, S, Z), fields[0].dtype)
                        for _ in range(spec.n_fields)],
        interpret=interpret,
    )
    out = fn(*pv, xm, ym, *fields)
    return tuple(out) if isinstance(out, (tuple, list)) else (out,)


def stencil_fused_batched(fields, params, spec, *, T: int = 4,
                          dt: float = 1.0, interpret: bool = True,
                          y_tile: int | None = None,
                          y_interior_mask=None, x_interior_mask=None):
    """Batched mega-launch of the spec kernel: B independent domains of any
    StencilSpec in ONE dispatch — the serving tier's packing move
    generalised beyond advection (cf. `advect_fused_batched`; slots stream
    back-to-back through the same VMEM rings, startup masking walls off
    the previous slot's stale ring content). `fields` are slot-stacked
    ``(B, X, Y, Z)``; `params` is shared across slots; interior masks may
    be shared ``(X,)``/``(Y,)`` or per-slot ``(B, X)``/``(B, Y)``."""
    fields = tuple(fields)
    for name, f in zip(spec.fields, fields):
        if f.ndim != 4:
            raise ValueError(f"field {name!r} must be slot-stacked "
                             f"(B, X, Y, Z), got rank {f.ndim}")
    shape = fields[0].shape
    for name, f in zip(spec.fields, fields):
        if f.shape != shape:
            raise ValueError(f"field {name!r} shape {f.shape} != {shape}")
    B, X, Y, Z = shape
    xm = (jnp.ones((X,), jnp.float32) if x_interior_mask is None
          else jnp.asarray(x_interior_mask, jnp.float32))
    ym = (jnp.ones((Y,), jnp.float32) if y_interior_mask is None
          else jnp.asarray(y_interior_mask, jnp.float32))
    xm_ax, ym_ax = _batch_axis(xm, 1), _batch_axis(ym, 1)

    def one(fs, xmm, ymm):
        return stencil_fused(fs, params, spec, T=T, dt=dt,
                             interpret=interpret, y_tile=y_tile,
                             y_interior_mask=ymm, x_interior_mask=xmm)

    return jax.vmap(one, in_axes=((0,) * len(fields), xm_ax, ym_ax))(
        fields, xm, ym)


# ---------------------------------------------------------------------------
# in-kernel halo-band exchange: async remote DMA (TPU, compiled mode)
# ---------------------------------------------------------------------------


def _band_schedule(L: int, depth: int):
    """Per-hop band messages of one exchange side, shared by every engine.

    Returns ``[(k, cnt, hi_off, lo_off), ...]``: hop k moves `cnt` =
    min(L, depth-(k-1)L) planes/rows to/from the k-away ring neighbour, and
    the received bands land at extended-slab offsets `hi_off` (band from
    the predecessor side, global coordinates ascending) and `lo_off` (from
    the successor side). Offsets partition the hi halo [0, depth) and the
    lo halo [depth+L, depth+L+depth) of the extended slab exactly — the
    recv-slab addresses the remote-DMA kernel writes and the emulation's
    assembly both use, and the operand sizes
    `stencil.distributed.remote_dma_schedule_wire_bytes` sums. Lives in
    the kernels layer because `_kernel_band_dma` issues exactly one
    `make_async_remote_copy` per (field, side, hop) entry of this list;
    `stencil.distributed` re-exports it for the ppermute emulation.
    """
    hops = -(-depth // L)
    sched = []
    for k in range(1, hops + 1):
        cnt = min(L, depth - (k - 1) * L)
        sched.append((k, cnt, depth - (k - 1) * L - cnt, depth + k * L))
    return sched


def band_checksum(band):
    """Integrity word over ONE `_band_schedule` message: the uint32
    wraparound sum of the band's raw 32-bit words, shaped ``(1,)`` so it
    can ride the same transport as the band itself.

    Exact and order-independent by construction — modular integer
    addition is associative/commutative, so sender and receiver compute
    the IDENTICAL word from identical bytes regardless of reduction
    order, and the verified exchange can gate BITWISE no-op against the
    unchecked one (a float reduction could not: its rounding depends on
    shape/order). Lives in the kernels layer beside `_band_schedule`
    because the word is part of the band-message wire format every
    engine shares; `stencil.distributed` verifies it per received band
    and `roofline.integrity_bytes_model` prices one word per message.

    Requires a 4-byte element type (the stencil fields are f32); other
    widths would need a different word packing and are rejected loudly.
    """
    if band.dtype.itemsize != 4:
        raise TypeError(
            f"band_checksum packs 32-bit words; got dtype {band.dtype} "
            f"(itemsize {band.dtype.itemsize})")
    bits = jax.lax.bitcast_convert_type(band, jnp.uint32)
    return jnp.sum(bits, dtype=jnp.uint32).reshape((1,))


def _band_slice(ref, dim: int, lo: int, size: int):
    """`size` planes (dim=0) or rows (dim=1) of `ref` starting at `lo`."""
    if dim == 0:
        return ref.at[pl.ds(lo, size)]
    return ref.at[:, pl.ds(lo, size)]


def _halo_slice(ref, slot, dim: int, lo: int, size: int):
    """`size` planes/rows of the `slot` recv slab of a double-buffered
    `(2,) + band` output ref, starting at halo-local offset `lo`. `slot`
    may be a traced value (the dynamic DMA parity)."""
    if dim == 0:
        return ref.at[slot, pl.ds(lo, size)]
    return ref.at[slot, :, pl.ds(lo, size)]


def _kernel_band_dma(step_ref, u_ref, v_ref, w_ref,
                     uhi_ref, ulo_ref, vhi_ref, vlo_ref, whi_ref, wlo_ref,
                     *scratch, axis, mesh_axes, n, depth, dim, L, sched):
    """One depth-T band exchange along mesh axis `axis`, issued as async
    remote DMA from INSIDE the kernel — the paper's §IV move of the
    transfer schedule out of the tooling's hands and into the kernel's.

    Per field, side and `_band_schedule` hop, a boundary band is staged
    through a VMEM send slab (`make_async_copy`) and then
    `make_async_remote_copy`'d into the k-away ring neighbour's
    DOUBLE-BUFFERED recv slab, at the hop's `hi_off`/`lo_off` recv
    offset (halo-local). All sends (3 fields x 2 sides x hops) are
    started before any wait: the DMAs fly concurrently and the issue
    order follows the fused ring's consumption order (the x-lo band
    feeds the ring's earliest grid steps). The entry barrier is the
    capacity handshake: every hop partner has entered this block's
    exchange — and therefore vacated the slot being written — before any
    band lands.

    The recv slot is `step_ref[0] % 2` — a TRACED value read from SMEM,
    so a pipelined multi-block driver (`stencil.distributed.
    make_distributed_run`) threads the block counter through ONE traced
    program and alternates parity without retracing: block k+1's bands
    always have a vacant slot to land in while block k's interior
    computes. Scope honesty: this call still waits all its DMAs before
    returning, so realising that cross-block landing needs the driver's
    ROADMAPped boundary-first continuation — what is delivered here is
    the dynamic parity and the multi-hop schedule.

    The traffic is ring-symmetric (for every hop k, everyone sends its
    tail forward-k and its head backward-k), so each device's descriptor
    also waits its OWN incoming bands: `rdma.wait()` blocks on the local
    send semaphore and on the recv semaphore its hop partner's copy
    signals.
    """
    hops = len(sched)
    sbufs = scratch[:hops]
    stage_sem, send_sem, recv_sem = scratch[hops:]
    slot = jax.lax.rem(step_ref[0], 2)
    coords = [jax.lax.axis_index(a) for a in mesh_axes]
    barrier = pltpu.get_barrier_semaphore()
    for k, _, _, _ in sched:
        for delta in (k, -k):
            dev = dma_neighbor_coords(mesh_axes, coords, axis, delta, n)
            pltpu.semaphore_signal(barrier, 1, device_id=dev,
                                   device_id_type=pltpu.DeviceIdType.MESH)
    pltpu.semaphore_wait(barrier, 2 * hops)
    rdmas = []
    for fi, (f_ref, hi_ref, lo_ref) in enumerate(
            ((u_ref, uhi_ref, ulo_ref), (v_ref, vhi_ref, vlo_ref),
             (w_ref, whi_ref, wlo_ref))):
        # side 0: my tail -> the k-away successor's hi slab (it reads those
        # planes/rows first); side 1: my head -> the k-away predecessor's
        # lo slab. Offsets are `_band_schedule`'s, rebased halo-local.
        for hk, (k, cnt, hi_off, lo_off) in enumerate(sched):
            fwd = dma_neighbor_coords(mesh_axes, coords, axis, k, n)
            bwd = dma_neighbor_coords(mesh_axes, coords, axis, -k, n)
            for si, (src_lo, dst_ref, dst_dev, dst_off) in enumerate(
                    ((L - cnt, hi_ref, fwd, hi_off),
                     (0, lo_ref, bwd, lo_off - (depth + L)))):
                stage = pltpu.make_async_copy(
                    _band_slice(f_ref, dim, src_lo, cnt),
                    sbufs[hk].at[fi, si], stage_sem.at[fi, si, hk])
                stage.start()
                stage.wait()
                rdma = pltpu.make_async_remote_copy(
                    src_ref=sbufs[hk].at[fi, si],
                    dst_ref=_halo_slice(dst_ref, slot, dim, dst_off, cnt),
                    send_sem=send_sem.at[fi, si, hk],
                    recv_sem=recv_sem.at[fi, si, hk],
                    device_id=dst_dev,
                    device_id_type=pltpu.DeviceIdType.MESH)
                rdma.start()
                rdmas.append(rdma)
    for rdma in rdmas:
        rdma.wait()


def halo_band_exchange_dma(u, v, w, *, axis: str, mesh_axes, n: int,
                           depth: int, dim: int, block_index=0,
                           collective_id: int = 0):
    """Exchange depth-`depth` boundary bands of three fields along mesh
    axis `axis` via in-kernel async remote DMA (TPU compiled mode ONLY —
    Mosaic semaphores have no interpret/CPU path; `stencil.distributed`
    runs its schedule-faithful ppermute emulation there instead, and the
    two are gated bitwise-equal).

    Returns ``((u_hi, u_lo), (v_hi, v_lo), (w_hi, w_lo))`` where `hi` is
    the band arriving from the ring predecessors (global coordinates just
    below the shard) and `lo` from the successors — the same contract as
    the collective `_exchange_halos`, so the caller-side slab assembly and
    the x-then-y corner ordering are engine-independent. Multi-hop: when
    `depth` exceeds the local extent, `_band_schedule` splits each side
    into ceil(depth/L) band messages and the kernel issues one
    `make_async_remote_copy` per (field, side, hop), each landing at its
    schedule recv offset, so arbitrarily deep halos move without falling
    back to the collective engine (the caller still bounds
    T <= global extent - 2 — past that no interior cell exists whose
    cone the ring can serve).

    `block_index` is the substep-block number k — a Python int or a
    TRACED scalar: the receive slabs are double-buffered on k % 2 and the
    parity is selected dynamically (SMEM-read slot in the kernel,
    `dynamic_index_in_dim` on the outputs), so the pipelined multi-block
    driver alternates slots inside one traced program instead of
    rebuilding per block. `collective_id` must differ between the x and y
    phases so their barrier semaphores stay distinct.
    """
    if dim not in (0, 1):
        raise ValueError(f"dim must be 0 (x-planes) or 1 (y-rows), got {dim}")
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    L = u.shape[dim]
    sched = _band_schedule(L, depth)
    band_shape = ((depth,) + u.shape[1:] if dim == 0
                  else (u.shape[0], depth) + u.shape[2:])

    def stage_shape(cnt):
        return ((cnt,) + u.shape[1:] if dim == 0
                else (u.shape[0], cnt) + u.shape[2:])

    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    smem_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
    out_shape = [jax.ShapeDtypeStruct((2,) + band_shape, u.dtype)
                 for _ in range(6)]
    fn = pl.pallas_call(
        functools.partial(_kernel_band_dma, axis=axis,
                          mesh_axes=tuple(mesh_axes), n=n, depth=depth,
                          dim=dim, L=L, sched=tuple(sched)),
        in_specs=[smem_spec, any_spec, any_spec, any_spec],
        out_specs=[any_spec] * 6,
        out_shape=out_shape,
        scratch_shapes=(
            # one staged-send slab per hop, sized to that hop's band
            [pltpu.VMEM((3, 2) + stage_shape(cnt), u.dtype)
             for _, cnt, _, _ in sched]
            + [pltpu.SemaphoreType.DMA((3, 2, len(sched))),  # staging
               pltpu.SemaphoreType.DMA((3, 2, len(sched))),  # remote send
               pltpu.SemaphoreType.DMA((3, 2, len(sched)))]  # remote recv
        ),
        compiler_params=pltpu.TPUCompilerParams(collective_id=collective_id),
    )
    block = jnp.asarray(block_index, jnp.int32)
    outs = fn(block.reshape((1,)), u, v, w)
    # dynamic parity: traced block counters (the pipelined driver's
    # fori_loop induction variable) select the recv slot without retracing
    slot = jax.lax.rem(block, 2)
    sel = [jax.lax.dynamic_index_in_dim(o, slot, 0, keepdims=False)
           for o in outs]
    return ((sel[0], sel[1]), (sel[2], sel[3]), (sel[4], sel[5]))


# ---------------------------------------------------------------------------
# analytic VMEM / HBM traffic models
# ---------------------------------------------------------------------------


def fused_register_bytes(T: int, y_rows: int, Z: int, itemsize: int = 4,
                         y_tile: int | None = None,
                         halo: int | None = None, *, n_fields: int = 3,
                         n_slots: int = 3,
                         n_levels: int | None = None) -> int:
    """VMEM footprint of the fused shift register: by default 3 fields x
    3T slices (the hand-written v4 ring).

    With Y-tiling each resident slice has ``y_tile + 2*halo`` rows (tile +
    slab halo; halo defaults to T, the fused contamination depth) no matter
    how large the grid's Y is — the Fig. 8 scaling contract, identical for
    the in-grid and host-tiled paths. Pass ``halo=8`` (the sublane-rounded
    fetch halo) to size the `wide` grid-tiled ring with T=1.

    The spec-driven generalised ring (`stencil_fused`) is sized by the
    same formula with `n_fields=spec.n_fields`,
    `n_slots=2*spec.radius + 1`, `n_levels=spec.stages*T` and
    `halo=spec.halo(T)`.
    """
    h = T if halo is None else halo
    levels = T if n_levels is None else n_levels
    rows = y_rows if y_tile is None else min(y_tile + 2 * h, y_rows)
    return n_fields * (n_slots * levels) * rows * Z * itemsize


def dma_slab_bytes(shape, depth: int, dim: int, itemsize: int = 4, *,
                   n_fields: int = 3) -> tuple[int, int]:
    """Static sizes of the remote-DMA exchange's on-chip slabs for one
    phase over a `shape` shard: ``(staged_send, recv)`` bytes, exactly
    the scratch/out shapes `halo_band_exchange_dma` declares —
    per-hop ``(n_fields, 2 sides) x stage_shape(cnt)`` VMEM staging
    slabs (the hop band counts partition `depth`, so the sum is
    depth-exact regardless of hop count) and ``n_fields x 2 sides x
    2 recv slots`` of the full depth band. The analysis layer's
    `vmem.distributed_block_plan` budgets these against
    `roofline.VMEM_PER_CORE` before anything compiles."""
    other = 1
    for d, s in enumerate(shape):
        if d != dim:
            other *= s
    staged = sum(n_fields * 2 * cnt * other * itemsize
                 for _, cnt, _, _ in _band_schedule(shape[dim], depth))
    recv = n_fields * 2 * 2 * depth * other * itemsize
    return staged, recv


def _n_y_tiles(Y: int, y_tile: int | None) -> int:
    if y_tile is None or y_tile >= Y:
        return 1
    return -(-Y // y_tile)


def _host_overlap_rows(Y: int, y_tile: int | None, halo: int) -> int:
    """Rows the HOST loop restages per x-slice: 2*halo per interior tile
    boundary. The host path tiles ANY y_tile >= 1 (edge blocks just clamp
    their halo), so this uses the plain ceil-div tile count — deliberately
    unlike `_grid_geometry`, whose untiled fallback models the in-grid
    kernel refusing slabs that cannot fit (`y_tile + 2*halo > Y`).
    `core.roofline.stencil_tiling_bytes_factor` is this same formula as a
    multiplier; tests pin the two together.
    """
    return 2 * halo * (_n_y_tiles(Y, y_tile) - 1)


def _check_wide_model_tile(Y: int, y_tile: int | None,
                           grid_tiled: bool) -> None:
    """Mirror advect_wide's tiling contract in the analytic models: no host
    path exists at all, and the in-grid path needs a sublane-multiple
    tile."""
    if y_tile is None or y_tile >= Y:
        return
    if not grid_tiled:
        raise ValueError("wide cannot Y-tile host-side; model grid_tiled=True"
                         " or use dataflow/fused")
    if y_tile % 8:
        raise ValueError(
            f"wide y_tile must be a multiple of 8 (sublane), got {y_tile}; "
            "no such execution path exists to model")


def hbm_bytes_model(X: int, Y: int, Z: int, itemsize: int, variant: str,
                    *, T: int = 1, y_tile: int | None = None,
                    grid_tiled: bool = True, fuse_update: bool = True,
                    n_fields: int = 3,
                    halo_depth: int | None = None) -> int:
    """Analytic HBM traffic per advection call (for the Fig. 3/9 tables).

    `T` is the number of explicit-Euler steps the call advances: the
    pre-fusion variants pay a full read+write pass per step, while `fused`
    streams each field in and out ONCE for all T steps — the ~T×
    amortisation of Fig. 9.

    `grid_tiled=True` (the kernels' default path) models the in-grid
    `(y_tile, x)` tiling at compulsory traffic: outputs are written in
    place (the host loop's write-side halo duplication is gone outright)
    and the read-side stencil halo is charged to VMEM slab residency
    rather than HBM, so the HBM term carries ZERO halo overlap — every
    domain byte moves exactly once per pass, independent of `y_tile`.
    The relocated halo bytes are reported by ``vmem_halo_bytes_model``.
    (This is the analytic contract for the Fig. 3/8/9 tables; the
    interpret-mode reference implementation still materialises each
    slab window per grid step.) `grid_tiled=False` models the retained
    host-side loop (`tiling="host"`), which restages `2*halo` rows per
    interior tile boundary from HBM on BOTH the read and write side.

    `fuse_update=False` additionally charges the separate explicit-Euler
    update pass the non-fused variants pay when the update is NOT fused
    into the kernel (read field + read source + write field per step —
    dense contiguous arrays, so no lane penalty); `fuse_update=True`
    matches kernels run with their `fuse_update=True` flag (and `fused`,
    where the update is inherently in-kernel).

    `n_fields` and `halo_depth` generalise the model to the stencil-spec
    frontend: the spec-driven fused kernel streams `spec.n_fields` fields
    per pass with a slab halo of `spec.halo(T) = radius*stages*T` (the
    default `halo_depth=None` keeps the hand-written ladder's depths —
    T for `fused`, 1 otherwise). HBM traffic per fused pass is
    `n_fields`-proportional and halo-independent on the grid-tiled path:
    one compulsory read + write of every field, exactly what the MONC
    multi-kernel amortisation story predicts when extra operators ride
    the same rings.
    """
    slice_b = Y * Z * itemsize
    lane_eff = 1.0 if Z % 128 == 0 else (Z % 128) / 128.0
    if variant == "wide":
        _check_wide_model_tile(Y, y_tile, grid_tiled)
    if halo_depth is None:
        halo = T if variant == "fused" else 1
    else:
        halo = halo_depth
    # host tiling: interior tile boundaries each re-read `halo` rows from
    # both sides; in-grid tiling serves those rows from VMEM instead
    overlap_rows = 0 if grid_tiled else _host_overlap_rows(Y, y_tile, halo)
    tiled_slice_b = (Y + overlap_rows) * Z * itemsize
    if variant == "blocked":
        # n_fields x 3 views x X slices
        reads = T * n_fields * 3 * X * tiled_slice_b
    elif variant in ("dataflow", "wide"):
        reads = T * n_fields * X * tiled_slice_b
    elif variant == "fused":
        reads = n_fields * X * tiled_slice_b   # ONE pass for all T steps
    elif variant == "pointwise":
        # naive per-point gathers (7-point)
        reads = T * n_fields * 7 * X * slice_b
    else:
        raise ValueError(variant)
    # host tiling: each block's kernel writes its full slab (halo rows
    # included, trimmed host-side), so the overlap is paid on the write side
    # too — except pointwise, which has no tiled execution path. In-grid
    # tiling writes every output row exactly once (overlap_rows == 0).
    w_slice_b = slice_b if variant == "pointwise" else tiled_slice_b
    writes = (1 if variant == "fused" else T) * n_fields * X * w_slice_b
    eff = lane_eff if variant != "wide" else 1.0
    total = (reads + writes) / eff
    if not fuse_update and variant != "fused":
        # unfused host-side `f + dt*s` pass: read field + read source +
        # write field, per field per step (contiguous, no lane penalty)
        total += T * 3 * n_fields * X * slice_b
    return int(total)


def vmem_halo_bytes_model(X: int, Y: int, Z: int, itemsize: int,
                          variant: str, *, T: int = 1,
                          y_tile: int | None = None, n_fields: int = 3,
                          halo_depth: int | None = None) -> int:
    """Halo re-read bytes the in-grid path serves from VMEM instead of HBM.

    This is the read-side overlap the host-tiled model charges to HBM
    (`2*halo` rows per interior tile boundary, per x-slice, per field,
    per view for `blocked`), relocated on-chip: the slab rows are already
    resident in the persistent shift register when the tile's stencil
    re-reads them. The halo is the slab's FETCH halo — T for `fused`,
    the sublane-rounded 8 rows for `wide` (matching what
    ``fused_register_bytes(halo=8)`` sizes), 1 for the other source
    kernels — and the untiled fallback (`y_tile + 2*halo > Y`, where the
    kernel runs a single full-domain tile) is mirrored, so configs with
    no tiled execution report zero. The host path's write-side overlap
    has no VMEM counterpart — in-grid outputs are simply written once.

    `n_fields` / `halo_depth` generalise to the stencil-spec frontend:
    the spec kernel's slab halo is `spec.halo(T)` deep and every one of
    `spec.n_fields` rings re-reads it from VMEM residency.
    """
    if variant == "pointwise":
        return 0   # no tiled execution path
    if variant == "wide":
        _check_wide_model_tile(Y, y_tile, grid_tiled=True)
    if halo_depth is None:
        halo = {"fused": T, "wide": _WIDE_HALO}.get(variant, 1)
    else:
        halo = halo_depth
    _, _, n_ty = _grid_geometry(Y, y_tile, halo)
    overlap_rows = 2 * halo * (n_ty - 1)
    views = 3 if variant == "blocked" else 1
    passes = 1 if variant == "fused" else T
    return passes * views * n_fields * X * overlap_rows * Z * itemsize

"""Pallas TPU kernels for PW advection — the paper's Fig. 3 ladder on TPU.

FPGA -> TPU mapping of the paper's stages:

  v1 `blocked`   : grid over x; each step fetches the (x-1, x, x+1) z-y slices
                   of all three fields from HBM into VMEM (three index-mapped
                   views per field). This is the paper's *initial* BRAM-blocked
                   kernel: correct, pipelined by Pallas, but每 slice is fetched
                   three times — the "pipeline drains / re-reads" regime.

  v2 `dataflow`  : grid over x with a persistent VMEM shift-register
                   (3, Y, Z) per field. Each step fetches exactly ONE new
                   slice and rotates the register — the paper's "shift the
                   current slices down by one, retrieve x+1" (Listing 1 lines
                   9-13) fused with its dataflow pipeline (Fig. 4): the Pallas
                   grid pipeline double-buffers the incoming slice against
                   compute, so load/compute/store overlap structurally.
                   HBM traffic drops 3x vs v1 — the Fig. 3 rows 3-5 move.

  v3 `wide`      : v2 with lane-aligned slices (Z a multiple of 128, f32
                   (8,128) tiling). One HBM->VMEM transaction carries 128
                   lanes — the 64->256-bit port widening of Fig. 3 rows 6-7.
                   Kernel body is identical; alignment is a contract on the
                   data layout (checked), and the benchmark charges misaligned
                   grids the measured lane-efficiency penalty.

  v4 `fused`     : temporal blocking — T explicit-Euler steps per HBM pass.
                   The shift register widens to T stacked 3-slice rings, one
                   per time level: as input slice x=i streams in (level 0),
                   level k produces its slice x=i-k from level k-1's ring, so
                   the step-T field leaves the chip the only time it touches
                   HBM. Per T steps the kernel reads 3·X and writes 3·X
                   slices where v2/v3 read+write 6·T·X — HBM traffic drops
                   ~T× (the on-chip-reuse endgame of the paper's Fig. 3
                   progression; cf. Brown 2020/2021 on amortising MONC
                   advection transfers across reuse). Register cost is
                   3 fields × 3T slices; with Y-tiling (halo T per side)
                   it is VMEM-bounded at (3T, TY+2T, Z) per field for any Y.

`blocked`/`dataflow`/`fused` accept `y_tile`: the domain is processed in
halo-overlapped y-blocks (halo 1 for the source kernels, halo T for v4's
T-step update), keeping the VMEM working set fixed regardless of Y — this
is what unlocks the paper's Fig. 8 grids (Y=1024, 67M/268M cells) on a
16 MiB-VMEM part. `wide` rejects `y_tile` (tile+halo rows cannot satisfy
its sublane contract); at large Y use `fused`, which subsumes it.

Validated with interpret=True against ref.pw_advect_ref, the f64 oracle, and
the multi-step f64 oracle (fused) across shape/dtype/T/y_tile sweeps in
tests/test_advection_kernels.py and tests/test_advection_fused.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.advection.ref import AdvectParams


def _source_slices(um, uc, up, vm, vc, vp, wm, wc, wp, tcx, tcy, tzc1, tzc2):
    """PW source terms for one x-slice. Inputs (Y, Z) f32 views."""
    def inner(f):
        return f[1:-1, 1:-1]

    def sh(f_m, f_c, f_p, di, dj, dk):
        f = {-1: f_m, 0: f_c, 1: f_p}[di]
        Y, Z = f.shape
        return f[1 + dj:Y - 1 + dj, 1 + dk:Z - 1 + dk]

    t1 = tzc1[1:-1]
    t2 = tzc2[1:-1]

    def source(fm, fc, fp):
        fx = tcx * (sh(um, uc, up, -1, 0, 0) * (inner(fc) + inner(fm))
                    - sh(um, uc, up, 1, 0, 0) * (inner(fc) + inner(fp)))
        fy = tcy * (sh(vm, vc, vp, 0, -1, 0) * (inner(fc) + fc[0:-2, 1:-1])
                    - sh(vm, vc, vp, 0, 1, 0) * (inner(fc) + fc[2:, 1:-1]))
        fz = (t1 * sh(wm, wc, wp, 0, 0, -1) * (inner(fc) + fc[1:-1, 0:-2])
              - t2 * sh(wm, wc, wp, 0, 0, 1) * (inner(fc) + fc[1:-1, 2:]))
        return fx + fy + fz

    return (source(um, uc, up), source(vm, vc, vp), source(wm, wc, wp))


def _pad_edges(s):
    return jnp.pad(s, ((1, 1), (1, 1)))


# ---------------------------------------------------------------------------
# v1: blocked — three slice views per field, 3x HBM traffic
# ---------------------------------------------------------------------------


def _kernel_blocked(t1_ref, t2_ref,
                    um_ref, uc_ref, up_ref, vm_ref, vc_ref, vp_ref,
                    wm_ref, wc_ref, wp_ref,
                    su_ref, sv_ref, sw_ref, *, X):
    i = pl.program_id(0)
    args = [r[0] for r in (um_ref, uc_ref, up_ref, vm_ref, vc_ref, vp_ref,
                           wm_ref, wc_ref, wp_ref)]
    su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                t1_ref[2:], t2_ref[2:])
    interior = (i >= 1) & (i <= X - 2)
    for ref, s in ((su_ref, su), (sv_ref, sv), (sw_ref, sw)):
        ref[0] = jnp.where(interior, _pad_edges(s), 0.0).astype(ref.dtype)


def advect_blocked(u, v, w, p: AdvectParams, *, interpret: bool = True,
                   y_tile: int | None = None):
    if y_tile is not None and y_tile < u.shape[1]:
        fn = lambda a, b, c: advect_blocked(a, b, c, p, interpret=interpret)
        return _y_tiled(fn, u, v, w, y_tile=y_tile, halo=1)
    X, Y, Z = u.shape
    slice_spec = lambda off: pl.BlockSpec(
        (1, Y, Z),
        lambda i: (jnp.clip(i + off, 0, X - 1), 0, 0))
    # pack scalars+z-metrics into one (Z+2,) vector per metric for simplicity
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda i: (0,))
    out_spec = pl.BlockSpec((1, Y, Z), lambda i: (i, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3
    fn = pl.pallas_call(
        functools.partial(_kernel_blocked, X=X),
        grid=(X,),
        in_specs=[tz_spec, tz_spec] + [slice_spec(o) for _ in range(3)
                                       for o in (-1, 0, 1)],
        out_specs=[out_spec] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(t1, t2, u, u, u, v, v, v, w, w, w)


# ---------------------------------------------------------------------------
# v2: dataflow — persistent VMEM shift register, 1x HBM traffic
# ---------------------------------------------------------------------------


def _kernel_dataflow(t1_ref, t2_ref, u_ref, v_ref, w_ref,
                     su_ref, sv_ref, sw_ref,
                     ubuf, vbuf, wbuf, *, X):
    i = pl.program_id(0)
    # 1) shift register: store the newly-arrived slice at ring position i%3
    slot = jax.lax.rem(i, 3)
    load = i <= X - 1
    for buf, ref in ((ubuf, u_ref), (vbuf, v_ref), (wbuf, w_ref)):
        cur = buf[slot]
        buf[slot] = jnp.where(load, ref[0], cur)
    # 2) compute x = i-1 from ring slots (i-2, i-1, i)
    m, c, pslot = (jax.lax.rem(i + 1, 3), jax.lax.rem(i + 2, 3),
                   jax.lax.rem(i, 3))
    args = [ubuf[m], ubuf[c], ubuf[pslot],
            vbuf[m], vbuf[c], vbuf[pslot],
            wbuf[m], wbuf[c], wbuf[pslot]]
    su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                t1_ref[2:], t2_ref[2:])
    interior = (i >= 2) & (i <= X - 1)
    for ref, s in ((su_ref, su), (sv_ref, sv), (sw_ref, sw)):
        ref[0] = jnp.where(interior, _pad_edges(s), 0.0).astype(ref.dtype)


def _y_tiled(fn, u, v, w, *, y_tile: int, halo: int):
    """Run a slice kernel over halo-overlapped y-blocks and restitch.

    Each block sees `halo` extra rows per interior side; the kernel treats
    block edges as boundaries (zero source), which contaminates at most
    `halo` rows per side after `halo` update sweeps — exactly the rows we
    trim. Global-edge blocks get no extra rows, so the true boundary
    condition lands on the block edge. HBM cost of the overlap is charged in
    `hbm_bytes_model(..., y_tile=...)`.
    """
    Y = u.shape[1]
    outs = ([], [], [])
    for y0 in range(0, Y, y_tile):
        y1 = min(y0 + y_tile, Y)
        lo, hi = max(y0 - halo, 0), min(y1 + halo, Y)
        tile = fn(u[:, lo:hi], v[:, lo:hi], w[:, lo:hi])
        for acc, t in zip(outs, tile):
            acc.append(t[:, y0 - lo:y0 - lo + (y1 - y0)])
    return tuple(jnp.concatenate(a, axis=1) for a in outs)


def advect_dataflow(u, v, w, p: AdvectParams, *, interpret: bool = True,
                    y_tile: int | None = None):
    if y_tile is not None and y_tile < u.shape[1]:
        fn = lambda a, b, c: advect_dataflow(a, b, c, p, interpret=interpret)
        return _y_tiled(fn, u, v, w, y_tile=y_tile, halo=1)
    X, Y, Z = u.shape
    in_spec = pl.BlockSpec((1, Y, Z), lambda i: (jnp.minimum(i, X - 1), 0, 0))
    out_spec = pl.BlockSpec((1, Y, Z),
                            lambda i: (jnp.clip(i - 1, 0, X - 1), 0, 0))
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3
    fn = pl.pallas_call(
        functools.partial(_kernel_dataflow, X=X),
        grid=(X + 1,),
        in_specs=[tz_spec, tz_spec, in_spec, in_spec, in_spec],
        out_specs=[out_spec] * 3,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((3, Y, Z), u.dtype) for _ in range(3)],
        interpret=interpret,
    )
    return fn(t1, t2, u, v, w)


# ---------------------------------------------------------------------------
# v3: wide — v2 with lane-aligned layout (Z % 128 == 0)
# ---------------------------------------------------------------------------


def advect_wide(u, v, w, p: AdvectParams, *, interpret: bool = True,
                y_tile: int | None = None):
    Z = u.shape[2]
    if Z % 128:
        raise ValueError(
            f"advect_wide requires lane-aligned Z (multiple of 128), got {Z}; "
            "use advect_dataflow and accept the lane-efficiency penalty")
    if u.shape[1] % 8:
        raise ValueError(f"Y must be a multiple of 8 (sublane), got {u.shape[1]}")
    if y_tile is not None and y_tile < u.shape[1]:
        # halo'd blocks are y_tile+2 (edge: +1) rows — never a sublane
        # multiple, so tiling would silently break the layout contract this
        # variant exists to enforce
        raise ValueError(
            "advect_wide cannot Y-tile (tile+halo rows break the (8,128) "
            "sublane contract); use advect_dataflow(y_tile=...) or "
            "advect_fused")
    return advect_dataflow(u, v, w, p, interpret=interpret)


# ---------------------------------------------------------------------------
# v4: fused — temporal blocking, T Euler steps per HBM pass
# ---------------------------------------------------------------------------


def _kernel_fused(t1_ref, t2_ref, u_ref, v_ref, w_ref,
                  ou_ref, ov_ref, ow_ref,
                  ubuf, vbuf, wbuf, *, X, T, dt):
    """T stacked 3-slice rings: level k holds the step-k fields.

    At grid step i the newly-arrived input slice x=i lands in level 0's ring;
    level k (k=1..T) then computes its slice x=i-k from level k-1's ring.
    Level k-1's slice x=j is stored at grid step j+k-1, so for every level
    the (x-1, x, x+1) operands sit at ring slots ((i+1)%3, (i+2)%3, i%3) and
    every level writes slot i%3 — the same rotation as v2, T-deep.

    Startup/tail slices (x<0 or x>X-1) are garbage but provably walled off:
    a level's x=0 / x=X-1 output is a masked copy of its centre operand, and
    the depth-1 stencil cannot carry values past an unchanging slice.
    """
    i = pl.program_id(0)
    slot = jax.lax.rem(i, 3)
    m, c = jax.lax.rem(i + 1, 3), jax.lax.rem(i + 2, 3)
    for buf, ref in ((ubuf, u_ref), (vbuf, v_ref), (wbuf, w_ref)):
        buf[0, slot] = ref[0]
    outs = None
    for k in range(1, T + 1):
        j = i - k
        args = [ubuf[k - 1, m], ubuf[k - 1, c], ubuf[k - 1, slot],
                vbuf[k - 1, m], vbuf[k - 1, c], vbuf[k - 1, slot],
                wbuf[k - 1, m], wbuf[k - 1, c], wbuf[k - 1, slot]]
        su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                    t1_ref[2:], t2_ref[2:])
        interior = (j >= 1) & (j <= X - 2)
        new = []
        for cen, s in ((args[1], su), (args[4], sv), (args[7], sw)):
            src = jnp.where(interior, _pad_edges(s), 0.0).astype(cen.dtype)
            new.append(cen + dt * src)
        if k < T:
            ubuf[k, slot], vbuf[k, slot], wbuf[k, slot] = new
        else:
            outs = new
    for ref, val in zip((ou_ref, ov_ref, ow_ref), outs):
        ref[0] = val


def advect_fused(u, v, w, p: AdvectParams, *, T: int = 4, dt: float = 1.0,
                 interpret: bool = True, y_tile: int | None = None):
    """v4: advance the fields T explicit-Euler steps in ONE HBM pass.

    Returns the advanced `(u, v, w)` (not sources — the step is fused into
    the kernel). With `y_tile`, each y-block carries a T-deep halo so the
    register is VMEM-bounded at ``fused_register_bytes`` irrespective of Y.
    """
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    if y_tile is not None and y_tile < u.shape[1]:
        fn = lambda a, b, c: advect_fused(a, b, c, p, T=T, dt=dt,
                                          interpret=interpret)
        return _y_tiled(fn, u, v, w, y_tile=y_tile, halo=T)
    X, Y, Z = u.shape
    in_spec = pl.BlockSpec((1, Y, Z), lambda i: (jnp.minimum(i, X - 1), 0, 0))
    out_spec = pl.BlockSpec((1, Y, Z),
                            lambda i: (jnp.clip(i - T, 0, X - 1), 0, 0))
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3
    fn = pl.pallas_call(
        functools.partial(_kernel_fused, X=X, T=T, dt=dt),
        grid=(X + T,),
        in_specs=[tz_spec, tz_spec, in_spec, in_spec, in_spec],
        out_specs=[out_spec] * 3,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((T, 3, Y, Z), u.dtype) for _ in range(3)],
        interpret=interpret,
    )
    return fn(t1, t2, u, v, w)


def fused_register_bytes(T: int, y_rows: int, Z: int, itemsize: int = 4,
                         y_tile: int | None = None) -> int:
    """VMEM footprint of v4's shift register: 3 fields x 3T slices.

    With Y-tiling each resident slice has ``y_tile + 2T`` rows (tile + halo)
    no matter how large the grid's Y is — the Fig. 8 scaling contract.
    """
    rows = y_rows if y_tile is None else min(y_tile + 2 * T, y_rows)
    return 3 * (3 * T) * rows * Z * itemsize


def _n_y_tiles(Y: int, y_tile: int | None) -> int:
    if y_tile is None or y_tile >= Y:
        return 1
    return -(-Y // y_tile)


def hbm_bytes_model(X: int, Y: int, Z: int, itemsize: int, variant: str,
                    *, T: int = 1, y_tile: int | None = None) -> int:
    """Analytic HBM traffic per advection call (for the Fig. 3/9 tables).

    `T` is the number of explicit-Euler steps the call advances: the
    pre-fusion variants pay a full read+write pass per step, while `fused`
    streams each field in and out ONCE for all T steps (plus the y-halo
    overlap when tiled) — the ~T× amortisation of Fig. 9.
    """
    slice_b = Y * Z * itemsize
    lane_eff = 1.0 if Z % 128 == 0 else (Z % 128) / 128.0
    if variant == "wide" and y_tile is not None and y_tile < Y:
        # mirror advect_wide: tiling breaks the sublane contract, so there
        # is no such execution path to model
        raise ValueError("wide cannot Y-tile; model dataflow or fused")
    n_ty = _n_y_tiles(Y, y_tile)
    halo = T if variant == "fused" else 1
    # interior tile boundaries each re-read `halo` rows from both sides
    overlap_rows = 2 * halo * (n_ty - 1)
    tiled_slice_b = (Y + overlap_rows) * Z * itemsize
    if variant == "blocked":
        reads = T * 3 * 3 * X * tiled_slice_b  # 3 fields x 3 views x X slices
    elif variant in ("dataflow", "wide"):
        reads = T * 3 * X * tiled_slice_b
    elif variant == "fused":
        reads = 3 * X * tiled_slice_b          # ONE pass for all T steps
    elif variant == "pointwise":
        reads = T * 3 * 7 * X * slice_b        # naive per-point gathers (7-point)
    else:
        raise ValueError(variant)
    # each tile's kernel writes its full slab (halo rows included, trimmed
    # host-side), so the overlap is paid on the write side too — except
    # pointwise, which has no tiled execution path
    w_slice_b = slice_b if variant == "pointwise" else tiled_slice_b
    writes = (1 if variant == "fused" else T) * 3 * X * w_slice_b
    eff = lane_eff if variant != "wide" else 1.0
    return int((reads + writes) / eff)

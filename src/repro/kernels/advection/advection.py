"""Pallas TPU kernels for PW advection — the paper's Fig. 3 ladder on TPU.

FPGA -> TPU mapping of the paper's stages:

  v1 `blocked`   : grid over x; each step fetches the (x-1, x, x+1) z-y slices
                   of all three fields from HBM into VMEM (three index-mapped
                   views per field). This is the paper's *initial* BRAM-blocked
                   kernel: correct, pipelined by Pallas, but每 slice is fetched
                   three times — the "pipeline drains / re-reads" regime.

  v2 `dataflow`  : grid over x with a persistent VMEM shift-register
                   (3, Y, Z) per field. Each step fetches exactly ONE new
                   slice and rotates the register — the paper's "shift the
                   current slices down by one, retrieve x+1" (Listing 1 lines
                   9-13) fused with its dataflow pipeline (Fig. 4): the Pallas
                   grid pipeline double-buffers the incoming slice against
                   compute, so load/compute/store overlap structurally.
                   HBM traffic drops 3x vs v1 — the Fig. 3 rows 3-5 move.

  v3 `wide`      : v2 with lane-aligned slices (Z a multiple of 128, f32
                   (8,128) tiling). One HBM->VMEM transaction carries 128
                   lanes — the 64->256-bit port widening of Fig. 3 rows 6-7.
                   Kernel body is identical; alignment is a contract on the
                   data layout (checked), and the benchmark charges misaligned
                   grids the measured lane-efficiency penalty.

Validated with interpret=True against ref.pw_advect_ref (and the f64 oracle)
across shape/dtype sweeps in tests/test_advection_kernels.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.advection.ref import AdvectParams


def _source_slices(um, uc, up, vm, vc, vp, wm, wc, wp, tcx, tcy, tzc1, tzc2):
    """PW source terms for one x-slice. Inputs (Y, Z) f32 views."""
    def inner(f):
        return f[1:-1, 1:-1]

    def sh(f_m, f_c, f_p, di, dj, dk):
        f = {-1: f_m, 0: f_c, 1: f_p}[di]
        Y, Z = f.shape
        return f[1 + dj:Y - 1 + dj, 1 + dk:Z - 1 + dk]

    t1 = tzc1[1:-1]
    t2 = tzc2[1:-1]

    def source(fm, fc, fp):
        fx = tcx * (sh(um, uc, up, -1, 0, 0) * (inner(fc) + inner(fm))
                    - sh(um, uc, up, 1, 0, 0) * (inner(fc) + inner(fp)))
        fy = tcy * (sh(vm, vc, vp, 0, -1, 0) * (inner(fc) + fc[0:-2, 1:-1])
                    - sh(vm, vc, vp, 0, 1, 0) * (inner(fc) + fc[2:, 1:-1]))
        fz = (t1 * sh(wm, wc, wp, 0, 0, -1) * (inner(fc) + fc[1:-1, 0:-2])
              - t2 * sh(wm, wc, wp, 0, 0, 1) * (inner(fc) + fc[1:-1, 2:]))
        return fx + fy + fz

    return (source(um, uc, up), source(vm, vc, vp), source(wm, wc, wp))


def _pad_edges(s):
    return jnp.pad(s, ((1, 1), (1, 1)))


# ---------------------------------------------------------------------------
# v1: blocked — three slice views per field, 3x HBM traffic
# ---------------------------------------------------------------------------


def _kernel_blocked(t1_ref, t2_ref,
                    um_ref, uc_ref, up_ref, vm_ref, vc_ref, vp_ref,
                    wm_ref, wc_ref, wp_ref,
                    su_ref, sv_ref, sw_ref, *, X):
    i = pl.program_id(0)
    args = [r[0] for r in (um_ref, uc_ref, up_ref, vm_ref, vc_ref, vp_ref,
                           wm_ref, wc_ref, wp_ref)]
    su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                t1_ref[2:], t2_ref[2:])
    interior = (i >= 1) & (i <= X - 2)
    for ref, s in ((su_ref, su), (sv_ref, sv), (sw_ref, sw)):
        ref[0] = jnp.where(interior, _pad_edges(s), 0.0).astype(ref.dtype)


def advect_blocked(u, v, w, p: AdvectParams, *, interpret: bool = True):
    X, Y, Z = u.shape
    slice_spec = lambda off: pl.BlockSpec(
        (1, Y, Z),
        lambda i: (jnp.clip(i + off, 0, X - 1), 0, 0))
    # pack scalars+z-metrics into one (Z+2,) vector per metric for simplicity
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda i: (0,))
    out_spec = pl.BlockSpec((1, Y, Z), lambda i: (i, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3
    fn = pl.pallas_call(
        functools.partial(_kernel_blocked, X=X),
        grid=(X,),
        in_specs=[tz_spec, tz_spec] + [slice_spec(o) for _ in range(3)
                                       for o in (-1, 0, 1)],
        out_specs=[out_spec] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(t1, t2, u, u, u, v, v, v, w, w, w)


# ---------------------------------------------------------------------------
# v2: dataflow — persistent VMEM shift register, 1x HBM traffic
# ---------------------------------------------------------------------------


def _kernel_dataflow(t1_ref, t2_ref, u_ref, v_ref, w_ref,
                     su_ref, sv_ref, sw_ref,
                     ubuf, vbuf, wbuf, *, X):
    i = pl.program_id(0)
    # 1) shift register: store the newly-arrived slice at ring position i%3
    slot = jax.lax.rem(i, 3)
    load = i <= X - 1
    for buf, ref in ((ubuf, u_ref), (vbuf, v_ref), (wbuf, w_ref)):
        cur = buf[slot]
        buf[slot] = jnp.where(load, ref[0], cur)
    # 2) compute x = i-1 from ring slots (i-2, i-1, i)
    m, c, pslot = (jax.lax.rem(i + 1, 3), jax.lax.rem(i + 2, 3),
                   jax.lax.rem(i, 3))
    args = [ubuf[m], ubuf[c], ubuf[pslot],
            vbuf[m], vbuf[c], vbuf[pslot],
            wbuf[m], wbuf[c], wbuf[pslot]]
    su, sv, sw = _source_slices(*args, 0.0 + t1_ref[0], t1_ref[1],
                                t1_ref[2:], t2_ref[2:])
    interior = (i >= 2) & (i <= X - 1)
    for ref, s in ((su_ref, su), (sv_ref, sv), (sw_ref, sw)):
        ref[0] = jnp.where(interior, _pad_edges(s), 0.0).astype(ref.dtype)


def advect_dataflow(u, v, w, p: AdvectParams, *, interpret: bool = True):
    X, Y, Z = u.shape
    in_spec = pl.BlockSpec((1, Y, Z), lambda i: (jnp.minimum(i, X - 1), 0, 0))
    out_spec = pl.BlockSpec((1, Y, Z),
                            lambda i: (jnp.clip(i - 1, 0, X - 1), 0, 0))
    t1 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc1])
    t2 = jnp.concatenate([p.tcx[None], p.tcy[None], p.tzc2])
    tz_spec = pl.BlockSpec((Z + 2,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((X, Y, Z), u.dtype)] * 3
    fn = pl.pallas_call(
        functools.partial(_kernel_dataflow, X=X),
        grid=(X + 1,),
        in_specs=[tz_spec, tz_spec, in_spec, in_spec, in_spec],
        out_specs=[out_spec] * 3,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((3, Y, Z), u.dtype) for _ in range(3)],
        interpret=interpret,
    )
    return fn(t1, t2, u, v, w)


# ---------------------------------------------------------------------------
# v3: wide — v2 with lane-aligned layout (Z % 128 == 0)
# ---------------------------------------------------------------------------


def advect_wide(u, v, w, p: AdvectParams, *, interpret: bool = True):
    Z = u.shape[2]
    if Z % 128:
        raise ValueError(
            f"advect_wide requires lane-aligned Z (multiple of 128), got {Z}; "
            "use advect_dataflow and accept the lane-efficiency penalty")
    if u.shape[1] % 8:
        raise ValueError(f"Y must be a multiple of 8 (sublane), got {u.shape[1]}")
    return advect_dataflow(u, v, w, p, interpret=interpret)


def hbm_bytes_model(X: int, Y: int, Z: int, itemsize: int, variant: str) -> int:
    """Analytic HBM traffic per advection call (for the Fig. 3 table)."""
    slice_b = Y * Z * itemsize
    lane_eff = 1.0 if Z % 128 == 0 else (Z % 128) / 128.0
    if variant == "blocked":
        reads = 3 * 3 * X * slice_b          # 3 fields x 3 views x X slices
    elif variant in ("dataflow", "wide"):
        reads = 3 * X * slice_b
    elif variant == "pointwise":
        reads = 3 * 7 * X * slice_b          # naive per-point gathers (7-point)
    else:
        raise ValueError(variant)
    writes = 3 * X * slice_b
    eff = lane_eff if variant != "wide" else 1.0
    return int((reads + writes) / eff)

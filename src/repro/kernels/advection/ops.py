"""Jit'd public wrappers for the PW-advection kernel ladder.

`pw_advect(..., variant=...)` selects the Fig. 3 rung; `interpret` toggles
Pallas interpret mode (CPU validation) vs compiled TPU execution. `y_tile`
runs the in-grid 2D `(y_tile, x)` tiling by default (`tiling="grid"`, one
kernel launch, no HBM halo restaging); `tiling="host"` keeps the retained
per-block host loop for comparison. `fuse_update=True` makes the v1-v3
rungs return advanced fields (`f + dt*s` fused in-kernel) instead of raw
sources. `pw_advect_fused` is the v4 temporal-blocking entry point: it
always returns the *advanced fields* after `T` fused Euler steps.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.advection import advection as K
from repro.kernels.advection import ref as REF

# source-computing rungs dispatchable via pw_advect; the v4 `fused` rung
# advances whole steps instead and has its own entry point, pw_advect_fused
VARIANTS = {
    "reference": None,
    "blocked": K.advect_blocked,
    "dataflow": K.advect_dataflow,
    "wide": K.advect_wide,
}


@functools.partial(jax.jit, static_argnames=("variant", "interpret", "y_tile",
                                             "tiling", "fuse_update", "dt"))
def pw_advect(u, v, w, params: REF.AdvectParams, *, variant: str = "dataflow",
              interpret: bool = True,
              y_tile: Optional[int] = None,
              tiling: str = "grid",
              fuse_update: bool = False,
              dt: float = 1.0
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Momentum sources (or advanced fields with `fuse_update=True`) via the
    selected ladder rung (v1-v3 + reference)."""
    if variant == "fused":
        raise ValueError("fused advances fields, not sources; "
                         "use pw_advect_fused")
    if variant == "reference":
        if fuse_update:
            return REF.pw_step_ref(u, v, w, params, dt)
        return REF.pw_advect_ref(u, v, w, params)
    fn = VARIANTS[variant]
    return fn(u, v, w, params, interpret=interpret, y_tile=y_tile,
              tiling=tiling, fuse_update=fuse_update, dt=dt)


@functools.partial(jax.jit,
                   static_argnames=("T", "dt", "interpret", "y_tile",
                                    "tiling"))
def pw_advect_fused(u, v, w, params: REF.AdvectParams, *, T: int = 4,
                    dt: float = 1.0, interpret: bool = True,
                    y_tile: Optional[int] = None,
                    tiling: str = "grid"
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Advance (u, v, w) by T fused Euler steps in one HBM pass (v4)."""
    return K.advect_fused(u, v, w, params, T=T, dt=dt, interpret=interpret,
                          y_tile=y_tile, tiling=tiling)


def traffic_model(shape, itemsize: int, variant: str, *, T: int = 1,
                  y_tile: Optional[int] = None, grid_tiled: bool = True,
                  fuse_update: bool = True) -> int:
    X, Y, Z = shape
    return K.hbm_bytes_model(X, Y, Z, itemsize,
                             "pointwise" if variant == "reference" else variant,
                             T=T, y_tile=y_tile, grid_tiled=grid_tiled,
                             fuse_update=fuse_update)

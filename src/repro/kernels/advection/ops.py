"""Jit'd public wrappers for the PW-advection kernel ladder.

`pw_advect(..., variant=...)` selects the Fig. 3 rung; `interpret` toggles
Pallas interpret mode (CPU validation) vs compiled TPU execution.
`pw_advect_fused` is the v4 temporal-blocking entry point: it returns the
*advanced fields* after `T` fused Euler steps, not sources.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.advection import advection as K
from repro.kernels.advection import ref as REF

# source-computing rungs dispatchable via pw_advect; the v4 `fused` rung
# advances whole steps instead and has its own entry point, pw_advect_fused
VARIANTS = {
    "reference": None,
    "blocked": K.advect_blocked,
    "dataflow": K.advect_dataflow,
    "wide": K.advect_wide,
}


@functools.partial(jax.jit, static_argnames=("variant", "interpret", "y_tile"))
def pw_advect(u, v, w, params: REF.AdvectParams, *, variant: str = "dataflow",
              interpret: bool = True,
              y_tile: Optional[int] = None
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Momentum sources via the selected ladder rung (v1-v3 + reference)."""
    if variant == "fused":
        raise ValueError("fused advances fields, not sources; "
                         "use pw_advect_fused")
    if variant == "reference":
        return REF.pw_advect_ref(u, v, w, params)
    fn = VARIANTS[variant]
    return fn(u, v, w, params, interpret=interpret, y_tile=y_tile)


@functools.partial(jax.jit,
                   static_argnames=("T", "dt", "interpret", "y_tile"))
def pw_advect_fused(u, v, w, params: REF.AdvectParams, *, T: int = 4,
                    dt: float = 1.0, interpret: bool = True,
                    y_tile: Optional[int] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Advance (u, v, w) by T fused Euler steps in one HBM pass (v4)."""
    return K.advect_fused(u, v, w, params, T=T, dt=dt, interpret=interpret,
                          y_tile=y_tile)


def traffic_model(shape, itemsize: int, variant: str, *, T: int = 1,
                  y_tile: Optional[int] = None) -> int:
    X, Y, Z = shape
    return K.hbm_bytes_model(X, Y, Z, itemsize,
                             "pointwise" if variant == "reference" else variant,
                             T=T, y_tile=y_tile)

"""Jit'd public wrappers for the PW-advection kernel ladder.

`pw_advect(..., variant=...)` selects the Fig. 3 rung; `interpret` toggles
Pallas interpret mode (CPU validation) vs compiled TPU execution.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax

from repro.kernels.advection import advection as K
from repro.kernels.advection import ref as REF

VARIANTS = {
    "reference": None,
    "blocked": K.advect_blocked,
    "dataflow": K.advect_dataflow,
    "wide": K.advect_wide,
}


@functools.partial(jax.jit, static_argnames=("variant", "interpret"))
def pw_advect(u, v, w, params: REF.AdvectParams, *, variant: str = "dataflow",
              interpret: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    if variant == "reference":
        return REF.pw_advect_ref(u, v, w, params)
    fn = VARIANTS[variant]
    return fn(u, v, w, params, interpret=interpret)


def traffic_model(shape, itemsize: int, variant: str) -> int:
    X, Y, Z = shape
    return K.hbm_bytes_model(X, Y, Z, itemsize,
                             "pointwise" if variant == "reference" else variant)

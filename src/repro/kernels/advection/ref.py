"""Pure-jnp oracle for the Piacsek-Williams advection stencil (MONC).

The paper's kernel: depth-1 3D stencil computing momentum source terms
(su, sv, sw) for wind fields (u, v, w) — "53 double precision operations per
grid cell" (21 add/sub + 32 mul). The exact MONC discretisation is not listed
in the paper; this is the standard PW centred form on the MONC grid, with the
z metric terms (tzc1/tzc2) carried per-level exactly as MONC does. Our op
count is measured from the jaxpr in tests and reported alongside the paper's.

Boundary cells (first/last index in each dim) are zero, matching the paper's
kernel which computes k in [1, size_in_z) with halo-exchanged y/x edges.

TPU adaptation: f32 instead of f64 (the paper names reduced precision as its
own further-work item); the f64 numpy oracle in tests bounds the error.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class AdvectParams(NamedTuple):
    tcx: jax.Array   # scalar: 0.25 / dx
    tcy: jax.Array   # scalar: 0.25 / dy
    tzc1: jax.Array  # (Z,): 0.25 * rdz[k] * rho ratios (level-dependent)
    tzc2: jax.Array  # (Z,)


def default_params(Z: int, dx: float = 100.0, dy: float = 100.0,
                   dz: float = 40.0, dtype=jnp.float32) -> AdvectParams:
    k = np.arange(Z, dtype=np.float64)
    rdz = 1.0 / (dz * (1.0 + 0.001 * k))       # slightly stretched grid
    tzc1 = 0.25 * rdz * (1.0 - 0.002 * k)
    tzc2 = 0.25 * rdz * (1.0 + 0.002 * k)
    return AdvectParams(
        jnp.asarray(0.25 / dx, dtype), jnp.asarray(0.25 / dy, dtype),
        jnp.asarray(tzc1, dtype), jnp.asarray(tzc2, dtype))


def _interior_slices(x):
    """c = centre view (X-2, Y-2, Z-2); offsets index into the full array."""
    return x[1:-1, 1:-1, 1:-1]


def pw_advect_ref(u, v, w, p: AdvectParams):
    """Reference PW advection. u,v,w: (X,Y,Z). Returns (su, sv, sw) same shape,
    interior computed, boundary zero."""
    def sh(f, di, dj, dk):
        return f[1 + di:f.shape[0] - 1 + di,
                 1 + dj:f.shape[1] - 1 + dj,
                 1 + dk:f.shape[2] - 1 + dk]

    tzc1 = p.tzc1[1:-1]
    tzc2 = p.tzc2[1:-1]

    def source(f):
        """PW flux form: d(uf)/dx + d(vf)/dy + d(wf)/dz, centred."""
        fx = p.tcx * (sh(u, -1, 0, 0) * (sh(f, 0, 0, 0) + sh(f, -1, 0, 0))
                      - sh(u, 1, 0, 0) * (sh(f, 0, 0, 0) + sh(f, 1, 0, 0)))
        fy = p.tcy * (sh(v, 0, -1, 0) * (sh(f, 0, 0, 0) + sh(f, 0, -1, 0))
                      - sh(v, 0, 1, 0) * (sh(f, 0, 0, 0) + sh(f, 0, 1, 0)))
        fz = (tzc1 * sh(w, 0, 0, -1) * (sh(f, 0, 0, 0) + sh(f, 0, 0, -1))
              - tzc2 * sh(w, 0, 0, 1) * (sh(f, 0, 0, 0) + sh(f, 0, 0, 1)))
        return fx + fy + fz

    out = []
    for f in (u, v, w):
        s = source(f)
        out.append(jnp.pad(s, ((1, 1), (1, 1), (1, 1))))
    return tuple(out)


def _with_f64(fn, fields, p: AdvectParams):
    """Run ``fn(u64, v64, w64, p64)`` on genuinely-f64 jnp inputs.

    The jnp.asarray conversions must happen INSIDE the enable_x64 context —
    outside it they silently downcast f64 to f32 and the "oracle" stops
    being one.
    """
    f_np = [np.asarray(t, np.float64) for t in fields]
    p_np = [np.asarray(t, np.float64) for t in p]
    with jax.experimental.enable_x64():
        f64 = [jnp.asarray(t) for t in f_np]
        p64 = AdvectParams(*(jnp.asarray(t) for t in p_np))
        return fn(*f64, p64)


def pw_advect_ref_f64(u, v, w, p: AdvectParams):
    """f64 oracle (the paper's double-precision ground truth)."""
    return _with_f64(pw_advect_ref, (u, v, w), p)


def pw_step_ref(u, v, w, p: AdvectParams, dt: float = 1.0):
    """One explicit-Euler advection step: f <- f + dt * source(f)."""
    su, sv, sw = pw_advect_ref(u, v, w, p)
    return u + dt * su, v + dt * sv, w + dt * sw


def pw_multistep_ref_f64(u, v, w, p: AdvectParams, T: int, dt: float = 1.0):
    """T explicit-Euler steps in f64 — the oracle for the fused (v4) kernel.

    Every intermediate field is held in double precision, so this bounds the
    accumulated f32 error of ``advect_fused(T=...)`` from above.
    """
    def run(u64, v64, w64, p64):
        for _ in range(T):
            u64, v64, w64 = pw_step_ref(u64, v64, w64, p64, dt)
        return tuple(np.asarray(t, np.float64) for t in (u64, v64, w64))

    return _with_f64(run, (u, v, w), p)


def flops_per_cell() -> int:
    """Measured add/sub/mul count per interior cell (reported in EXPERIMENTS)."""
    import collections
    X = Y = Z = 4
    p = default_params(Z)
    args = [jnp.zeros((X, Y, Z), jnp.float32)] * 3
    jaxpr = jax.make_jaxpr(lambda u, v, w: pw_advect_ref(u, v, w, p))(*args)
    counts = collections.Counter(str(e.primitive) for e in jaxpr.jaxpr.eqns)
    cells = (X - 2) * (Y - 2) * (Z - 2)
    # every add/sub/mul in the jaxpr operates elementwise on interior views
    total = sum(counts[k] for k in ("add", "sub", "mul"))
    return total  # per-cell by construction (all ops are per-cell elementwise)

"""Production training driver.

Composes the whole substrate: config registry -> mesh -> sharded train step
-> chunk-prefetching data pipeline -> checkpoint/auto-resume -> NaN guard.

    python -m repro.launch.train --arch qwen3-32b --smoke --steps 50
    python -m repro.launch.train --arch qwen3-32b --smoke --resume ...

Fault tolerance exercised here (and in tests/test_fault_tolerance.py):
  * auto-resume from LATEST checkpoint (node restart),
  * deterministic per-step data (seeded), so a resumed run consumes exactly
    the batches it would have seen (no data loss/duplication on restart),
  * NaN/inf loss guard: skip the update and keep going (the training-time
    equivalent of the paper's "robustness to real-world conditions"),
  * async checkpointing overlaps serialisation with compute (§IV overlap).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import pspec
from repro.config import SHAPES, RunShape
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import Prefetcher, synth_batch
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_host_mesh, tp_degree
from repro.models import model as M
from repro.training import checkpoint as CKPT
from repro.training import optimizer as O
from repro.training import step as TS


def train_loop(cfg, *, steps: int, batch: int, seq: int, ckpt_dir=None,
               ckpt_every: int = 50, mesh=None, opt=None, log_every: int = 10,
               resume: bool = True, seed: int = 1234,
               inject_nan_at: int = -1):
    mesh = mesh or make_host_mesh()
    tp = tp_degree(mesh)
    layout = M.make_layout(cfg, tp)
    rules = make_rules(multi_pod="pod" in mesh.shape)
    opt = opt or O.OptConfig(peak_lr=3e-3, warmup_steps=20, total_steps=steps)

    state = TS.init_state(cfg, layout, jax.random.PRNGKey(seed))
    start_step = 0
    ckpt = None
    if ckpt_dir is not None:
        ckpt = CKPT.AsyncCheckpointer(ckpt_dir)
        if resume and CKPT.latest_step(ckpt_dir) is not None:
            state, start_step = CKPT.restore(ckpt_dir, state, cfg=cfg,
                                             layout=layout)
            state = jax.tree.map(jnp.asarray, state)
            print(f"[train] resumed from step {start_step}")

    shape = RunShape("adhoc", "train", seq, batch)
    with mesh:
        step_fn = jax.jit(TS.make_train_step(cfg, layout, rules, mesh, opt=opt),
                          donate_argnums=(0,))
        pf = Prefetcher(lambda s: synth_batch(cfg, shape, s, seed),
                        start_step, depth=2)
        history = []
        t0 = time.time()
        skipped = 0
        try:
            for i in range(start_step, steps):
                s, b = next(pf)
                assert s == i
                if i == inject_nan_at:  # fault-injection hook (tests):
                    # poison the batch's float inputs (corrupt data shard)
                    b = jax.tree.map(
                        lambda a: (a * jnp.nan
                                   if jnp.issubdtype(a.dtype, jnp.floating)
                                   else a), b)
                # the step itself guards: non-finite loss -> state unchanged
                state, metrics = step_fn(state, b)
                loss = float(metrics["loss"])
                if not bool(metrics["good"]):
                    skipped += 1
                    print(f"[train] step {i}: non-finite loss, update skipped "
                          f"in-graph")
                    continue
                history.append(loss)
                if log_every and (i % log_every == 0 or i == steps - 1):
                    dt = time.time() - t0
                    print(f"[train] step {i:5d} loss {loss:8.4f} "
                          f"gnorm {float(metrics['grad_norm']):7.3f} "
                          f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
                if ckpt is not None and ((i + 1) % ckpt_every == 0
                                         or i == steps - 1):
                    ckpt.save(state, i + 1, cfg=cfg, layout=layout)
        finally:
            pf.close()
            if ckpt is not None:
                ckpt.wait()
    return state, history, {"skipped": skipped}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt = O.OptConfig(peak_lr=args.lr, warmup_steps=min(20, args.steps // 5),
                      total_steps=args.steps)
    state, history, info = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, opt=opt,
        resume=not args.no_resume)
    print(f"[train] done: first loss {history[0]:.4f} -> last {history[-1]:.4f} "
          f"({info['skipped']} skipped)")


if __name__ == "__main__":
    main()

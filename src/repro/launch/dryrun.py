import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * proof of shardability: `.lower().compile()` on the production mesh
    (16x16 single-pod and 2x16x16 multi-pod) succeeds,
  * `memory_analysis()` (bytes per device — does it fit 16 GiB HBM),
  * roofline terms: per-device FLOPs / HBM bytes from `cost_analysis()` and
    per-chip collective wire bytes parsed from the HLO, using *differential
    costing* (1-layer vs 2-layer unrolled lowerings; scan bodies are costed
    once by XLA, so the scanned full compile cannot be used for FLOPs).

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
  python -m repro.launch.dryrun --all --skip-cost        # shardability only
Outputs JSON records under experiments/dryrun/.
"""
import argparse
import dataclasses
import json
import math
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import pspec
from repro.config import ALL_SHAPES, SHAPES, ArchConfig, RunShape, supports
from repro.configs import ARCH_IDS, get_config
from repro.core import hlo as H
from repro.core import roofline as R
from repro.distributed.sharding import make_rules, sharding_for
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh, tp_degree
from repro.models import model as M
from repro.training import step as TS

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _divisor_near(n: int, target: int) -> int:
    best = 1
    for d in range(1, n + 1):
        if n % d == 0 and abs(d - target) < abs(best - target):
            best = d
    return best


def exec_policy(cfg: ArchConfig, shape: RunShape, *, for_cost: bool = False,
                overrides: dict | None = None) -> ArchConfig:
    """Execution knobs for the production dry-run (documented in DESIGN.md)."""
    kw: dict = {}
    uniform = len(set(M.layer_kinds(cfg))) <= 1 and cfg.family != "encdec"
    if shape.kind == "train":
        kw["remat"] = "full"
        kw["seq_parallel"] = True
        if cfg.scan_layers and uniform:
            kw["scan_group"] = _divisor_near(cfg.n_layers,
                                             int(math.sqrt(cfg.n_layers)) + 2)
        elif not uniform:
            kw["scan_group"] = 3  # enables pattern-grouped scan (hybrid/moe)
    else:
        kw["remat"] = "none"
        kw["seq_parallel"] = shape.kind == "prefill"
    kw["attention_impl"] = "chunked"
    if for_cost:
        kw["scan_layers"] = False
        kw["scan_group"] = 0
        kw["attention_impl"] = "dense"  # exact-FLOP logits (chunked == dense math)
    if overrides:
        kw.update(overrides)
    if "expert_fsdp" in kw:  # nested MoE knob
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, expert_fsdp=bool(kw.pop("expert_fsdp"))))
    return cfg.replace(**kw)


def _cost_cfg(cfg: ArchConfig, n: int) -> ArchConfig:
    """Reduced-layer config for differential costing (n pattern-groups)."""
    if cfg.family == "encdec":
        e = dataclasses.replace(cfg.encdec, enc_layers=n, dec_layers=n)
        return cfg.replace(encdec=e)
    if cfg.family == "hybrid":
        return cfg.replace(n_layers=3 * n)  # n pattern-groups of (rec,rec,attn)
    if cfg.family == "moe" and cfg.moe.moe_every > 1:
        return cfg.replace(n_layers=cfg.moe.moe_every * n)
    return cfg.replace(n_layers=n)


def _layer_multiplier(cfg: ArchConfig) -> float:
    """How many differential units the full config has."""
    if cfg.family == "encdec":
        return float(cfg.encdec.enc_layers)  # enc+dec pairs (equal counts)
    if cfg.family == "hybrid":
        return cfg.n_layers / 3.0
    if cfg.family == "moe" and cfg.moe.moe_every > 1:
        return cfg.n_layers / cfg.moe.moe_every
    return float(cfg.n_layers)


def build_cell(cfg: ArchConfig, shape: RunShape, mesh, *, unroll=False):
    """Build (fn, args_abstract, in_shardings, out_shardings, donate)."""
    tp = tp_degree(mesh)
    multi = "pod" in mesh.shape
    layout = M.make_layout(cfg, tp)
    rules = make_rules(multi_pod=multi, shape_kind=shape.kind,
                       seq_parallel=cfg.seq_parallel)
    ispecs, _ = SP.input_specs(cfg, shape)
    bshard = SP.batch_shardings(cfg, shape, rules, mesh)

    if shape.kind == "train":
        state_sp = TS.state_specs(cfg, layout)
        st_abs = pspec.abstract_params(state_sp)
        st_sh = pspec.param_shardings(state_sp, rules, mesh)
        fn = TS.make_train_step(cfg, layout, rules, mesh, unroll=unroll)
        return (fn, (st_abs, ispecs), (st_sh, bshard), (st_sh, None), (0,))
    if shape.kind == "prefill":
        p_sp = M.param_specs(cfg, layout)
        p_abs = pspec.abstract_params(p_sp)
        p_sh = pspec.param_shardings(p_sp, rules, mesh)
        fn = TS.make_prefill_step(cfg, layout, rules, mesh, unroll=unroll)
        return (fn, (p_abs, ispecs), (p_sh, bshard), None, ())
    # decode
    p_sp = M.param_specs(cfg, layout)
    p_abs = pspec.abstract_params(p_sp)
    p_sh = pspec.param_shardings(p_sp, rules, mesh)
    c_sp = M.cache_specs(cfg, layout, shape.global_batch, shape.seq_len)
    c_abs = pspec.abstract_params(c_sp)
    c_sh = pspec.param_shardings(c_sp, rules, mesh)
    fn = TS.make_serve_step(cfg, layout, rules, mesh)
    return (fn, (p_abs, c_abs, ispecs), (p_sh, c_sh, bshard), (None, c_sh), (1,))


def lower_compile(cfg, shape, mesh, *, unroll=False):
    fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh, unroll=unroll)
    with mesh:
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def mem_record(compiled) -> dict:
    ma = compiled.memory_analysis()
    rec = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        rec[k] = getattr(ma, k, None)
    args_b = rec.get("argument_size_in_bytes") or 0
    alias_b = rec.get("alias_size_in_bytes") or 0
    temp_b = rec.get("temp_size_in_bytes") or 0
    out_b = rec.get("output_size_in_bytes") or 0
    rec["resident_bytes_per_dev"] = args_b + temp_b + max(out_b - alias_b, 0)
    rec["fits_16g"] = rec["resident_bytes_per_dev"] <= R.HBM_PER_CHIP
    return rec


def _one_cost_lowering(cfg, shape, mesh, pod) -> dict:
    lowered, compiled = lower_compile(cfg, shape, mesh, unroll=True)
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    ops = H.parse_collectives(text, pod_size=pod)
    rec = {
        "flops": float(ca.get("flops", 0.0) or 0.0),
        "bytes": float(ca.get("bytes accessed", 0.0) or 0.0),
        "ici": H.total_wire_bytes(ops, "ici") + H.total_wire_bytes(ops, "unknown"),
        "dcn": H.total_wire_bytes(ops, "dcn"),
        "census": H.op_census(text),
    }
    del lowered, compiled, text
    return rec


def cost_record(cfg, shape, mesh, *, attribute_core: bool = True,
                overrides=None) -> dict:
    """Differential costing: unrolled 1-unit vs 2-unit lowerings, plus a
    skip-core pair that attributes bytes/FLOPs to the S^2/scan cores (the
    paper's profiler-block methodology applied to HLO)."""
    pod = mesh.shape.get("data", 16) * mesh.shape.get("model", 16)
    recs, skips = {}, {}
    for n in (1, 2):
        c = exec_policy(_cost_cfg(cfg, n), shape, for_cost=True,
                        overrides=overrides)
        recs[n] = _one_cost_lowering(c, shape, mesh, pod)
        if attribute_core:
            cs = c.replace(attention_impl="skip_core")
            skips[n] = _one_cost_lowering(cs, shape, mesh, pod)
    mult = _layer_multiplier(cfg)
    out = {}
    for key in ("flops", "bytes", "ici", "dcn"):
        out[key] = R.differential(recs[1], recs[2], mult, key)
    out["per_layer"] = {k: recs[2][k] - recs[1][k]
                        for k in ("flops", "bytes", "ici", "dcn")}
    out["const"] = {k: max(recs[1][k] - out["per_layer"][k], 0.0)
                    for k in ("flops", "bytes", "ici", "dcn")}
    out["census_2l"] = recs[2]["census"]
    if skips:
        out["core"] = {}
        for key in ("flops", "bytes"):
            total_skip = R.differential(skips[1], skips[2], mult, key)
            out["core"][key] = max(out[key] - total_skip, 0.0)
            out["core"][f"{key}_rest"] = total_skip
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             skip_cost: bool = False, overrides=None,
             tag: str = "") -> dict:
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    if not supports(cfg0, shape):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k requires sub-quadratic attention"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.shape.values())
    cfg = exec_policy(cfg0, shape, overrides=overrides)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "x".join(str(v) for v in mesh.shape.values()),
           "multi_pod": multi_pod, "n_chips": n_chips, "tag": tag,
           "exec": {"remat": cfg.remat, "scan_group": cfg.scan_group,
                    "seq_parallel": cfg.seq_parallel,
                    "attention_impl": cfg.attention_impl,
                    "param_dtype": cfg.param_dtype,
                    "opt_dtype": cfg.opt_dtype}}
    t0 = time.time()
    lowered, compiled = lower_compile(cfg, shape, mesh)
    rec["compile_s"] = round(time.time() - t0, 2)
    rec["memory"] = mem_record(compiled)
    full_text = compiled.as_text()
    pod = mesh.shape.get("data", 16) * mesh.shape.get("model", 16)
    rec["census_full"] = H.op_census(full_text)
    ops = H.parse_collectives(full_text, pod_size=pod)
    rec["collectives_full_unscaled"] = H.collective_summary(ops)
    del full_text, lowered, compiled

    if not skip_cost and not multi_pod:
        cost = cost_record(cfg0, shape, mesh, overrides=overrides)
        terms = R.RooflineTerms(
            flops_per_dev=cost["flops"],
            hbm_bytes_per_dev=cost["bytes"],
            ici_wire_bytes=cost["ici"],
            dcn_wire_bytes=cost["dcn"],
            n_chips=n_chips,
            model_flops_global=R.model_flops(cfg0, shape),
        )
        rec["cost"] = cost
        rec["roofline"] = terms.as_dict()
        if "core" in cost:
            layout = M.make_layout(cfg0, tp_degree(mesh))
            core_io = R.kernel_core_io_bytes(cfg0, shape, layout,
                                             dict(mesh.shape))
            adj_bytes = cost["bytes"] - cost["core"]["bytes"] + core_io
            adj = dataclasses.replace(terms, hbm_bytes_per_dev=adj_bytes)
            rec["core_io_bytes"] = core_io
            rec["roofline_kernel_adjusted"] = adj.as_dict()
            # fused-TPU streaming estimate (third bracket; see roofline.py)
            stream_bytes = R.streaming_memory_bytes(
                cfg, shape,
                args_bytes_per_dev=rec["memory"]["argument_size_in_bytes"] or 0,
                core_io_bytes=core_io, mesh_shape=dict(mesh.shape))
            stream = dataclasses.replace(terms, hbm_bytes_per_dev=stream_bytes)
            rec["roofline_streaming"] = stream.as_dict()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--skip-cost", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--override", action="append", default=[],
                    help="exec override key=value (e.g. param_dtype=bfloat16)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        overrides[k] = v

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = []
    if not args.multipod_only:
        meshes.append(False)
    if not args.singlepod_only:
        meshes.append(True)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                cell = f"{arch}/{shape}/{'2x16x16' if multi else '16x16'}"
                try:
                    rec = run_cell(arch, shape, multi_pod=multi,
                                   skip_cost=args.skip_cost,
                                   overrides=overrides or None, tag=args.tag)
                    status = ("SKIP" if rec.get("skipped") else
                              f"ok compile={rec.get('compile_s')}s "
                              f"resident={rec.get('memory', {}).get('resident_bytes_per_dev', 0)/2**30:.2f}GiB"
                              + (f" bound={rec['roofline']['bound']}"
                                 if "roofline" in rec else ""))
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append(cell)
                    rec = {"arch": arch, "shape": shape, "multi_pod": multi,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:],
                           "tag": args.tag}
                    status = f"FAIL {type(e).__name__}: {str(e)[:120]}"
                name = f"{arch}__{shape}__{'2x16x16' if multi else '16x16'}"
                if args.tag != "baseline":
                    name += f"__{args.tag}"
                (OUT_DIR / f"{name}.json").write_text(json.dumps(rec, indent=1))
                print(f"[dryrun] {cell:60s} {status}", flush=True)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells passed")


if __name__ == "__main__":
    main()

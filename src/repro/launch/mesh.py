"""Production mesh construction (assignment-prescribed shapes).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """`jax.make_mesh` across jax versions.

    `axis_types` (and `jax.sharding.AxisType`) only exist on newer jax; the
    pinned 0.4.x simply has no explicit/auto axis distinction, so omitting
    the kwarg there is semantically identical to Auto everywhere.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_stencil_mesh(nx: int, ny: int, *, x_axis: str = "x",
                      y_axis: str = "y"):
    """(nx, ny) device mesh for the 2D-decomposed stencil step: each shard
    owns an (X/nx, Y/ny, Z) slab under
    `stencil.distributed.make_distributed_step(axis=y_axis, x_axis=x_axis)`.
    """
    return compat_make_mesh((nx, ny), (x_axis, y_axis))


def ring_neighbor(idx, n: int, delta: int):
    """Logical ring coordinate of the `delta`-away neighbour on an n-shard
    mesh axis (wraps periodically — wrapped halo data must be frozen by the
    caller's global-interior mask, exactly as for the ppermute engine).

    Pure index math, usable both host-side and on traced values (Python %
    on a traced value follows jnp.mod's sign-of-divisor semantics, so
    delta=-1 at coordinate 0 wraps to n-1): the remote-DMA exchange kernel
    computes its `make_async_remote_copy` `device_id` mesh coordinates
    through `dma_neighbor_coords`, which builds the full coordinate tuple.
    """
    if n < 1:
        raise ValueError(f"axis size must be >= 1, got {n}")
    return (idx + delta) % n


def dma_neighbor_coords(mesh_axes, my_coords, axis: str, delta: int,
                        n: int):
    """Mesh-coordinate tuple addressing the `delta`-away ring neighbour
    along `axis` (an n-shard ring), holding every other axis coordinate
    fixed — the `device_id` (``DeviceIdType.MESH``) the in-kernel
    remote-DMA exchange kernel (`_kernel_band_dma`) sends its boundary
    bands to. `mesh_axes`/`my_coords` are parallel over the mesh's axis
    order; coordinates may be traced values."""
    if axis not in mesh_axes:
        raise ValueError(f"axis {axis!r} not in mesh axes {tuple(mesh_axes)}")
    return tuple(
        ring_neighbor(c, n, delta) if a == axis else c
        for a, c in zip(mesh_axes, my_coords))


def resize_stencil_mesh(nx: int, ny: int, *, x_axis: str = "x",
                        y_axis: str = "y"):
    """Elastic rebuild of the stencil mesh: the device-loss recovery path
    (`serving.faults.resilient_distributed_run`) gathers to host, calls
    this to lay out the survivors (shrink) or the returned fleet
    (regrow), and re-shards onto the result. Same shape contract as
    `make_stencil_mesh`, plus a CLEAR error when the requested shape
    exceeds what this process can see — the failure mode of resharding
    UP after a loss that was real."""
    if nx < 1 or ny < 1:
        raise ValueError(f"mesh shape must be >= 1, got ({nx}, {ny})")
    avail = len(jax.devices())
    if nx * ny > avail:
        raise ValueError(
            f"cannot build a ({nx}, {ny}) stencil mesh: needs {nx * ny} "
            f"devices, {avail} available to this process")
    return make_stencil_mesh(nx, ny, x_axis=x_axis, y_axis=y_axis)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Whatever this host offers (smoke tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


def tp_degree(mesh) -> int:
    return mesh.shape.get("model", 1)

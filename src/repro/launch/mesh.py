"""Production mesh construction (assignment-prescribed shapes).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """`jax.make_mesh` across jax versions.

    `axis_types` (and `jax.sharding.AxisType`) only exist on newer jax; the
    pinned 0.4.x simply has no explicit/auto axis distinction, so omitting
    the kwarg there is semantically identical to Auto everywhere.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_stencil_mesh(nx: int, ny: int, *, x_axis: str = "x",
                      y_axis: str = "y"):
    """(nx, ny) device mesh for the 2D-decomposed stencil step: each shard
    owns an (X/nx, Y/ny, Z) slab under
    `stencil.distributed.make_distributed_step(axis=y_axis, x_axis=x_axis)`.
    """
    return compat_make_mesh((nx, ny), (x_axis, y_axis))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Whatever this host offers (smoke tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


def tp_degree(mesh) -> int:
    return mesh.shape.get("model", 1)

"""Production mesh construction (assignment-prescribed shapes).

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(*, model: int = 1):
    """Whatever this host offers (smoke tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def tp_degree(mesh) -> int:
    return mesh.shape.get("model", 1)

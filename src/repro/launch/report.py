"""Aggregate dry-run JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.launch.report [--tag baseline]
prints markdown; use `--write` to refresh the §Dry-run/§Roofline sections
inside EXPERIMENTS.md between the AUTO-GENERATED markers.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]
DRYRUN = ROOT / "experiments" / "dryrun"


def _gb(x) -> str:
    return f"{(x or 0)/2**30:.2f}"


def load(tag: str = "baseline"):
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("tag", "baseline") != tag:
            continue
        r["_file"] = f.name
        recs.append(r)
    return recs


def dryrun_table(recs) -> str:
    out = ["| arch | shape | mesh | compile s | resident GiB/dev | fits 16G | collectives (full program) |",
           "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                       f"SKIP: {r['reason']} |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} "
                       f"| - | - | - | ERROR {r['error'][:60]} |")
            continue
        m = r["memory"]
        cc = r.get("census_full", {})
        coll = ",".join(f"{k}:{v}" for k, v in sorted(cc.items())
                        if k in ("all-gather", "all-reduce", "reduce-scatter",
                                 "all-to-all", "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']} "
            f"| {_gb(m['resident_bytes_per_dev'])} "
            f"| {'Y' if m['fits_16g'] else 'N'} | {coll} |")
    return "\n".join(out)


def _streaming(r):
    """Fused-TPU streaming memory term (backfilled for older records)."""
    if "roofline_streaming" in r:
        return r["roofline_streaming"]
    if "roofline" not in r or "core_io_bytes" not in r:
        return None
    import dataclasses
    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.core import roofline as R
    from repro.launch.dryrun import exec_policy
    cfg = exec_policy(get_config(r["arch"]), SHAPES[r["shape"]])
    a = r["roofline"]
    terms = R.RooflineTerms(
        flops_per_dev=a["flops_per_dev"], hbm_bytes_per_dev=0.0,
        ici_wire_bytes=a["ici_wire_bytes"], dcn_wire_bytes=a["dcn_wire_bytes"],
        n_chips=a["n_chips"], model_flops_global=a["model_flops_global"])
    mesh_shape = ({"pod": 2, "data": 16, "model": 16} if r.get("multi_pod")
                  else {"data": 16, "model": 16})
    b = R.streaming_memory_bytes(
        cfg, SHAPES[r["shape"]],
        args_bytes_per_dev=r["memory"].get("argument_size_in_bytes") or 0,
        core_io_bytes=r["core_io_bytes"], mesh_shape=mesh_shape)
    return dataclasses.replace(terms, hbm_bytes_per_dev=b).as_dict()


def roofline_table(recs) -> str:
    out = ["| arch | shape | compute s | memory s raw→kernel-adj→streaming | "
           "collective s | bound* | step* s | MFU* | useful-FLOPs |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("skipped") or "roofline" not in r:
            continue
        if r.get("multi_pod"):
            continue
        a = r["roofline"]
        k = r.get("roofline_kernel_adjusted", a)
        s = _streaming(r) or k
        out.append(
            f"| {r['arch']} | {r['shape']} | {k['compute_s']:.3f} "
            f"| {a['memory_s']:.2f}→{k['memory_s']:.3f}→{s['memory_s']:.3f} "
            f"| {k['collective_s']:.3f} | {s['bound']} "
            f"| {s['step_time_s']:.3f} | {s['mfu']:.3f} "
            f"| {k['useful_flops_ratio']:.2f} |")
    out.append("")
    out.append("(*) bound/step/MFU at the fused-TPU streaming memory estimate;"
               " raw & kernel-adjusted columns bracket it (core/roofline.py).")
    return "\n".join(out)


def summary(recs) -> str:
    cells = [r for r in recs if not r.get("skipped") and "error" not in r]
    skips = [r for r in recs if r.get("skipped")]
    errs = [r for r in recs if "error" in r]
    sp = [r for r in cells if not r.get("multi_pod")]
    mp = [r for r in cells if r.get("multi_pod")]
    fits = sum(1 for r in cells if r.get("memory", {}).get("fits_16g"))
    return (f"cells compiled: {len(cells)} (single-pod {len(sp)}, "
            f"multi-pod {len(mp)}), skipped-by-rule: {len(skips)}, "
            f"errors: {len(errs)}; fit in 16 GiB/dev: {fits}/{len(cells)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args()
    recs = load(args.tag)
    md = (f"### Summary ({args.tag})\n\n{summary(recs)}\n\n"
          f"### Dry-run table\n\n{dryrun_table(recs)}\n\n"
          f"### Roofline table (single-pod 16x16, kernel-adjusted)\n\n"
          f"{roofline_table(recs)}\n")
    if args.write:
        path = ROOT / "EXPERIMENTS.md"
        text = path.read_text() if path.exists() else ""
        start, end = "<!-- AUTO-DRYRUN-START -->", "<!-- AUTO-DRYRUN-END -->"
        if start in text:
            pre = text.split(start)[0]
            post = text.split(end)[1]
            path.write_text(pre + start + "\n" + md + "\n" + end + post)
        else:
            path.write_text(text + "\n" + start + "\n" + md + "\n" + end + "\n")
        print(f"wrote {path}")
    else:
        print(md)


if __name__ == "__main__":
    main()

"""Serving driver: continuous-batching engine over a (smoke) checkpoint.

    python -m repro.launch.serve --arch qwen3-32b --smoke --requests 8
Optionally --ckpt-dir to serve trained weights (elastic TP relayout applies).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import pspec
from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.training import checkpoint as CKPT
from repro.training import step as TS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    layout = M.make_layout(cfg, tp=1)
    if args.ckpt_dir:
        like = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                            pspec.abstract_params(TS.state_specs(cfg, layout)))
        state, step = CKPT.restore(args.ckpt_dir, like, cfg=cfg, layout=layout)
        params = jax.tree.map(jax.numpy.asarray, state["params"])
        print(f"[serve] restored step {step} from {args.ckpt_dir}")
    else:
        params = pspec.init_params(M.param_specs(cfg, layout),
                                   jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_size=args.batch_size,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 24))).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    import time
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in done.values())
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s aggregate)")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {done[uid][:10]}")


if __name__ == "__main__":
    main()

"""Serving driver: continuous-batching engine over a (smoke) checkpoint.

    python -m repro.launch.serve --arch qwen3-32b --smoke --requests 8
Optionally --ckpt-dir to serve trained weights (elastic TP relayout applies).

`--stencil` serves forecast jobs instead of tokens: batched multi-domain
advection over the fused kernel (`repro.serving.stencil_engine`), with
`--max-new` bounding each job's fused-step budget and `--fault-plan`
injecting a deterministic fault schedule (`serving.faults.FaultPlan`
spec grammar: ``kind@step[:key=val,...]`` clauses joined by ``;``) whose
recovery counters print as the health surface:

    python -m repro.launch.serve --smoke --stencil --requests 4 \
        --fault-plan "nan_poison@1:slot=1;device_loss@2:reshard_to=1"

`--lose-device-at` is the DEPRECATED single-fault alias — it builds a
one-device-loss plan.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import pspec
from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.training import checkpoint as CKPT
from repro.training import step as TS


def _run_stencil(args) -> None:
    from repro.serving.stencil_engine import (StencilRequest,
                                              StencilServingEngine)
    from repro.stencil.advection import AdvectionDomain, stratus_fields

    from repro.serving.faults import Fault, FaultPlan

    X, Y, Z, T = (12, 16, 64, 2) if args.smoke else (64, 256, 64, 4)
    dom = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T, dt=0.005)
    plan = None
    if args.fault_plan is not None:
        if args.lose_device_at is not None:
            raise SystemExit("--lose-device-at is a deprecated alias for "
                             "--fault-plan; pass only one")
        plan = FaultPlan.parse(args.fault_plan)
    elif args.lose_device_at is not None:
        print("[serve] --lose-device-at is deprecated; use --fault-plan "
              f'"device_loss@{args.lose_device_at}"')
        plan = FaultPlan((Fault("device_loss",
                                at_step=args.lose_device_at),))
    engine = StencilServingEngine(dom, batch_size=args.batch_size,
                                  fault_plan=plan)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        Xr = int(rng.integers(4, X + 1))
        Yr = int(rng.integers(4, Y + 1))
        u, v, w = stratus_fields(Xr, Yr, Z, seed=i)
        reqs.append(StencilRequest(
            uid=i, u=np.asarray(u), v=np.asarray(v), w=np.asarray(w),
            n_steps=int(rng.integers(1, args.max_new + 1))))
    t0 = time.time()
    done = engine.run(reqs)
    dt_s = time.time() - t0
    steps = sum(len(r.states) for r in done.values() if r.states)
    stats = engine.cache_stats()
    print(f"[serve] {len(done)} forecast domains, {steps} fused steps "
          f"(T={T}) in {dt_s:.1f}s; executable cache "
          f"hits={stats['hits']} misses={stats['misses']} "
          f"evictions={stats['evictions']}")
    print(f"[serve] modelled serving throughput at batch={engine.B}: "
          f"{engine.modelled_throughput():.1f} domains/s")
    h = engine.health()
    print(f"[serve] health: faults={h['faults_injected']} "
          f"retries={h['retries']} quarantines={h['quarantines']} "
          f"rollbacks={h['rollbacks']} degradations={h['degradations']} "
          f"reshards={h['reshards']} exchange={h['exchange']}")
    for t_line in h["transitions"]:
        print(f"  [health] {t_line}")
    for uid in sorted(done)[:4]:
        r = done[uid]
        if r.status == "quarantined":
            print(f"  job {uid}: QUARANTINED ({r.error})")
            continue
        print(f"  job {uid}: extent {r.out[0].shape}, {len(r.states)} "
              f"streamed states, |u|max={float(np.abs(r.out[0]).max()):.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--stencil", action="store_true",
                    help="serve batched advection-forecast jobs instead of "
                         "tokens")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--fault-plan", default=None,
                    help="(--stencil) deterministic fault schedule, e.g. "
                         "'nan_poison@1:slot=1;device_loss@2:reshard_to=1' "
                         "(serving.faults.FaultPlan.parse grammar)")
    ap.add_argument("--lose-device-at", type=int, default=None,
                    help="(--stencil) DEPRECATED alias for --fault-plan "
                         "'device_loss@K': simulate a device loss after "
                         "this many mega-steps, re-shard to half the slots")
    args = ap.parse_args()

    if args.stencil:
        _run_stencil(args)
        return

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    layout = M.make_layout(cfg, tp=1)
    if args.ckpt_dir:
        like = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                            pspec.abstract_params(TS.state_specs(cfg, layout)))
        state, step = CKPT.restore(args.ckpt_dir, like, cfg=cfg, layout=layout)
        params = jax.tree.map(jax.numpy.asarray, state["params"])
        print(f"[serve] restored step {step} from {args.ckpt_dir}")
    else:
        params = pspec.init_params(M.param_specs(cfg, layout),
                                   jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_size=args.batch_size,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 24))).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in done.values())
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s aggregate)")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {done[uid][:10]}")


if __name__ == "__main__":
    main()

"""Serving driver: continuous-batching engine over a (smoke) checkpoint.

    python -m repro.launch.serve --arch qwen3-32b --smoke --requests 8
Optionally --ckpt-dir to serve trained weights (elastic TP relayout applies).

`--stencil` serves forecast jobs instead of tokens: batched multi-domain
advection over the fused kernel (`repro.serving.stencil_engine`), with
`--max-new` bounding each job's fused-step budget and `--lose-device-at`
injecting a mid-run device loss + re-shard:

    python -m repro.launch.serve --smoke --stencil --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import pspec
from repro.configs import get_config, get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.training import checkpoint as CKPT
from repro.training import step as TS


def _run_stencil(args) -> None:
    from repro.serving.stencil_engine import (StencilRequest,
                                              StencilServingEngine)
    from repro.stencil.advection import AdvectionDomain, stratus_fields

    X, Y, Z, T = (12, 16, 64, 2) if args.smoke else (64, 256, 64, 4)
    dom = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T, dt=0.005)
    engine = StencilServingEngine(dom, batch_size=args.batch_size)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        Xr = int(rng.integers(4, X + 1))
        Yr = int(rng.integers(4, Y + 1))
        u, v, w = stratus_fields(Xr, Yr, Z, seed=i)
        reqs.append(StencilRequest(
            uid=i, u=np.asarray(u), v=np.asarray(v), w=np.asarray(w),
            n_steps=int(rng.integers(1, args.max_new + 1))))
    t0 = time.time()
    done = engine.run(reqs, lose_device_at=args.lose_device_at)
    dt_s = time.time() - t0
    steps = sum(len(r.states) for r in done.values())
    stats = engine.cache_stats()
    print(f"[serve] {len(done)} forecast domains, {steps} fused steps "
          f"(T={T}) in {dt_s:.1f}s; executable cache "
          f"hits={stats['hits']} misses={stats['misses']}")
    print(f"[serve] modelled serving throughput at batch={engine.B}: "
          f"{engine.modelled_throughput():.1f} domains/s")
    for uid in sorted(done)[:4]:
        r = done[uid]
        print(f"  job {uid}: extent {r.out[0].shape}, {len(r.states)} "
              f"streamed states, |u|max={float(np.abs(r.out[0]).max()):.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--stencil", action="store_true",
                    help="serve batched advection-forecast jobs instead of "
                         "tokens")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lose-device-at", type=int, default=None,
                    help="(--stencil) simulate a device loss after this "
                         "many mega-steps and re-shard to half the slots")
    args = ap.parse_args()

    if args.stencil:
        _run_stencil(args)
        return

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    layout = M.make_layout(cfg, tp=1)
    if args.ckpt_dir:
        like = jax.tree.map(lambda s: np.zeros(s.shape, s.dtype),
                            pspec.abstract_params(TS.state_specs(cfg, layout)))
        state, step = CKPT.restore(args.ckpt_dir, like, cfg=cfg, layout=layout)
        params = jax.tree.map(jax.numpy.asarray, state["params"])
        print(f"[serve] restored step {step} from {args.ckpt_dir}")
    else:
        params = pspec.init_params(M.param_specs(cfg, layout),
                                   jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_size=args.batch_size,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 24))).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in done.values())
    print(f"[serve] {len(done)} requests, {total} tokens in {dt:.1f}s "
          f"({total/dt:.1f} tok/s aggregate)")
    for uid in sorted(done)[:4]:
        print(f"  req {uid}: {done[uid][:10]}")


if __name__ == "__main__":
    main()

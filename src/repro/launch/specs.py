"""Abstract input specs per (architecture x run shape).

ShapeDtypeStruct stand-ins (weak-type-correct, shardable, no allocation) for
every model input, plus the matching logical axes used to derive shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, RunShape
from repro.distributed.sharding import HeadLayout, Rules, sharding_for
from repro.models import model as M

Specs = Dict[str, jax.ShapeDtypeStruct]
Axes = Dict[str, Tuple]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: RunShape) -> Tuple[Specs, Axes]:
    """Returns ({name: ShapeDtypeStruct}, {name: logical axes})."""
    B, S = shape.global_batch, shape.seq_len
    E = cfg.d_model
    cd = cfg.compute_dtype

    if shape.kind in ("train", "prefill"):
        specs: Specs = {}
        axes: Axes = {}
        if cfg.family == "encdec":
            Td = cfg.encdec.dec_len
            specs["enc_embeds"] = _sds((B, S, E), cd)
            axes["enc_embeds"] = ("batch", None, None)
            specs["dec_inputs"] = _sds((B, Td), "int32")
            axes["dec_inputs"] = ("batch", None)
            if shape.kind == "train":
                specs["targets"] = _sds((B, Td), "int32")
                axes["targets"] = ("batch", None)
            return specs, axes
        if cfg.embeds_input:
            specs["embeds"] = _sds((B, S, E), cd)
            axes["embeds"] = ("batch", None, None)
            if cfg.pos == "mrope":
                specs["positions"] = _sds((B, S, 3), "int32")
                axes["positions"] = ("batch", None, None)
        else:
            specs["inputs"] = _sds((B, S), "int32")
            axes["inputs"] = ("batch", None)
        if shape.kind == "train":
            specs["targets"] = _sds((B, S), "int32")
            axes["targets"] = ("batch", None)
        return specs, axes

    # decode: one new token against a seq_len cache
    specs = {"token": _sds((B,), "int32"), "pos": _sds((B,), "int32")}
    axes = {"token": ("batch",), "pos": ("batch",)}
    if cfg.embeds_input and cfg.family != "encdec":
        specs["embeds"] = _sds((B, 1, E), cd)
        axes["embeds"] = ("batch", None, None)
    return specs, axes


def batch_shardings(cfg: ArchConfig, shape: RunShape, rules: Rules, mesh):
    specs, axes = input_specs(cfg, shape)
    return {k: sharding_for(specs[k].shape, axes[k], rules, mesh)
            for k in specs}


def decode_cache_abstract(cfg: ArchConfig, layout: HeadLayout,
                          shape: RunShape):
    """Abstract cache tree for a decode shape (cache length = seq_len)."""
    from repro import pspec
    specs = M.cache_specs(cfg, layout, shape.global_batch, shape.seq_len)
    return specs


def make_batch(cfg: ArchConfig, shape: RunShape, rng=None, batch=None, seq=None):
    """Materialise a random batch matching input_specs (smoke/examples)."""
    import numpy as np
    rng = rng or np.random.default_rng(0)
    sh = shape
    if batch or seq:
        import dataclasses
        sh = dataclasses.replace(shape,
                                 global_batch=batch or shape.global_batch,
                                 seq_len=seq or shape.seq_len)
    specs, _ = input_specs(cfg, sh)
    out = {}
    for k, s in specs.items():
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = cfg.vocab_size if k in ("inputs", "targets", "dec_inputs", "token") else max(sh.seq_len, 4)
            out[k] = jnp.asarray(rng.integers(0, hi, s.shape), s.dtype)
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape), jnp.float32).astype(s.dtype)
    return out

"""Synthetic token data pipeline with chunked prefetch (paper §IV at host level).

A real deployment points `source` at tokenised shards on disk; here the
source synthesises deterministic pseudo-corpus batches (seeded per step, so
restarts resume identically — fault-tolerance requirement). The prefetcher
is the paper's DMA-chunk pipeline: host preparation of batch i+depth overlaps
device compute of batch i via the dataflow `Pipeline`.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig, RunShape


@dataclass
class DataConfig:
    seed: int = 1234
    prefetch_depth: int = 2   # batches in flight (paper: chunk queue)


def synth_batch(cfg: ArchConfig, shape: RunShape, step: int,
                seed: int = 1234, batch: Optional[int] = None,
                seq: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Deterministic synthetic LM batch for a given step (restart-stable)."""
    B = batch or shape.global_batch
    S = seq or shape.seq_len
    rng = np.random.default_rng(np.uint64(seed) + np.uint64(step) * 1_000_003)
    V = cfg.vocab_size
    if cfg.family == "encdec":
        Td = cfg.encdec.dec_len
        toks = rng.integers(0, V, (B, Td + 1), dtype=np.int32)
        return {"enc_embeds": rng.normal(size=(B, S, cfg.d_model)).astype(np.float32),
                "dec_inputs": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.embeds_input:
        out = {"embeds": rng.normal(size=(B, S, cfg.d_model)).astype(np.float32),
               "targets": rng.integers(0, V, (B, S), dtype=np.int32)}
        if cfg.pos == "mrope":
            out["positions"] = np.broadcast_to(
                np.arange(S, dtype=np.int32)[None, :, None], (B, S, 3)).copy()
        return out
    # markov-ish synthetic stream so the loss has learnable structure
    toks = rng.integers(0, V, (B, S + 1), dtype=np.int32)
    toks[:, 1:] = (toks[:, :-1] * 31 + toks[:, 1:] % 7) % V
    return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


class Prefetcher:
    """Background-thread batch preparation, bounded queue (chunk overlap)."""

    def __init__(self, make_batch: Callable[[int], Dict], start_step: int,
                 depth: int = 2, put_fn: Optional[Callable] = None):
        self.make_batch = make_batch
        self.put_fn = put_fn or (lambda b: jax.tree.map(jnp.asarray, b))
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.put_fn(self.make_batch(s))
            self.q.put((s, batch))
            s += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass

"""Model assembly: parameter trees, forward passes, caches, loss.

One uniform public API for all 10 assigned architectures:

  layout      = make_layout(cfg, tp)
  specs       = param_specs(cfg, layout)          # pytree[ParamSpec]
  params      = pspec.init_params(specs, rng)     # or abstract_params(specs)
  loss, aux   = loss_fn(params, batch, cfg, layout, ...)        (train)
  logits, kv  = forward(params, batch, ..., mode="prefill")     (prefill)
  logits, kv  = decode_step(params, cache, batch, ...)          (decode)

Layer stacks run under ``lax.scan`` (bounded HLO at 96 layers) with optional
remat; ``unroll=True`` produces loop-free HLO for exact-FLOP cost lowerings.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.distributed.sharding import HeadLayout, Rules, make_head_layout, constrain
from repro.models import blocks as B
from repro.models import layers as L
from repro.models.blocks import Ctx
from repro.pspec import ParamSpec, stack_specs

Params = Dict[str, Any]


def make_layout(cfg: ArchConfig, tp: int = 1) -> HeadLayout:
    if cfg.n_heads == 0:  # attention-free
        return HeadLayout(0, 0, tp, 0, 1, 0, 0)
    return make_head_layout(cfg.n_heads, cfg.n_kv_heads, tp)


def padded_vocab(cfg: ArchConfig, tp: int) -> int:
    v = cfg.vocab_size
    if tp > 1 and v % tp:
        v = math.ceil(v / tp) * tp
    return v


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _norm_specs(cfg: ArchConfig, dt: str, bias: bool = False) -> Params:
    p = {"w": ParamSpec((cfg.d_model,), (None,), dt, "ones")}
    if bias:
        p["b"] = ParamSpec((cfg.d_model,), (None,), dt, "zeros")
    return p


def _apply_norm(p: Params, x, eps: float):
    if "b" in p:
        return L.layer_norm(x, p["w"], p["b"], eps)
    return L.rms_norm(x, p["w"], eps)


def block_specs(cfg: ArchConfig, layout: HeadLayout, kind: str, dt: str) -> Params:
    ln_bias = cfg.family == "encdec"
    if kind == "attn_mlp":
        return {"ln1": _norm_specs(cfg, dt, ln_bias),
                "attn": B.attention_specs(cfg, layout, dt),
                "ln2": _norm_specs(cfg, dt, ln_bias),
                "mlp": B.mlp_specs(cfg, dt, bias=ln_bias)}
    if kind == "moe":
        return {"ln1": _norm_specs(cfg, dt),
                "attn": B.attention_specs(cfg, layout, dt),
                "ln2": _norm_specs(cfg, dt),
                "moe": B.moe_specs(cfg, dt)}
    if kind == "mamba":
        return {"ln": _norm_specs(cfg, dt),
                "mamba": B.mamba_specs(cfg, dt)}
    if kind == "rec":
        return {"ln1": _norm_specs(cfg, dt),
                "rec": B.rglru_specs(cfg, dt),
                "ln2": _norm_specs(cfg, dt),
                "mlp": B.mlp_specs(cfg, dt)}
    if kind == "dec":  # enc-dec decoder layer: self + cross + mlp
        return {"ln1": _norm_specs(cfg, dt, True),
                "self": B.attention_specs(cfg, layout, dt),
                "ln2": _norm_specs(cfg, dt, True),
                "cross": B.attention_specs(cfg, layout, dt),
                "ln3": _norm_specs(cfg, dt, True),
                "mlp": B.mlp_specs(cfg, dt, bias=True)}
    raise ValueError(kind)


def layer_kinds(cfg: ArchConfig) -> Tuple[str, ...]:
    """Block kind per layer for the decoder(-only) stack."""
    if cfg.family == "ssm":
        return ("mamba",) * cfg.n_layers
    if cfg.family == "hybrid":
        pat = []
        while len(pat) < cfg.n_layers:
            pat.extend(cfg.hybrid.pattern or ("rec", "rec", "attn"))
        return tuple("rec" if k == "rec" else "attn_mlp" for k in pat[: cfg.n_layers])
    if cfg.family == "moe":
        k = cfg.moe.moe_every
        return tuple("moe" if (i % k == k - 1) else "attn_mlp"
                     for i in range(cfg.n_layers))
    return ("attn_mlp",) * cfg.n_layers


def _uniform(kinds) -> bool:
    return len(set(kinds)) == 1


def param_specs(cfg: ArchConfig, layout: HeadLayout) -> Params:
    dt = cfg.param_dtype
    E = cfg.d_model
    Vp = padded_vocab(cfg, layout.tp)
    specs: Params = {}

    if cfg.family == "encdec":
        e = cfg.encdec
        specs["tok_embed"] = ParamSpec((Vp, E), ("vocab", "embed"), dt, "embed", 0.02)
        specs["dec_pos"] = ParamSpec((e.max_dec_len, E), (None, "embed"), dt, "embed", 0.02)
        enc = block_specs(cfg, layout, "attn_mlp", dt)
        dec = block_specs(cfg, layout, "dec", dt)
        specs["enc_layers"] = jax.tree.map(
            lambda s: stack_specs(s, e.enc_layers), enc,
            is_leaf=lambda x: isinstance(x, ParamSpec))
        specs["dec_layers"] = jax.tree.map(
            lambda s: stack_specs(s, e.dec_layers), dec,
            is_leaf=lambda x: isinstance(x, ParamSpec))
        specs["enc_norm"] = _norm_specs(cfg, dt, True)
        specs["final_norm"] = _norm_specs(cfg, dt, True)
        return specs

    if not cfg.embeds_input:
        specs["tok_embed"] = ParamSpec((Vp, E), ("vocab", "embed"), dt, "embed", 0.02)
    kinds = layer_kinds(cfg)
    if cfg.scan_layers and _uniform(kinds):
        one = block_specs(cfg, layout, kinds[0], dt)
        specs["layers"] = jax.tree.map(
            lambda s: stack_specs(s, cfg.n_layers), one,
            is_leaf=lambda x: isinstance(x, ParamSpec))
    else:
        specs["layers"] = [block_specs(cfg, layout, k, dt) for k in kinds]
    specs["final_norm"] = _norm_specs(cfg, dt)
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((E, Vp), ("embed", "vocab"), dt, "fan_in")
    return specs


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def layer_cache_specs(cfg: ArchConfig, layout: HeadLayout, kind: str,
                      batch: int, max_len: int, dt: str) -> Params:
    D = cfg.head_dim
    Ks = layout.n_kv_stored
    if kind in ("attn_mlp", "moe"):
        W = cfg.hybrid.window if cfg.family == "hybrid" else 0
        Lc = min(max_len, W) if W else max_len
        ax = ("batch", None, "act_kv_heads", None)
        return {"k": ParamSpec((batch, Lc, Ks, D), ax, dt, "zeros"),
                "v": ParamSpec((batch, Lc, Ks, D), ax, dt, "zeros")}
    if kind == "mamba":
        Di, N, K = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.conv_k
        return {"conv": ParamSpec((batch, K - 1, Di), ("batch", None, "act_ffn"), dt, "zeros"),
                "state": ParamSpec((batch, Di, N), ("batch", "act_ffn", None), dt, "zeros")}
    if kind == "rec":
        Dr, K = cfg.hybrid.d_rnn, cfg.hybrid.conv_k
        return {"conv": ParamSpec((batch, K - 1, Dr), ("batch", None, "act_ffn"), dt, "zeros"),
                "state": ParamSpec((batch, Dr), ("batch", "act_ffn"), dt, "zeros")}
    if kind == "dec":
        e = cfg.encdec
        ax = ("batch", None, "act_kv_heads", None)
        return {"k": ParamSpec((batch, e.max_dec_len, Ks, D), ax, dt, "zeros"),
                "v": ParamSpec((batch, e.max_dec_len, Ks, D), ax, dt, "zeros"),
                "ck": ParamSpec((batch, max_len, Ks, D), ax, dt, "zeros"),
                "cv": ParamSpec((batch, max_len, Ks, D), ax, dt, "zeros")}
    raise ValueError(kind)


def cache_specs(cfg: ArchConfig, layout: HeadLayout, batch: int,
                max_len: int) -> Any:
    dt = cfg.compute_dtype
    if cfg.family == "encdec":
        one = layer_cache_specs(cfg, layout, "dec", batch, max_len, dt)
        return jax.tree.map(lambda s: stack_specs(s, cfg.encdec.dec_layers), one,
                            is_leaf=lambda x: isinstance(x, ParamSpec))
    kinds = layer_kinds(cfg)
    if cfg.scan_layers and _uniform(kinds):
        one = layer_cache_specs(cfg, layout, kinds[0], batch, max_len, dt)
        return jax.tree.map(lambda s: stack_specs(s, cfg.n_layers), one,
                            is_leaf=lambda x: isinstance(x, ParamSpec))
    return [layer_cache_specs(cfg, layout, k, batch, max_len, dt) for k in kinds]


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_block(kind: str, p: Params, x, ctx: Ctx, cache=None):
    """Returns (x, aux, new_cache)."""
    cfg = ctx.cfg
    ctx = dataclasses.replace(ctx, cache=cache, new_cache=None)
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn_mlp":
        window = cfg.hybrid.window if cfg.family == "hybrid" else 0
        x = x + B.attention_apply(p["attn"], _apply_norm(p["ln1"], x, cfg.norm_eps),
                                  ctx, window=window)
        x = x + B.mlp_apply(p["mlp"], _apply_norm(p["ln2"], x, cfg.norm_eps), ctx)
    elif kind == "moe":
        x = x + B.attention_apply(p["attn"], _apply_norm(p["ln1"], x, cfg.norm_eps), ctx)
        out, aux = B.moe_apply(p["moe"], _apply_norm(p["ln2"], x, cfg.norm_eps), ctx)
        x = x + out
    elif kind == "mamba":
        x = x + B.mamba_apply(p["mamba"], _apply_norm(p["ln"], x, cfg.norm_eps), ctx)
    elif kind == "rec":
        x = x + B.rglru_apply(p["rec"], _apply_norm(p["ln1"], x, cfg.norm_eps), ctx)
        x = x + B.mlp_apply(p["mlp"], _apply_norm(p["ln2"], x, cfg.norm_eps), ctx)
    elif kind == "enc":
        sub = dataclasses.replace(ctx, causal=False)
        x = x + B.attention_apply(p["attn"], _apply_norm(p["ln1"], x, cfg.norm_eps),
                                  sub, use_rope=False)
        x = x + B.mlp_apply(p["mlp"], _apply_norm(p["ln2"], x, cfg.norm_eps), ctx)
    else:
        raise ValueError(kind)
    x = ctx.con(x, ("batch", "res_seq", "act_embed"))
    return x, aux, ctx.new_cache


def _apply_dec_block(p: Params, x, enc_out, ctx: Ctx, cache=None):
    cfg = ctx.cfg
    new_cache = {}
    c1 = dataclasses.replace(ctx, cache=cache, new_cache=None)
    x = x + B.attention_apply(p["self"], _apply_norm(p["ln1"], x, cfg.norm_eps),
                              c1, use_rope=False)
    if c1.new_cache:
        new_cache.update(c1.new_cache)
    if ctx.mode == "decode":
        c2 = dataclasses.replace(ctx, cache=cache, new_cache=None)
        x = x + B.attention_apply(p["cross"], _apply_norm(p["ln2"], x, cfg.norm_eps),
                                  c2, is_cross=True, use_rope=False)
    else:
        c2 = dataclasses.replace(ctx, cache=cache, new_cache=None, causal=False)
        x = x + B.attention_apply(p["cross"], _apply_norm(p["ln2"], x, cfg.norm_eps),
                                  c2, kv_x=enc_out, is_cross=True, use_rope=False)
        if ctx.mode == "prefill" and c2.new_cache:
            new_cache["ck"] = c2.new_cache["k"]
            new_cache["cv"] = c2.new_cache["v"]
    x = x + B.mlp_apply(p["mlp"], _apply_norm(p["ln3"], x, cfg.norm_eps), ctx)
    x = ctx.con(x, ("batch", "res_seq", "act_embed"))
    return x, new_cache


def _run_stack(params_layers, kinds, x, ctx: Ctx, caches=None, *,
               scanned: bool, remat: str):
    """Apply the layer stack. Returns (x, aux_total, new_caches)."""
    want_cache = ctx.mode in ("prefill", "decode")

    def one(kind):
        def f(p, x, cache):
            return _apply_block(kind, p, x, ctx, cache)
        if remat == "full" and ctx.mode == "train":
            f = jax.checkpoint(f, policy=None)
        elif remat == "dots" and ctx.mode == "train":
            f = jax.checkpoint(
                f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        return f

    if scanned:
        blk = one(kinds[0])

        if caches is None:
            g = ctx.cfg.scan_group
            nL = ctx.cfg.n_layers
            if (ctx.mode == "train" and g > 1 and nL % g == 0 and nL // g > 1):
                # sqrt-remat: outer scan over groups (boundaries saved), inner
                # scan over g layers recomputed during backward
                grouped = jax.tree.map(
                    lambda a: a.reshape((nL // g, g) + a.shape[1:]), params_layers)

                def group_body(carry, gp):
                    def inner(carry, p):
                        x, aux = carry
                        x, a, _ = blk(p, x, None)
                        return (x, aux + a), ()
                    return jax.lax.scan(inner, carry, gp)[0], ()

                group_body = jax.checkpoint(group_body, policy=None)
                (x, aux), _ = jax.lax.scan(
                    group_body, (x, jnp.zeros((), jnp.float32)), grouped)
                return x, aux, None

            def body2(carry, p):
                x, aux = carry
                x, a, nc = blk(p, x, None)
                return (x, aux + a), (nc if want_cache else ())
            (x, aux), ys = jax.lax.scan(body2, (x, jnp.zeros((), jnp.float32)),
                                        params_layers)
            return x, aux, (ys if want_cache else None)

        def body(carry, xs):
            x, aux = carry
            p, cache = xs
            x, a, nc = blk(p, x, cache)
            return (x, aux + a), nc

        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params_layers, caches))
        return x, aux, new_caches

    if (ctx.mode == "train" and caches is None and ctx.cfg.scan_group
            and not _uniform(kinds) and len(kinds) >= 6):
        # pattern-grouped scan for non-uniform stacks (hybrid / interleaved
        # MoE): scan over repeating groups with sqrt-style remat — the
        # unrolled-remat alternative saves every layer input (recurrentgemma
        # baseline: 247 GiB/dev); this saves only group boundaries.
        pat = _pattern_period(kinds)
        if pat and len(kinds) // pat > 1:
            return _run_grouped_pattern(params_layers, kinds, x, ctx, pat,
                                        remat)

    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, kind in enumerate(kinds):
        cache = caches[i] if caches is not None else None
        x, a, nc = one(kind)(params_layers[i], x, cache)
        aux = aux + a
        new_caches.append(nc)
    return x, aux, (new_caches if want_cache else None)


def _pattern_period(kinds) -> int:
    """Smallest repeating period of the layer-kind pattern (0 if none)."""
    for p in range(1, len(kinds) // 2 + 1):
        if all(kinds[i] == kinds[i % p] for i in range(len(kinds))):
            return p
    return 0


def _run_grouped_pattern(params_layers, kinds, x, ctx: Ctx, pat: int,
                         remat: str):
    ng = len(kinds) // pat
    gkinds = kinds[:pat]
    # stack member j of every full group: ng x (per-layer tree)
    stacked = tuple(
        jax.tree.map(lambda *a: jnp.stack(a), *[params_layers[g * pat + j]
                                                for g in range(ng)])
        for j in range(pat))

    def group_body(carry, gp):
        x, aux = carry
        for j, kind in enumerate(gkinds):
            x, a, _ = _apply_block(kind, gp[j], x, ctx, None)
            aux = aux + a
        return (x, aux), ()

    if remat != "none":
        group_body = jax.checkpoint(group_body, policy=None)
    (x, aux), _ = jax.lax.scan(group_body, (x, jnp.zeros((), jnp.float32)),
                               stacked)
    # remainder layers (pattern tail), per-layer remat
    for i in range(ng * pat, len(kinds)):
        f = lambda p, x: _apply_block(kinds[i], p, x, ctx, None)
        if remat != "none":
            f = jax.checkpoint(f, policy=None)
        x, a, _ = f(params_layers[i], x)
        aux = aux + a
    return x, aux, None


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed(params, cfg: ArchConfig, tokens):
    tab = params["tok_embed"]
    x = jnp.take(tab, tokens, axis=0).astype(jnp.dtype(cfg.compute_dtype))
    return x


def _lm_logits(params, cfg: ArchConfig, layout: HeadLayout, x):
    if cfg.tie_embeddings:
        w = params["tok_embed"].astype(x.dtype)
        logits = jnp.einsum("bse,ve->bsv", x, w)
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = L.softcap(logits, cfg.logit_softcap)
    Vp = logits.shape[-1]
    if Vp > cfg.vocab_size:
        mask = jnp.arange(Vp) < cfg.vocab_size
        logits = jnp.where(mask, logits, L.NEG_INF)
    return logits


def _make_ctx(cfg, layout, rules, mesh, positions, mode, unroll, pos=None) -> Ctx:
    return Ctx(cfg=cfg, layout=layout, rules=rules, mesh=mesh,
               positions=positions, mode=mode, unroll=unroll, pos=pos)


def _default_positions(cfg: ArchConfig, batch_dict, Bsz, S):
    if "positions" in batch_dict:
        return batch_dict["positions"]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (Bsz, S))
    if cfg.pos == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (Bsz, S, 3))
    return pos


def forward(params, batch, cfg: ArchConfig, layout: HeadLayout, *,
            rules: Optional[Rules] = None, mesh=None, mode: str = "train",
            caches=None, unroll: bool = False):
    """Full-sequence forward (train / prefill). Returns (logits, aux, caches)."""
    if cfg.family == "encdec":
        return _forward_encdec(params, batch, cfg, layout, rules=rules,
                               mesh=mesh, mode=mode, unroll=unroll)
    if cfg.embeds_input:
        x = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = _embed(params, cfg, batch["inputs"])
    Bsz, S = x.shape[0], x.shape[1]
    positions = _default_positions(cfg, batch, Bsz, S)
    ctx = _make_ctx(cfg, layout, rules, mesh, positions, mode, unroll)
    x = ctx.con(x, ("batch", "res_seq", "act_embed"))

    kinds = layer_kinds(cfg)
    scanned = cfg.scan_layers and _uniform(kinds) and not unroll
    if unroll and cfg.scan_layers and _uniform(kinds) and not isinstance(params["layers"], list):
        # stacked params, unrolled application
        n = cfg.n_layers
        plist = [jax.tree.map(lambda a: a[i], params["layers"]) for i in range(n)]
        clist = None
        if caches is not None:
            clist = [jax.tree.map(lambda a: a[i], caches) for i in range(n)]
        x, aux, ncl = _run_stack(plist, kinds, x, ctx, clist,
                                 scanned=False, remat=cfg.remat)
        ncaches = None
        if ncl is not None and ncl[0] is not None:
            ncaches = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ncl)
        x = _apply_norm(params["final_norm"], x, cfg.norm_eps)
        return _lm_logits(params, cfg, layout, x), aux, ncaches
    x, aux, ncaches = _run_stack(params["layers"], kinds, x, ctx, caches,
                                 scanned=scanned, remat=cfg.remat)
    x = _apply_norm(params["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, cfg, layout, x), aux, ncaches


def _forward_encdec(params, batch, cfg: ArchConfig, layout: HeadLayout, *,
                    rules=None, mesh=None, mode="train", unroll=False):
    dtc = jnp.dtype(cfg.compute_dtype)
    enc_x = batch["enc_embeds"].astype(dtc)
    Bsz, Se = enc_x.shape[0], enc_x.shape[1]
    enc_x = enc_x + jnp.asarray(L.sincos_positions(Se, cfg.d_model), dtc)
    ctx = _make_ctx(cfg, layout, rules, mesh, None, "train", unroll)
    enc_x = ctx.con(enc_x, ("batch", "res_seq", "act_embed"))

    e = cfg.encdec

    def enc_body(carry, p):
        x, aux = carry
        x, a, _ = _apply_block("enc", p, x, ctx, None)
        return (x, aux + a), ()

    if unroll:
        x = enc_x
        for i in range(e.enc_layers):
            p = jax.tree.map(lambda a: a[i], params["enc_layers"])
            x, _, _ = _apply_block("enc", p, x, ctx, None)
        enc_out = x
    else:
        (enc_out, _), _ = jax.lax.scan(
            enc_body, (enc_x, jnp.zeros((), jnp.float32)), params["enc_layers"])
    enc_out = _apply_norm(params["enc_norm"], enc_out, cfg.norm_eps)

    dec_tokens = batch["dec_inputs"]
    Td = dec_tokens.shape[1]
    x = _embed(params, cfg, dec_tokens)
    x = x + params["dec_pos"][:Td].astype(dtc)[None]
    dctx = _make_ctx(cfg, layout, rules, mesh,
                     jnp.broadcast_to(jnp.arange(Td)[None], (Bsz, Td)),
                     mode, unroll)
    x = dctx.con(x, ("batch", "res_seq", "act_embed"))

    def dec_body(carry, p):
        x = carry
        x, nc = _apply_dec_block(p, x, enc_out, dctx, None)
        return x, nc

    if unroll:
        ncs = []
        for i in range(e.dec_layers):
            p = jax.tree.map(lambda a: a[i], params["dec_layers"])
            x, nc = _apply_dec_block(p, x, enc_out, dctx, None)
            ncs.append(nc)
        ncaches = (jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ncs)
                   if (ncs and ncs[0]) else None)
    else:
        x, ncaches = jax.lax.scan(dec_body, x, params["dec_layers"])
    x = _apply_norm(params["final_norm"], x, cfg.norm_eps)
    logits = _lm_logits(params, cfg, layout, x)
    if mode == "prefill" and ncaches:
        # pad the self-attn cache out to max_dec_len
        def padlen(a, target):
            padw = [(0, 0)] * a.ndim
            padw[2] = (0, target - a.shape[2])
            return jnp.pad(a, padw)
        ncaches = {
            "k": padlen(ncaches["k"], e.max_dec_len),
            "v": padlen(ncaches["v"], e.max_dec_len),
            "ck": ncaches["ck"], "cv": ncaches["cv"],
        }
    return logits, jnp.zeros((), jnp.float32), ncaches


def decode_step(params, caches, batch, cfg: ArchConfig, layout: HeadLayout, *,
                rules=None, mesh=None):
    """One-token decode. batch: {"token": (B,), "pos": (B,)}.

    Returns (logits (B, Vp), new_caches).
    """
    tok, pos = batch["token"], batch["pos"]
    Bsz = tok.shape[0]
    if cfg.family == "encdec":
        x = _embed(params, cfg, tok[:, None])
        x = x + jnp.take(params["dec_pos"], pos, axis=0)[:, None].astype(x.dtype)
        ctx = _make_ctx(cfg, layout, rules, mesh, None, "decode", False, pos=pos)

        def body(x, xs):
            p, cache = xs
            x, nc = _apply_dec_block(p, x, None, ctx, cache)
            return x, {**cache, **nc}

        x, ncaches = jax.lax.scan(body, x, (params["dec_layers"], caches))
        x = _apply_norm(params["final_norm"], x, cfg.norm_eps)
        return _lm_logits(params, cfg, layout, x)[:, 0], ncaches

    x = _embed(params, cfg, tok[:, None]) if not cfg.embeds_input else \
        batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
    ctx = _make_ctx(cfg, layout, rules, mesh, None, "decode", False, pos=pos)
    kinds = layer_kinds(cfg)
    scanned = cfg.scan_layers and _uniform(kinds)

    if scanned:
        blk_kind = kinds[0]

        def body(x, xs):
            p, cache = xs
            x, _, nc = _apply_block(blk_kind, p, x, ctx, cache)
            return x, nc

        x, ncaches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        ncaches = []
        for i, kind in enumerate(kinds):
            x, _, nc = _apply_block(kind, params["layers"][i], x, ctx, caches[i])
            ncaches.append(nc)
    x = _apply_norm(params["final_norm"], x, cfg.norm_eps)
    return _lm_logits(params, cfg, layout, x)[:, 0], ncaches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(logits, targets, *, z_loss: float = 1e-4):
    """Masked softmax cross-entropy in f32. targets < 0 are masked."""
    logits = logits.astype(jnp.float32)
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * mask
    z = jnp.square(logz) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return nll.sum() / denom + z_loss * z.sum() / denom


def loss_fn(params, batch, cfg: ArchConfig, layout: HeadLayout, *,
            rules=None, mesh=None, unroll: bool = False):
    """Training loss. Returns (loss, metrics)."""
    logits, aux, _ = forward(params, batch, cfg, layout, rules=rules,
                             mesh=mesh, mode="train", unroll=unroll)
    tgt_key = "targets"
    loss = lm_loss(logits, batch[tgt_key]) + aux
    return loss, {"loss": loss, "aux": aux}

"""Per-family layer blocks: param specs + apply fns.

Each block kind provides ``<kind>_specs(cfg, layout) -> pytree[ParamSpec]``
and an apply function operating on (params, x, ctx).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.distributed.sharding import HeadLayout, Rules, constrain
from repro.models import layers as L
from repro.pspec import ParamSpec

Params = Dict[str, Any]


@dataclass
class Ctx:
    """Per-call context: positions, mode, sharding, cache slot."""
    cfg: ArchConfig
    layout: HeadLayout
    rules: Optional[Rules] = None
    mesh: Any = None
    positions: Any = None        # (B,S) or (B,S,3) for mrope
    mode: str = "train"          # train | prefill | decode
    cache: Any = None            # layer cache dict at decode
    pos: Any = None              # (B,) decode position
    causal: bool = True
    unroll: bool = False         # unroll inner scans for exact-FLOP costing
    new_cache: Any = None        # out: updated layer cache

    def con(self, x, axes):
        return constrain(x, axes, self.rules, self.mesh) if self.rules else x


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------


def attention_specs(cfg: ArchConfig, layout: HeadLayout, dt: str) -> Params:
    E, D = cfg.d_model, cfg.head_dim
    Hs, Ks = layout.n_q_stored, layout.n_kv_stored
    p: Params = {
        "wq": ParamSpec((E, Hs, D), ("embed", "heads", "head_dim"), dt),
        "wk": ParamSpec((E, Ks, D), ("embed", "kv_heads", "head_dim"), dt),
        "wv": ParamSpec((E, Ks, D), ("embed", "kv_heads", "head_dim"), dt),
        "wo": ParamSpec((Hs, D, E), ("heads", "head_dim", "embed"), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((Hs, D), ("heads", "head_dim"), dt, "zeros")
        p["bk"] = ParamSpec((Ks, D), ("kv_heads", "head_dim"), dt, "zeros")
        p["bv"] = ParamSpec((Ks, D), ("kv_heads", "head_dim"), dt, "zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((D,), (None,), dt, "ones")
        p["k_norm"] = ParamSpec((D,), (None,), dt, "ones")
    return p


def _q_head_mask(layout: HeadLayout, dtype):
    if layout.n_q_stored == layout.n_q:
        return None
    return jnp.asarray(layout.q_head_mask(), dtype).reshape(
        layout.n_kv_stored, layout.q_per_group)


def attention_apply(p: Params, x, ctx: Ctx, *, kv_x=None, window: int = 0,
                    use_rope: Optional[bool] = None,
                    is_cross: bool = False) -> jax.Array:
    """x: (B, S, E). kv_x: cross-attention source (B, Skv, E) if given."""
    cfg, lo = ctx.cfg, ctx.layout
    B, S, E = x.shape
    D = cfg.head_dim
    kv_src = x if kv_x is None else kv_x
    Skv = kv_src.shape[1]

    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    q = q.reshape(B, S, lo.n_kv_stored, lo.q_per_group, D)
    q = ctx.con(q, ("batch", "seq", "act_kv_heads", None, None))

    use_rope = cfg.pos in ("rope", "mrope") if use_rope is None else use_rope

    if ctx.mode == "decode" and not is_cross:
        # self-attention against cache
        k_new = jnp.einsum("bse,ehd->bshd", x, p["wk"].astype(x.dtype))
        v_new = jnp.einsum("bse,ehd->bshd", x, p["wv"].astype(x.dtype))
        if "bk" in p:
            k_new, v_new = k_new + p["bk"].astype(x.dtype), v_new + p["bv"].astype(x.dtype)
        if cfg.qk_norm:
            q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
            k_new = L.rms_norm(k_new, p["k_norm"], cfg.norm_eps)
        if use_rope:
            pos_q = ctx.pos[:, None]  # (B,1)
            if cfg.pos == "mrope":
                pos_q = jnp.broadcast_to(pos_q[..., None], (B, 1, 3))
            q = L.apply_rope(q, pos_q, cfg.rope_theta, cfg.pos == "mrope")
            k_new = L.apply_rope(k_new, pos_q, cfg.rope_theta, cfg.pos == "mrope")
        kc, vc = ctx.cache["k"], ctx.cache["v"]
        Lc = kc.shape[1]
        slot = (ctx.pos % Lc) if window else ctx.pos
        kc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))(
            kc, k_new.astype(kc.dtype), slot)
        vc = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0, 0)))(
            vc, v_new.astype(vc.dtype), slot)
        ctx.new_cache = {"k": kc, "v": vc}
        if window:
            # ring buffer: valid entries are pos-window+1..pos at slot (idx%Lc)
            idx = jnp.arange(Lc)
            age = (slot[:, None] - idx[None, :]) % Lc
            mask = age[:, :] < jnp.minimum(ctx.pos + 1, window)[:, None]
            logits = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                                kc.astype(jnp.float32)) / math.sqrt(D)
            logits = jnp.where(mask[:, None, None, None, :], logits, L.NEG_INF)
            pr = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bkgqs,bskd->bqkgd", pr, vc.astype(jnp.float32)).astype(x.dtype)
        else:
            out = L.attn_decode(q, kc, vc, pos=ctx.pos, scale=1.0 / math.sqrt(D))
    elif ctx.mode == "decode":
        # cross-attention at decode: cached projected enc K/V, all positions valid
        kc, vc = ctx.cache["ck"], ctx.cache["cv"]
        if cfg.qk_norm:
            q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        pos_full = jnp.full((B,), kc.shape[1] - 1, jnp.int32)
        out = L.attn_decode(q, kc, vc, pos=pos_full, scale=1.0 / math.sqrt(D))
    else:
        k = jnp.einsum("bse,ehd->bshd", kv_src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bse,ehd->bshd", kv_src, p["wv"].astype(x.dtype))
        if "bk" in p:
            k, v = k + p["bk"].astype(x.dtype), v + p["bv"].astype(x.dtype)
        if cfg.qk_norm:
            q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
        k = ctx.con(k, ("batch", "seq", "act_kv_heads", None))
        if use_rope and kv_x is None:
            q = L.apply_rope(q, ctx.positions, cfg.rope_theta, cfg.pos == "mrope")
            k = L.apply_rope(k, ctx.positions, cfg.rope_theta, cfg.pos == "mrope")
        scale = 1.0 / math.sqrt(D)
        q_pos = kv_pos = jnp.arange(S)
        if kv_x is not None:
            kv_pos = jnp.arange(Skv)
        impl = cfg.attention_impl
        if ctx.mode == "prefill":
            ctx.new_cache = {"k": k, "v": v}
        if impl == "skip_core":
            # phase-attribution lowering: keep projections, drop the S^2 core
            vv = v if Skv == S else v[:, :S]
            out = jnp.broadcast_to(
                vv[:, :, :, None, :],
                (B, S, lo.n_kv_stored, lo.q_per_group, D)).astype(q.dtype)
            out = out + 0.0 * q
        elif window:
            out = L.attn_local(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                               scale=scale, window=window)
        elif impl == "dense" or not ctx.causal:
            out = L.attn_dense(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                               causal=ctx.causal and kv_x is None, scale=scale)
        elif impl == "flash":
            out = L.attn_flash(q, k, v, q_pos, kv_pos, True, scale,
                               cfg.attn_chunk)
        elif impl == "pallas":
            # the real TPU kernel (interpret-mode on CPU); forward-only path
            from repro.kernels.attention.ops import gqa_layout_attention
            out = gqa_layout_attention(q, k, v, causal=True)
        else:
            out = L.attn_chunked(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                                 causal=True, scale=scale,
                                 chunk=cfg.attn_chunk, unroll=ctx.unroll)

    mask = _q_head_mask(ctx.layout, out.dtype)
    if mask is not None:
        out = out * mask[None, None, :, :, None]
    out = out.reshape(B, out.shape[1], lo.n_q_stored, D)
    return jnp.einsum("bshd,hde->bse", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ArchConfig, dt: str, d_ff: Optional[int] = None,
              bias: bool = False) -> Params:
    E, F = cfg.d_model, d_ff or cfg.d_ff
    p = {"wi": ParamSpec((E, F), ("embed", "ffn"), dt),
         "wo": ParamSpec((F, E), ("ffn", "embed"), dt)}
    if cfg.mlp == "swiglu":
        p["wg"] = ParamSpec((E, F), ("embed", "ffn"), dt)
    if bias:
        p["bi"] = ParamSpec((F,), (None,), dt, "zeros")
        p["bo"] = ParamSpec((E,), (None,), dt, "zeros")
    return p


def mlp_apply(p: Params, x, ctx: Ctx) -> jax.Array:
    cfg = ctx.cfg
    xw = x.astype(x.dtype)
    cast = lambda w: w.astype(x.dtype)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(xw @ cast(p["wg"])) * (xw @ cast(p["wi"]))
    elif cfg.mlp == "sq_relu":
        h = jnp.square(jax.nn.relu(xw @ cast(p["wi"])))
    else:  # gelu
        h = xw @ cast(p["wi"])
        if "bi" in p:
            h = h + cast(p["bi"])
        h = jax.nn.gelu(h)
    h = ctx.con(h, ("batch", "seq", "act_ffn"))
    out = h @ cast(p["wo"])
    if "bo" in p:
        out = out + cast(p["bo"])
    return out


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard-style capacity routing, EP over "expert" axis)
# ---------------------------------------------------------------------------


def moe_specs(cfg: ArchConfig, dt: str) -> Params:
    E, m = cfg.d_model, cfg.moe
    X, Fe = m.n_experts, m.d_ff_expert
    # EP-resident experts use a distinct logical axis so the FSDP rule does
    # not apply to them (weights stay resident; tokens all-to-all instead)
    emb = "embed" if m.expert_fsdp else "expert_embed"
    p: Params = {
        "router": ParamSpec((E, X), ("embed", "expert"), dt, "normal"),
        "wi": ParamSpec((X, E, Fe), ("expert", emb, "expert_ffn"), dt),
        "wg": ParamSpec((X, E, Fe), ("expert", emb, "expert_ffn"), dt),
        "wo": ParamSpec((X, Fe, E), ("expert", "expert_ffn", emb), dt),
    }
    if m.shared_expert:
        p["shared"] = mlp_specs(cfg, dt, d_ff=Fe)
    if m.dense_residual:
        p["dense"] = mlp_specs(cfg, dt, d_ff=cfg.d_ff)
    return p


def moe_apply(p: Params, x, ctx: Ctx):
    """Returns (out, aux_loss). Token-group capacity routing.

    Dispatch/combine are *pure data movement* — the paper's subject — and are
    the tensors that become all-to-alls under expert parallelism.
    """
    cfg = ctx.cfg
    m = cfg.moe
    B, S, E = x.shape
    X, k = m.n_experts, m.top_k
    T = B * S
    g_size = min(m.group_size or min(S, 2048), T)
    while T % g_size:
        g_size -= 1
    G = T // g_size
    xg = x.reshape(G, g_size, E)

    # router matmul in compute dtype (softmax statistics still f32): an f32
    # router einsum promotes xg's COTANGENT to f32, doubling the payload of
    # every dispatch/combine all-reduce on the backward path (measured:
    # 3x(g,s,e) f32 tuple-ARs dominate the MoE collective term)
    logits = jnp.einsum("gse,ex->gsx", xg,
                        p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (G,s,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(math.ceil(k * g_size / X * m.capacity_factor))
    cap = max(cap, 4)

    if cfg.attention_impl == "skip_core":
        # phase-attribution lowering: keep the expert matmuls (flop parity),
        # drop the one-hot dispatch/combine einsums — their differential is
        # the dispatch's data-movement share (a fused sort-based dispatch
        # kernel would move ~token bytes instead)
        tok = (xg[:, :cap] if cap <= g_size else
               jnp.pad(xg, ((0, 0), (0, cap - g_size), (0, 0))))
        exp_in = jnp.broadcast_to(tok[:, None], (G, X, cap, E)).astype(x.dtype)
        exp_in = ctx.con(exp_in, (None, "act_expert", None, None))
        h = (jax.nn.silu(jnp.einsum("gxce,xef->gxcf", exp_in, p["wg"].astype(x.dtype)))
             * jnp.einsum("gxce,xef->gxcf", exp_in, p["wi"].astype(x.dtype)))
        exp_out = jnp.einsum("gxcf,xfe->gxce", h, p["wo"].astype(x.dtype))
        pad = jnp.zeros_like(xg).at[:, :min(cap, g_size)].add(
            exp_out[:, 0, :min(cap, g_size)])
        out = (pad + (0.0 * probs.sum(-1, keepdims=True)).astype(pad.dtype)
               ).reshape(B, S, E)
        aux = jnp.zeros((), jnp.float32)
        if m.shared_expert:
            out = out + _moe_inner_mlp(p["shared"], x, ctx)
        if m.dense_residual:
            out = out + _moe_inner_mlp(p["dense"], x, ctx)
        return out, aux

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, X, dtype=jnp.int32)   # (G,s,k,X)
    flatoh = onehot.reshape(G, g_size * k, X)
    pos_in_expert = jnp.cumsum(flatoh, axis=1) - flatoh     # (G,s*k,X)
    pos_in_expert = (pos_in_expert * flatoh).sum(-1).reshape(G, g_size, k)
    keep = pos_in_expert < cap
    gate_vals = gate_vals * keep

    # dispatch (G,s,X,cap) one-hot; combine carries gate weights
    disp = (jax.nn.one_hot(gate_idx, X, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(pos_in_expert, cap, dtype=x.dtype)[..., None, :]
            * keep[..., None, None].astype(x.dtype))        # (G,s,k,X,cap)
    comb = disp * gate_vals[..., None, None].astype(x.dtype)
    disp = disp.sum(2)                                      # (G,s,X,cap)
    comb = comb.sum(2)

    exp_in = jnp.einsum("gsxc,gse->gxce", disp, xg)         # (G,X,cap,E)
    exp_in = ctx.con(exp_in, (None, "act_expert", None, None))
    h = (jax.nn.silu(jnp.einsum("gxce,xef->gxcf", exp_in, p["wg"].astype(x.dtype)))
         * jnp.einsum("gxce,xef->gxcf", exp_in, p["wi"].astype(x.dtype)))
    exp_out = jnp.einsum("gxcf,xfe->gxce", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("gsxc,gxce->gse", comb, exp_out).reshape(B, S, E)

    # aux losses: load balance (Switch) + router z-loss
    density = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], X, dtype=jnp.float32),
                       axis=(0, 1))
    p_mean = jnp.mean(probs, axis=(0, 1))
    lb = X * jnp.sum(density * p_mean) * m.load_balance_loss
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))) * m.router_z_loss
    aux = lb + z

    if m.shared_expert:
        sub = dict(cfg=ctx.cfg)
        out = out + _moe_inner_mlp(p["shared"], x, ctx)
    if m.dense_residual:
        out = out + _moe_inner_mlp(p["dense"], x, ctx)
    return out, aux


def _moe_inner_mlp(p, x, ctx: Ctx):
    h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    h = ctx.con(h, ("batch", "seq", "act_ffn"))
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba-1 block (falcon-mamba)
# ---------------------------------------------------------------------------


def mamba_specs(cfg: ArchConfig, dt: str) -> Params:
    E, Di = cfg.d_model, cfg.d_inner
    N, K, R = cfg.ssm.d_state, cfg.ssm.conv_k, cfg.ssm.dt_rank
    return {
        "in_proj": ParamSpec((E, 2 * Di), ("embed", "ffn"), dt),
        "conv_w": ParamSpec((K, Di), ("conv", "ffn"), dt),
        "conv_b": ParamSpec((Di,), ("ffn",), dt, "zeros"),
        "x_proj": ParamSpec((Di, R + 2 * N), ("ffn", None), dt),
        "dt_proj": ParamSpec((R, Di), ("lowrank", "ffn"), dt),
        "dt_bias": ParamSpec((Di,), ("ffn",), dt, "zeros"),
        "A_log": ParamSpec((Di, N), ("ffn", "state"), dt, "ones"),
        "D": ParamSpec((Di,), ("ffn",), dt, "ones"),
        "out_proj": ParamSpec((Di, E), ("ffn", "embed"), dt),
    }


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv. x (B,S,C), w (K,C). Returns y, new_cache (B,K-1,C)."""
    K = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_cache = xp[:, -(K - 1):] if K > 1 else pad
    return y + b.astype(x.dtype), new_cache


def _mamba_chunk_scan(xc, dt_r, Bmat, Cmat, p: Params, h0, *, chunk: int,
                      unroll: bool):
    """Fused selective-scan over sequence chunks.

    Everything quadratic-in-state — dt expansion, discretised (a, bu) of shape
    (B, chunk, Di, N), the associative scan, and the C-projection — happens
    *inside* the chunk step, so only a (B, chunk, Di, N) window is ever live
    (the paper's BRAM slice window, in SSM form). On TPU this step is the
    Pallas selective-scan kernel (`repro.kernels.ssm`).

    Returns (y (B,S,Di) f32, h_final (B,Di,N) f32).
    """
    B, S, Di = xc.shape
    N = Cmat.shape[-1]
    n = max(S // chunk, 1)
    chunk = S // n
    assert S % n == 0
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (Di,N)
    dt_w = p["dt_proj"].astype(jnp.float32)
    dt_b = p["dt_bias"].astype(jnp.float32)

    def to_chunks(t):
        return jnp.moveaxis(
            t.reshape((B, n, chunk) + t.shape[2:]), 1, 0)

    # chunks stream in compute dtype (bf16); f32 promotion happens INSIDE the
    # step so the full-sequence f32 copies never exist (halves streamed bytes)
    xs = (to_chunks(xc), to_chunks(dt_r), to_chunks(Bmat), to_chunks(Cmat))

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    def step(h, inp):
        xj, dj, bj, cj = (t.astype(jnp.float32) for t in inp)  # (B, chunk, ...)
        dt = jax.nn.softplus(dj @ dt_w + dt_b)            # (B, chunk, Di)
        a = jnp.exp(dt[..., None] * A)                    # (B, chunk, Di, N)
        bu = (dt * xj)[..., None] * bj[..., None, :]
        pa, pb = jax.lax.associative_scan(combine, (a, bu), axis=1)
        h_all = pa * h[:, None] + pb                      # (B, chunk, Di, N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, cj)
        return h_all[:, -1], y

    if unroll:
        ys = []
        h = h0
        for j in range(n):
            h, y = step(h, tuple(t[j] for t in xs))
            ys.append(y)
        ys = jnp.stack(ys, 0)
    else:
        h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, Di)
    return y, h


def _ssm_scan(a, b, h0, *, chunk: int, unroll: bool):
    """h_t = a_t * h_{t-1} + b_t elementwise; a,b (B,S,...); h0 (B,...).

    Chunked: associative scan within chunks, lax.scan (or python loop when
    `unroll`) across chunks. Returns (h_all (B,S,...), h_final).
    """
    B, S = a.shape[0], a.shape[1]
    n = max(S // chunk, 1)
    chunk = S // n
    assert S % n == 0
    ac = jnp.moveaxis(a.reshape((B, n, chunk) + a.shape[2:]), 1, 0)
    bc = jnp.moveaxis(b.reshape((B, n, chunk) + b.shape[2:]), 1, 0)

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    def step(h, ab):
        aj, bj = ab  # (B, chunk, ...)
        pa, pb = jax.lax.associative_scan(combine, (aj, bj), axis=1)
        h_all = pa * h[:, None] + pb
        return h_all[:, -1], h_all

    if unroll:
        outs = []
        h = h0
        for j in range(n):
            h, h_all = step(h, (ac[j], bc[j]))
            outs.append(h_all)
        hs = jnp.stack(outs, 0)
    else:
        h, hs = jax.lax.scan(step, h0, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, S) + a.shape[2:])
    return hs, h


def mamba_apply(p: Params, x, ctx: Ctx):
    """Mamba-1 selective SSM. Returns block output (B,S,E)."""
    cfg = ctx.cfg
    N, R = cfg.ssm.d_state, cfg.ssm.dt_rank
    Di = cfg.d_inner
    B, S, E = x.shape
    xz = x @ p["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = ctx.con(xin, ("batch", "seq", "act_ffn"))

    conv_cache = ctx.cache.get("conv") if ctx.mode == "decode" else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_cache)
    xc = jax.nn.silu(xc)

    proj = xc @ p["x_proj"].astype(x.dtype)
    dt_r, Bmat, Cmat = jnp.split(proj, [R, R + N], axis=-1)

    if ctx.mode == "decode":
        dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(x.dtype)
                             + p["dt_bias"].astype(x.dtype)).astype(jnp.float32)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        a = jnp.exp(dt[..., None] * A)                        # (B,1,Di,N)
        bu = ((dt * xc.astype(jnp.float32))[..., None]
              * Bmat.astype(jnp.float32)[..., None, :])
        h0 = ctx.cache["state"].astype(jnp.float32)
        h = a[:, 0] * h0 + bu[:, 0]
        ctx.new_cache = {"conv": new_conv, "state": h.astype(ctx.cache["state"].dtype)}
        y = jnp.einsum("bdn,bsn->bsd", h, Cmat.astype(jnp.float32)).astype(x.dtype)
    elif cfg.attention_impl == "skip_core":
        # phase-attribution lowering: drop the scan core, keep projections
        y = xc.astype(x.dtype) + 0.0 * Bmat.sum(-1, keepdims=True) \
            + 0.0 * Cmat.sum(-1, keepdims=True) + 0.0 * dt_r.sum(-1, keepdims=True)
    else:
        h0 = jnp.zeros((B, Di, N), jnp.float32)
        y, h = _mamba_chunk_scan(xc, dt_r, Bmat, Cmat, p, h0,
                                 chunk=cfg.scan_chunk, unroll=ctx.unroll)
        y = y.astype(x.dtype)
        if ctx.mode == "prefill":
            ctx.new_cache = {"conv": new_conv, "state": h.astype(x.dtype)}

    y = y + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma)
# ---------------------------------------------------------------------------

_RG_BLOCKS = 16  # block-diagonal gate heads (TP-local)


def rglru_specs(cfg: ArchConfig, dt: str) -> Params:
    E, Dr = cfg.d_model, cfg.hybrid.d_rnn
    K = cfg.hybrid.conv_k
    nb = _RG_BLOCKS
    bs = Dr // nb
    return {
        "in_proj": ParamSpec((E, 2 * Dr), ("embed", "ffn"), dt),
        "conv_w": ParamSpec((K, Dr), ("conv", "ffn"), dt),
        "conv_b": ParamSpec((Dr,), ("ffn",), dt, "zeros"),
        "gate_a": ParamSpec((nb, bs, bs), ("heads", None, None), dt),
        "gate_x": ParamSpec((nb, bs, bs), ("heads", None, None), dt),
        "gate_a_b": ParamSpec((Dr,), ("ffn",), dt, "zeros"),
        "gate_x_b": ParamSpec((Dr,), ("ffn",), dt, "zeros"),
        "Lambda": ParamSpec((Dr,), ("ffn",), dt, "recurrent"),
        "out_proj": ParamSpec((Dr, E), ("ffn", "embed"), dt),
    }


def rglru_apply(p: Params, x, ctx: Ctx):
    cfg = ctx.cfg
    Dr = cfg.hybrid.d_rnn
    nb = _RG_BLOCKS
    B, S, E = x.shape
    xg = x @ p["in_proj"].astype(x.dtype)
    xin, gate = jnp.split(xg, 2, axis=-1)
    xin = ctx.con(xin, ("batch", "seq", "act_ffn"))

    conv_cache = ctx.cache.get("conv") if ctx.mode == "decode" else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_cache)

    xb = xc.reshape(B, S, nb, Dr // nb)
    r = jax.nn.sigmoid(jnp.einsum("bsnd,nde->bsne", xb, p["gate_a"].astype(x.dtype))
                       .reshape(B, S, Dr) + p["gate_a_b"].astype(x.dtype))
    i = jax.nn.sigmoid(jnp.einsum("bsnd,nde->bsne", xb, p["gate_x"].astype(x.dtype))
                       .reshape(B, S, Dr) + p["gate_x_b"].astype(x.dtype))

    c = 8.0
    log_a = -c * jax.nn.softplus(p["Lambda"].astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = (i * xc).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * gated_x

    if ctx.mode == "decode":
        h0 = ctx.cache["state"].astype(jnp.float32)
        h = a[:, 0] * h0 + b[:, 0]
        hs = h[:, None]
        ctx.new_cache = {"conv": new_conv, "state": h.astype(ctx.cache["state"].dtype)}
    elif cfg.attention_impl == "skip_core":
        hs = b  # phase-attribution lowering: drop the recurrence core
    else:
        h0 = jnp.zeros((B, Dr), jnp.float32)
        hs, h = _ssm_scan(a, b, h0, chunk=cfg.scan_chunk, unroll=ctx.unroll)
        if ctx.mode == "prefill":
            ctx.new_cache = {"conv": new_conv, "state": h.astype(x.dtype)}

    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    return y @ p["out_proj"].astype(x.dtype)

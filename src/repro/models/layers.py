"""Shared neural layers: norms, RoPE / M-RoPE, GQA attention, MLPs.

Shape conventions
-----------------
  x            : (B, S, E)           activations, compute dtype (bf16)
  q            : (B, S, K, G, D)     K = stored kv groups, G = q heads/group
  k, v         : (B, S, K, D)
  decode cache : k/v (B, L, K, D) ring/linear buffers

Attention implementations
-------------------------
  dense   : full S x S logits (reference; exact-FLOP cost lowerings)
  chunked : online-softmax streaming over KV chunks (lax.scan) — the
            data-movement-aware form: KV slices stream through the fast
            memory tier exactly like the paper's BRAM slice window
  local   : sliding-window (block-banded), linear in S

All softmax/statistics in float32.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.distributed.sharding import HeadLayout

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w.astype(dt) + b.astype(dt)


def softcap(logits, cap: float):
    if not cap:
        return logits
    return jnp.tanh(logits / cap) * cap


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE / M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float64) / half))


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Split the head_dim//2 frequency slots into (t, h, w) sections.

    Uses qwen2-vl's 1/4:3/8:3/8 proportions (16:24:24 at head_dim 128).
    """
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    return t, h, half - t - h


def apply_rope(x, positions, theta: float, mrope: bool = False):
    """x: (..., S, K, G?, D) with positions (B, S) int or (B, S, 3) for M-RoPE.

    positions broadcasting: x leading dims are (B, S, heads...), rope applied
    over the trailing D dim.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)  # (half,)
    if mrope:
        # positions (B, S, 3): each frequency slot uses one of t/h/w positions
        t, h, w = mrope_sections(d)
        sec = jnp.concatenate([
            jnp.zeros((t,), jnp.int32),
            jnp.ones((h,), jnp.int32),
            jnp.full((w,), 2, jnp.int32),
        ])  # (half,)
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec, positions.shape[:-1] + (half,)).astype(jnp.int32),
            axis=-1,
        )  # (B, S, half)
    else:
        pos = positions.astype(jnp.float32)[..., None]  # (B, S, 1)
    angles = pos * freqs  # (B, S, half)
    # broadcast over head dims: x is (B, S, K, G, D) or (B, S, K, D)
    for _ in range(x.ndim - 3):
        angles = angles[..., None, :]
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sincos_positions(seq_len: int, d_model: int) -> np.ndarray:
    """Classic transformer sinusoidal table (whisper encoder)."""
    pos = np.arange(seq_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    inv = 1.0 / (10000 ** (dim / (d_model // 2)))
    ang = pos * inv
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------

NEG_INF = -2.0 ** 30


def _causal_mask(q_pos, kv_pos):
    """(..., Sq, Skv) additive mask, True where kv may be attended."""
    return (kv_pos[None, :] <= q_pos[:, None])


def attn_dense(q, k, v, *, q_pos, kv_pos, causal: bool, scale: float,
               window: int = 0):
    """Reference attention. q (B,Sq,K,G,D), k/v (B,Skv,K,D)."""
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.ones(logits.shape[-2:], bool)
    if causal:
        mask = _causal_mask(q_pos, kv_pos)
    if window:
        mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return out


def attn_chunked(q, k, v, *, q_pos, kv_pos, causal: bool, scale: float,
                 chunk: int, unroll: bool = False):
    """Online-softmax streaming attention over KV chunks (flash-style).

    The KV stream through VMEM mirrors the paper's z-y slice window through
    BRAM; `unroll=True` yields exact FLOP accounting in cost lowerings.
    """
    B, Skv, K, D = k.shape
    Sq, G = q.shape[1], q.shape[3]
    n = max(Skv // chunk, 1)
    chunk = Skv // n
    assert Skv % n == 0

    kc = k.reshape(B, n, chunk, K, D)
    vc = v.reshape(B, n, chunk, K, D)
    pc = kv_pos.reshape(n, chunk)

    qf = q.astype(jnp.float32)

    def step(carry, inputs):
        m, l, acc = carry
        kj, vj, pj = inputs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kj.astype(jnp.float32)) * scale
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask = _causal_mask(q_pos, pj)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p, vj.astype(jnp.float32))
        acc = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
        return (m_new, l, acc), ()

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for j in range(n):
            carry, _ = step(carry, (kc[:, j], vc[:, j], pc[j]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    out = acc / jnp.maximum(jnp.moveaxis(l, -1, 1)[..., None], 1e-20)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (pure JAX mirror of the Pallas kernel)
# ---------------------------------------------------------------------------
#
# `attn_chunked`'s lax.scan saves its (m, l, acc) carries per KV chunk for the
# backward pass — tens of GiB at 32k context. Flash backward instead saves
# only (q, k, v, o, logsumexp) and *recomputes* each chunk's probabilities:
# the classic compute-for-data-movement trade, and exactly what the Pallas
# kernel (repro.kernels.attention) does on real TPU hardware.


def _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, scale, chunk):
    B, Skv, K, D = k.shape
    Sq, G = q.shape[1], q.shape[3]
    n = max(Skv // chunk, 1)
    c = Skv // n
    kc = jnp.moveaxis(k.reshape(B, n, c, K, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n, c, K, D), 1, 0)
    pc = kv_pos.reshape(n, c)
    qf = q.astype(jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        kj, vj, pj = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kj.astype(jnp.float32)) * scale
        if causal:
            s = jnp.where(_causal_mask(q_pos, pj), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p, vj.astype(jnp.float32))
        acc = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
        return (m_new, l, acc), ()

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))           # (B,K,G,Sq)
    out = acc / jnp.maximum(jnp.moveaxis(l, -1, 1)[..., None], 1e-30)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def attn_flash(q, k, v, q_pos, kv_pos, causal: bool, scale: float, chunk: int):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, scale, chunk)
    return out


def _attn_flash_fwd(q, k, v, q_pos, kv_pos, causal, scale, chunk):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, kv_pos, causal, scale, chunk)
    return out, (q, k, v, out, lse, q_pos, kv_pos)


def _attn_flash_bwd(causal, scale, chunk, res, do):
    q, k, v, out, lse, q_pos, kv_pos = res
    B, Skv, K, D = k.shape
    Sq, G = q.shape[1], q.shape[3]
    n = max(Skv // chunk, 1)
    c = Skv // n
    kc = jnp.moveaxis(k.reshape(B, n, c, K, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n, c, K, D), 1, 0)
    pc = kv_pos.reshape(n, c)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    # rowwise D_i = sum_d dO * O
    Drow = jnp.einsum("bqkgd,bqkgd->bkgq", dof, out.astype(jnp.float32))

    def step(dq, inp):
        kj, vj, pj = inp
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kj.astype(jnp.float32)) * scale
        if causal:
            s = jnp.where(_causal_mask(q_pos, pj), s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                       # (B,K,G,Sq,C)
        dv_j = jnp.einsum("bkgqs,bqkgd->bskd", p, dof)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dof, vj.astype(jnp.float32))
        ds = p * (dp - Drow[..., None]) * scale
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds, kj.astype(jnp.float32))
        dk_j = jnp.einsum("bkgqs,bqkgd->bskd", ds, qf)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(step, dq0, (kc, vc, pc))
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, Skv, K, D)
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, Skv, K, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


attn_flash.defvjp(_attn_flash_fwd, _attn_flash_bwd)


def attn_local(q, k, v, *, q_pos, kv_pos, scale: float, window: int):
    """Sliding-window causal attention, block-banded (linear in S).

    Each block of `window` queries attends to its own block and the previous
    one under the (causal & distance < window) mask — exact sliding window.
    """
    B, S, K, D = k.shape
    G = q.shape[3]
    W = min(window, S)
    S0 = S
    if S % W:  # pad to a block multiple; trailing pads are causally masked out
        pad = W - S % W
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    n = S // W
    qb = q.reshape(B, n, W, K, G, D)
    kb = k.reshape(B, n, W, K, D)
    vb = v.reshape(B, n, W, K, D)
    k_prev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    v_prev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B, n, 2W, K, D)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    logits = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2,
                        preferred_element_type=jnp.float32) * scale
    qp = jnp.arange(W)
    kp = jnp.arange(2 * W) - W
    rel = qp[:, None] - kp[None, :]
    band = (rel >= 0) & (rel < W)                              # (W, 2W)
    # block 0's "previous block" is padding: mask kv by global-position validity
    valid = (jnp.arange(n)[:, None, None] * W + kp[None, None, :]) >= 0
    mask_all = band[None] & valid                              # (n, W, 2W)
    logits = jnp.where(mask_all[None, :, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", p.astype(v.dtype), v2)
    return out.reshape(B, S, K, G, D)[:, :S0]


def attn_decode(q, k_cache, v_cache, *, pos, scale: float, window: int = 0):
    """Single-token decode vs a (B, L, K, D) cache. pos: (B,) current index."""
    B, L, K, D = k_cache.shape
    idx = jnp.arange(L)
    mask = idx[None, :] <= pos[:, None]                      # (B, L)
    if window:
        mask = mask & (pos[:, None] - idx[None, :] < window)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    logits = jnp.where(mask[:, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_apply(params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
        return h @ params["wo"]
    if kind == "sq_relu":
        h = jnp.square(jax.nn.relu(x @ params["wi"]))
        return h @ params["wo"]
    if kind == "gelu":
        h = jax.nn.gelu(x @ params["wi"] + params.get("bi", 0.0))
        return h @ params["wo"] + params.get("bo", 0.0)
    raise ValueError(kind)


def gqa_reshape_q(q_flat, layout: HeadLayout):
    """(B, S, Hs*D) -> (B, S, K, G, D)."""
    B, S, _ = q_flat.shape
    return q_flat.reshape(B, S, layout.n_kv_stored, layout.q_per_group, -1)

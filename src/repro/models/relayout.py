"""Re-lay-out attention parameters between TP head layouts.

Checkpoints store the logical (tp=1) layout; on restore the params are
re-laid-out for the serving/training mesh's TP degree (elastic restarts may
change the mesh). Dead padded heads are zero-filled and masked at runtime, so
the relayout is semantics-preserving by construction.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ArchConfig
from repro.distributed.sharding import HeadLayout

# key -> (head axis, unstacked ndim); scanned stacks shift axes by +1
_Q_KEYS = {"wq": (1, 3), "bq": (0, 2)}
_KV_KEYS = {"wk": (1, 3), "bk": (0, 2), "wv": (1, 3), "bv": (0, 2)}
_O_KEYS = {"wo": (0, 3)}


def _ax(arr, ax_nd):
    ax, nd = ax_nd
    return ax + (arr.ndim - nd)


def _gather_pad(arr, idx: np.ndarray, live: np.ndarray, axis: int):
    out = jnp.take(arr, jnp.asarray(idx), axis=axis)
    shape = [1] * out.ndim
    shape[axis] = len(idx)
    mask = jnp.asarray(live, out.dtype).reshape(shape)
    return out * mask


def _attn_to_logical(p: Dict[str, Any], lo: HeadLayout) -> Dict[str, Any]:
    """Stored layout -> logical (tp=1, unpadded) layout."""
    qmask = lo.q_head_mask().astype(bool)
    qidx = lo.q_gather_index()
    # inverse permutation: logical head h lives at stored slot inv[h]
    inv = np.zeros((lo.n_q,), np.int64)
    for stored, logical in enumerate(qidx):
        if qmask[stored]:
            inv[logical] = stored
    kv_first = np.arange(lo.n_kv) * lo.kv_repeat  # first stored copy per kv head
    out = dict(p)
    for k, ax in _Q_KEYS.items():
        if k in p:
            out[k] = jnp.take(p[k], jnp.asarray(inv), axis=_ax(p[k], ax))
    for k, ax in _KV_KEYS.items():
        if k in p:
            out[k] = jnp.take(p[k], jnp.asarray(kv_first), axis=_ax(p[k], ax))
    for k, ax in _O_KEYS.items():
        if k in p:
            out[k] = jnp.take(p[k], jnp.asarray(inv), axis=_ax(p[k], ax))
    return out


def _attn_from_logical(p: Dict[str, Any], lo: HeadLayout) -> Dict[str, Any]:
    """Logical layout -> stored layout for `lo` (pad/replicate)."""
    qidx, qlive = lo.q_gather_index(), lo.q_head_mask().astype(bool)
    kidx = lo.kv_gather_index()
    klive = np.ones((lo.n_kv_stored,), bool)
    if lo.n_kv_dead:
        klive[-lo.n_kv_dead:] = False
    out = dict(p)
    for k, ax in _Q_KEYS.items():
        if k in p:
            out[k] = _gather_pad(p[k], qidx, qlive, _ax(p[k], ax))
    for k, ax in _KV_KEYS.items():
        if k in p:
            out[k] = _gather_pad(p[k], kidx, klive, _ax(p[k], ax))
    for k, ax in _O_KEYS.items():
        if k in p:
            out[k] = _gather_pad(p[k], qidx, qlive, _ax(p[k], ax))
    return out


def _is_attn(d) -> bool:
    return isinstance(d, dict) and "wq" in d and "wo" in d


def _map_attn(tree, fn):
    if _is_attn(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _map_attn(v, fn) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_map_attn(v, fn) for v in tree]
    return tree


def _resize_vocab(params, vocab: int):
    out = dict(params)
    if "tok_embed" in out:
        t = out["tok_embed"]
        if t.shape[0] > vocab:
            out["tok_embed"] = t[:vocab]
        elif t.shape[0] < vocab:
            out["tok_embed"] = jnp.pad(t, ((0, vocab - t.shape[0]), (0, 0)))
    if "lm_head" in out:
        h = out["lm_head"]
        if h.shape[1] > vocab:
            out["lm_head"] = h[:, :vocab]
        elif h.shape[1] < vocab:
            out["lm_head"] = jnp.pad(h, ((0, 0), (0, vocab - h.shape[1])))
    return out


def to_logical(params, cfg: ArchConfig, layout: HeadLayout):
    params = _resize_vocab(params, cfg.vocab_size)
    if layout.n_q_stored == layout.n_q and layout.n_kv_stored == layout.n_kv:
        return params
    return _map_attn(params, lambda p: _attn_to_logical(p, layout))


def from_logical(params, cfg: ArchConfig, layout: HeadLayout):
    from repro.models.model import padded_vocab
    params = _resize_vocab(params, padded_vocab(cfg, layout.tp))
    if layout.n_q_stored == layout.n_q and layout.n_kv_stored == layout.n_kv:
        return params
    return _map_attn(params, lambda p: _attn_from_logical(p, layout))


def relayout(params, cfg: ArchConfig, src: HeadLayout, dst: HeadLayout):
    return from_logical(to_logical(params, cfg, src), cfg, dst)

"""Phase-attributing profiler over compiled XLA artifacts.

"If you can't measure it you can't improve it" (§III-A). Vivado HLS gave the
authors no on-device profiling, so they attached a counter IP block that
attributed cycles to code blocks. XLA gives us program *totals*
(`cost_analysis`) but no phase attribution, so this profiler recovers it the
same way the paper did — by instrumenting variants:

  * `profile(fn, args)`       — totals: flops, bytes, collectives, census
  * `attribute(variants)`     — skip-block differentials: cost(full) minus
                                cost(without block) = the block's share
  * `wallclock(fn, args)`     — CPU wall time (the paper's gettimeofday
                                cross-check of its cycle counters)

Used by the dry-run (attention/scan core attribution) and the Fig. 3
benchmark ladder.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.core import hlo as H


@dataclass
class PhaseCost:
    flops: float = 0.0
    bytes: float = 0.0
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    census: Dict[str, int] = field(default_factory=dict)

    def minus(self, other: "PhaseCost") -> "PhaseCost":
        return PhaseCost(
            max(self.flops - other.flops, 0.0),
            max(self.bytes - other.bytes, 0.0),
            max(self.ici_bytes - other.ici_bytes, 0.0),
            max(self.dcn_bytes - other.dcn_bytes, 0.0),
        )


def profile(fn: Callable, *args, jit_kwargs: Optional[dict] = None,
            pod_size: int = 0) -> PhaseCost:
    """Lower+compile fn on abstract args and return its cost totals."""
    jfn = jax.jit(fn, **(jit_kwargs or {}))
    compiled = jfn.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    ops = H.parse_collectives(text, pod_size=pod_size)
    return PhaseCost(
        flops=float(ca.get("flops", 0.0) or 0.0),
        bytes=float(ca.get("bytes accessed", 0.0) or 0.0),
        ici_bytes=H.total_wire_bytes(ops, "ici") + H.total_wire_bytes(ops, "unknown"),
        dcn_bytes=H.total_wire_bytes(ops, "dcn"),
        census=H.op_census(text),
    )


def attribute(full: PhaseCost, without: Dict[str, PhaseCost]) -> Dict[str, PhaseCost]:
    """Differential phase attribution: share of each skipped block."""
    out = {"total": full}
    for name, w in without.items():
        out[name] = full.minus(w)
    rest = full
    for name, w in without.items():
        rest = rest.minus(out[name])
    out["rest"] = rest
    return out


def wallclock(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time of a jitted callable on real inputs (seconds)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]

"""Dataflow-pipeline abstraction + analytic pipeline model.

The paper's Fig. 4 restructure — load / prepare / compute / store as
concurrently-executing stages connected by streams — has two realisations
in this framework:

  1. *In-kernel*: the Pallas grid pipeline (kernels/advection v2, the flash
     attention kernel): HBM->VMEM block DMA double-buffered against compute.
     That overlap is structural in `pallas_call`; nothing to schedule here.

  2. *Cross-device / host*: `Pipeline` below — named stages over a chunk
     stream with bounded queues (the paper's stream depth 16), executed with
     real thread-per-stage concurrency. Used by the data pipeline
     (host read -> shard -> device) and by the serving engine's
     prefill/decode overlap.

`pipeline_model` gives the analytic makespan used in the Fig. 3/Fig. 5
reproductions: serial sum vs. max-stage (filled pipeline) plus fill/drain.
"""
from __future__ import annotations

import logging
import queue
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

_STOP = object()


@dataclass
class Stage:
    name: str
    fn: Callable[[Any], Any]
    depth: int = 16                    # paper: HLS stream depth 16


_log = logging.getLogger(__name__)


class Pipeline:
    """Thread-per-stage dataflow pipeline with bounded inter-stage queues.

    `join_timeout` bounds the per-thread wait at drain time. A worker
    still alive past it is a LEAK — typically an upstream stage blocked
    on a bounded queue whose consumer died — and is never ignored: the
    leak is logged loudly and, when no stage error explains it, raised
    as RuntimeError naming the hung stages."""

    def __init__(self, stages: Sequence[Stage], *,
                 join_timeout: float = 10.0):
        if join_timeout <= 0:
            raise ValueError(f"join_timeout must be > 0, got {join_timeout}")
        self.stages = list(stages)
        self.join_timeout = join_timeout

    def run(self, items: Sequence[Any]) -> List[Any]:
        qs = [queue.Queue(maxsize=max(s.depth, 1)) for s in self.stages]
        out_q: queue.Queue = queue.Queue()
        errs: List[BaseException] = []

        def worker(stage: Stage, q_in: queue.Queue, q_out: queue.Queue):
            while True:
                item = q_in.get()
                if item is _STOP:
                    q_out.put(_STOP)
                    return
                try:
                    q_out.put(stage.fn(item))
                except BaseException as e:  # noqa: BLE001
                    errs.append(e)
                    q_out.put(_STOP)
                    return

        threads = []
        chain = qs + [out_q]
        for i, st in enumerate(self.stages):
            t = threading.Thread(target=worker, args=(st, chain[i], chain[i + 1]),
                                 daemon=True)
            t.start()
            threads.append(t)
        for it in items:
            qs[0].put(it)
        qs[0].put(_STOP)
        results = []
        while True:
            r = out_q.get()
            if r is _STOP:
                break
            results.append(r)
        leaked = []
        for st, t in zip(self.stages, threads):
            t.join(timeout=self.join_timeout)
            if t.is_alive():
                leaked.append(st.name)
        if leaked:
            _log.error(
                "pipeline leaked %d worker thread(s) still alive after "
                "%.1fs join: stages %s%s", len(leaked), self.join_timeout,
                leaked, " (stage error below)" if errs else "")
        if errs:
            raise errs[0]
        if leaked:
            raise RuntimeError(
                f"pipeline worker thread(s) for stage(s) {leaked} still "
                f"alive after {self.join_timeout}s join with no stage "
                "error: a bounded queue is wedged (likely a producer "
                "blocked on a dead consumer)")
        return results


def pipeline_model(stage_s: Dict[str, float], n_items: int,
                   *, overlapped: bool = True) -> Dict[str, float]:
    """Analytic makespan of a dataflow pipeline.

    serial      : sum over items of sum of stages (paper's pre-Fig.4 code)
    overlapped  : fill + n * max_stage + drain (paper's dataflow region)
    """
    total_stage = sum(stage_s.values())
    serial = n_items * total_stage
    bottleneck = max(stage_s.values())
    fill_drain = total_stage - bottleneck
    pipelined = fill_drain + n_items * bottleneck
    makespan = pipelined if overlapped else serial
    compute_total = n_items * stage_s.get("compute", 0.0)
    return {
        "serial_s": serial,
        "pipelined_s": pipelined if overlapped else serial,
        "bottleneck": max(stage_s, key=stage_s.get),
        "compute_share": compute_total / max(makespan, 1e-30),
        "speedup": serial / max(pipelined, 1e-30),
    }

"""Three-term roofline from compiled dry-run artifacts.

    compute term    = FLOPs / (chips * peak FLOP/s)
    memory term     = HBM bytes / (chips * HBM bandwidth)
    collective term = wire bytes / (link bandwidth)

`cost_analysis()` on an SPMD-partitioned module reports *per-device* flops
and bytes (verified empirically: a 16-way-sharded matmul reports 1/16 of the
global FLOPs), so per-device terms divide by per-chip peaks directly.

`lax.scan` bodies are costed ONCE by XLA (verified: a scan of 10 matmuls
reports the flops of one), so scanned-layer programs undercount. The
dry-run therefore uses *differential costing*: lower the same step unrolled
at 1 and 2 layers; the delta is the exact per-layer cost and
``total = const + n_layers * delta`` reconstructs the full program.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Optional

# TPU v5e-class hardware constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
DCN_BW = 25e9                # bytes/s per host link (pod-to-pod share), est.
HBM_PER_CHIP = 16 * 1024**3  # 16 GiB
VMEM_PER_CORE = 16 * 1024**2  # 16 MiB on-chip vector memory per core

# fixed per-mega-launch dispatch cost the serving tier amortises over its
# batch slots: host->device launch latency + slot bookkeeping. A modelling
# assumption (like XLA_OVERLAP_DISCOUNT below), not a measurement — revisit
# once compiled-mode TPU wallclock lands.
SERVING_LAUNCH_OVERHEAD_S = 50e-6


@dataclass
class RooflineTerms:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    ici_wire_bytes: float
    dcn_wire_bytes: float
    n_chips: int
    model_flops_global: float = 0.0   # analytic 6ND / 2ND
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    dcn_bw: float = DCN_BW
    overlap_efficiency: float = 0.0   # fraction of collective_s the exchange
                                      # engine hides behind compute/memory
                                      # (see overlap_efficiency_model)

    def __post_init__(self):
        if not 0.0 <= self.overlap_efficiency <= 1.0:
            raise ValueError(f"overlap_efficiency must be in [0, 1], got "
                             f"{self.overlap_efficiency}")

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_dev / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.ici_wire_bytes / self.ici_bw + self.dcn_wire_bytes / self.dcn_bw

    @property
    def collective_hidden_s(self) -> float:
        """Seconds of wire time the exchange engine hides behind the
        compute/memory term. An engine can hide at most the whole exchange,
        and never more than there is independent on-chip work to hide
        behind — hence the min() against max(compute, memory). With
        ``overlap_efficiency=0`` (the `overlap=False` baseline) nothing is
        hidden and the exchange is fully exposed."""
        hideable = min(self.collective_s, max(self.compute_s, self.memory_s))
        return self.overlap_efficiency * hideable

    @property
    def collective_exposed_s(self) -> float:
        """Wire seconds left on the critical path after overlap — the
        quantity BENCH_overlap.json gates (falling vs the overlap=False
        baseline)."""
        return self.collective_s - self.collective_hidden_s

    @property
    def overlapped_step_time_s(self) -> float:
        """Step time under the engine's modelled (partial) overlap: the
        on-chip bottleneck term plus the exposed wire seconds. Sits between
        ``step_time_s`` (perfect overlap of everything) and
        ``no_overlap_s`` (fully serial)."""
        return max(self.compute_s, self.memory_s) + self.collective_exposed_s

    @property
    def bound(self) -> str:
        """Largest RAW term — ranks `collective_s` as if nothing were
        hidden. Under an overlapping engine this over-reports
        "collective"-bound configs; `overlapped_bound` ranks the wire
        seconds actually left on the critical path."""
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def overlapped_bound(self) -> str:
        """Bottleneck under the engine's modelled overlap: ranks the
        EXPOSED collective seconds (what is left on the critical path
        after hiding) against compute/memory — a config whose exchange is
        98% hidden is not "collective"-bound, whatever `bound` says. The
        quantity BENCH_overlap/BENCH_pipeline rows report alongside
        `bound`."""
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_exposed_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap model: bottleneck term defines the step."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def no_overlap_s(self) -> float:
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def useful_flops_ratio(self) -> float:
        if not self.model_flops_global:
            return float("nan")
        return self.model_flops_global / (self.flops_per_dev * self.n_chips)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        if not self.model_flops_global:
            return float("nan")
        return (self.model_flops_global
                / (self.n_chips * self.peak_flops * self.step_time_s))

    @property
    def hw_flops_fraction(self) -> float:
        """Fraction of peak the *compiled* flops achieve at roofline time."""
        return self.compute_s / self.step_time_s

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, bound=self.bound,
                 overlapped_bound=self.overlapped_bound,
                 step_time_s=self.step_time_s, mfu=self.mfu,
                 useful_flops_ratio=self.useful_flops_ratio,
                 hw_flops_fraction=self.hw_flops_fraction,
                 collective_hidden_s=self.collective_hidden_s,
                 collective_exposed_s=self.collective_exposed_s,
                 overlapped_step_time_s=self.overlapped_step_time_s)
        return d


# fraction of a collective an XLA-SCHEDULED overlap is trusted to hide: the
# `overlap=True` collective path merely removes the data dependence between
# the interior pass and the two-phase ppermute and *hopes* XLA schedules
# them concurrently (the ROADMAP's open question on real ICI). The in-kernel
# remote-DMA engine issues and waits the transfers itself, so it gets no
# discount. 0.5 is a modelling assumption, not a measurement — revisit once
# compiled-mode TPU wallclock lands.
XLA_OVERLAP_DISCOUNT = 0.5


def interior_compute_fraction(Xl: int, Yl: int, T: int, *,
                              nx: int = 1, ny: int = 1) -> float:
    """Fraction of a shard's cells whose depth-T dependence cone stays inside
    the owned (Xl, Yl) slab — the halo-independent work an exchange can hide
    behind (`make_distributed_step(overlap=True)` computes exactly these
    cells in its interior pass). An undecomposed axis contributes no
    boundary band; a shard swallowed whole by its bands (extent <= 2T)
    leaves nothing to overlap with.
    """
    if Xl < 1 or Yl < 1:
        raise ValueError(f"shard extents must be >= 1, got ({Xl}, {Yl})")
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    fx = max(Xl - 2 * T, 0) / Xl if nx > 1 else 1.0
    fy = max(Yl - 2 * T, 0) / Yl if ny > 1 else 1.0
    return fx * fy


def overlap_efficiency_model(*, overlap: bool, exchange: str = "collective",
                             interior_fraction: float = 1.0) -> float:
    """Modelled fraction of the halo exchange hidden behind compute.

    ``overlap=False`` exposes the whole exchange (0.0). With overlap, the
    hideable fraction is bounded by the interior work available
    (`interior_compute_fraction`); the `collective` engine is additionally
    discounted by ``XLA_OVERLAP_DISCOUNT`` because its overlap is an XLA
    scheduling *opportunity*, not a guarantee, while `remote_dma` issues
    the boundary-band DMAs from inside the kernel and owns its own
    issue/wait schedule (the paper's §IV overlap, done where the paper
    does it). Feeds ``RooflineTerms.overlap_efficiency``.

    Both efficiencies are MODELS of each engine's intended schedule, not
    measurements. This is the PER-BLOCK steady-state figure: for the
    remote_dma engine the hiding belongs to the pipelined multi-block
    driver (`stencil.distributed.make_distributed_run`), whose spare recv
    slot gives block k+1's bands somewhere to land during block k's
    interior pass (the slots and dynamic parity are shipped; forcing the
    in-block issue order to exploit them is the ROADMAPped follow-on) —
    ``pipeline_efficiency_model`` prices the K-block run including the
    pipeline-fill block, and reduces to this model as K grows. A single
    isolated block (K=1) serialises the remote-DMA waits and hides
    nothing, which is exactly what ``pipeline_efficiency_model(n_blocks=
    1)`` reports. Compiled-mode TPU wallclock is the roadmapped
    replacement for both numbers.
    """
    if exchange not in ("collective", "remote_dma"):
        raise ValueError(f"unknown exchange engine {exchange!r}")
    if not 0.0 <= interior_fraction <= 1.0:
        raise ValueError(f"interior_fraction must be in [0, 1], got "
                         f"{interior_fraction}")
    if not overlap:
        return 0.0
    eff = interior_fraction
    if exchange == "collective":
        eff *= XLA_OVERLAP_DISCOUNT
    return eff


def pipeline_efficiency_model(*, n_blocks: int, overlap: bool,
                              exchange: str = "collective",
                              interior_fraction: float = 1.0) -> float:
    """Hidden fraction of the PER-BLOCK exchange over a K-block pipelined
    run (`stencil.distributed.make_distributed_run(n_blocks=K)`),
    averaged across the K blocks.

    The `collective` engine's overlap is within-block (the interior pass
    has no ppermute dependence, every block alike), so its figure is
    K-independent — `overlap_efficiency_model` unchanged. The
    `remote_dma` engine's within-block waits are serialised by its own
    kernel (`_kernel_band_dma` waits every DMA before returning); its
    hiding is CROSS-block — the double-buffered recv slots let block
    k+1's bands land during block k's interior pass, which exists for
    every block except the pipeline-fill first one. Hence the remote_dma
    figure is the steady-state `overlap_efficiency_model` scaled by
    (K-1)/K: zero for an isolated block (K=1 — the serialised-waits
    truth the old single-block accounting glossed), approaching the
    interior fraction as K grows. Feeds
    ``AdvectionDomain.pipeline_efficiency`` and the BENCH_pipeline rows.
    """
    if n_blocks < 1:
        raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
    eff = overlap_efficiency_model(overlap=overlap, exchange=exchange,
                                   interior_fraction=interior_fraction)
    if exchange == "remote_dma":
        eff *= (n_blocks - 1) / n_blocks
    return eff


def serving_max_batch(ring_bytes_per_slot: int, *,
                      vmem_budget: int = VMEM_PER_CORE) -> int:
    """Largest batch the mega-launch can carry before the VMEM ring budget
    binds: each resident slot of the batched-grid layout owns a fused
    shift-register ring of ``ring_bytes_per_slot``
    (`kernels.advection.fused_register_bytes`, y-tile-bounded), and the
    slots' rings must together fit on chip for the batch dimension to
    pipeline without spilling. Past this point adding slots buys nothing
    — `serving_throughput_model` refuses rather than extrapolating."""
    if ring_bytes_per_slot < 1:
        raise ValueError(f"ring_bytes_per_slot must be >= 1, got "
                         f"{ring_bytes_per_slot}")
    if ring_bytes_per_slot > vmem_budget:
        raise ValueError(
            f"one slot's ring ({ring_bytes_per_slot} B) already exceeds the "
            f"VMEM budget ({vmem_budget} B); shrink y_tile or T")
    return vmem_budget // ring_bytes_per_slot


def serving_throughput_model(batch: int, *, hbm_bytes_per_domain: float,
                             ring_bytes_per_slot: int,
                             exposed_wire_s_per_domain: float = 0.0,
                             launch_overhead_s: float =
                             SERVING_LAUNCH_OVERHEAD_S,
                             vmem_budget: int = VMEM_PER_CORE,
                             hbm_bw: float = HBM_BW) -> float:
    """Domains/s of a `batch`-slot mega-launch serving step.

    One mega-step pays a fixed ``launch_overhead_s`` dispatch cost, then
    streams every slot's HBM pass (the batched bytes are B x the
    per-domain `hbm_bytes_model` — slots share nothing) plus each slot's
    EXPOSED wire seconds (`RooflineTerms.collective_exposed_s` for a
    distributed slot; 0 single-shard):

        step_s       = launch_overhead_s
                       + batch * (hbm_bytes/HBM_BW + exposed_wire_s)
        domains/s    = batch / step_s

    Amortising the fixed launch cost over more slots makes this STRICTLY
    increasing in `batch` — d(throughput)/d(batch) =
    overhead / step_s^2 > 0 — saturating toward the pure streaming rate
    1/(hbm_s + wire_s). It increases only UNTIL the VMEM ring budget
    binds (`serving_max_batch`): past that the resident slot rings no
    longer fit and the model refuses (ValueError) instead of pricing a
    layout that cannot pipeline. BENCH_serving.json gates both halves.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if hbm_bytes_per_domain <= 0:
        raise ValueError(f"hbm_bytes_per_domain must be > 0, got "
                         f"{hbm_bytes_per_domain}")
    if exposed_wire_s_per_domain < 0:
        raise ValueError(f"exposed_wire_s_per_domain must be >= 0, got "
                         f"{exposed_wire_s_per_domain}")
    if launch_overhead_s <= 0:
        raise ValueError(f"launch_overhead_s must be > 0, got "
                         f"{launch_overhead_s}")
    max_b = serving_max_batch(ring_bytes_per_slot, vmem_budget=vmem_budget)
    if batch > max_b:
        raise ValueError(
            f"batch {batch} exceeds the VMEM-ring-bound maximum {max_b} "
            f"({ring_bytes_per_slot} B/slot against a {vmem_budget} B "
            "budget)")
    step_s = launch_overhead_s + batch * (
        hbm_bytes_per_domain / hbm_bw + exposed_wire_s_per_domain)
    return batch / step_s


GUARD_FLAG_ITEMSIZE = 4   # the finite-guard flag output is f32


def guard_bytes_model(X: int, Y: int, Z: int, *, batch: int = 1,
                      itemsize: int = 4, n_fields: int = 3) -> int:
    """Extra HBM bytes of the serving tier's finite-guard pass.

    The guard (``advect_fused(..., guard=True)`` /
    ``kernels.advection.finite_guard``) is a separate pallas pass over
    the ADVANCED fields (`n_fields` of them — 3 for the hand-written
    advection ladder, `spec.n_fields` for a stencil-spec operator): it
    re-reads ``n_fields * X * Y * Z`` field words and writes ``X`` f32
    flag words per slot, `batch` slots per mega-launch. Detection is deliberately NOT fused into the advection
    kernel — an in-loop `isfinite` probe perturbs the fused loop body's
    float contraction by one ulp, breaking the engine's bitwise
    contracts — so its price is this honest extra read pass: exactly
    half the fused kernel's six-array pass, amortised over the T fused
    Euler steps the pass just bought.

    `stencil.distributed.count_guard_bytes` recounts the executing
    program's actual guard-pass operands from the jaxpr;
    BENCH_faults.json gates the two equal EXACTLY — the recovery tier
    priced under the same model-equals-counted discipline as every
    other byte in this repo.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if min(X, Y, Z) < 1:
        raise ValueError(f"extents must be >= 1, got {(X, Y, Z)}")
    if n_fields < 1:
        raise ValueError(f"n_fields must be >= 1, got {n_fields}")
    parts = guard_bytes_model_parts(X, Y, Z, batch=batch,
                                    itemsize=itemsize, n_fields=n_fields)
    return parts["field_reads"] + parts["flag_words"]


def guard_bytes_model_parts(X: int, Y: int, Z: int, *, batch: int = 1,
                            itemsize: int = 4,
                            n_fields: int = 3) -> dict:
    """`guard_bytes_model` split into its two movement categories —
    ``{"field_reads": ..., "flag_words": ...}`` — matching the
    analysis ledger's `guard_field_reads` / `guard_flag_words`
    attribution, so the model-coverage pass can claim each category
    exactly (their sum IS `guard_bytes_model`; a test pins it)."""
    return {"field_reads": batch * n_fields * X * Y * Z * itemsize,
            "flag_words": batch * X * GUARD_FLAG_ITEMSIZE}


INTEGRITY_WORD_ITEMSIZE = 4   # band checksums are one uint32 word each


def integrity_bytes_model(X: int, Y: int, Z: int, *, nx: int = 1,
                          ny: int = 1, T: int = 1, n_fields: int = 3,
                          depth: int | None = None) -> int:
    """Per-shard EXTRA wire bytes of the checksummed (verified) exchange.

    The integrity layer (`stencil.distributed.make_distributed_step(...,
    verify_integrity=True)`) rides one uint32 checksum word
    (`kernels.advection.band_checksum`) on every `_band_schedule` band
    message: per decomposed axis, per field, per hop, per side — so the
    extra traffic is ``2 * n_fields * (hops_x + hops_y)`` words of
    `INTEGRITY_WORD_ITEMSIZE` bytes, where ``hops_a = ceil(depth / local
    extent)`` on a decomposed axis and 0 on an undecomposed one (`depth`
    defaults to T — the hand-written advection ladder's exchange depth;
    a stencil-spec operator passes `depth=spec.halo(T)`). Unlike
    `halo_wire_bytes_model` the cost is hop-count DEPENDENT (each hop
    carries its own word) but payload-size independent — the whole point:
    verifying a depth-T band costs 4 bytes on the wire, not 2x the band.

    `stencil.distributed.count_integrity_bytes` recounts the executing
    program's actual checksum ppermute operands from the jaxpr;
    BENCH_recovery.json gates the two equal EXACTLY — the integrity rung
    priced under the same model-equals-counted discipline as the field,
    wire and guard bytes.
    """
    if nx < 1 or ny < 1:
        raise ValueError(f"mesh shape must be >= 1, got ({nx}, {ny})")
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    if X % nx or Y % ny:
        raise ValueError(f"grid ({X}, {Y}) not divisible by mesh "
                         f"({nx}, {ny}); shard_map requires even shards")
    D = T if depth is None else depth
    if D < 1:
        raise ValueError(f"depth must be >= 1, got {D}")
    Xl, Yl = X // nx, Y // ny
    hops_x = -(-D // Xl) if nx > 1 else 0
    hops_y = -(-D // Yl) if ny > 1 else 0
    return 2 * n_fields * (hops_x + hops_y) * INTEGRITY_WORD_ITEMSIZE


def stencil_tiling_bytes_factor(Y: int, y_tile: Optional[int], halo: int,
                                *, grid_tiled: bool = True) -> float:
    """Multiplier on the compulsory per-pass HBM bytes from y-tiling.

    The in-grid `(y_tile, x)` path (`grid_tiled=True`, the kernels'
    default) serves halo re-reads from the persistent VMEM slab and writes
    each output row once, so its HBM traffic is the compulsory 1.0x —
    independent of `y_tile`. The host-side loop restages `2*halo` rows per
    interior tile boundary on both the read and write side, inflating
    every pass by `(Y + 2*halo*(n_tiles-1)) / Y`.
    """
    if halo < 0:
        raise ValueError(f"halo must be >= 0, got {halo}")
    if y_tile is None or y_tile >= Y or grid_tiled:
        return 1.0
    n_tiles = -(-Y // y_tile)
    return (Y + 2 * halo * (n_tiles - 1)) / Y


def halo_wire_bytes_model(X: int, Y: int, Z: int, itemsize: int, *,
                          nx: int = 1, ny: int = 1, T: int = 1,
                          n_fields: int = 3,
                          depth: int | None = None) -> int:
    """Per-shard bytes SENT on the wire for ONE depth-`depth` halo exchange
    of the 2D (nx, ny)-decomposed stencil step (one exchange per T
    substeps; `depth` defaults to T — the hand-written advection ladder,
    radius 1, one stage. A stencil-spec operator passes
    ``depth=spec.halo(T)`` and ``n_fields=spec.n_fields``).

    The exchange is two-phase, x-then-y (`stencil.distributed.
    make_distributed_step`): phase 1 trades ``2 * depth * (Y/ny) * Z``
    x-planes of the raw shard along the x ring; phase 2 trades ``2 *
    depth * (X/nx + 2*depth) * Z`` y-rows of the x-EXTENDED slab — the
    extra ``2*depth`` columns are the four corner blocks riding phase 2,
    so no diagonal sends exist to price. An undecomposed axis (nx==1 /
    ny==1) moves nothing. Multi-hop exchanges send the same byte total
    (hop k carries the k-away neighbour's share), so the model is
    hop-count independent; `stencil.distributed.count_exchange_wire_bytes`
    counts the implementation's actual ppermute operands and the
    scaling2d/stencil benchmarks gate the two against each other exactly.

    Feeds ``RooflineTerms.ici_wire_bytes`` -> ``collective_s``: divide a
    step's wire bytes by T for the per-substep collective term.
    """
    if nx < 1 or ny < 1:
        raise ValueError(f"mesh shape must be >= 1, got ({nx}, {ny})")
    if T < 1:
        raise ValueError(f"T must be >= 1, got {T}")
    if X % nx or Y % ny:
        raise ValueError(f"grid ({X}, {Y}) not divisible by mesh "
                         f"({nx}, {ny}); shard_map requires even shards")
    D = T if depth is None else depth
    if D < 1:
        raise ValueError(f"depth must be >= 1, got {D}")
    Xl, Yl = X // nx, Y // ny
    phase_x = 2 * D * Yl * Z if nx > 1 else 0
    x_ext = Xl + (2 * D if nx > 1 else 0)
    phase_y = 2 * D * x_ext * Z if ny > 1 else 0
    return (phase_x + phase_y) * n_fields * itemsize


def stencil_arithmetic_intensity(flops_per_cell: float,
                                 bytes_per_cell_pass: float,
                                 fusion_T: int = 1,
                                 tiling_bytes_factor: float = 1.0) -> float:
    """FLOP/byte of a (temporally fused, optionally y-tiled) streaming
    stencil.

    One HBM pass moves `bytes_per_cell_pass` per cell; temporal fusion
    performs `fusion_T` steps of `flops_per_cell` work on that pass, so AI
    scales linearly in T — the lever that walks a memory-bound stencil
    toward the ridge point (paper Fig. 3 endgame; our Fig. 9 sweep).
    `tiling_bytes_factor` (from ``stencil_tiling_bytes_factor``) deflates
    the AI by the host-tiling halo restaging; the in-grid path keeps it
    at 1.0.
    """
    if fusion_T < 1:
        raise ValueError(f"fusion_T must be >= 1, got {fusion_T}")
    if tiling_bytes_factor < 1.0:
        raise ValueError("tiling_bytes_factor must be >= 1.0, got "
                         f"{tiling_bytes_factor}")
    return fusion_T * flops_per_cell / (bytes_per_cell_pass
                                        * tiling_bytes_factor)


def stencil_ridge_T(flops_per_cell: float, bytes_per_cell_pass: float,
                    peak_flops: float = PEAK_FLOPS,
                    hbm_bw: float = HBM_BW,
                    tiling_bytes_factor: float = 1.0) -> int:
    """Smallest fusion depth T at which the fused stencil leaves the
    memory-bound regime (AI >= machine ridge point), rounded up. Host-side
    tiling (tiling_bytes_factor > 1) pushes the required T up; the in-grid
    path does not."""
    ridge = peak_flops / hbm_bw
    ai1 = stencil_arithmetic_intensity(
        flops_per_cell, bytes_per_cell_pass,
        tiling_bytes_factor=tiling_bytes_factor)
    return max(1, math.ceil(ridge / ai1))


def differential(cost1: Dict[str, float], cost2: Dict[str, float],
                 n_layers: int, key: str) -> float:
    """total(key) = const + n_layers * (cost2-cost1) with const from cost1."""
    c1, c2 = cost1.get(key, 0.0) or 0.0, cost2.get(key, 0.0) or 0.0
    per_layer = max(c2 - c1, 0.0)
    const = max(c1 - per_layer, 0.0)
    return const + n_layers * per_layer


def kernel_core_io_bytes(cfg, shape, layout, mesh_shape: Dict[str, int]) -> float:
    """Per-device HBM bytes a fused TPU kernel moves for the S^2/scan cores.

    XLA's `bytes accessed` charges every softmax/scan intermediate as HBM
    traffic, but the Pallas flash-attention / selective-scan kernels keep
    those tiles in VMEM (the paper's BRAM-slice window) and only stream the
    kernel inputs/outputs. This is that analytic I/O:

      attention : read Q,K,V + write O  (x ~3.5 with backward recompute)
      ssm       : read xc, dt_r, B, C + write y + inter-chunk states
    """
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    B, S = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    passes = 3.5 if train else 1.0
    bpe = 2.0  # bf16 core I/O

    def attn_io(n_layers, s_q, s_kv) -> float:
        hq = max(layout.n_q_stored // tp, 1)
        hkv = max(layout.n_kv_stored // tp, 1)
        d = cfg.head_dim
        per_b = (s_q * hq * d) * 2 + (s_kv * hkv * d) * 2  # q+o, k+v
        return n_layers * (B / dp) * per_b * bpe * passes

    fam = cfg.family
    if fam == "moe":
        m = cfg.moe
        n_moe = cfg.n_layers // m.moe_every
        toks = (B / dp) * S
        slots = toks * m.top_k * m.capacity_factor
        d = cfg.d_model
        # fused (sort-based) dispatch/combine kernel: token reads + gathered
        # buffer writes in, the reverse out — not the dense one-hot einsums
        disp = n_moe * (toks * d + 2 * slots * d) * 2 * bpe * passes
        return attn_io(cfg.n_layers, S, S) + disp
    if fam in ("dense", "vlm"):
        return attn_io(cfg.n_layers, S, S)
    if fam == "encdec":
        e = cfg.encdec
        td = e.dec_len
        return (attn_io(e.enc_layers, S, S) + attn_io(e.dec_layers, td, td)
                + attn_io(e.dec_layers, td, S))
    if fam == "ssm":
        di = max(cfg.d_inner // tp, 1)
        n = cfg.ssm.d_state
        nchunks = max(S // cfg.scan_chunk, 1)
        io_b = 2.0   # chunks stream in bf16; f32 promotion stays in VMEM
        per_b = (2 * S * di            # xc read + y write
                 + S * (cfg.ssm.dt_rank + 2 * n)) * io_b \
            + nchunks * di * n * 4.0   # inter-chunk state spill (f32)
        return cfg.n_layers * (B / dp) * per_b * passes
    if fam == "hybrid":
        pat = cfg._pattern_full()
        n_attn = sum(1 for p in pat if p == "attn")
        n_rec = len(pat) - n_attn
        w = cfg.hybrid.window
        dr = max(cfg.hybrid.d_rnn // tp, 1)
        attn = attn_io(n_attn, S, min(2 * w, S))
        rec = n_rec * (B / dp) * (3 * S * dr) * 4.0 * passes
        return attn + rec
    return 0.0


MATERIALIZATIONS_PER_BLOCK = 16   # fusion-boundary tensors per layer (est.)


def streaming_memory_bytes(cfg, shape, *, args_bytes_per_dev: float,
                           core_io_bytes: float,
                           mesh_shape: Dict[str, int]) -> float:
    """Fused-TPU HBM-traffic estimate (the optimistic roofline bound).

    XLA's `bytes accessed` charges every HLO op's operands — an upper bound
    that a fused TPU program beats by orders of magnitude. This model counts
    what must stream from HBM on a well-fused program:
      * state I/O: params read (fwd + bwd recompute) + grad write + AdamW
        moment read/write  -> ~4x the per-device argument bytes at train,
        1x at prefill/decode (cache read dominates decode's args);
      * activations: MATERIALIZATIONS_PER_BLOCK tensors of the residual-
        stream size per layer, x(1 fwd) or x(3.5 with remat backward);
      * the measured/fused core I/O (attention / scan / dispatch kernels).
    Reported alongside the raw-XLA and kernel-adjusted terms; the three
    bracket the truth from both sides.
    """
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    tp = mesh_shape.get("model", 1)
    B, S = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    passes = 3.5 if train else 1.0
    state_io = args_bytes_per_dev * (4.0 if train else 1.0)
    seq_local = S / tp if (cfg.seq_parallel and shape.kind != "decode") else S
    if shape.kind == "decode":
        seq_local = 1
    act = (B / dp) * seq_local * cfg.d_model * 2.0
    n_layers = (cfg.encdec.enc_layers + cfg.encdec.dec_layers
                if cfg.family == "encdec" else cfg.n_layers)
    act_io = n_layers * MATERIALIZATIONS_PER_BLOCK * act * passes
    return state_io + act_io + core_io_bytes


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D inference (MoE: active N)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.tokens if cfg.family != "encdec" else (
            shape.global_batch * (shape.seq_len + cfg.encdec.dec_len))
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.tokens if cfg.family != "encdec" else (
            shape.global_batch * (shape.seq_len + cfg.encdec.dec_len))
        return 2.0 * n_active * toks
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch

"""Chunked transfer/compute overlap — the paper's §IV on TPU/JAX.

The paper splits host->PCIe field data into chunks, starts an advection
kernel the moment *its* chunk lands, and copies results back while other
kernels still run ("effectively ... CUDA streams", Fig. 6). On a JAX device
the same structure is:

  host chunk -> device_put (async) -> jit kernel (async dispatch) -> fetch

`ChunkScheduler.run_overlapped` drives a pool of in-flight chunks, bounded by
`depth` (the paper's kernel pool). JAX's async dispatch gives real
transfer/compute overlap on a real device; on this CPU container the overlap
is partial but measurable. `run_serial` is the paper's baseline ("transfer
everything, then compute, then copy back"). The analytic model
`overlap_model` reproduces Fig. 8's DMA-overhead fractions for TPU-scale
bandwidth numbers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Sequence

import jax
import numpy as np


@dataclass
class ChunkTiming:
    serial_s: float
    overlapped_s: float

    @property
    def speedup(self) -> float:
        return self.serial_s / max(self.overlapped_s, 1e-12)


class ChunkScheduler:
    """Overlap host->device transfer with per-chunk kernel compute."""

    def __init__(self, kernel: Callable, *, depth: int = 4,
                 device=None):
        self.kernel = kernel          # jitted fn: chunk arrays -> result
        self.depth = depth            # in-flight chunks (kernel pool size)
        self.device = device or jax.devices()[0]

    def _put(self, chunk):
        return jax.tree.map(
            lambda a: jax.device_put(a, self.device), chunk)

    def run_serial(self, chunks: Sequence) -> List[np.ndarray]:
        """Paper baseline: all transfers, then all compute, then all fetch."""
        dev = [self._put(c) for c in chunks]
        jax.block_until_ready(dev)
        outs = [self.kernel(*c) if isinstance(c, tuple) else self.kernel(c)
                for c in dev]
        jax.block_until_ready(outs)
        return [np.asarray(o) for o in outs]

    def run_overlapped(self, chunks: Sequence) -> List[np.ndarray]:
        """§IV: issue transfer i+depth while chunk i computes; fetch eagerly.

        JAX dispatch is async: device_put and the kernel call return
        immediately, so the host thread races ahead issuing work `depth`
        chunks deep, exactly like the paper's non-blocking DMA + kernel pool.
        """
        results: List = [None] * len(chunks)
        inflight: List = []
        for i, c in enumerate(chunks):
            d = self._put(c)
            out = self.kernel(*d) if isinstance(d, tuple) else self.kernel(d)
            inflight.append((i, out))
            if len(inflight) >= self.depth:
                j, o = inflight.pop(0)
                results[j] = np.asarray(o)     # blocks only on the oldest
        for j, o in inflight:
            results[j] = np.asarray(o)
        return results

    def time_both(self, chunks, *, warmup: bool = True) -> ChunkTiming:
        if warmup:
            self.run_serial(chunks[:1])
        t0 = time.perf_counter()
        self.run_serial(chunks)
        t1 = time.perf_counter()
        self.run_overlapped(chunks)
        t2 = time.perf_counter()
        return ChunkTiming(t1 - t0, t2 - t1)


def overlap_model(total_bytes: float, compute_s: float, bw: float,
                  n_chunks: int) -> dict:
    """Analytic §IV model: transfer T=total_bytes/bw against compute C.

    serial      = T_in + C + T_out
    overlapped  = max(C, T) + first-chunk-in + last-chunk-out
    (the paper: "the first few input chunks and last few result chunks will
    need to be waited on regardless").
    """
    t_in = total_bytes / bw
    t_out = total_bytes / bw
    serial = t_in + compute_s + t_out
    chunk_in = t_in / n_chunks
    chunk_out = t_out / n_chunks
    overlapped = chunk_in + max(compute_s, t_in + t_out - chunk_in - chunk_out) + chunk_out
    return {"serial_s": serial, "overlapped_s": overlapped,
            "dma_overhead_serial": (t_in + t_out) / serial,
            "dma_overhead_overlapped": max(overlapped - compute_s, 0.0) / overlapped,
            "speedup": serial / overlapped}

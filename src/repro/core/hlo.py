"""HLO-text analysis: collective inventory + phase attribution.

This is the TPU analogue of the paper's profiler IP block: Vivado HLS gave
the authors no way to see where DRAM time went, so they built a counter that
attributed cycles to code blocks. XLA's `cost_analysis()` similarly reports
only program totals, so this module walks the compiled HLO text and
attributes *bytes on the wire* to each collective op (kind, shape, mesh
group) — the numbers the roofline's collective term is built from.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %all-reduce.1 = (f32[128,64]{1,0}, f32[16]{0}) all-reduce(...)
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    name: str
    kind: str
    out_bytes: int          # output shape bytes (per participant)
    group_size: int         # participants per replica group
    group_span: str         # "ici" | "dcn" | "unknown"
    wire_bytes: float = 0.0  # est. bytes crossing each chip's links (ring algo)


def _group_info(line: str, pod_size: int) -> Tuple[int, str]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ng, gs, total = int(m.group(1)), int(m.group(2)), int(m.group(3))
        # iota groups [ng,gs]<=[total]: contiguous strided groups; a group is
        # intra-pod iff its index span stays below pod_size
        span = "ici"
        if pod_size and gs > 1:
            stride = total // (ng * gs) if ng * gs <= total else 1
            if gs * max(stride, 1) > pod_size:
                span = "dcn"
        return gs, span
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        ids = [int(x) for x in first.split(",") if x.strip().isdigit()]
        gs = max(len(ids), 1)
        span = "ici"
        if pod_size and ids and (max(ids) // pod_size != min(ids) // pod_size):
            span = "dcn"
        return gs, span
    return 1, "unknown"


def parse_collectives(hlo_text: str, *, pod_size: int = 0) -> List[CollectiveOp]:
    """Inventory of collective ops with per-chip wire-byte estimates.

    Ring-algorithm accounting (per participating chip):
      all-reduce      2 * (n-1)/n * bytes
      all-gather      (n-1)/n * bytes_out
      reduce-scatter  (n-1)/n * bytes_in  (~= (n-1) * bytes_out)
      all-to-all      (n-1)/n * bytes
      collective-permute  bytes
    """
    seen = set()
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind = m.group(1), m.group(2), m.group(3)
        base = name.split(".")[0]
        if name in seen:
            continue
        seen.add(name)
        if "-done" in line.split("=")[1][:60] and kind + "-done" in line:
            continue  # -done carries no new bytes; -start counted
        out_b = _shape_bytes(type_str)
        gs, span = _group_info(line, pod_size)
        n = max(gs, 1)
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * out_b
        elif kind == "all-gather":
            wire = (n - 1) / n * out_b
        elif kind == "reduce-scatter":
            wire = (n - 1) * out_b  # in_bytes ~= n * out_bytes
        elif kind == "all-to-all":
            wire = (n - 1) / n * out_b
        else:  # collective-permute
            wire = float(out_b)
        ops.append(CollectiveOp(name, kind, out_b, n, span, wire))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = defaultdict(lambda: {"count": 0, "wire_bytes": 0.0})
    for op in ops:
        key = f"{op.kind}/{op.group_span}"
        out[key]["count"] += 1
        out[key]["wire_bytes"] += op.wire_bytes
    return dict(out)


def total_wire_bytes(ops: List[CollectiveOp], span: Optional[str] = None) -> float:
    return sum(o.wire_bytes for o in ops if span is None or o.group_span == span)


# ---------------------------------------------------------------------------
# Phase attribution ("profiler blocks"): classify ops into load/compute/store
# ---------------------------------------------------------------------------

_DOT_RE = re.compile(r"=\s*\(?[^=]*?\)?\s*(dot|convolution)\(")
_FUSION_RE = re.compile(r"=\s*[^=]*?fusion\(")
_COPY_RE = re.compile(r"=\s*[^=]*?(copy|transpose|reshape|bitcast)\(")


def op_census(hlo_text: str) -> Dict[str, int]:
    """Rough census: how many dots / fusions / layout-change ops the program has.

    Layout-change ops between sharded ops are the HLO signature of the paper's
    "non-contiguous access" regression (Fig. 3 row 4) — they show data being
    reshuffled rather than streamed.
    """
    census = defaultdict(int)
    for line in hlo_text.splitlines():
        if _DOT_RE.search(line):
            census["dot"] += 1
        elif _FUSION_RE.search(line):
            census["fusion"] += 1
        elif _COPY_RE.search(line):
            census["layout_change"] += 1
        for k in _COLLECTIVE_KINDS:
            if f" {k}(" in line or f" {k}-start(" in line:
                census[k] += 1
    return dict(census)

"""Parameter specs: one declaration -> init arrays / abstract shapes / shardings.

A model declares a pytree of `ParamSpec`s. From that single source we derive
  * real initialised arrays (smoke tests, examples, training),
  * `jax.ShapeDtypeStruct` stand-ins (multi-pod dry-run: no allocation),
  * `NamedSharding`s via the logical-axis rules (dry-run in_shardings).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import Rules, sharding_for

try:  # jax >= 0.5
    from jax.sharding import Mesh
except ImportError:  # pragma: no cover
    Mesh = object


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: str = "float32"
    init: str = "fan_in"      # fan_in | zeros | ones | normal | embed | recurrent
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "recurrent":
        # RG-LRU Lambda init: a in (0.9, 0.999) via softplus parametrisation
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        return (-jnp.log(jnp.expm1(-jnp.log(u)))).astype(dt) * spec.scale
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dt)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02 * spec.scale).astype(dt)
    # fan_in: truncated-normal-ish scaled by 1/sqrt(fan_in); fan_in = first axis
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[0], 1)
    if len(spec.shape) >= 3:
        fan_in = int(np.prod(spec.shape[:-2])) * spec.shape[-2]
        fan_in = spec.shape[0]
    std = spec.scale / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)


def init_params(specs, rng) -> dict:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=is_spec,
    )


def param_shardings(specs, rules: Rules, mesh):
    return jax.tree.map(
        lambda s: sharding_for(s.shape, s.axes, rules, mesh),
        specs, is_leaf=is_spec,
    )


def param_pspecs(specs, rules: Rules, mesh):
    from repro.distributed.sharding import spec_for
    return jax.tree.map(
        lambda s: spec_for(s.shape, s.axes, rules, mesh),
        specs, is_leaf=is_spec,
    )


def count_params(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def stack_specs(spec: ParamSpec, n: int) -> ParamSpec:
    """Add a leading stacked-layers dim (scan axis)."""
    return ParamSpec((n,) + spec.shape, ("layers",) + spec.axes,
                     spec.dtype, spec.init, spec.scale)

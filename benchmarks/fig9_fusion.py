"""Fig. 9 (ours): temporal-fusion sweep — HBM traffic amortised over T steps.

For T in {1, 2, 4, 8} the v4 `fused` kernel advances T explicit-Euler steps
per HBM pass; the sweep reports, per step:

  * modelled HBM bytes (fused vs the per-step `dataflow` baseline),
  * arithmetic intensity with the fusion factor (core.roofline),
  * roofline time and compute share on the v5e constants,
  * interpret-mode wallclock + max |err| vs the multi-step f64 oracle on a
    reduced grid (correctness pinned where we cannot wall-clock the TPU).

The sweep models the in-grid (y_tile, x) tiled path (the kernels' default:
zero HBM halo overlap, halo re-reads served from VMEM) and reports the
retained host-tiled bytes alongside for comparison.

Emits the usual CSV rows AND writes ``BENCH_fusion.json`` next to the CWD
(CI uploads it as an artifact). ``run(smoke=True)`` (CLI: ``--quick``, or
``BENCH_SMOKE=1``) shrinks the measured grid for the CI smoke invocation.
"""
from __future__ import annotations

import json
import os

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

import numpy as np

from benchmarks.common import comp_s, emit, mem_s, wallclock_us
from repro.core import roofline as R
from repro.kernels.advection.advection import (advect_fused,
                                               fused_register_bytes,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import (default_params, flops_per_cell,
                                         pw_multistep_ref_f64)
from repro.stencil.advection import stratus_fields

# modelled at the paper's Fig. 3 grid; measured on a reduced grid (interpret)
X, Y, Z = 512, 512, 64
ITEM = 4  # f32
T_SWEEP = (1, 2, 4, 8)
Y_TILE = 128


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    cells = X * Y * Z
    fpc = flops_per_cell()
    flops_step = cells * fpc
    rows = []
    base_step_b = hbm_bytes_model(X, Y, Z, ITEM, "dataflow")  # one step, v2
    for T in T_SWEEP:
        # in-grid tiled path (the default): zero HBM halo overlap
        fused_b = hbm_bytes_model(X, Y, Z, ITEM, "fused", T=T, y_tile=Y_TILE,
                                  grid_tiled=True)
        host_b = hbm_bytes_model(X, Y, Z, ITEM, "fused", T=T, y_tile=Y_TILE,
                                 grid_tiled=False)
        per_step_b = fused_b / T
        host_factor = R.stencil_tiling_bytes_factor(Y, Y_TILE, T,
                                                    grid_tiled=False)
        ai = R.stencil_arithmetic_intensity(fpc, per_step_b / cells)
        ai_host = R.stencil_arithmetic_intensity(
            fpc, per_step_b / cells, tiling_bytes_factor=host_factor)
        t_mem = mem_s(per_step_b)
        t_cmp = comp_s(flops_step)
        t_roof = max(t_mem, t_cmp)
        reg_b = fused_register_bytes(T, Y, Z, ITEM, y_tile=Y_TILE)
        emit(f"fig9.fused_T{T}", t_roof * 1e6,
             f"bytes_per_step={per_step_b:.3e};amortisation="
             f"{base_step_b / per_step_b:.2f}x;AI={ai:.2f};"
             f"compute_share={t_cmp / t_roof * 100:.1f}%;vmem_reg_B={reg_b}")
        rows.append({
            "T": T,
            "grid": [X, Y, Z],
            "y_tile": Y_TILE,
            "tiling": "grid",
            "bytes_per_step_modelled": per_step_b,
            "bytes_per_pass_modelled": fused_b,
            "host_tiled_bytes_per_pass": host_b,
            "baseline_dataflow_bytes_per_step": base_step_b,
            "amortisation_x": base_step_b / per_step_b,
            "arithmetic_intensity": ai,
            "arithmetic_intensity_host_tiled": ai_host,
            "roofline_us_per_step": t_roof * 1e6,
            "vmem_register_bytes": reg_b,
        })

    # measured (interpret-mode) correctness + wallclock on a reduced grid
    Xr, Yr, Zr = (5, 16, 16) if smoke else (8, 32, 32)
    u, v, w = stratus_fields(Xr, Yr, Zr)
    p = default_params(Zr)
    dt = 0.01
    for T, row in zip(T_SWEEP, rows):
        out = advect_fused(u, v, w, p, T=T, dt=dt)
        oracle = pw_multistep_ref_f64(u, v, w, p, T, dt)
        err = max(float(np.max(np.abs(np.asarray(a, np.float64) - b)))
                  for a, b in zip(out, oracle))
        us = wallclock_us(
            lambda a, b, c: advect_fused(a, b, c, p, T=T, dt=dt), u, v, w,
            iters=1 if smoke else 3)
        row.update(reduced_grid=[Xr, Yr, Zr],
                   interpret_us_per_pass=us, max_err_vs_f64_oracle=err)
        emit(f"fig9.fused_T{T}_interpret", us,
             f"grid={Xr}x{Yr}x{Zr};err_vs_f64={err:.2e}")
        assert err < 1e-4, (T, err)

    ridge_T = R.stencil_ridge_T(fpc, base_step_b / cells)
    emit("fig9.ridge_T", 0.0,
         f"T_to_compute_bound={ridge_T};v5e_ridge="
         f"{R.PEAK_FLOPS / R.HBM_BW:.0f}flop_per_byte")
    payload = {"rows": rows, "ridge_T": ridge_T,
               "flops_per_cell": fpc,
               "hw": {"peak_flops": R.PEAK_FLOPS, "hbm_bw": R.HBM_BW}}
    out_path = os.path.join(os.getcwd(), "BENCH_fusion.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("fig9.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

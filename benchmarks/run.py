"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see common.emit).
"""
from __future__ import annotations

import os
import sys
import traceback

# allow `python benchmarks/run.py` (CI) as well as `python -m benchmarks.run`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from benchmarks import common


def main() -> None:
    from benchmarks import (dma_overlap, fig3_ladder, fig5_scaling,
                            fig7_compare, fig8_gridsize, fig9_fusion,
                            roofline_table)
    common.header()
    failures = []
    for mod in (fig3_ladder, fig5_scaling, fig7_compare, fig8_gridsize,
                fig9_fusion, dma_overlap, roofline_table):
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see common.emit).
"""
from __future__ import annotations

import sys
import traceback

# allow `python benchmarks/run.py` (CI) as well as `python -m benchmarks.run`
try:                        # package context
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

from benchmarks import common


def main() -> None:
    from benchmarks import (dma_overlap, fault_recovery_sweep,
                            fault_sweep, fig3_ladder,
                            fig5_scaling, fig7_compare, fig8_gridsize,
                            fig9_fusion, overlap_sweep, pipeline_sweep,
                            roofline_table, scaling2d_sweep, serving_sweep,
                            stencil_sweep, tiling_sweep)
    common.header()
    failures = []
    for mod in (fig3_ladder, fig5_scaling, fig7_compare, fig8_gridsize,
                fig9_fusion, tiling_sweep, scaling2d_sweep, overlap_sweep,
                pipeline_sweep, serving_sweep, fault_sweep,
                fault_recovery_sweep, stencil_sweep, dma_overlap,
                roofline_table):
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

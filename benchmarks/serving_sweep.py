"""Serving-tier sweep: the batched multi-domain mega-launch priced and
gated, written to ``BENCH_serving.json``.

Row families:

  * ``modelled[]`` — `roofline.serving_throughput_model` over a padded
    slot shape at growing batch sizes, single-shard and 2D-mesh-priced
    (exposed wire seconds from the overlap accounting feeding the
    per-domain cost). GATES: domains/s STRICTLY RISES with batch all the
    way to the VMEM-ring-bound maximum (`roofline.serving_max_batch`),
    and one slot past the bound the model REFUSES (ValueError) rather
    than extrapolating a layout whose resident rings cannot fit.
  * ``counted[]`` — the batched kernel itself, in process: the
    mega-launch output gated BITWISE-equal to per-domain sequential
    `advect_fused` runs at every swept batch size, and the jaxpr-counted
    HBM bytes (`count_pallas_hbm_bytes`) gated == batch x
    `hbm_bytes_model` EXACTLY (lane-aligned Z).
  * ``engine[]`` — `StencilServingEngine` end to end: mixed-extent
    requests padded into the mega-launch, streamed states and final
    outputs gated BITWISE-equal to unpadded sequential runs; executable
    cache hit/miss counters gated (one miss per configuration); a
    simulated mid-run device loss + re-shard gated bitwise-equal to the
    uninterrupted run with exactly one extra recorded miss.

Every gate is an explicit ``SystemExit`` raise (python -O safe). CI runs
``--quick`` in the benchmark-smoke job.
"""
from __future__ import annotations

import dataclasses
import json
import os

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import roofline as R
from repro.kernels.advection.advection import (advect_fused,
                                               advect_fused_batched,
                                               fused_register_bytes,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import default_params
from repro.serving.stencil_engine import (StencilRequest,
                                          StencilServingEngine)
from repro.stencil.advection import AdvectionDomain, stratus_fields
from repro.stencil.distributed import count_pallas_hbm_bytes

SLOT = (64, 256, 128)       # modelled padded slot shape (lane-aligned Z)
COUNTED_GRID = (8, 16, 128)  # in-process batched-kernel grid (lane-aligned)
ENGINE_GRID = (12, 16, 64)   # engine slot shape for the bitwise gates


def _modelled_rows(smoke: bool):
    X, Y, Z = SLOT
    cases = [  # (T, y_tile, mesh, exchange, n_blocks)
        (4, 64, (1, 1), "collective", 1),
        (4, 64, (4, 4), "remote_dma", 4),
    ] if smoke else [
        (4, 64, (1, 1), "collective", 1),
        (8, 32, (1, 1), "collective", 1),
        (4, 64, (4, 4), "remote_dma", 4),
        (4, 64, (4, 4), "collective", 1),
    ]
    rows = []
    for T, y_tile, (nx, ny), exchange, n_blocks in cases:
        base = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T,
                               y_tile=y_tile, mesh_nx=nx, mesh_ny=ny,
                               exchange=exchange, overlap=nx * ny > 1,
                               n_blocks=n_blocks)
        ring = fused_register_bytes(T, Y, Z, 4, y_tile=y_tile)
        max_b = R.serving_max_batch(ring)
        batches = sorted(set([1, 2, 4, max(max_b // 2, 1), max_b]))
        tputs = []
        for b in batches:
            dom = dataclasses.replace(base, batch=b)
            if dom.vmem_register_bytes() != ring * b:
                raise SystemExit(
                    f"serving gate: AdvectionDomain(batch={b}) ring bytes "
                    f"{dom.vmem_register_bytes()} != {b} x per-slot {ring}")
            tputs.append(dom.serving_throughput())
        if not all(b > a for a, b in zip(tputs, tputs[1:])):
            raise SystemExit(
                f"serving gate: modelled domains/s not strictly rising in "
                f"batch for T={T} mesh=({nx},{ny}): {tputs}")
        try:
            dataclasses.replace(base, batch=max_b + 1).serving_throughput()
        except ValueError:
            pass
        else:
            raise SystemExit(
                f"serving gate: batch={max_b + 1} must exceed the VMEM "
                f"ring budget (max {max_b}) and be refused, but was priced")
        rows.append({"slot": [X, Y, Z], "T": T, "y_tile": y_tile,
                     "mesh": [nx, ny], "exchange": exchange,
                     "n_blocks": n_blocks,
                     "ring_bytes_per_slot": ring, "max_batch": max_b,
                     "batches": batches,
                     "domains_per_s": tputs})
        emit(f"serving.modelled.T{T}.{nx}x{ny}.{exchange}",
             1e6 / tputs[-1],
             f"max_batch={max_b};domains_per_s_B1={tputs[0]:.1f};"
             f"domains_per_s_Bmax={tputs[-1]:.1f}")
    return rows


def _counted_rows(smoke: bool):
    X, Y, Z = COUNTED_GRID
    T = 2
    p = default_params(Z)
    batches = (1, 3) if smoke else (1, 2, 4)
    rows = []
    for B in batches:
        doms = [stratus_fields(X, Y, Z, seed=s) for s in range(B)]
        u = jnp.stack([d[0] for d in doms])
        v = jnp.stack([d[1] for d in doms])
        w = jnp.stack([d[2] for d in doms])

        def batched(uu, vv, ww):
            return advect_fused_batched(uu, vv, ww, p, T=T, dt=0.005,
                                        interpret=True)

        ou, ov, ow = batched(u, v, w)
        diff = 0.0
        for b, (du, dv, dw) in enumerate(doms):
            su, sv, sw = advect_fused(du, dv, dw, p, T=T, dt=0.005,
                                      interpret=True)
            diff = max(diff, *(float(jnp.max(jnp.abs(x[b] - y)))
                               for x, y in ((ou, su), (ov, sv), (ow, sw))))
        if diff != 0.0:
            raise SystemExit(
                f"serving gate: batched mega-launch differs from "
                f"per-domain sequential advect_fused by {diff} at B={B}")
        counted = count_pallas_hbm_bytes(batched, u, v, w)
        model = B * hbm_bytes_model(X, Y, Z, 4, "fused", T=T)
        if counted != model:
            raise SystemExit(
                f"serving gate: jaxpr-counted HBM bytes {counted} != "
                f"batched model {model} at B={B} — the mega-launch must "
                "stream exactly B x the per-domain pass")
        rows.append({"grid": [X, Y, Z], "T": T, "batch": B,
                     "counted_hbm_bytes": counted,
                     "modelled_hbm_bytes": model,
                     "bitwise_diff_vs_sequential": diff})
        emit(f"serving.counted.B{B}", 0.0,
             f"hbm_B={counted};bitwise_equal=True")
    return rows


def _engine_rows(smoke: bool):
    X, Y, Z = ENGINE_GRID
    T = 2
    dom = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T, dt=0.005)
    p = default_params(Z)
    sizes = [(X, Y), (6, 8), (4, 10)] if smoke else \
        [(X, Y), (6, 8), (4, 10), (X, 5), (5, Y), (7, 7)]
    n_steps = [1 + i % 3 for i in range(len(sizes))]

    def make_requests():
        reqs = []
        for i, (Xr, Yr) in enumerate(sizes):
            u, v, w = stratus_fields(Xr, Yr, Z, seed=i)
            reqs.append(StencilRequest(uid=i, u=np.asarray(u),
                                       v=np.asarray(v), w=np.asarray(w),
                                       n_steps=n_steps[i]))
        return reqs

    # per-domain sequential oracle on the UNPADDED fields
    oracle = {}
    for i, (Xr, Yr) in enumerate(sizes):
        u, v, w = stratus_fields(Xr, Yr, Z, seed=i)
        states = []
        for _ in range(n_steps[i]):
            u, v, w = advect_fused(u, v, w, p, T=T, dt=0.005, interpret=True)
            states.append((np.asarray(u), np.asarray(v), np.asarray(w)))
        oracle[i] = states

    engine = StencilServingEngine(dom, batch_size=2)
    done = engine.run(make_requests())
    diff = max(float(np.max(np.abs(np.asarray(a) - b)))
               for i in done for st, ref in zip(done[i].states, oracle[i])
               for a, b in zip(st, ref))
    if diff != 0.0:
        raise SystemExit(
            f"serving gate: padded mega-launch engine differs from "
            f"unpadded sequential runs by {diff}")
    if any(len(done[i].states) != n_steps[i] for i in done):
        raise SystemExit("serving gate: streamed state count != n_steps")
    stats = engine.cache_stats()
    if stats["misses"] != 1 or stats["entries"] != 1 or stats["hits"] < 1:
        raise SystemExit(
            f"serving gate: executable cache should trace once and hit "
            f"thereafter, got {stats}")

    # simulated device loss: batch 2 -> 1 after the first mega-step
    faulted = StencilServingEngine(dom, batch_size=2)
    done_f = faulted.run(make_requests(), lose_device_at=1, reshard_to=1)
    diff_f = max(float(np.max(np.abs(done_f[i].out[j] - done[i].out[j])))
                 for i in done for j in range(3))
    if diff_f != 0.0:
        raise SystemExit(
            f"serving gate: re-sharded (device-loss) run differs from "
            f"uninterrupted run by {diff_f}")
    stats_f = faulted.cache_stats()
    if stats_f["misses"] != 2 or stats_f["entries"] != 2:
        raise SystemExit(
            f"serving gate: the re-shard must record exactly one extra "
            f"cache miss (new batch in the key), got {stats_f}")
    row = {"slot": [X, Y, Z], "T": T, "batch": 2,
           "request_extents": sizes, "n_steps": n_steps,
           "bitwise_diff_vs_sequential": diff,
           "cache_stats": stats,
           "reshard_bitwise_diff": diff_f,
           "reshard_cache_stats": stats_f}
    emit("serving.engine.2slots", 0.0,
         f"jobs={len(sizes)};bitwise_equal=True;"
         f"cache_hits={stats['hits']};reshard_ok=True")
    return [row]


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    payload = {
        "modelled": _modelled_rows(smoke),
        "counted": _counted_rows(smoke),
        "engine": _engine_rows(smoke),
        "itemsize": 4,
        "contract": "batched mega-launch bitwise-equal to per-domain "
                    "sequential advect_fused runs at every batch size "
                    "(raw kernel AND the padded mixed-extent engine, "
                    "streamed states included); jaxpr-counted HBM bytes "
                    "== batch x hbm_bytes_model exactly; modelled "
                    "domains/s strictly rises with batch until the VMEM "
                    "ring budget binds and the model refuses past it; "
                    "executable cache traces once per (shape, T, dtype, "
                    "n_blocks, exchange, mesh) key and a device-loss "
                    "re-shard records exactly one extra miss with "
                    "bitwise-identical outputs",
    }
    out_path = os.path.join(os.getcwd(), "BENCH_serving.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("serving.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

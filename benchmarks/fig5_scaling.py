"""Fig. 5 reproduction: aggregate kernel runtime vs number of concurrent
kernels contending for memory bandwidth.

Paper: 1..8 HLS kernels share the card's DRAM. Without wide ports the
aggregate runtime scales terribly; 256-bit ports help; 4-streams-deep rate
decoupling makes 8 kernels only ~20% slower than 1. The TPU analogue is N
stencil workers sharing one chip's HBM: per-worker compute is fixed, the
memory term scales with N workers' combined traffic, and buffering depth
determines how much of the bandwidth variance is hidden.

Model: aggregate_time(N) = max(compute, N * bytes / BW) * contention(N, depth)
where contention captures scheduling losses that deeper buffering hides
(the paper's stream-depth-16 FIFO argument).
"""
from __future__ import annotations

from benchmarks.common import comp_s, emit, mem_s
from repro.kernels.advection.advection import hbm_bytes_model
from repro.kernels.advection.ref import flops_per_cell

X, Y, Z = 512, 512, 64
CELLS = X * Y * Z


def contention(n: int, depth: int, burstiness: float) -> float:
    """Scheduling-loss factor: n workers' bursty request streams collide on
    the shared memory system; a depth-d FIFO hides (1 - 1/d) of the variance
    (the paper's 4-doubles-per-cycle rate-decoupling argument)."""
    if n <= 1:
        return 1.0
    burst = burstiness * (n - 1) / n
    hidden = 1.0 - 1.0 / depth
    return 1.0 + burst * (1.0 - hidden)


def run() -> None:
    """Paper metric: *aggregate* runtime — the problem is split across N
    kernels, so perfect scaling keeps the aggregate flat; contention makes
    it grow. Paper: narrow ports scale 'very poorly' (~2x at n=8), 256-bit
    ~1.9x, 4-streams-deep only 1.2x."""
    flops = CELLS * flops_per_cell()
    c_s = comp_s(flops)
    variants = [
        # (name, total bytes, fifo depth, burstiness)
        ("narrow", hbm_bytes_model(X, Y, Z, 4, "dataflow"), 1, 1.2),
        ("wide", hbm_bytes_model(X, Y, 128, 4, "wide") * (Z / 128), 1, 1.0),
        ("wide_deep", hbm_bytes_model(X, Y, 128, 4, "wide") * (Z / 128), 4, 1.0),
    ]
    print("# fig5: aggregate runtime, problem split across N workers")
    for name, bytes_, depth, burst in variants:
        t1 = None
        for n in (1, 2, 4, 8):
            t = max(c_s, mem_s(bytes_)) * contention(n, depth, burst)
            t1 = t1 or t
            emit(f"fig5.{name}.n{n}", t * 1e6, f"aggregate_vs_1={t/t1:.2f}")
    b, d, _ = variants[2][1:]
    t1 = max(c_s, mem_s(b))
    t8 = t1 * contention(8, 4, 1.0)
    emit("fig5.deep_n8_overhead", 0.0,
         f"aggregate_vs_n1={t8/t1:.2f};paper=1.20")


if __name__ == "__main__":
    run()

"""Fig. 7 reproduction: device-vs-CPU comparison at the 67M-point case.

The paper compares its 8-kernel FPGA against Sandybridge/Ivybridge/Broadwell
(4-core and all-core). Here: measured CPU wall-clock (this container's CPU,
scaled from a reduced grid — linear in cells, verified in-run) against the
TPU v5e roofline projection of the dataflow+wide kernel, plus the equal-
resource normalisation the paper does (its "4 kernels vs 4 cores").
"""
from __future__ import annotations

import jax

from benchmarks.common import comp_s, emit, mem_s, wallclock_us
from repro.core.chunking import overlap_model
from repro.kernels.advection.advection import hbm_bytes_model
from repro.kernels.advection.ref import default_params, flops_per_cell, pw_advect_ref
from repro.stencil.advection import stratus_fields

TARGET = (1024, 1024, 64)   # 67M points


def run() -> None:
    # measured CPU at two reduced sizes to verify linear scaling, then project
    times = []
    for (X, Y, Z) in [(32, 128, 64), (64, 128, 64)]:
        u, v, w = stratus_fields(X, Y, Z)
        p = default_params(Z)
        fn = jax.jit(lambda a, b, c: pw_advect_ref(a, b, c, p))
        us = wallclock_us(fn, u, v, w)
        times.append((X * Y * Z, us))
        emit(f"fig7.cpu_measured_{X}x{Y}x{Z}", us, "")
    (c1, t1), (c2, t2) = times
    lin = (t2 / t1) / (c2 / c1)
    cells = TARGET[0] * TARGET[1] * TARGET[2]
    cpu_proj_us = t2 * cells / c2
    emit("fig7.cpu_projected_67M", cpu_proj_us, f"linearity={lin:.2f}")

    flops = cells * flops_per_cell()
    kern_s = max(comp_s(flops),
                 mem_s(hbm_bytes_model(*TARGET, 4, "wide")))
    io = 2 * 3 * cells * 4
    total_s = overlap_model(io, kern_s, 100e9, 64)["overlapped_s"]
    emit("fig7.tpu_kernel_67M", kern_s * 1e6,
         f"vs_cpu={cpu_proj_us/(kern_s*1e6):.1f}x")
    emit("fig7.tpu_total_67M", total_s * 1e6,
         f"vs_cpu={cpu_proj_us/(total_s*1e6):.1f}x;paper_fpga_vs_broadwell=1.22x")


if __name__ == "__main__":
    run()

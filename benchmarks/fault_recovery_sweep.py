"""Checksummed halo exchange + checkpointed elastic recovery sweep,
gated end to end, written to ``BENCH_recovery.json``.

The distributed run only earns its data-movement wins if the moved bytes
can be TRUSTED and the run can survive losing them: this sweep prices
and gates the integrity layer, the checkpoint/resume path, and the
elastic (mesh-shrink/regrow) recovery of
`serving.faults.resilient_distributed_run` on 4 forced host devices
(the scaling2d subprocess idiom).

Row families:

  * ``integrity[]`` — per (mesh, T, engine): the jaxpr-counted checksum
    wire bytes of a `verify_integrity=True` step
    (`stencil.distributed.count_integrity_bytes`) gated ==
    `roofline.integrity_bytes_model` EXACTLY (hop-count dependent,
    payload independent: one uint32 word per band message per side per
    field); the verified step's field outputs gated BITWISE-equal to the
    unchecked step with zero mismatch flags; the FIELD wire bytes gated
    verify-invariant; and an injected wire corruption
    (`corrupt_halo=`) gated DETECTED (non-zero receiver-side flags).
  * ``checkpoint[]`` — `make_distributed_run(checkpoint_every=k)`
    interrupted mid-run and continued by `resume_distributed_run`,
    gated BITWISE-equal to the uninterrupted run.
  * ``recovery[]`` — a halo-corruption plan through
    `resilient_distributed_run`: gated detected by the band checksums
    (not the NaN guard), rolled back EXACTLY once, replay overhead
    bounded by the snapshot interval, final fields bitwise-clean.
  * ``elastic[]`` — a device-loss shrink (4 -> 2 shards) followed by a
    device-return regrow (2 -> 4): gated BITWISE-equal both to the
    never-interrupted 4-shard run and to the single-device global
    oracle (the fused kernel's fixed y_tile keeps per-tile arithmetic
    shard-shape independent, so elasticity is bitwise-invisible).

Every gate is an explicit ``SystemExit`` raise (python -O safe). CI runs
``--quick`` in the benchmark-smoke job;
`scripts/check_bench_trends.py` compares the artifact against
``benchmarks/baselines.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

from benchmarks.common import emit

GRID = (6, 16, 12)
DT = 0.005
N_BLOCKS = 5
CKPT_EVERY = 2

_SUB_CODE = textwrap.dedent("""
    import json, os, sys, tempfile, time
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.roofline import integrity_bytes_model
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.serving import faults as F
    from repro.stencil import distributed as D
    from repro.stencil.advection import stratus_fields

    cfg = json.loads(sys.argv[1])
    X, Y, Z = cfg["grid"]
    DT = cfg["dt"]
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)

    def bitdiff(a, b):
        return max(float(jnp.max(jnp.abs(jnp.asarray(np.asarray(x))
                                         - jnp.asarray(np.asarray(y)))))
                   for x, y in zip(a, b))

    def clock(fn, *args):
        jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[1] * 1e6

    out = {"integrity": []}
    for nx, ny, T, ex in cfg["integrity_cases"]:
        mesh = make_stencil_mesh(nx, ny)
        kw = dict(axis="y", x_axis=("x" if nx > 1 else None), T=T, dt=DT)
        step0 = D.make_distributed_step(mesh, p, exchange=ex, **kw)
        stepv = D.make_distributed_step(mesh, p, exchange=ex,
                                        verify_integrity=True, **kw)
        o0 = step0(u, v, w)
        *ov, fl = stepv(u, v, w)
        stepc = D.make_distributed_step(mesh, p, exchange=ex,
                                        verify_integrity=True,
                                        corrupt_halo=(1, 1, float("nan")),
                                        **kw)
        *_, flc = stepc(u, v, w)
        out["integrity"].append({
            "mesh": [nx, ny], "T": T, "exchange": ex,
            "counted_integrity_bytes": D.count_integrity_bytes(
                stepv, u, v, w),
            "modelled_integrity_bytes": integrity_bytes_model(
                X, Y, Z, nx=nx, ny=ny, T=T),
            "unchecked_integrity_bytes": D.count_integrity_bytes(
                step0, u, v, w),
            "wire_bytes_unchecked": D.count_exchange_wire_bytes(
                step0, u, v, w),
            "wire_bytes_verified": D.count_exchange_wire_bytes(
                stepv, u, v, w),
            "bitwise_diff_verified": bitdiff(o0, ov),
            "clean_mismatch_flags": int(np.sum(np.asarray(fl))),
            "corrupt_mismatch_flags": int(np.sum(np.asarray(flc))),
            "us_unchecked": clock(step0, u, v, w),
            "us_verified": clock(stepv, u, v, w),
        })

    K = cfg["n_blocks"]; every = cfg["ckpt_every"]
    mesh = make_stencil_mesh(1, 4)
    kw = dict(axis="y", x_axis=None, T=2, dt=DT, exchange="remote_dma")
    full = D.make_distributed_run(mesh, p, n_blocks=K, **kw)(u, v, w)
    cut = K - 2
    with tempfile.TemporaryDirectory() as d:
        D.make_distributed_run(mesh, p, n_blocks=cut, checkpoint_every=every,
                               checkpoint_dir=d, **kw)(u, v, w)
        n_snaps = len([x for x in os.listdir(d) if x.startswith("step_")])
        res = D.resume_distributed_run(mesh, p, u, v, w, n_blocks=K,
                                       checkpoint_dir=d,
                                       checkpoint_every=every, **kw)
    out["checkpoint"] = {
        "n_blocks": K, "interrupted_at": cut, "checkpoint_every": every,
        "snapshots_on_disk": n_snaps,
        "bitwise_diff_resumed": bitdiff(full, res),
    }

    rkw = dict(n_blocks=K, T=2, dt=DT, axis="y", x_axis=None,
               checkpoint_every=every)
    plan = F.FaultPlan.parse("halo_corruption@3:field=v")
    got, inj = F.resilient_distributed_run(
        mesh, p, u, v, w, injector=F.FaultInjector(plan), **rkw)
    h = inj.health()
    out["recovery"] = {
        "plan": h["plan"], "checkpoint_every": every,
        "bitwise_diff_recovered": bitdiff(full, got),
        "rollbacks": h["rollbacks"], "replayed_blocks": h["replayed_blocks"],
        "faults_injected": h["faults_injected"],
        "faults_skipped": h["faults_skipped"],
        "detected_by_checksum": any("checksum" in t
                                    for t in h["transitions"]),
    }

    fkw = dict(n_blocks=K, T=2, dt=DT, axis="y", x_axis=None,
               local_kernel="fused", y_tile=2)
    clean4 = D.make_distributed_run(mesh, p, exchange="remote_dma",
                                    **fkw)(u, v, w)
    oracle = D.make_distributed_run(make_stencil_mesh(1, 1), p,
                                    exchange="collective", **fkw)(u, v, w)
    plan = F.FaultPlan.parse(
        "device_loss@1:reshard_to=2;device_loss@3:reshard_to=4")
    got, inj = F.resilient_distributed_run(
        mesh, p, u, v, w, injector=F.FaultInjector(plan), **fkw)
    h = inj.health()
    out["elastic"] = {
        "plan": h["plan"],
        "bitwise_diff_vs_4shard": bitdiff(clean4, got),
        "bitwise_diff_vs_global_oracle": bitdiff(oracle, got),
        "device_losses": h["device_losses"], "reshards": h["reshards"],
        "faults_skipped": h["faults_skipped"],
        "transitions": [t for t in h["transitions"] if "re-shard" in t],
    }
    print(json.dumps(out))
""")


def _subprocess_payload(smoke: bool) -> dict:
    cases = ([[1, 4, 2, "collective"], [1, 4, 2, "remote_dma"]]
             if smoke else
             [[1, 4, 2, "collective"], [1, 4, 2, "remote_dma"],
              [1, 4, 6, "collective"], [1, 4, 6, "remote_dma"],
              [2, 2, 2, "collective"]])
    cfg = {"grid": list(GRID), "dt": DT, "n_blocks": N_BLOCKS,
           "ckpt_every": CKPT_EVERY, "integrity_cases": cases}
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.update({
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(root, "src"), root,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
    })
    r = subprocess.run([sys.executable, "-c", _SUB_CODE, json.dumps(cfg)],
                       capture_output=True, text=True, cwd=root, env=env,
                       timeout=900)
    if r.returncode != 0:
        raise SystemExit(f"recovery subprocess failed:\n{r.stderr[-3000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _gate(payload: dict) -> None:
    for row in payload["integrity"]:
        tag = f"{row['mesh']}/T{row['T']}/{row['exchange']}"
        if (row["counted_integrity_bytes"]
                != row["modelled_integrity_bytes"]):
            raise SystemExit(
                f"recovery gate: counted integrity bytes "
                f"{row['counted_integrity_bytes']} != modelled "
                f"{row['modelled_integrity_bytes']} at {tag} — one uint32 "
                f"word per band message per side per field, exactly")
        if row["unchecked_integrity_bytes"] != 0:
            raise SystemExit(
                f"recovery gate: an UNCHECKED step carries "
                f"{row['unchecked_integrity_bytes']} checksum bytes at "
                f"{tag}; verification must be strictly opt-in")
        if row["wire_bytes_unchecked"] != row["wire_bytes_verified"]:
            raise SystemExit(
                f"recovery gate: verification changed the FIELD wire "
                f"bytes at {tag}: {row['wire_bytes_unchecked']} -> "
                f"{row['wire_bytes_verified']}")
        if row["bitwise_diff_verified"] != 0.0:
            raise SystemExit(
                f"recovery gate: verified step differs from unchecked by "
                f"{row['bitwise_diff_verified']} at {tag}; checksums must "
                f"ride beside the bands, never touch them")
        if row["clean_mismatch_flags"] != 0:
            raise SystemExit(
                f"recovery gate: clean exchange raised "
                f"{row['clean_mismatch_flags']} mismatch flags at {tag}")
        if row["corrupt_mismatch_flags"] == 0:
            raise SystemExit(
                f"recovery gate: injected wire corruption NOT detected at "
                f"{tag}; receiver-side checksums must trip")
        emit(f"recovery.integrity.{row['exchange']}."
             f"{row['mesh'][0]}x{row['mesh'][1]}.T{row['T']}",
             row["us_verified"],
             f"words_B={row['counted_integrity_bytes']};"
             f"us_unchecked={row['us_unchecked']:.1f};"
             f"corrupt_flags={row['corrupt_mismatch_flags']}")

    ck = payload["checkpoint"]
    if ck["bitwise_diff_resumed"] != 0.0:
        raise SystemExit(
            f"recovery gate: interrupt-at-{ck['interrupted_at']} + resume "
            f"differs from the uninterrupted {ck['n_blocks']}-block run by "
            f"{ck['bitwise_diff_resumed']}; resume must be bitwise")
    if ck["snapshots_on_disk"] < 1:
        raise SystemExit("recovery gate: checkpointed run left no "
                         "snapshots on disk")
    emit("recovery.checkpoint.resume", 0.0,
         f"blocks={ck['n_blocks']};cut={ck['interrupted_at']};"
         f"snapshots={ck['snapshots_on_disk']};bitwise=True")

    rec = payload["recovery"]
    if rec["bitwise_diff_recovered"] != 0.0:
        raise SystemExit(
            f"recovery gate: halo-corruption replay differs from the "
            f"clean run by {rec['bitwise_diff_recovered']}")
    if not rec["detected_by_checksum"]:
        raise SystemExit(
            "recovery gate: corruption must be detected by the band "
            "checksums (transition note), not the NaN guard")
    if rec["rollbacks"] != 1 or rec["faults_skipped"] != 0:
        raise SystemExit(
            f"recovery gate: one-shot corruption must roll back exactly "
            f"once and never be skipped; health {rec}")
    if rec["replayed_blocks"] > rec["checkpoint_every"] * rec["rollbacks"]:
        raise SystemExit(
            f"recovery gate: replay overhead {rec['replayed_blocks']} "
            f"blocks exceeds the snapshot interval "
            f"{rec['checkpoint_every']} — rollback went too far")
    emit("recovery.replay.halo_corruption", 0.0,
         f"rollbacks={rec['rollbacks']};"
         f"replayed_blocks={rec['replayed_blocks']};bitwise=True")

    el = payload["elastic"]
    if el["bitwise_diff_vs_4shard"] != 0.0:
        raise SystemExit(
            f"recovery gate: shrink/regrow run differs from the "
            f"never-interrupted 4-shard run by "
            f"{el['bitwise_diff_vs_4shard']}")
    if el["bitwise_diff_vs_global_oracle"] != 0.0:
        raise SystemExit(
            f"recovery gate: shrink/regrow run differs from the "
            f"single-device global oracle by "
            f"{el['bitwise_diff_vs_global_oracle']} — the fused kernel's "
            f"fixed y_tile must make elasticity bitwise-invisible")
    if el["device_losses"] != 2 or el["reshards"] != 2:
        raise SystemExit(
            f"recovery gate: loss+return must record 2 device_losses and "
            f"2 reshards; health {el}")
    if el["faults_skipped"] != 0:
        raise SystemExit(
            f"recovery gate: device_loss skipped "
            f"({el['faults_skipped']}); every kind is injectable now")
    emit("recovery.elastic.shrink_regrow", 0.0,
         f"losses={el['device_losses']};reshards={el['reshards']};"
         f"bitwise_vs_oracle=True")


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    payload = _subprocess_payload(smoke)
    _gate(payload)
    payload["itemsize"] = 4
    payload["contract"] = (
        "jaxpr-counted checksum wire bytes == integrity_bytes_model "
        "exactly per (mesh, T, engine) with the field wire bytes "
        "verify-invariant and the verified step bitwise-equal to the "
        "unchecked step; injected wire corruption trips the "
        "receiver-side checksums; an interrupted checkpointed run "
        "resumed by resume_distributed_run is bitwise-equal to the "
        "uninterrupted run; a halo corruption through "
        "resilient_distributed_run is detected by the checksums, rolled "
        "back exactly once with replay bounded by the snapshot "
        "interval, and finishes bitwise-clean; a device-loss shrink + "
        "device-return regrow is bitwise-equal to both the 4-shard run "
        "and the single-device global oracle")
    out_path = os.path.join(os.getcwd(), "BENCH_recovery.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("recovery.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

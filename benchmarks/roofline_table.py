"""Beyond-paper benchmark: the 40-cell roofline table from the dry-run JSONs.

Prints one row per (arch x shape) single-pod cell: the three terms,
bottleneck, MFU. Reads experiments/dryrun/*.json (run the dry-run first).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run() -> None:
    if not DRYRUN.exists():
        emit("roofline.missing", 0.0, "run python -m repro.launch.dryrun --all")
        return
    for f in sorted(DRYRUN.glob("*__16x16.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped") or "roofline" not in rec:
            continue
        r = rec.get("roofline_kernel_adjusted", rec["roofline"])
        emit(f"roofline.{rec['arch']}.{rec['shape']}",
             r["step_time_s"] * 1e6,
             f"bound={r['bound']};mfu={r['mfu']:.3f};"
             f"c={r['compute_s']:.2f}s;m={r['memory_s']:.2f}s;"
             f"n={r['collective_s']:.2f}s")


if __name__ == "__main__":
    run()

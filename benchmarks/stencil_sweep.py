"""Stencil-spec frontend sweep: every operator the spec layer opens —
PW advection, scalar-tracer advection, 3D diffusion, each under euler and
the in-ring RK2 — priced counted-vs-modelled and differenced against the
f64 oracle, written to ``BENCH_stencils.json``.

Row families and their gates (every gate an explicit ``SystemExit`` —
``python -O`` safe):

  * ``bitwise[]``     — the spec-driven `stencil_fused` vs the hand-written
    `advect_fused` for the Piacsek-Williams spec over a (T, y_tile, dtype)
    sweep. GATE: max |diff| == 0.0 — the frontend is a generalisation of
    the v4 kernel, not a fork.
  * ``oracle[]``      — every operator x dtype vs `spec_multistep_ref_f64`
    (genuine float64). GATE: max err <= per-dtype tolerance x operator
    scale (the tolerance ladder: f32 tight, bf16 loose).
  * ``hbm[]``         — `count_pallas_hbm_bytes` of the spec kernel on a
    lane-aligned grid vs ``hbm_bytes_model(..., "fused",
    n_fields=spec.n_fields, halo_depth=spec.halo(T))``. GATE: equal
    EXACTLY — one compulsory read+write per field per T steps, whatever
    the operator (the MONC multi-kernel amortisation claim, priced).
  * ``halo[]``        — `_band_schedule(L, spec.halo(T))` partition checks.
    GATE: the per-hop band counts sum to exactly ``radius * stages * T``.
  * ``distributed[]`` — a subprocess on 4 forced host devices builds the
    spec-driven `make_distributed_step` per operator/mesh, GATES counted
    ppermute bytes == ``halo_wire_bytes_model(depth=spec.halo(T),
    n_fields=spec.n_fields)`` exactly, fused local kernel bitwise-equal
    to the reference one, and the sharded result vs the single-device
    oracle.
  * ``ai[]``          — jaxpr-counted `spec_flops_per_cell` feeding
    `stencil_arithmetic_intensity` / `stencil_ridge_T` per operator (the
    fusion depth each operator needs to reach the ridge).

``--quick`` / ``BENCH_SMOKE=1`` runs a prefix of each sweep (row 0 of
every family is identical in both modes, so the trend-gate baselines in
``benchmarks/baselines.json`` resolve either way).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import roofline as R
from repro.kernels.advection import advection as K
from repro.kernels.advection.ref import default_params
from repro.stencil import spec as SP
from repro.stencil.advection import stratus_fields

ITEM = 4  # f32
GRID = (10, 12, 8)           # interpret-mode compute grid
HBM_GRID = (8, 16, 128)      # lane-aligned trace-only grid (Z % 128 == 0)

# per-dtype relative tolerance ladder for the f64-oracle gate
TOL_REL = {"float32": 2e-5, "bfloat16": 0.02}


def _operators(Z: int, dtype=jnp.float32):
    """(key, spec, kernel params, packed-spec params, fields, dt) per
    operator; the velocity fields double as the tracer's carriers."""
    X, Y = GRID[0], GRID[1]
    p = default_params(Z)
    dp = SP.default_diffusion_params(Z)
    u, v, w = stratus_fields(X, Y, Z, dtype=dtype)
    q = SP.tracer_field(X, Y, Z, dtype=dtype)
    phi = SP.diffusion_field(X, Y, Z, dtype=dtype)
    return [
        ("pw", SP.pw_advection_spec(), p, (u, v, w), 0.01),
        ("pw_rk2", SP.pw_advection_spec("rk2"), p, (u, v, w), 0.01),
        ("tracer", SP.tracer_advection_spec(), p, (u, v, w, q), 0.01),
        ("diffusion", SP.diffusion_spec(), dp, (phi,), 1e-3),
        ("diffusion_rk2", SP.diffusion_spec("rk2"), dp, (phi,), 1e-3),
    ]


def _bitwise_rows(smoke: bool):
    """Spec-driven kernel == hand-written `advect_fused`, bit for bit."""
    X, Y, Z = GRID
    p = default_params(Z)
    pw = SP.pw_advection_spec()
    combos = [(2, None, jnp.float32)]
    if not smoke:
        combos += [(1, 5, jnp.float32), (3, 5, jnp.float32),
                   (4, None, jnp.float32), (2, 4, jnp.bfloat16)]
    rows = []
    for T, y_tile, dtype in combos:
        u, v, w = stratus_fields(X, Y, Z, dtype=dtype)
        ref = K.advect_fused(u, v, w, p, T=T, dt=0.01, y_tile=y_tile)
        got = K.stencil_fused((u, v, w), p, pw, T=T, dt=0.01, y_tile=y_tile)
        diff = max(float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                         - jnp.asarray(b, jnp.float32))))
                   for a, b in zip(got, ref))
        if diff != 0.0:
            raise SystemExit(
                f"stencils gate: spec-driven kernel differs from "
                f"advect_fused by {diff} at T={T}, y_tile={y_tile}, "
                f"dtype={jnp.dtype(dtype).name} — the frontend must be "
                f"bitwise-equal for the PW spec")
        rows.append({"T": T, "y_tile": y_tile,
                     "dtype": jnp.dtype(dtype).name,
                     "max_bitwise_diff": diff})
        emit(f"stencils.bitwise.T{T}.yt{y_tile}.{jnp.dtype(dtype).name}",
             0.0, f"diff={diff}")
    return rows


def _oracle_rows(smoke: bool):
    """Every operator vs the genuine-f64 reference, per-dtype ladder."""
    T = 2
    dtypes = [jnp.float32] if smoke else [jnp.float32, jnp.bfloat16]
    rows = []
    for dtype in dtypes:
        dname = jnp.dtype(dtype).name
        for key, spec, params, fields, dt in _operators(GRID[2], dtype):
            ref = SP.spec_multistep_ref_f64(fields, params, spec, T, dt)
            got = K.stencil_fused(fields, params, spec, T=T, dt=dt)
            err = max(float(np.max(np.abs(np.asarray(a, np.float64) - b)))
                      for a, b in zip(got, ref))
            scale = max(1.0, max(float(np.max(np.abs(b))) for b in ref))
            tol = TOL_REL[dname] * scale
            if err > tol:
                raise SystemExit(
                    f"stencils gate: {key} ({dname}) err {err} vs the f64 "
                    f"oracle exceeds the tolerance ladder ({tol})")
            rows.append({"operator": key, "dtype": dname, "T": T,
                         "max_err": err, "tolerance": tol})
            emit(f"stencils.oracle.{key}.{dname}", 0.0,
                 f"err={err:.2e};tol={tol:.2e}")
    return rows


def _hbm_rows():
    """Counted Pallas HBM bytes == the n_fields/halo-generalised model."""
    from repro.stencil.distributed import count_pallas_hbm_bytes

    X, Y, Z = HBM_GRID
    T = 2
    p = default_params(Z)
    dp = SP.default_diffusion_params(Z)
    rows = []
    for key, spec, params in (
            ("pw", SP.pw_advection_spec(), p),
            ("pw_rk2", SP.pw_advection_spec("rk2"), p),
            ("tracer", SP.tracer_advection_spec(), p),
            ("diffusion", SP.diffusion_spec(), dp)):
        F = spec.n_fields
        fields = tuple(jnp.zeros((X, Y, Z), jnp.float32) for _ in range(F))

        def fn(*fs, _p=params, _s=spec):
            return K.stencil_fused(fs, _p, _s, T=T, interpret=True)

        counted = count_pallas_hbm_bytes(fn, *fields)
        model = K.hbm_bytes_model(X, Y, Z, ITEM, "fused", T=T,
                                  grid_tiled=True, n_fields=F,
                                  halo_depth=spec.halo(T))
        if counted != model:
            raise SystemExit(
                f"stencils gate: {key} counted HBM bytes {counted} != "
                f"modelled {model} (n_fields={F}, "
                f"halo_depth={spec.halo(T)})")
        ring = K.fused_register_bytes(
            T, Y, Z, ITEM, y_tile=8, halo=spec.halo(T), n_fields=F,
            n_slots=2 * spec.radius + 1, n_levels=spec.stages * T)
        vmem_halo = K.vmem_halo_bytes_model(
            X, Y, Z, ITEM, "fused", T=T, y_tile=8, n_fields=F,
            halo_depth=spec.halo(T))
        rows.append({"operator": key, "T": T, "n_fields": F,
                     "halo_depth": spec.halo(T),
                     "counted_hbm_bytes": counted,
                     "modelled_hbm_bytes": model,
                     "ring_vmem_bytes": ring,
                     "vmem_halo_bytes": vmem_halo})
        emit(f"stencils.hbm.{key}", 0.0,
             f"hbm_B={counted};model_exact=True;ring_B={ring}")
    return rows


def _halo_rows():
    """`_band_schedule` partitions exactly `spec.halo(T)` rows per side."""
    rows = []
    for key, spec in (("pw", SP.pw_advection_spec()),
                      ("pw_rk2", SP.pw_advection_spec("rk2")),
                      ("tracer", SP.tracer_advection_spec()),
                      ("diffusion_rk2", SP.diffusion_spec("rk2"))):
        for T in (1, 2, 3):
            D = spec.halo(T)
            for L in (2, 3, 5):
                sched = K._band_schedule(L, D)
                moved = sum(cnt for _, cnt, _, _ in sched)
                if moved != D:
                    raise SystemExit(
                        f"stencils gate: {key} T={T} band schedule over "
                        f"local extent {L} moves {moved} rows, not "
                        f"spec.halo(T)={D}")
                if len(sched) != -(-D // L):
                    raise SystemExit(
                        f"stencils gate: {key} T={T} L={L}: "
                        f"{len(sched)} hops != ceil({D}/{L})")
            rows.append({"operator": key, "T": T, "halo_depth": D,
                         "radius": spec.radius, "stages": spec.stages})
            emit(f"stencils.halo.{key}.T{T}", 0.0,
                 f"depth={D}=r{spec.radius}*s{spec.stages}*T{T}")
    return rows


_SUB_CODE = textwrap.dedent("""
    import json, os, sys
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.roofline import halo_wire_bytes_model
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import compat_make_mesh
    from repro.stencil import spec as SP
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_step,
                                           reference_global_spec_step)

    cfg = json.loads(sys.argv[1])
    X, Y, Z = cfg["grid"]
    p = default_params(Z)
    dp = SP.default_diffusion_params(Z)
    u, v, w = stratus_fields(X, Y, Z)
    q = SP.tracer_field(X, Y, Z)
    phi = SP.diffusion_field(X, Y, Z)
    OPS = {
        "pw": (SP.pw_advection_spec(), p, (u, v, w), 0.01),
        "tracer": (SP.tracer_advection_spec(), p, (u, v, w, q), 0.01),
        "diffusion_rk2": (SP.diffusion_spec("rk2"), dp, (phi,), 1e-3),
    }
    rows = []
    for key, nx, ny, T, exchange in cfg["cases"]:
        spec, sp_params, fields, dt = OPS[key]
        if nx > 1:
            mesh = compat_make_mesh((nx, ny), ("x", "y"))
            kw = dict(axis="y", x_axis="x")
        else:
            mesh = compat_make_mesh((ny,), ("y",))
            kw = dict(axis="y")
        ref_step = make_distributed_step(mesh, p, T=T, dt=dt, spec=spec,
                                         spec_params=sp_params,
                                         exchange=exchange, **kw)
        fus_step = make_distributed_step(mesh, p, T=T, dt=dt, spec=spec,
                                         spec_params=sp_params,
                                         local_kernel="fused", y_tile=4,
                                         exchange=exchange, **kw)
        out_r = ref_step(*fields)
        out_f = fus_step(*fields)
        bitwise = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(out_r, out_f))
        oracle = reference_global_spec_step(fields, sp_params, spec,
                                            T=T, dt=dt)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(out_r, oracle))
        counted = count_exchange_wire_bytes(ref_step, *fields)
        model = halo_wire_bytes_model(X, Y, Z, 4, nx=nx, ny=ny, T=T,
                                      n_fields=spec.n_fields,
                                      depth=spec.halo(T))
        rows.append({"operator": key, "mesh": [nx, ny], "T": T,
                     "exchange": exchange,
                     "halo_depth": spec.halo(T),
                     "n_fields": spec.n_fields,
                     "counted_wire_bytes": counted,
                     "modelled_wire_bytes": model,
                     "fused_vs_reference_diff": bitwise,
                     "max_err_vs_oracle": err})
    print(json.dumps({"rows": rows}))
""")


def _distributed_rows(smoke: bool):
    """Spec-driven distributed step on 4 forced host devices: counted
    wire bytes vs the depth-generalised model, fused-vs-reference local
    kernels bitwise, shards vs the single-device oracle."""
    cases = [["tracer", 2, 2, 2, "collective"],
             ["diffusion_rk2", 1, 4, 2, "collective"]]
    if not smoke:
        cases += [["pw", 2, 2, 1, "collective"],
                  ["tracer", 1, 4, 3, "remote_dma"],
                  ["diffusion_rk2", 2, 2, 1, "remote_dma"]]
    cfg = {"grid": [12, 16, 8], "cases": cases}
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.update({
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(root, "src"), root,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
    })
    r = subprocess.run([sys.executable, "-c", _SUB_CODE, json.dumps(cfg)],
                       capture_output=True, text=True, cwd=root, env=env,
                       timeout=900)
    if r.returncode != 0:
        raise SystemExit(f"stencils subprocess failed:\n{r.stderr[-3000:]}")
    rows = json.loads(r.stdout.strip().splitlines()[-1])["rows"]
    for row in rows:
        if row["counted_wire_bytes"] != row["modelled_wire_bytes"]:
            raise SystemExit(
                f"stencils gate: counted wire bytes "
                f"{row['counted_wire_bytes']} != modelled "
                f"{row['modelled_wire_bytes']} for {row}")
        if row["fused_vs_reference_diff"] != 0.0:
            raise SystemExit(
                f"stencils gate: fused local kernel differs from the "
                f"reference one by {row['fused_vs_reference_diff']} "
                f"for {row}")
        if row["max_err_vs_oracle"] > 1e-5:
            raise SystemExit(
                f"stencils gate: distributed spec step err "
                f"{row['max_err_vs_oracle']} vs oracle for {row}")
        emit(f"stencils.dist.{row['operator']}"
             f".{row['mesh'][0]}x{row['mesh'][1]}.T{row['T']}", 0.0,
             f"wire_B={row['counted_wire_bytes']};model_exact=True;"
             f"depth={row['halo_depth']}")
    return rows


def _ai_rows():
    """Per-operator arithmetic intensity and the ridge fusion depth."""
    n = SP._PROBE_N
    p = default_params(n)
    dp = SP.default_diffusion_params(n)
    rows = []
    for key, spec, params in (
            ("pw", SP.pw_advection_spec(), p),
            ("tracer", SP.tracer_advection_spec(), p),
            ("diffusion", SP.diffusion_spec(), dp)):
        flops = SP.spec_flops_per_cell(spec, params)
        bytes_pass = 2 * spec.n_fields * ITEM   # one read + write per field
        ai1 = R.stencil_arithmetic_intensity(flops * spec.stages,
                                             bytes_pass)
        ridge_T = R.stencil_ridge_T(flops * spec.stages, bytes_pass)
        rows.append({"operator": key, "flops_per_cell": flops,
                     "stages": spec.stages,
                     "bytes_per_cell_pass": bytes_pass,
                     "ai_T1": ai1, "ridge_T": ridge_T})
        emit(f"stencils.ai.{key}", 0.0,
             f"flops={flops};ai_T1={ai1:.3f};ridge_T={ridge_T}")
    return rows


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    bitwise = _bitwise_rows(smoke)
    oracle = _oracle_rows(smoke)
    hbm = _hbm_rows()
    halo = _halo_rows()
    distributed = _distributed_rows(smoke)
    ai = _ai_rows()
    payload = {
        "bitwise": bitwise, "oracle": oracle, "hbm": hbm, "halo": halo,
        "distributed": distributed, "ai": ai, "itemsize": ITEM,
        "contract": "spec-driven fused kernel bitwise-equal to "
                    "advect_fused for the PW spec; every operator within "
                    "the per-dtype tolerance of the f64 oracle; counted "
                    "Pallas HBM bytes == hbm_bytes_model(n_fields, "
                    "halo_depth) exactly; band schedules partition "
                    "radius*stages*T; counted distributed wire bytes == "
                    "halo_wire_bytes_model(depth=spec.halo(T)) exactly",
    }
    out_path = os.path.join(os.getcwd(), "BENCH_stencils.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("stencils.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

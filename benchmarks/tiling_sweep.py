"""Tiling sweep: host-side y-tile loop vs in-grid (y_tile, x) 2D grid.

For every swept (grid, variant, y_tile, T) config this compares the two
y-tiling execution paths analytically and (on a reduced grid) by measured
interpret-mode wallclock + bit-exactness:

  * `host_hbm_bytes`   — the retained `tiling="host"` loop: one pallas_call
    per halo-overlapped block, every halo row restaged from HBM on the read
    AND write side, restitched with a host `jnp.concatenate`;
  * `grid_hbm_bytes`   — the in-grid path: one launch, element-indexed tile
    slabs, halo re-reads served from the persistent VMEM register, outputs
    written in place (zero HBM halo overlap, so bytes match untiled);
  * `vmem_halo_bytes`  — the relocated halo traffic, now an on-chip cost;
  * `register_bytes`   — the VMEM ring footprint (identical for both paths).

The module is also the CI acceptance gate for the in-grid refactor: it
FAILS (explicit SystemExit, immune to python -O) if any swept config's
grid-tiled bytes exceed the host-tiled bytes, if grid-tiled is not
strictly cheaper whenever the tile actually splits the domain, or if a
tiled restitch is not bit-exact. Emits the usual CSV rows and
writes ``BENCH_tiling.json``. ``--quick`` / ``BENCH_SMOKE=1`` shrinks the
measured part for CI.
"""
from __future__ import annotations

import json
import os

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, mem_s, wallclock_us
from repro.kernels.advection.advection import (advect_blocked,
                                               advect_dataflow, advect_fused,
                                               fused_register_bytes,
                                               hbm_bytes_model,
                                               vmem_halo_bytes_model)
from repro.kernels.advection.ref import default_params
from repro.stencil.advection import stratus_fields

ITEM = 4  # f32

# modelled at the paper's Fig. 3 and Fig. 8 grid classes
MODEL_GRIDS = {"fig3_16M": (512, 512, 64), "fig8_67M": (1024, 1024, 64)}
VARIANT_T = [("blocked", 1), ("dataflow", 1), ("fused", 4), ("fused", 8)]
Y_TILES = (64, 128, 256)


def _model_rows():
    rows = []
    for gname, (X, Y, Z) in MODEL_GRIDS.items():
        for variant, T in VARIANT_T:
            for y_tile in Y_TILES:
                host_b = hbm_bytes_model(X, Y, Z, ITEM, variant, T=T,
                                         y_tile=y_tile, grid_tiled=False)
                grid_b = hbm_bytes_model(X, Y, Z, ITEM, variant, T=T,
                                         y_tile=y_tile, grid_tiled=True)
                untiled_b = hbm_bytes_model(X, Y, Z, ITEM, variant, T=T)
                vmem_b = vmem_halo_bytes_model(X, Y, Z, ITEM, variant, T=T,
                                               y_tile=y_tile)
                halo = T if variant == "fused" else 1
                reg_b = fused_register_bytes(T if variant == "fused" else 1,
                                             Y, Z, ITEM, y_tile=y_tile,
                                             halo=halo)
                # the acceptance gate: in-grid tiling must never move MORE
                # HBM bytes than the host loop, must be strictly cheaper
                # whenever the tile actually splits the domain, and must
                # match the untiled compulsory traffic. Explicit raise, not
                # assert: the gate must survive python -O / PYTHONOPTIMIZE.
                cfg = (gname, variant, T, y_tile)
                if grid_b > host_b or (y_tile < Y and grid_b >= host_b):
                    raise SystemExit(f"tiling gate: grid bytes {grid_b} not "
                                     f"below host bytes {host_b} for {cfg}")
                if grid_b != untiled_b:
                    raise SystemExit(f"tiling gate: grid bytes {grid_b} != "
                                     f"untiled {untiled_b} for {cfg}")
                emit(f"tiling.{gname}.{variant}_T{T}_ty{y_tile}",
                     mem_s(grid_b) * 1e6,
                     f"host_B={host_b};grid_B={grid_b};"
                     f"halo_saved={(host_b - grid_b) / host_b * 100:.1f}%;"
                     f"vmem_halo_B={vmem_b}")
                rows.append({
                    "grid_name": gname, "grid": [X, Y, Z],
                    "variant": variant, "T": T, "y_tile": y_tile,
                    "host_hbm_bytes": host_b,
                    "grid_hbm_bytes": grid_b,
                    "untiled_hbm_bytes": untiled_b,
                    "vmem_halo_bytes": vmem_b,
                    "register_bytes": reg_b,
                    "hbm_saved_frac": (host_b - grid_b) / host_b,
                })
    return rows


def _measured_rows(smoke: bool):
    """Interpret-mode wallclock + exactness on a reduced grid: one launch
    (grid) vs n_tiles launches + restitch (host)."""
    X, Y, Z = (5, 16, 16) if smoke else (6, 48, 16)
    y_tile = 4 if smoke else 12
    T = 2
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    rows = []
    cases = [("dataflow",
              lambda tiling: advect_dataflow(u, v, w, p, y_tile=y_tile,
                                             tiling=tiling),
              lambda: advect_dataflow(u, v, w, p)),
             ("fused",
              lambda tiling: advect_fused(u, v, w, p, T=T, dt=0.01,
                                          y_tile=y_tile, tiling=tiling),
              lambda: advect_fused(u, v, w, p, T=T, dt=0.01))]
    if not smoke:
        cases.append(("blocked",
                      lambda tiling: advect_blocked(u, v, w, p,
                                                    y_tile=y_tile,
                                                    tiling=tiling),
                      lambda: advect_blocked(u, v, w, p)))
    iters = 1 if smoke else 3
    for name, tiled_fn, untiled_fn in cases:
        full = untiled_fn()
        for tiling in ("grid", "host"):
            out = tiled_fn(tiling)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(full, out))
            if err != 0.0:   # bit-exact restitch is part of the CI gate
                raise SystemExit(f"tiling gate: {name}/{tiling} not "
                                 f"bit-exact vs untiled (err={err})")
            us = wallclock_us(lambda t=tiling: tiled_fn(t), iters=iters)
            emit(f"tiling.measured.{name}_{tiling}", us,
                 f"grid={X}x{Y}x{Z};y_tile={y_tile};exact=True")
            rows.append({"variant": name, "tiling": tiling,
                         "grid": [X, Y, Z], "y_tile": y_tile,
                         "T": T if name == "fused" else 1,
                         "interpret_us": us, "max_err_vs_untiled": err})
    return rows


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    rows = _model_rows()
    measured = _measured_rows(smoke)
    payload = {"modelled": rows, "measured": measured,
               "itemsize": ITEM,
               "contract": "grid_hbm_bytes <= host_hbm_bytes for every "
                           "config; strict whenever y_tile < Y"}
    out_path = os.path.join(os.getcwd(), "BENCH_tiling.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("tiling.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

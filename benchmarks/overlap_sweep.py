"""Exchange-overlap sweep: how much of the depth-T halo exchange each
engine hides — the paper's §IV DMA/compute overlap priced at the
chip-to-chip level, written to ``BENCH_overlap.json``.

Row families:

  * ``modelled[]`` — the 268M-cell grid on growing (nx, ny) meshes, one
    entry per (mesh, T) with the three engine configurations priced side
    by side: `overlap=False` (exchange fully exposed), the collective
    engine with overlap (XLA *may* hide it —
    `roofline.XLA_OVERLAP_DISCOUNT`), and the in-kernel remote-DMA engine
    (owns its issue/wait schedule). GATES: hidden + exposed reconstruct
    ``collective_s`` exactly, and modelled EXPOSED wire seconds fall
    STRICTLY, `remote_dma < collective+overlap < overlap=False`, for every
    swept point.
  * ``counted[]`` — subprocess on 4 forced host devices: the remote-DMA
    step's jaxpr-counted wire bytes (`count_exchange_wire_bytes`; the
    emulation sends one ppermute operand per DMA band message) GATED ==
    `halo_wire_bytes_model` == `remote_dma_schedule_wire_bytes` EXACTLY,
    and the engine's outputs GATED BITWISE-equal to the collective engine.
  * ``measured[]`` — interpret-mode wallclock of both engines on the
    reduced grid (informational; interpret mode serialises everything).

Every gate is an explicit ``SystemExit`` raise (python -O safe). CI runs
``--quick`` in the benchmark-smoke job.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import textwrap

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

from benchmarks.common import emit
from repro.stencil.advection import PAPER_GRIDS, AdvectionDomain

GRID = PAPER_GRIDS["268M"]                       # (4096, 1024, 64)
MESHES = [(2, 2), (4, 4), (8, 8), (16, 8), (16, 16)]
T_SWEEP = (4, 8)
Y_TILE = 128

CONFIGS = (                 # (label, exchange, overlap)
    ("no_overlap", "collective", False),
    ("collective_overlap", "collective", True),
    ("remote_dma", "remote_dma", True),
)


def _modelled_rows():
    X, Y, Z = GRID
    rows = []
    for T in T_SWEEP:
        for nx, ny in MESHES:
            # wire bytes are engine-independent: take them from the FIRST
            # priced config (no throwaway domain) and gate the rest equal
            row = {"grid": [X, Y, Z], "mesh": [nx, ny], "devices": nx * ny,
                   "T": T, "y_tile": Y_TILE, "configs": {}}
            for label, ex, ov in CONFIGS:
                dom = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T,
                                      y_tile=Y_TILE, mesh_nx=nx, mesh_ny=ny,
                                      exchange=ex, overlap=ov)
                t = dom.roofline_terms()
                if not math.isclose(t.collective_hidden_s
                                    + t.collective_exposed_s,
                                    t.collective_s, rel_tol=1e-12):
                    raise SystemExit(
                        f"overlap gate: hidden {t.collective_hidden_s} + "
                        f"exposed {t.collective_exposed_s} != collective "
                        f"{t.collective_s} at ({nx},{ny}) T={T} {label}")
                if "wire_bytes" not in row:
                    row["wire_bytes"] = t.ici_wire_bytes
                elif t.ici_wire_bytes != row["wire_bytes"]:
                    raise SystemExit(
                        f"overlap gate: wire bytes diverged between "
                        f"engine configs at ({nx},{ny}) T={T} {label}: "
                        f"{t.ici_wire_bytes} != {row['wire_bytes']}")
                row["configs"][label] = {
                    "overlap_efficiency": t.overlap_efficiency,
                    "collective_s": t.collective_s,
                    "collective_hidden_s": t.collective_hidden_s,
                    "collective_exposed_s": t.collective_exposed_s,
                    "overlapped_step_time_s": t.overlapped_step_time_s,
                    "bound": t.bound,
                    "overlapped_bound": t.overlapped_bound,
                }
            c = row["configs"]
            exposed = [c[label]["collective_exposed_s"]
                       for label, _, _ in CONFIGS]
            # THE acceptance gate: each rung of the overlap ladder strictly
            # cuts the exposed wire time vs the overlap=False baseline
            if not (exposed[2] < exposed[1] < exposed[0]):
                raise SystemExit(
                    f"overlap gate: exposed wire seconds not strictly "
                    f"falling (no_overlap {exposed[0]} -> collective "
                    f"{exposed[1]} -> remote_dma {exposed[2]}) at "
                    f"({nx},{ny}) T={T}")
            emit(f"overlap.modelled.T{T}.{nx}x{ny}",
                 c["remote_dma"]["overlapped_step_time_s"] * 1e6,
                 f"exposed_us_no_overlap={exposed[0]*1e6:.2f};"
                 f"exposed_us_collective={exposed[1]*1e6:.2f};"
                 f"exposed_us_remote_dma={exposed[2]*1e6:.2f}")
            rows.append(row)
    return rows


_SUB_CODE = textwrap.dedent("""
    import json, os, sys, time
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.roofline import halo_wire_bytes_model
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_step,
                                           remote_dma_schedule_wire_bytes)

    cfg = json.loads(sys.argv[1])
    X, Y, Z = cfg["grid"]
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    counted, measured = [], []
    for nx, ny in cfg["meshes"]:
        mesh = make_stencil_mesh(nx, ny)
        sh = NamedSharding(mesh, P("x", "y", None))
        args = [jax.device_put(t, sh) for t in (u, v, w)]
        for T in cfg["T"]:
            for ov in (False, True):
                kw = dict(axis="y", x_axis="x", T=T, dt=0.01,
                          local_kernel="fused", overlap=ov,
                          y_tile=cfg["y_tile"])
                fc = make_distributed_step(mesh, p, exchange="collective",
                                           **kw)
                fr = make_distributed_step(mesh, p, exchange="remote_dma",
                                           **kw)
                oc, orr = fc(*args), fr(*args)
                diff = max(float(jnp.max(jnp.abs(a - b)))
                           for a, b in zip(oc, orr))
                got = count_exchange_wire_bytes(fr, u, v, w)
                model = halo_wire_bytes_model(X, Y, Z, 4, nx=nx, ny=ny,
                                              T=T)
                sched = remote_dma_schedule_wire_bytes(
                    X // nx, Y // ny, Z, 4, nx=nx, ny=ny, T=T)
                counted.append({"mesh": [nx, ny], "T": T, "overlap": ov,
                                "counted_wire_bytes": got,
                                "modelled_wire_bytes": model,
                                "schedule_wire_bytes": sched,
                                "bitwise_diff_vs_collective": diff})
                if ov:
                    ts = {}
                    for name, fn in (("collective", fc),
                                     ("remote_dma", fr)):
                        samples = []
                        for _ in range(cfg["iters"]):
                            t0 = time.perf_counter()
                            jax.block_until_ready(fn(*args))
                            samples.append(time.perf_counter() - t0)
                        ts[name] = sorted(samples)[len(samples) // 2] * 1e6
                    measured.append({"mesh": [nx, ny], "T": T,
                                     "interpret_us": ts})
    print(json.dumps({"counted": counted, "measured": measured}))
""")


def _subprocess_rows(smoke: bool):
    """Counted wire bytes + bitwise engine equivalence on 4 forced host
    devices (the scaling2d subprocess idiom: device count must be fixed by
    XLA_FLAGS before jax initialises)."""
    cfg = {"grid": [8, 12, 16], "y_tile": 5, "iters": 1 if smoke else 3,
           "meshes": [[2, 2]] if smoke else [[2, 2], [1, 4], [4, 1]],
           "T": [2] if smoke else [1, 2, 3]}
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.update({
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(root, "src"), root,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
    })
    r = subprocess.run([sys.executable, "-c", _SUB_CODE, json.dumps(cfg)],
                       capture_output=True, text=True, cwd=root, env=env,
                       timeout=900)
    if r.returncode != 0:
        raise SystemExit(f"overlap subprocess failed:\n{r.stderr[-3000:]}")
    payload = json.loads(r.stdout.strip().splitlines()[-1])
    for row in payload["counted"]:
        if not (row["counted_wire_bytes"] == row["modelled_wire_bytes"]
                == row["schedule_wire_bytes"]):
            raise SystemExit(
                f"overlap gate: counted {row['counted_wire_bytes']} / "
                f"modelled {row['modelled_wire_bytes']} / schedule "
                f"{row['schedule_wire_bytes']} wire bytes differ for {row}")
        if row["bitwise_diff_vs_collective"] != 0.0:
            raise SystemExit(
                f"overlap gate: remote_dma outputs differ from collective "
                f"by {row['bitwise_diff_vs_collective']} for {row} — the "
                "engines must be bitwise equal")
        emit(f"overlap.counted.{row['mesh'][0]}x{row['mesh'][1]}.T{row['T']}"
             f".{'ov' if row['overlap'] else 'noov'}", 0.0,
             f"wire_B={row['counted_wire_bytes']};bitwise_equal=True")
    for row in payload["measured"]:
        emit(f"overlap.measured.{row['mesh'][0]}x{row['mesh'][1]}"
             f".T{row['T']}", row["interpret_us"]["remote_dma"],
             f"collective_us={row['interpret_us']['collective']:.1f};"
             "note=interpret_mode_serialises_everything")
    return payload["counted"], payload["measured"]


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    modelled = _modelled_rows()
    counted, measured = _subprocess_rows(smoke)
    payload = {
        "modelled": modelled, "counted": counted, "measured": measured,
        "itemsize": 4,
        "contract": "modelled exposed collective seconds strictly fall "
                    "remote_dma < collective+overlap < overlap=False at "
                    "every (mesh, T); hidden+exposed == collective_s; "
                    "counted ppermute bytes == halo_wire_bytes_model == "
                    "remote_dma_schedule_wire_bytes exactly; remote_dma "
                    "outputs bitwise-equal to collective",
    }
    out_path = os.path.join(os.getcwd(), "BENCH_overlap.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("overlap.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

"""2D (x, y) mesh decomposition sweep: weak/strong scaling of the fused
distributed step — the Fig. 8 endgame that unlocks the 268M-cell
(4096, 1024, 64) grid.

Three row families, written to ``BENCH_scaling2d.json``:

  * ``strong[]``  — the 268M grid on growing (nx, ny) meshes: per-shard HBM
    bytes (`AdvectionDomain.hbm_bytes_per_shard_step`, the halo'd-slab
    kernel pass), per-shard wire bytes (`roofline.halo_wire_bytes_model`,
    the ONE depth-T two-phase exchange per T substeps), and the resulting
    three-term roofline (`RooflineTerms`, exchange bytes feeding
    ``collective_s``). GATE: per-shard HBM bytes fall STRICTLY as the
    device count grows.
  * ``weak[]``    — fixed per-shard slab, growing mesh: per-shard HBM and
    wire bytes must be CONSTANT (gated) — the flat-line that makes the
    decomposition scale-free.
  * ``counted[]`` / ``measured[]`` — a subprocess on 4 forced host CPU
    devices builds the real `make_distributed_step` per mesh shape, walks
    its jaxpr with `count_exchange_wire_bytes`, and GATES counted ==
    modelled wire bytes EXACTLY (the x-then-y corner contract: phase-2
    operands are x-extended); it also runs the fused step in interpret
    mode for wallclock + equivalence vs `reference_global_step`.

Every gate is an explicit ``SystemExit`` raise (never ``assert``), so the
CI `benchmark-smoke` job keeps failing under ``python -O`` /
``PYTHONOPTIMIZE``. ``--quick`` / ``BENCH_SMOKE=1`` shrinks the subprocess
part for CI.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

from benchmarks.common import emit
from repro.core.roofline import HBM_PER_CHIP
from repro.stencil.advection import PAPER_GRIDS, AdvectionDomain

ITEM = 4  # f32

STRONG_GRID = PAPER_GRIDS["268M"]               # (4096, 1024, 64)
STRONG_MESHES = [(1, 1), (2, 1), (2, 2), (4, 2), (4, 4), (8, 4),
                 (8, 8), (16, 8), (16, 16)]     # devices: 1 .. 256
WEAK_SHARD = (256, 128, 64)
WEAK_MESHES = [(2, 2), (2, 4), (4, 4), (8, 4), (8, 8), (16, 16)]
T_SWEEP = (4, 8)
Y_TILE = 128


def _domain(X, Y, Z, nx, ny, T):
    return AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T,
                           y_tile=Y_TILE, mesh_nx=nx, mesh_ny=ny)


def _row(dom, nx, ny, T):
    X, Y, Z = dom.X, dom.Y, dom.Z
    n_dev = nx * ny
    shard_hbm = dom.hbm_bytes_per_shard_step()
    wire = dom.halo_wire_bytes_per_step()
    terms = dom.roofline_terms()
    Xl, Yl = dom.shard_shape()
    # steady-state HBM residency per shard: fields in+out + the VMEM ring's
    # HBM shadow is negligible; the point is the 268M grid fitting
    resident = 2 * 3 * Xl * Yl * Z * ITEM
    return {
        "grid": [X, Y, Z], "mesh": [nx, ny], "devices": n_dev, "T": T,
        "y_tile": Y_TILE,
        "shard_shape": [Xl, Yl],
        "hbm_bytes_per_shard_step": shard_hbm,
        "halo_wire_bytes_per_step": wire,
        "wire_bytes_per_substep": wire / T,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "compute_s": terms.compute_s,
        "step_time_s": terms.step_time_s,
        "bound": terms.bound,
        "hbm_resident_frac": resident / HBM_PER_CHIP,
    }


def _strong_rows():
    X, Y, Z = STRONG_GRID
    rows = []
    for T in T_SWEEP:
        prev = None
        for nx, ny in STRONG_MESHES:
            r = _row(_domain(X, Y, Z, nx, ny, T), nx, ny, T)
            # the acceptance gate: growing the mesh must STRICTLY cut the
            # per-shard HBM pass — otherwise the decomposition isn't
            # unlocking anything. Explicit raise: python -O safe.
            if prev is not None and r["hbm_bytes_per_shard_step"] >= prev:
                raise SystemExit(
                    f"scaling2d gate: per-shard HBM bytes "
                    f"{r['hbm_bytes_per_shard_step']} did not fall below "
                    f"{prev} at mesh ({nx}, {ny}), T={T}")
            prev = r["hbm_bytes_per_shard_step"]
            emit(f"scaling2d.strong.268M.T{T}.{nx}x{ny}",
                 r["step_time_s"] * 1e6,
                 f"shard_hbm_B={r['hbm_bytes_per_shard_step']};"
                 f"wire_B={r['halo_wire_bytes_per_step']};"
                 f"bound={r['bound']}")
            rows.append(r)
    return rows


def _weak_rows():
    Xl, Yl, Z = WEAK_SHARD
    rows = []
    for T in T_SWEEP:
        base = None
        for nx, ny in WEAK_MESHES:
            r = _row(_domain(Xl * nx, Yl * ny, Z, nx, ny, T), nx, ny, T)
            key = (r["hbm_bytes_per_shard_step"],
                   r["halo_wire_bytes_per_step"])
            if base is None:
                base = key
            elif key != base:
                raise SystemExit(
                    f"scaling2d gate: weak-scaling per-shard bytes "
                    f"{key} drifted from {base} at mesh ({nx}, {ny}), "
                    f"T={T} — the decomposition is not scale-free")
            emit(f"scaling2d.weak.T{T}.{nx}x{ny}",
                 r["step_time_s"] * 1e6,
                 f"shard_hbm_B={r['hbm_bytes_per_shard_step']};"
                 f"wire_B={r['halo_wire_bytes_per_step']}")
            rows.append(r)
    return rows


_SUB_CODE = textwrap.dedent("""
    import json, os, sys, time
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.roofline import halo_wire_bytes_model
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import compat_make_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_step,
                                           reference_global_step)

    cfg = json.loads(sys.argv[1])
    X, Y, Z = cfg["grid"]
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    counted, measured = [], []
    for nx, ny in cfg["meshes"]:
        mesh = compat_make_mesh((nx, ny), ("x", "y"))
        sh = NamedSharding(mesh, P("x", "y", None))
        args = [jax.device_put(t, sh) for t in (u, v, w)]
        for T in cfg["T"]:
            for lk, ov in (("reference", False), ("fused", True)):
                fn = make_distributed_step(mesh, p, axis="y", x_axis="x",
                                           T=T, dt=0.01, local_kernel=lk,
                                           overlap=ov)
                got = count_exchange_wire_bytes(fn, u, v, w)
                model = halo_wire_bytes_model(X, Y, Z, 4, nx=nx, ny=ny, T=T)
                counted.append({"mesh": [nx, ny], "T": T,
                                "local_kernel": lk, "overlap": ov,
                                "counted_wire_bytes": got,
                                "modelled_wire_bytes": model})
            fn = make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                                       dt=0.01, local_kernel="fused",
                                       y_tile=cfg["y_tile"])
            out = fn(*args)
            ref = reference_global_step(u, v, w, p, T=T, dt=0.01)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(out, ref))
            ts = []
            for _ in range(cfg["iters"]):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(*args))
                ts.append(time.perf_counter() - t0)
            measured.append({"mesh": [nx, ny], "T": T,
                             "y_tile": cfg["y_tile"],
                             "interpret_us": sorted(ts)[len(ts) // 2] * 1e6,
                             "max_err_vs_oracle": err})
    print(json.dumps({"counted": counted, "measured": measured}))
""")


def _subprocess_rows(smoke: bool):
    """Counted wire bytes + interpret-mode equivalence on 4 forced host
    devices. Subprocess because the device count must be fixed by XLA_FLAGS
    before jax initialises (tests/test_distributed_stencil.py idiom)."""
    cfg = {"grid": [8, 8, 16], "y_tile": 3, "iters": 1 if smoke else 3,
           "meshes": [[2, 2], [1, 4]] if smoke else [[2, 2], [1, 4], [4, 1]],
           "T": [2] if smoke else [1, 2]}
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.update({
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(root, "src"), root,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
    })
    r = subprocess.run([sys.executable, "-c", _SUB_CODE, json.dumps(cfg)],
                       capture_output=True, text=True, cwd=root, env=env,
                       timeout=900)
    if r.returncode != 0:
        raise SystemExit(f"scaling2d subprocess failed:\n{r.stderr[-3000:]}")
    payload = json.loads(r.stdout.strip().splitlines()[-1])
    for row in payload["counted"]:
        if row["counted_wire_bytes"] != row["modelled_wire_bytes"]:
            raise SystemExit(
                f"scaling2d gate: counted wire bytes "
                f"{row['counted_wire_bytes']} != modelled "
                f"{row['modelled_wire_bytes']} for {row}")
        emit(f"scaling2d.counted.{row['mesh'][0]}x{row['mesh'][1]}"
             f".T{row['T']}.{row['local_kernel']}", 0.0,
             f"wire_B={row['counted_wire_bytes']};model_exact=True")
    for row in payload["measured"]:
        if row["max_err_vs_oracle"] > 1e-4:
            raise SystemExit(
                f"scaling2d gate: 2D fused step err "
                f"{row['max_err_vs_oracle']} vs oracle for {row}")
        emit(f"scaling2d.measured.{row['mesh'][0]}x{row['mesh'][1]}"
             f".T{row['T']}", row["interpret_us"],
             f"err={row['max_err_vs_oracle']:.2e}")
    return payload["counted"], payload["measured"]


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    strong = _strong_rows()
    weak = _weak_rows()
    counted, measured = _subprocess_rows(smoke)
    payload = {
        "strong": strong, "weak": weak,
        "counted": counted, "measured": measured,
        "itemsize": ITEM,
        "contract": "strong: per-shard HBM bytes strictly fall with mesh "
                    "size; weak: per-shard HBM+wire bytes constant; "
                    "counted ppermute bytes == halo_wire_bytes_model "
                    "exactly; 2D fused step matches the global oracle",
    }
    out_path = os.path.join(os.getcwd(), "BENCH_scaling2d.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("scaling2d.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

"""Shared shim for benchmark scripts that must run standalone in CI
(`python benchmarks/<mod>.py [--quick]`) as well as via `benchmarks.run`:
puts the repo root and `src/` on sys.path at import time, and parses the
common smoke-mode flag."""
from __future__ import annotations

import os
import sys
from typing import List, Optional

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def smoke_arg(argv: Optional[List[str]] = None):
    """`True` if --quick was passed, else `None` (defer to BENCH_SMOKE)."""
    return "--quick" in (sys.argv[1:] if argv is None else argv) or None

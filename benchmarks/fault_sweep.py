"""Fault-injection + recovery sweep: the serving stack under a
deterministic fault plan, gated end to end, written to
``BENCH_faults.json``.

Row families:

  * ``guard[]`` — the finite-guard pass priced and counted: the
    jaxpr-counted guard-pass bytes of the guarded fused kernel
    (`stencil.distributed.count_guard_bytes` — the pass re-reads the
    three advanced fields and writes X flag words) gated ==
    `roofline.guard_bytes_model` EXACTLY across (y_tile, batch), the
    guard's field outputs gated BITWISE-equal to the unguarded kernel
    (the reason detection is a separate pass, not fused into the
    advection loop), and the detection overhead gated BOUNDED: guard
    bytes <= 51% of the pass's field bytes (one read pass against the
    six-array field pass), amortised over the T fused Euler steps.
  * ``isolation[]`` — the engine under the ISSUE's combined
    NaN-poisoning + device-loss + exchange-stall plan: the poisoned slot
    is quarantined with an error status (rollback first — replay
    re-poisons — then quarantine), the device loss re-shards, the stall
    retries then degrades the ladder, and every COMPLETED healthy job's
    streamed states and final outputs are gated BITWISE-equal to a
    fault-free run. `health()` counters gated exact.
  * ``rollback[]`` — a one-shot halo-corruption plan with per-step
    snapshots (round-tripped through `training/checkpoint`'s atomic
    on-disk format): the fault rolls back and replays clean, ALL
    outputs gated bitwise-equal to the uninterrupted run, and the
    recovery overhead gated BOUNDED: mega-steps run == clean steps +
    exactly `rollbacks` x (snapshot interval) replayed steps.
  * ``cache[]`` — the bounded-LRU executable cache: a `cache_evict`
    fault records exactly one eviction + one extra re-trace miss, and
    shape-diverse traffic past `max_entries` evicts LRU-first.

Every gate is an explicit ``SystemExit`` raise (python -O safe). CI runs
``--quick`` in the benchmark-smoke job.
"""
from __future__ import annotations

import json
import os
import tempfile

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import roofline as R
from repro.kernels.advection.advection import (advect_fused,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import default_params
from repro.serving.faults import FaultPlan
from repro.serving.stencil_engine import (ExecutableCache, StencilRequest,
                                          StencilServingEngine)
from repro.stencil.advection import AdvectionDomain, stratus_fields
from repro.stencil.distributed import count_guard_bytes

ENGINE_GRID = (12, 16, 64)   # engine slot shape for the bitwise gates
GUARD_GRID = (8, 16, 128)    # lane-aligned grid for the byte-count gates
T = 2
DT = 0.005


def _dom(**kw):
    X, Y, Z = ENGINE_GRID
    kw.setdefault("variant", "fused")
    kw.setdefault("fuse_T", T)
    kw.setdefault("dt", DT)
    return AdvectionDomain(X, Y, Z, **kw)


def _requests(sizes, n_steps):
    _, _, Z = ENGINE_GRID
    reqs = []
    for i, (Xr, Yr) in enumerate(sizes):
        u, v, w = stratus_fields(Xr, Yr, Z, seed=i)
        reqs.append(StencilRequest(uid=i, u=np.asarray(u), v=np.asarray(v),
                                   w=np.asarray(w), n_steps=n_steps[i]))
    return reqs


def _guard_rows(smoke: bool):
    X, Y, Z = GUARD_GRID
    p = default_params(Z)
    cases = [(None, 1), (4, 3)] if smoke else [(None, 1), (None, 3),
                                               (4, 1), (4, 3), (6, 2)]
    rows = []
    for y_tile, B in cases:
        fields = [stratus_fields(X, Y, Z, seed=s) for s in range(B)]
        if B == 1:
            u, v, w = fields[0]

            def fn(uu, vv, ww):
                return advect_fused(uu, vv, ww, p, T=T, dt=DT,
                                    y_tile=y_tile, interpret=True,
                                    guard=True)
        else:
            u, v, w = (jnp.stack([f[i] for f in fields]) for i in range(3))
            from repro.kernels.advection.advection import advect_fused_batched

            def fn(uu, vv, ww):
                return advect_fused_batched(uu, vv, ww, p, T=T, dt=DT,
                                            y_tile=y_tile, interpret=True,
                                            guard=True)
        counted = count_guard_bytes(fn, u, v, w)
        model = R.guard_bytes_model(X, Y, Z, batch=B)
        if counted != model:
            raise SystemExit(
                f"fault gate: jaxpr-counted guard bytes {counted} != "
                f"guard_bytes_model {model} at y_tile={y_tile} B={B}")
        pass_bytes = B * hbm_bytes_model(X, Y, Z, 4, "fused", T=T)
        if counted > 0.51 * pass_bytes:
            raise SystemExit(
                f"fault gate: guard bytes {counted} not bounded by 51% "
                f"of the {pass_bytes}-byte field pass — detection is one "
                "read pass against the six-array field pass")
        # the guard must not perturb the field outputs
        res = fn(u, v, w)
        gu, gv, gw, flags = res
        if B == 1:
            ru, rv, rw = advect_fused(u, v, w, p, T=T, dt=DT,
                                      y_tile=y_tile, interpret=True)
        else:
            from repro.kernels.advection.advection import advect_fused_batched
            ru, rv, rw = advect_fused_batched(u, v, w, p, T=T, dt=DT,
                                              y_tile=y_tile, interpret=True)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in ((gu, ru), (gv, rv), (gw, rw)))
        if diff != 0.0:
            raise SystemExit(
                f"fault gate: guarded kernel differs from unguarded by "
                f"{diff} at y_tile={y_tile} B={B}")
        if float(jnp.min(flags)) <= 0.0:
            raise SystemExit(
                f"fault gate: clean fields tripped the finite guard at "
                f"y_tile={y_tile} B={B}")
        rows.append({"grid": [X, Y, Z], "T": T, "y_tile": y_tile,
                     "batch": B, "counted_guard_bytes": counted,
                     "modelled_guard_bytes": model,
                     "field_pass_bytes": pass_bytes,
                     "guard_overhead_frac": counted / pass_bytes,
                     "bitwise_diff_vs_unguarded": diff})
        emit(f"faults.guard.yt{y_tile}.B{B}", 0.0,
             f"guard_B={counted};frac={counted / pass_bytes:.2e};"
             f"bitwise_equal=True")
    return rows


def _isolation_rows(smoke: bool):
    sizes = [(12, 16), (6, 8), (4, 10)]
    n_steps = [3, 2, 3]
    clean = StencilServingEngine(_dom(), batch_size=2)
    done_c = clean.run(_requests(sizes, n_steps))
    # the ISSUE's combined plan: poison slot 1, lose a device, stall the
    # exchange — all in one run
    plan = ("nan_poison@1:slot=1;"
            "exchange_stall@2:stalls=6,rung=remote_dma;"
            "device_loss@3:reshard_to=1")
    eng = StencilServingEngine(_dom(exchange="remote_dma"), batch_size=2,
                               fault_plan=plan, max_retries=2)
    done_f = eng.run(_requests(sizes, n_steps))
    h = eng.health()
    quarantined = [u for u in done_f if done_f[u].status == "quarantined"]
    healthy = [u for u in done_f if done_f[u].status == "done"]
    if len(quarantined) != 1:
        raise SystemExit(
            f"fault gate: the poisoned slot must be quarantined exactly "
            f"once, got {quarantined} (health {h})")
    if done_f[quarantined[0]].out is not None:
        raise SystemExit("fault gate: a quarantined job must not carry an "
                         "output")
    diff = 0.0
    for u in healthy:
        for got, ref in zip(done_f[u].out, done_c[u].out):
            diff = max(diff, float(np.max(np.abs(got - ref))))
        for st_g, st_r in zip(done_f[u].states, done_c[u].states):
            for got, ref in zip(st_g, st_r):
                diff = max(diff, float(np.max(np.abs(got - ref))))
    if diff != 0.0:
        raise SystemExit(
            f"fault gate: healthy-slot outputs differ from the fault-free "
            f"run by {diff} under the combined plan — isolation broken")
    expect = {"quarantines": 1, "rollbacks": 1, "device_losses": 1,
              "degradations": 1}
    for k, want in expect.items():
        if h[k] != want:
            raise SystemExit(
                f"fault gate: health[{k!r}] == {h[k]}, expected {want} "
                f"under plan {plan!r} (health {h})")
    if h["retries"] < 1:
        raise SystemExit(f"fault gate: the stall must record retries, "
                         f"got {h['retries']}")
    row = {"plan": h["plan"], "healthy_uids": sorted(healthy),
           "quarantined_uids": sorted(quarantined),
           "healthy_bitwise_diff": diff,
           "health": {k: h[k] for k in ("faults_injected", "retries",
                                        "quarantines", "rollbacks",
                                        "degradations", "device_losses",
                                        "reshards", "snapshots")},
           "transitions": h["transitions"], "final_exchange": h["exchange"]}
    emit("faults.isolation.combined_plan", 0.0,
         f"healthy={len(healthy)};quarantined={len(quarantined)};"
         f"bitwise_equal=True;final_exchange={h['exchange']}")
    return [row]


def _rollback_rows(smoke: bool):
    sizes = [(12, 16), (6, 8), (4, 10)]
    n_steps = [3, 2, 3]
    clean = StencilServingEngine(_dom(), batch_size=2)
    done_c = clean.run(_requests(sizes, n_steps))
    steps_clean = clean.megasteps_executed
    with tempfile.TemporaryDirectory() as td:
        eng = StencilServingEngine(
            _dom(), batch_size=2, snapshot_every=1, snapshot_dir=td,
            fault_plan="halo_corruption@1:slot=0,mode=inf,depth=2")
        done_f = eng.run(_requests(sizes, n_steps))
        h = eng.health()
    if any(done_f[u].status != "done" for u in done_f):
        raise SystemExit(
            "fault gate: a one-shot halo corruption must replay clean "
            f"after rollback, got statuses "
            f"{[done_f[u].status for u in done_f]}")
    diff = 0.0
    for u in done_c:
        for got, ref in zip(done_f[u].out, done_c[u].out):
            diff = max(diff, float(np.max(np.abs(got - ref))))
        for st_g, st_r in zip(done_f[u].states, done_c[u].states):
            for got, ref in zip(st_g, st_r):
                diff = max(diff, float(np.max(np.abs(got - ref))))
    if diff != 0.0:
        raise SystemExit(
            f"fault gate: rollback-resume differs from the uninterrupted "
            f"run by {diff} — resume must be bitwise")
    if h["rollbacks"] != 1 or h["quarantines"] != 0:
        raise SystemExit(
            f"fault gate: one-shot corruption must roll back exactly once "
            f"and quarantine nothing, got rollbacks={h['rollbacks']} "
            f"quarantines={h['quarantines']}")
    # bounded recovery overhead: snapshot_every=1 means each rollback
    # replays exactly one mega-step (physical executions, not the
    # logical step index a rollback rewinds)
    steps_faulted = eng.megasteps_executed
    if steps_faulted != steps_clean + h["rollbacks"]:
        raise SystemExit(
            f"fault gate: recovery overhead not bounded — faulted run took "
            f"{steps_faulted} mega-steps vs clean {steps_clean} + "
            f"{h['rollbacks']} rollback replays")
    row = {"plan": h["plan"], "resume_bitwise_diff": diff,
           "steps_clean": steps_clean, "steps_faulted": steps_faulted,
           "rollbacks": h["rollbacks"], "snapshots": h["snapshots"],
           "snapshot_transport": "training.checkpoint (atomic on-disk)"}
    emit("faults.rollback.halo_corruption", 0.0,
         f"bitwise_equal=True;overhead_steps={steps_faulted - steps_clean}")
    return [row]


def _cache_rows(smoke: bool):
    sizes = [(12, 16), (6, 8)]
    n_steps = [3, 2]
    # a cache_evict fault: exactly one eviction + one extra re-trace miss
    eng = StencilServingEngine(_dom(), batch_size=2,
                               fault_plan="cache_evict@2")
    eng.run(_requests(sizes, n_steps))
    stats = eng.cache_stats()
    if stats["evictions"] != 1 or stats["misses"] != 2:
        raise SystemExit(
            f"fault gate: cache_evict must record exactly one eviction "
            f"and one extra re-trace miss, got {stats}")
    # bounded LRU: max_entries=2 under 3 distinct keys evicts LRU-first
    c = ExecutableCache(max_entries=2)
    for key in ("a", "b", "c"):
        c.get(key, lambda k=key: (lambda: k))
    if c.stats() != {"hits": 0, "misses": 3, "entries": 2, "evictions": 1}:
        raise SystemExit(f"fault gate: bounded LRU stats wrong: {c.stats()}")
    c.get("b", lambda: (lambda: "rebuilt"))        # b still resident: hit
    c.get("a", lambda: (lambda: "rebuilt"))        # a evicted: miss
    if c.stats() != {"hits": 1, "misses": 4, "entries": 2, "evictions": 2}:
        raise SystemExit(f"fault gate: LRU order wrong: {c.stats()}")
    row = {"evict_fault_stats": stats, "lru_stats": c.stats(),
           "max_entries": 2}
    emit("faults.cache.evict_and_lru", 0.0,
         f"evictions={stats['evictions']};extra_miss=True;lru_ok=True")
    return [row]


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    payload = {
        "guard": _guard_rows(smoke),
        "isolation": _isolation_rows(smoke),
        "rollback": _rollback_rows(smoke),
        "cache": _cache_rows(smoke),
        "itemsize": 4,
        "contract": "jaxpr-counted guard-pass bytes == guard_bytes_model "
                    "exactly at every (y_tile, batch), guarded kernel "
                    "bitwise-equal to unguarded (detection is a separate "
                    "pass over the advanced fields: one extra read pass, "
                    "<= 51% of the six-array field pass, amortised over "
                    "the T fused Euler steps); under the combined "
                    "NaN-poisoning + "
                    "device-loss + exchange-stall plan the poisoned slot "
                    "is quarantined and every completed healthy job is "
                    "bitwise-equal to a fault-free run with exact health "
                    "counters; a one-shot halo corruption rolls back "
                    "through the atomic on-disk snapshot and resumes "
                    "bitwise-equal to the uninterrupted run with exactly "
                    "rollbacks extra mega-steps; cache_evict records one "
                    "eviction + one re-trace miss and the bounded LRU "
                    "evicts least-recently-used first",
    }
    out_path = os.path.join(os.getcwd(), "BENCH_faults.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("faults.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

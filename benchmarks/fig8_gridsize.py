"""Fig. 8 + Fig. 9 reproduction: runtime and GFLOP/s vs grid size, with the
host-transfer (DMA) overhead split out.

The paper: 1M..268M grid points; FPGA kernel-only time beats 18-core
Broadwell at every size, but host<->card DMA overhead grows from 2% to >40%
of total runtime; chunked overlap (§IV) hides most but not all of it
(first/last chunks are exposed). TPU analogue: host->HBM staging over PCIe
(~100 GB/s effective), overlapped per the same chunk model; kernel time from
the v5e roofline at the dataflow+wide rung.

Fig. 9's numbers derive directly: GFLOP/s = FLOPs / time.
"""
from __future__ import annotations

import jax

from benchmarks.common import comp_s, emit, mem_s, wallclock_us
from repro.core.chunking import overlap_model
from repro.kernels.advection.advection import (fused_register_bytes,
                                               hbm_bytes_model)
from repro.kernels.advection.ref import default_params, flops_per_cell, pw_advect_ref
from repro.stencil.advection import PAPER_GRIDS, stratus_fields

PCIE_BW = 100e9        # host->HBM staging bandwidth (bytes/s)
N_CHUNKS = 64
ITEM = 4
FUSE_T = 4
Y_TILE = 128           # keeps the v4 register VMEM-bounded at every size


def run() -> None:
    print("# fig8: total/kernel/DMA time vs grid size; fig9: GFLOP/s")
    for name, (X, Y, Z) in PAPER_GRIDS.items():
        cells = X * Y * Z
        flops = cells * flops_per_cell()
        kern_s = max(comp_s(flops),
                     mem_s(hbm_bytes_model(X, Y, Z, ITEM, "wide")))
        io_bytes = 2 * 3 * cells * ITEM          # 3 fields in + 3 out
        m = overlap_model(io_bytes, kern_s, PCIE_BW, N_CHUNKS)
        gf_kernel = flops / kern_s / 1e9
        gf_total = flops / m["overlapped_s"] / 1e9
        emit(f"fig8.{name}.staged", m["overlapped_s"] * 1e6,
             f"kernel_us={kern_s*1e6:.0f};dma_overhead="
             f"{m['dma_overhead_overlapped']*100:.0f}%")
        # hardware adaptation: the v5e kernel is ~75x faster than the KU115's,
        # so per-step host staging (the paper's regime) is PCIe-dominated at
        # EVERY size. The TPU-native deployment keeps fields HBM-resident
        # across timesteps (they fit: 268M pts x 6 fields x 4B = 6.4 GB);
        # then the paper's DMA problem disappears entirely in steady state.
        emit(f"fig8.{name}.resident", kern_s * 1e6, "dma_overhead=0%")
        emit(f"fig9.{name}.gflops", 0.0,
             f"kernel={gf_kernel:.0f};staged_total={gf_total:.0f}")
        # v4 temporal fusion at this size: in-grid Y-tiling keeps the
        # register constant while the grid grows 268x — the Fig. 8 enabler.
        # Since PR 2 the tiles live inside the Pallas grid, so the halo
        # overlap costs VMEM re-reads, not HBM: grid-tiled bytes equal the
        # untiled compulsory traffic. Lane-aligned accounting (same
        # convention as the `wide` row above): model at Z=128 and scale
        # back to this grid's cell count.
        fused_b = hbm_bytes_model(X, Y, 128, ITEM, "fused", T=FUSE_T,
                                  y_tile=Y_TILE, grid_tiled=True) * (Z / 128)
        host_b = hbm_bytes_model(X, Y, 128, ITEM, "fused", T=FUSE_T,
                                 y_tile=Y_TILE, grid_tiled=False) * (Z / 128)
        fused_s = max(comp_s(FUSE_T * flops), mem_s(fused_b)) / FUSE_T
        emit(f"fig8.{name}.fused_T{FUSE_T}", fused_s * 1e6,
             f"speedup_vs_wide={kern_s/fused_s:.2f}x;vmem_reg_B="
             f"{fused_register_bytes(FUSE_T, Y, Z, ITEM, y_tile=Y_TILE)}")
        emit(f"fig8.{name}.tiling_halo", (mem_s(host_b - fused_b)) * 1e6,
             f"host_tiled_B={host_b:.3e};grid_tiled_B={fused_b:.3e};"
             f"hbm_halo_saved={(host_b - fused_b) / host_b * 100:.1f}%")

    # CPU baseline wall-clock (reduced grid, the paper's CPU comparison)
    X, Y, Z = 64, 128, 64
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    fn = jax.jit(lambda a, b, c: pw_advect_ref(a, b, c, p))
    us = wallclock_us(fn, u, v, w)
    cpu_gflops = (X * Y * Z * flops_per_cell()) / (us / 1e6) / 1e9
    emit("fig8.cpu_reference", us, f"cpu_gflops={cpu_gflops:.2f}")


if __name__ == "__main__":
    run()

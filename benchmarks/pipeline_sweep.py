"""Pipelined multi-block driver sweep: `make_distributed_run(n_blocks=K)`
— K substep-blocks in ONE traced program, the remote-DMA engine's
double-buffered recv slots alternating on a TRACED block counter — priced
and gated, written to ``BENCH_pipeline.json``.

Row families:

  * ``modelled[]`` — the 268M-cell grid on growing (nx, ny) meshes, one
    entry per (mesh, T) with the per-block exchange priced across the
    K sweep: `remote_dma` hiding is cross-block (the spare recv slot —
    `roofline.pipeline_efficiency_model`, one pipeline-fill block paid),
    `collective` is the K-independent within-block figure. GATES:
    hidden + exposed reconstruct ``collective_s`` exactly at every K, and
    the remote-DMA exposed wire seconds fall STRICTLY MONOTONICALLY in K
    (the steady state approaches the interior-fraction bound).
  * ``counted[]`` — subprocess on 4 forced host devices, swept across
    HOP COUNTS (T below and above the local extent): the K-block run's
    jaxpr-counted wire bytes (`count_exchange_wire_bytes` walks the
    `fori_loop` body ONCE) GATED == `halo_wire_bytes_model` ==
    `remote_dma_schedule_wire_bytes` == the single-block step's count at
    EVERY hop count — one trace for all K blocks, no per-block retrace —
    and the K-block output GATED BITWISE-equal to K sequential
    `make_distributed_step` calls with alternating `dma_block_index`
    parity AND to the K-block collective run.

Every gate is an explicit ``SystemExit`` raise (python -O safe). CI runs
``--quick`` in the benchmark-smoke job.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import subprocess
import sys
import textwrap

try:                        # package context (benchmarks.run / -m)
    from benchmarks import _bootstrap
except ImportError:         # script context: benchmarks/ is sys.path[0]
    import _bootstrap

from benchmarks.common import emit
from repro.stencil.advection import PAPER_GRIDS, AdvectionDomain

GRID = PAPER_GRIDS["268M"]                       # (4096, 1024, 64)
MESHES = [(4, 4), (16, 8), (16, 16)]
T_SWEEP = (4, 8)
K_SWEEP = (1, 2, 4, 16, 64)
Y_TILE = 128


def _modelled_rows():
    X, Y, Z = GRID
    rows = []
    for T in T_SWEEP:
        for nx, ny in MESHES:
            row = {"grid": [X, Y, Z], "mesh": [nx, ny], "devices": nx * ny,
                   "T": T, "y_tile": Y_TILE, "blocks": {}}
            dma_exposed = []
            for K in K_SWEEP:
                entry = {}
                for label, ex in (("remote_dma", "remote_dma"),
                                  ("collective", "collective")):
                    dom = AdvectionDomain(X, Y, Z, variant="fused",
                                          fuse_T=T, y_tile=Y_TILE,
                                          mesh_nx=nx, mesh_ny=ny,
                                          exchange=ex, overlap=True,
                                          n_blocks=K)
                    # price the PIPELINED schedule at every K, including
                    # the honest K=1 (remote-DMA waits fully serialised) —
                    # roofline_terms() keeps the single-block figure there
                    # for BENCH_overlap back-compat
                    t = dataclasses.replace(
                        dom.roofline_terms(),
                        overlap_efficiency=dom.pipeline_efficiency())
                    if "wire_bytes" not in row:
                        row["wire_bytes"] = t.ici_wire_bytes
                    elif t.ici_wire_bytes != row["wire_bytes"]:
                        raise SystemExit(
                            f"pipeline gate: wire bytes diverged at "
                            f"({nx},{ny}) T={T} K={K} {label}: "
                            f"{t.ici_wire_bytes} != {row['wire_bytes']}")
                    if not math.isclose(t.collective_hidden_s
                                        + t.collective_exposed_s,
                                        t.collective_s, rel_tol=1e-12):
                        raise SystemExit(
                            f"pipeline gate: hidden+exposed != collective "
                            f"at ({nx},{ny}) T={T} K={K} {label}")
                    entry[label] = {
                        "pipeline_efficiency": t.overlap_efficiency,
                        "collective_s": t.collective_s,
                        "collective_hidden_s": t.collective_hidden_s,
                        "collective_exposed_s": t.collective_exposed_s,
                        "overlapped_step_time_s": t.overlapped_step_time_s,
                        "bound": t.bound,
                        "overlapped_bound": t.overlapped_bound,
                    }
                dma_exposed.append(
                    entry["remote_dma"]["collective_exposed_s"])
                row["blocks"][str(K)] = entry
            # THE modelled gate: pipelining strictly cuts the remote-DMA
            # engine's per-block exposed wire seconds as K grows (one
            # fill block amortised over more and more hidden blocks)
            if not all(b < a for a, b in zip(dma_exposed, dma_exposed[1:])):
                raise SystemExit(
                    f"pipeline gate: remote_dma exposed seconds not "
                    f"strictly falling in K at ({nx},{ny}) T={T}: "
                    f"{dma_exposed}")
            emit(f"pipeline.modelled.T{T}.{nx}x{ny}",
                 row["blocks"][str(K_SWEEP[-1])]["remote_dma"][
                     "overlapped_step_time_s"] * 1e6,
                 f"exposed_us_K1={dma_exposed[0]*1e6:.2f};"
                 f"exposed_us_K{K_SWEEP[-1]}={dma_exposed[-1]*1e6:.2f}")
            rows.append(row)
    return rows


_SUB_CODE = textwrap.dedent("""
    import json, os, sys
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.roofline import halo_wire_bytes_model
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_run,
                                           make_distributed_step,
                                           reference_global_step,
                                           remote_dma_schedule_wire_bytes)

    cfg = json.loads(sys.argv[1])
    X, Y, Z = cfg["grid"]
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    K = cfg["n_blocks"]
    rows = []
    for nx, ny, T, lk in cfg["cases"]:
        mesh = make_stencil_mesh(nx, ny)
        sh = NamedSharding(mesh, P("x", "y", None))
        args = [jax.device_put(t, sh) for t in (u, v, w)]
        kw = dict(axis="y", x_axis="x", T=T, dt=0.005, local_kernel=lk,
                  overlap=True)
        runs = {ex: make_distributed_run(mesh, p, n_blocks=K, exchange=ex,
                                         **kw)
                for ex in ("collective", "remote_dma")}
        outs = {ex: fn(*args) for ex, fn in runs.items()}
        diff_engines = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                           zip(outs["collective"], outs["remote_dma"]))
        # K sequential one-block steps, dma_block_index alternating parity
        seq = args
        for k in range(K):
            seq = make_distributed_step(mesh, p, exchange="remote_dma",
                                        dma_block_index=k, **kw)(*seq)
        diff_seq = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                       zip(outs["remote_dma"], seq))
        ref = reference_global_step(u, v, w, p, T=K * T, dt=0.005)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                  zip(outs["remote_dma"], ref))
        counted_run = count_exchange_wire_bytes(runs["remote_dma"], u, v, w)
        counted_step = count_exchange_wire_bytes(
            make_distributed_step(mesh, p, exchange="remote_dma", **kw),
            u, v, w)
        model = halo_wire_bytes_model(X, Y, Z, 4, nx=nx, ny=ny, T=T)
        sched = remote_dma_schedule_wire_bytes(X // nx, Y // ny, Z, 4,
                                               nx=nx, ny=ny, T=T)
        hops = [-(-T // (X // nx)) if nx > 1 else 0,
                -(-T // (Y // ny)) if ny > 1 else 0]
        rows.append({"mesh": [nx, ny], "T": T, "n_blocks": K,
                     "local_kernel": lk, "hops_xy": hops,
                     "counted_run_wire_bytes": counted_run,
                     "counted_step_wire_bytes": counted_step,
                     "modelled_wire_bytes": model,
                     "schedule_wire_bytes": sched,
                     "bitwise_diff_engines": diff_engines,
                     "bitwise_diff_vs_sequential": diff_seq,
                     "max_err_vs_oracle": err})
    print(json.dumps({"counted": rows}))
""")


def _subprocess_rows(smoke: bool):
    """K-block bitwise + trace-once + wire-byte gates on 4 forced host
    devices, swept across hop counts (the scaling2d subprocess idiom)."""
    # (nx, ny, T, local_kernel): Yl = 4 on the (1, 4) mesh, so T = 2/6/10
    # takes 1/2/3 band messages (hops) per side; the (2, 2) case runs
    # multi-hop in x (Xl = 3 < T = 4) and single-hop in y through the
    # fused local kernel.
    cases = ([[1, 4, 2, "reference"], [1, 4, 6, "reference"],
              [2, 2, 4, "fused"]] if smoke else
             [[1, 4, 2, "reference"], [1, 4, 6, "reference"],
              [1, 4, 10, "reference"], [2, 2, 2, "fused"],
              [2, 2, 4, "fused"]])
    cfg = {"grid": [6, 16, 12], "n_blocks": 3, "cases": cases}
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env.update({
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(root, "src"), root,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep),
    })
    r = subprocess.run([sys.executable, "-c", _SUB_CODE, json.dumps(cfg)],
                       capture_output=True, text=True, cwd=root, env=env,
                       timeout=900)
    if r.returncode != 0:
        raise SystemExit(f"pipeline subprocess failed:\n{r.stderr[-3000:]}")
    payload = json.loads(r.stdout.strip().splitlines()[-1])
    for row in payload["counted"]:
        if not (row["counted_run_wire_bytes"]
                == row["counted_step_wire_bytes"]
                == row["modelled_wire_bytes"]
                == row["schedule_wire_bytes"]):
            raise SystemExit(
                f"pipeline gate: K-block counted "
                f"{row['counted_run_wire_bytes']} / single-step counted "
                f"{row['counted_step_wire_bytes']} / modelled "
                f"{row['modelled_wire_bytes']} / schedule "
                f"{row['schedule_wire_bytes']} wire bytes differ for {row} "
                "— the K-block jaxpr must contain the step body exactly "
                "once (no per-block retrace) at every hop count")
        if row["bitwise_diff_engines"] != 0.0:
            raise SystemExit(
                f"pipeline gate: K-block remote_dma differs from "
                f"collective by {row['bitwise_diff_engines']} for {row}")
        if row["bitwise_diff_vs_sequential"] != 0.0:
            raise SystemExit(
                f"pipeline gate: K-block run differs from K sequential "
                f"alternating-parity steps by "
                f"{row['bitwise_diff_vs_sequential']} for {row}")
        if row["max_err_vs_oracle"] >= 1e-4:
            raise SystemExit(
                f"pipeline gate: K-block run drifted "
                f"{row['max_err_vs_oracle']} from the global oracle "
                f"for {row}")
        emit(f"pipeline.counted.{row['mesh'][0]}x{row['mesh'][1]}"
             f".T{row['T']}.K{row['n_blocks']}", 0.0,
             f"wire_B={row['counted_run_wire_bytes']};"
             f"hops_xy={row['hops_xy']};bitwise_equal=True")
    return payload["counted"]


def run(smoke: bool = None) -> None:
    if smoke is None:
        smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    modelled = _modelled_rows()
    counted = _subprocess_rows(smoke)
    payload = {
        "modelled": modelled, "counted": counted, "itemsize": 4,
        "contract": "K-block make_distributed_run output bitwise-equal to "
                    "K sequential alternating-parity make_distributed_step "
                    "calls AND to the K-block collective run, at every "
                    "swept hop count; K-block jaxpr-counted wire bytes == "
                    "single-step counted == halo_wire_bytes_model == "
                    "remote_dma_schedule_wire_bytes exactly (step body "
                    "traced once); modelled remote_dma exposed seconds "
                    "strictly fall in K; hidden+exposed == collective_s",
    }
    out_path = os.path.join(os.getcwd(), "BENCH_pipeline.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    emit("pipeline.json_written", 0.0, out_path)


if __name__ == "__main__":
    run(smoke=_bootstrap.smoke_arg())

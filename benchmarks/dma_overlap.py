"""§IV reproduction: measured chunked transfer/compute overlap.

Unlike the roofline figures this one is a *real wall-clock measurement* on
this host: the ChunkScheduler runs the advection kernel over chunks with
serial staging vs overlapped staging (JAX async dispatch = the paper's
non-blocking DMA + kernel pool). On a single CPU device overlap is partial;
on a real accelerator the transfer/compute overlap is full — the analytic
§IV model for the TPU case is printed alongside.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.chunking import ChunkScheduler, overlap_model
from repro.kernels.advection.ref import default_params, pw_advect_ref


def run() -> None:
    X, Y, Z = 16, 64, 64
    p = default_params(Z)
    kernel = jax.jit(lambda u, v, w: pw_advect_ref(u, v, w, p)[0])
    rng = np.random.default_rng(0)
    chunks = [tuple(rng.normal(size=(X, Y, Z)).astype(np.float32)
                    for _ in range(3)) for _ in range(16)]
    sched = ChunkScheduler(kernel, depth=4)
    t = sched.time_both(chunks)
    emit("dma.measured_serial", t.serial_s * 1e6, "")
    emit("dma.measured_overlapped", t.overlapped_s * 1e6,
         f"speedup={t.speedup:.2f};note=cpu_device_put_is_zero_copy")

    # host-side data PREPARATION overlapped with device compute — the part of
    # §IV that IS measurable on one CPU device (numpy releases the GIL):
    import time
    from repro.core.dataflow import Pipeline, Stage
    rng2 = np.random.default_rng(1)
    prep = lambda i: tuple(rng2.normal(size=(X, Y, Z)).astype(np.float32)
                           for _ in range(3))
    n = 12
    t0 = time.perf_counter()
    for i in range(n):
        jax.block_until_ready(kernel(*prep(i)))
    serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    pipe = Pipeline([Stage("prep", prep),
                     Stage("compute", lambda c: np.asarray(kernel(*c)))])
    pipe.run(list(range(n)))
    overlapped = time.perf_counter() - t0
    emit("dma.prep_overlap_serial", serial * 1e6, "")
    emit("dma.prep_overlap_pipelined", overlapped * 1e6,
         f"speedup={serial/overlapped:.2f}")
    # §IV analytic model at paper scale (12.88 GB moved for 268M points)
    m = overlap_model(12.88e9, 0.2, 100e9, 64)
    emit("dma.model_268M", m["overlapped_s"] * 1e6,
         f"serial_overhead={m['dma_overhead_serial']*100:.0f}%;"
         f"overlapped_overhead={m['dma_overhead_overlapped']*100:.0f}%;"
         f"paper=71%->42%")


if __name__ == "__main__":
    run()

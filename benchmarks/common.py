"""Shared benchmark utilities: CSV emission + roofline shortcuts."""
from __future__ import annotations

import sys
import time
from typing import Iterable

import jax

from repro.core import roofline as R

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def header():
    print("name,us_per_call,derived")


def wallclock_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def mem_s(bytes_: float) -> float:
    return bytes_ / R.HBM_BW


def comp_s(flops: float) -> float:
    return flops / R.PEAK_FLOPS

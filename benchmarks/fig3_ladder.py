"""Fig. 3 reproduction: the data-movement optimisation ladder.

The paper's table tracks one kernel through seven data-access optimisations,
runtime 584.65 ms -> 63.49 ms and compute share 14% -> 85%, at grid
512x512x64 (16.7M cells). On the TPU target we can't wall-clock the v5e, so
each rung is scored with the same roofline the dry-run uses: modelled HBM
bytes (per-variant traffic model, validated against the kernels' BlockSpecs)
vs the stencil's measured FLOPs. The paper's qualitative claims to reproduce:

  * pre-dataflow rungs are overwhelmingly memory-bound (compute share ~14%),
  * the dataflow/pipelined rungs cut traffic ~3x,
  * full-width access pushes compute share above 80%.

Correctness of every rung's kernel is pinned by tests (interpret=True vs
oracle). CPU wall-clock for the jnp reference is also measured (the paper's
CPU baseline analogue) on a reduced grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import comp_s, emit, mem_s, wallclock_us
from repro.core.dataflow import pipeline_model
from repro.kernels.advection.advection import hbm_bytes_model
from repro.kernels.advection.ref import default_params, flops_per_cell, pw_advect_ref
from repro.stencil.advection import stratus_fields

# the paper's Fig. 3 grid
X, Y, Z = 512, 512, 64
CELLS = X * Y * Z
ITEM = 4  # f32

LADDER = [
    # (paper row, variant, overlapped?, paper runtime ms, paper compute %)
    ("initial_blocked", "blocked", False, 584.65, 14),
    ("split_ports", "blocked_split", False, 490.98, 17),
    ("dataflow_stages", "dataflow_noX", True, 189.64, 30),
    ("x_in_dataflow_contiguous", "dataflow", True, 163.43, 33),
    ("wide_256bit_ports", "wide", True, 65.41, 82),
    ("wide_4_per_cycle", "wide_deep", True, 63.49, 85),
    # our extension beyond the paper's ladder: temporal fusion (v4), charged
    # per step — the paper has no row here; Brown 2020/2021 motivate the rung
    ("temporal_fusion_T4", "fused_T4", True, float("nan"), float("nan")),
]


def variant_bytes(variant: str) -> float:
    if variant == "blocked":
        # single shared port: fields serialised -> model as 3x slice traffic
        return hbm_bytes_model(X, Y, Z, ITEM, "blocked") * 1.2
    if variant == "blocked_split":
        return hbm_bytes_model(X, Y, Z, ITEM, "blocked")
    if variant == "dataflow_noX":
        # dataflow but pipeline drains per slice: 1x traffic, drain overhead
        return hbm_bytes_model(X, Y, Z, ITEM, "dataflow") * 1.15
    if variant == "dataflow":
        return hbm_bytes_model(X, Y, Z, ITEM, "dataflow")
    if variant == "wide":
        return hbm_bytes_model(X, Y, 128, ITEM, "wide") * (CELLS / (X * Y * 128))
    if variant == "wide_deep":
        return hbm_bytes_model(X, Y, 128, ITEM, "wide") * (CELLS / (X * Y * 128)) * 0.97
    if variant == "fused_T4":
        # per-STEP traffic of the T=4 fused pass (one read+write for 4 steps)
        return hbm_bytes_model(X, Y, 128, ITEM, "fused", T=4,
                               y_tile=128) * (CELLS / (X * Y * 128)) / 4
    raise ValueError(variant)


def run() -> None:
    flops = CELLS * flops_per_cell()
    c_s = comp_s(flops)
    print("# fig3: variant, modelled GB moved, roofline ms, compute share "
          "(paper runtime ms / compute %)")
    base_ms = None
    for row, variant, overlapped, paper_ms, paper_pct in LADDER:
        b = variant_bytes(variant)
        m_s = mem_s(b)
        # per-slice stage times (the pipeline items are the X slices)
        stages = {"load": m_s * 0.55 / X, "compute": c_s / X,
                  "store": m_s * 0.45 / X}
        model = pipeline_model(stages, n_items=X, overlapped=overlapped)
        t = model["pipelined_s"] if overlapped else model["serial_s"]
        t = max(t, c_s)
        share = c_s / t
        base_ms = base_ms or t * 1e3
        emit(f"fig3.{row}", t * 1e6,
             f"GB={b/1e9:.2f};compute_share={share*100:.2f}%;"
             f"paper_ms={paper_ms};paper_share={paper_pct}%")
        # NOTE (hardware adaptation): on v5e the stencil's arithmetic
        # intensity (~1.3 flop/byte) sits far below the 240 flop/byte ridge,
        # so compute share stays low even at the top rung — the paper's 85%
        # reflects the KU115's much lower flops:bandwidth ratio. The claim
        # that transfers (bytes and runtime ladder) is the 9.2x, which we hit.
    # trajectory checks (the paper's qualitative claims)
    def stage_t(variant):
        m = mem_s(variant_bytes(variant))
        return {"load": m * .55 / X, "compute": c_s / X, "store": m * .45 / X}
    t_first = max(pipeline_model(stage_t(LADDER[0][1]), X,
                                 overlapped=False)["serial_s"], c_s)
    # the paper's ladder tops out at wide_4_per_cycle; fused is our extension
    t_paper_top = max(pipeline_model(stage_t("wide_deep"), X)["pipelined_s"],
                      c_s)
    emit("fig3.ladder_speedup", 0.0,
         f"ours={t_first/t_paper_top:.1f}x;paper=9.2x")
    t_fused = max(pipeline_model(stage_t("fused_T4"), X)["pipelined_s"], c_s)
    emit("fig3.fusion_extension_speedup", 0.0,
         f"vs_initial={t_first/t_fused:.1f}x;vs_paper_top="
         f"{t_paper_top/t_fused:.1f}x")

    # CPU wall-clock of the reference kernel (the paper's CPU baseline)
    Xr, Yr, Zr = 64, 128, 64
    u, v, w = stratus_fields(Xr, Yr, Zr)
    p = default_params(Zr)
    fn = jax.jit(lambda a, b, c: pw_advect_ref(a, b, c, p))
    us = wallclock_us(fn, u, v, w)
    per_cell = us / (Xr * Yr * Zr)
    emit("fig3.cpu_reference_reduced", us,
         f"grid={Xr}x{Yr}x{Zr};us_per_Mcell={per_cell*1e6:.1f}")


if __name__ == "__main__":
    run()

"""Tier-1 tests for the fault-injection + recovery subsystem.

Contracts pinned here (the fault_sweep.py gates, at test-sized grids):

  * `FaultPlan` is deterministic and seed-reproducible; `describe()`
    round-trips through `parse()`.
  * The finite-guard pass (`advect_fused(..., guard=True)`) leaves the
    field outputs BITWISE-equal to an unguarded call (detection is a
    separate pallas pass, never fused into the advection loop), flags
    non-finite slots exactly, and its extra HBM bytes are counted from
    the jaxpr == `roofline.guard_bytes_model` EXACTLY.
  * Every fault kind drives injection -> detection -> recovery through
    the serving engine with `health()` counters asserted: a persistent
    NaN poison rolls back once then quarantines its slot while healthy
    slots stay bitwise; a one-shot halo corruption rolls back (memory or
    atomic on-disk snapshot) and resumes bitwise with exactly one
    replayed mega-step; an exchange stall retries with backoff then
    degrades the ladder; ladder exhaustion reshards down; a cache
    eviction records one eviction + one re-trace miss; a device loss
    reshards (down OR up) bitwise.
  * `retry_with_backoff` / `DegradationLadder` /
    `resilient_distributed_run` implement the same discipline at the
    exchange-block layer.
  * `core.dataflow.Pipeline` never silently leaks a hung worker thread.
  * `SlotManager` rejects the fault-path edge misuses (release of a
    dead slot, double occupy, tick of a dead slot).
"""
import logging

import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st
from repro.core import roofline as R
from repro.core.dataflow import Pipeline, Stage
from repro.kernels.advection.advection import (advect_fused,
                                               advect_fused_batched,
                                               finite_guard)
from repro.kernels.advection.ref import default_params
from repro.serving.faults import (DEFAULT_LADDER, FAULT_KINDS,
                                  DegradationLadder, ExchangeStalled, Fault,
                                  FaultInjector, FaultPlan,
                                  RecoveryExhausted,
                                  resilient_distributed_run,
                                  retry_with_backoff)
from repro.serving.slots import SlotManager
from repro.serving.stencil_engine import (StencilRequest,
                                          StencilServingEngine)
from repro.stencil.advection import AdvectionDomain, stratus_fields
from repro.stencil.distributed import count_guard_bytes

X, Y, Z, T = 8, 10, 16, 2
DT = 0.005
SIZES = [(X, Y, 3), (5, 6, 2), (4, 8, 3)]


def _dom(**kw):
    kw.setdefault("variant", "fused")
    kw.setdefault("fuse_T", T)
    kw.setdefault("dt", DT)
    return AdvectionDomain(X, Y, Z, **kw)


def _req(uid, Xr, Yr, n_steps=1):
    u, v, w = stratus_fields(Xr, Yr, Z, seed=uid)
    return StencilRequest(uid=uid, u=np.asarray(u), v=np.asarray(v),
                          w=np.asarray(w), n_steps=n_steps)


def _reqs():
    return [_req(i, xr, yr, n) for i, (xr, yr, n) in enumerate(SIZES)]


@pytest.fixture(scope="module")
def clean_done():
    return StencilServingEngine(_dom(), batch_size=2).run(_reqs())


def _assert_bitwise(req, ref_req):
    for got, ref in zip(req.out, ref_req.out):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert len(req.states) == len(ref_req.states)
    for st_g, st_r in zip(req.states, ref_req.states):
        for got, ref in zip(st_g, st_r):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# -- the fault plan --------------------------------------------------------

def test_fault_plan_parse_describe_roundtrip():
    spec = ("nan_poison@1:slot=1,field=v,mode=inf;"
            "exchange_stall@2:stalls=6,rung=remote_dma;"
            "device_loss@3:reshard_to=1;"
            "halo_corruption@4:depth=2;cache_evict@5")
    plan = FaultPlan.parse(spec)
    assert len(plan.faults) == 5
    assert plan.at(1)[0].field == "v" and plan.at(1)[0].mode == "inf"
    assert plan.at(2)[0].stalls == 6
    assert plan.at(3)[0].reshard_to == 1
    assert plan.max_step() == 5
    again = FaultPlan.parse(plan.describe())
    assert again.faults == plan.faults


def test_fault_plan_random_is_seed_reproducible():
    a = FaultPlan.random(7, n_steps=5, batch=4)
    b = FaultPlan.random(7, n_steps=5, batch=4)
    assert a.faults == b.faults and a.seed == 7
    assert all(f.kind in ("device_loss", "nan_poison", "halo_corruption",
                          "exchange_stall", "cache_evict")
               for f in a.faults)
    # the plan round-trips so artifacts record exactly what ran
    assert FaultPlan.parse(a.describe()).faults == a.faults


def test_fault_validation():
    with pytest.raises(ValueError, match="kind"):
        Fault("bit_rot", at_step=0)
    with pytest.raises(ValueError, match="at_step"):
        Fault("nan_poison", at_step=-1)
    with pytest.raises(ValueError, match="field"):
        Fault("nan_poison", at_step=0, field="q")
    with pytest.raises(ValueError, match="mode"):
        Fault("nan_poison", at_step=0, mode="zero")
    with pytest.raises(ValueError, match="stalls"):
        Fault("exchange_stall", at_step=0, stalls=0)
    with pytest.raises(ValueError, match="depth"):
        Fault("halo_corruption", at_step=0, depth=0)
    with pytest.raises(ValueError, match="reshard_to"):
        Fault("device_loss", at_step=0, reshard_to=0)
    with pytest.raises(ValueError, match="kind@step"):
        FaultPlan.parse("nan_poison1")
    with pytest.raises(ValueError, match="key=val"):
        FaultPlan.parse("nan_poison@1:slot")


def test_fault_persistence_defaults():
    assert Fault("nan_poison", at_step=0).is_persistent
    assert not Fault("halo_corruption", at_step=0).is_persistent
    assert Fault("halo_corruption", at_step=0, persistent=True).is_persistent
    assert not Fault("nan_poison", at_step=0, persistent=False).is_persistent


# -- the finite-guard pass -------------------------------------------------

def test_guard_pass_is_bitwise_and_detects():
    # (8, 16, 64): a shape where an IN-kernel isfinite probe provably
    # drifts by one ulp — the separate guard pass must not
    Xg, Yg, Zg = 8, 16, 64
    p = default_params(Zg)
    u, v, w = stratus_fields(Xg, Yg, Zg, seed=0)
    ru, rv, rw = advect_fused(u, v, w, p, T=T, dt=DT, interpret=True)
    gu, gv, gw, flags = advect_fused(u, v, w, p, T=T, dt=DT, interpret=True,
                                     guard=True)
    for got, ref in ((gu, ru), (gv, rv), (gw, rw)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert flags.shape == (Xg,) and float(jnp.min(flags)) == 1.0
    # direct pass over poisoned fields: exactly the poisoned slice flags
    up = np.asarray(u).copy()
    up[3, 1, 0] = np.nan
    f = np.asarray(finite_guard(jnp.asarray(up), v, w, interpret=True))
    assert f[3] == 0.0 and np.all(np.delete(f, 3) == 1.0)


def test_guard_pass_batched_isolates_slots():
    p = default_params(Z)
    B = 3
    u, v, w = (jnp.stack([stratus_fields(X, Y, Z, seed=s)[i]
                          for s in range(B)]) for i in range(3))
    up = np.array(u)
    up[1, 1, 1, 0] = np.inf
    ou, ov, ow, gf = advect_fused_batched(jnp.asarray(up), v, w, p, T=T,
                                          dt=DT, interpret=True, guard=True)
    ok = np.asarray(gf).min(axis=1) > 0.0
    assert list(ok) == [True, False, True]
    cu, cv, cw = advect_fused_batched(u, v, w, p, T=T, dt=DT, interpret=True)
    for b in (0, 2):                      # healthy slots stay bitwise
        for got, ref in ((ou, cu), (ov, cv), (ow, cw)):
            np.testing.assert_array_equal(np.asarray(got[b]),
                                          np.asarray(ref[b]))


def test_guard_bytes_counted_equals_model():
    p = default_params(Z)
    for B in (1, 3):
        u, v, w = (jnp.stack([stratus_fields(X, Y, Z, seed=s)[i]
                              for s in range(B)]) for i in range(3))

        def guarded(uu, vv, ww):
            return advect_fused_batched(uu, vv, ww, p, T=T, dt=DT,
                                        interpret=True, guard=True)

        def plain(uu, vv, ww):
            return advect_fused_batched(uu, vv, ww, p, T=T, dt=DT,
                                        interpret=True)

        assert count_guard_bytes(guarded, u, v, w) == \
            R.guard_bytes_model(X, Y, Z, batch=B)
        assert count_guard_bytes(plain, u, v, w) == 0


def test_guard_bytes_model_validation_and_accessors():
    with pytest.raises(ValueError, match="batch"):
        R.guard_bytes_model(X, Y, Z, batch=0)
    with pytest.raises(ValueError, match="extents"):
        R.guard_bytes_model(0, Y, Z)
    assert _dom(batch=3).guard_bytes_per_step() == \
        3 * _dom().guard_bytes_per_step()
    with pytest.raises(ValueError, match="fused"):
        AdvectionDomain(X, Y, Z, variant="baseline").guard_bytes_per_step()
    eng = StencilServingEngine(_dom(), batch_size=2)
    assert eng.guard_bytes_per_step() == R.guard_bytes_model(X, Y, Z,
                                                             batch=2)


# -- engine fault paths: injection -> detection -> recovery ----------------

def test_nan_poison_rolls_back_then_quarantines(clean_done):
    eng = StencilServingEngine(_dom(), batch_size=2,
                               fault_plan="nan_poison@1:slot=1,field=v")
    done = eng.run(_reqs())
    h = eng.health()
    # first sighting rolls back; the replay re-poisons (persistent) and
    # the suspect site falls through to quarantine
    assert h["rollbacks"] == 1 and h["quarantines"] == 1
    assert h["faults_injected"] == 2          # fired on both crossings
    [quid] = h["quarantined_uids"]
    assert done[quid].status == "quarantined" and done[quid].out is None
    assert "non-finite" in done[quid].error
    for uid in done:
        if uid != quid:
            assert done[uid].status == "done"
            _assert_bitwise(done[uid], clean_done[uid])


def test_halo_corruption_rolls_back_bitwise(clean_done):
    clean_steps = StencilServingEngine(_dom(), batch_size=2)
    clean_steps.run(_reqs())
    eng = StencilServingEngine(
        _dom(), batch_size=2,
        fault_plan="halo_corruption@1:slot=0,mode=inf,depth=2")
    done = eng.run(_reqs())
    h = eng.health()
    assert h["rollbacks"] == 1 and h["quarantines"] == 0
    for uid in done:                          # one-shot: ALL jobs clean
        assert done[uid].status == "done"
        _assert_bitwise(done[uid], clean_done[uid])
    # bounded recovery overhead: snapshot_every=1 -> exactly one replayed
    # mega-step (physical executions; the logical index is rewound)
    assert eng.megasteps_executed == clean_steps.megasteps_executed + 1


def test_disk_snapshot_rollback_bitwise(tmp_path, clean_done):
    eng = StencilServingEngine(
        _dom(), batch_size=2, snapshot_dir=tmp_path,
        fault_plan="halo_corruption@1:slot=1")
    done = eng.run(_reqs())
    h = eng.health()
    assert h["rollbacks"] == 1 and h["snapshots"] >= 1
    for uid in done:
        _assert_bitwise(done[uid], clean_done[uid])


def test_exchange_stall_retries_then_degrades():
    clean = StencilServingEngine(_dom(exchange="remote_dma"), batch_size=2)
    done_c = clean.run(_reqs())
    sleeps = []
    eng = StencilServingEngine(
        _dom(exchange="remote_dma"), batch_size=2,
        fault_plan="exchange_stall@1:stalls=10,rung=remote_dma",
        max_retries=2, backoff_s=0.25, sleeper=sleeps.append)
    done = eng.run(_reqs())
    h = eng.health()
    assert h["retries"] == 2 and h["degradations"] == 1
    assert h["exchange"] == "collective"      # walked the ladder
    assert sleeps == [0.25, 0.5]              # exponential backoff
    assert any("remote_dma -> collective" in t for t in h["transitions"])
    # the re-trace on the fallback transport is a recorded miss
    assert eng.cache_stats()["misses"] == 2
    for uid in done:
        _assert_bitwise(done[uid], done_c[uid])


def test_ladder_exhaustion_reshards_down(clean_done):
    # collective is the LAST rung: a stall there exhausts the ladder and
    # the engine takes the implicit final rung — reshard to half
    eng = StencilServingEngine(
        _dom(), batch_size=2, max_retries=1,
        fault_plan="exchange_stall@1:stalls=10,rung=collective")
    done = eng.run(_reqs())
    h = eng.health()
    assert h["degradations"] == 0 and h["reshards"] == 1
    assert eng.B == 1
    assert any("exhausted" in t for t in h["transitions"])
    for uid in done:
        _assert_bitwise(done[uid], clean_done[uid])


def test_cache_evict_records_eviction_and_retrace():
    eng = StencilServingEngine(_dom(), batch_size=2,
                               fault_plan="cache_evict@2")
    eng.run(_reqs())
    stats = eng.cache_stats()
    assert stats["evictions"] == 1 and stats["misses"] == 2
    assert eng.health()["cache_evictions"] == 1


def test_device_loss_plan_matches_deprecated_alias(clean_done):
    eng = StencilServingEngine(_dom(), batch_size=2,
                               fault_plan="device_loss@1:reshard_to=1")
    done = eng.run(_reqs())
    h = eng.health()
    assert h["device_losses"] == 1 and h["reshards"] == 1
    for uid in done:
        _assert_bitwise(done[uid], clean_done[uid])
    alias = StencilServingEngine(_dom(), batch_size=2)
    done_a = alias.run(_reqs(), lose_device_at=1, reshard_to=1)
    ha = alias.health()
    assert (ha["device_losses"], ha["reshards"]) == (1, 1)
    for uid in done_a:
        _assert_bitwise(done_a[uid], done[uid])
    with pytest.raises(ValueError, match="not both"):
        StencilServingEngine(_dom(), batch_size=2).run(
            _reqs(), lose_device_at=1, fault_plan="cache_evict@1")


def test_reshard_up_mid_flight_bitwise(clean_done):
    # devices RETURN: reshard 2 -> 4 slots mid-run, everything bitwise
    eng = StencilServingEngine(_dom(), batch_size=2,
                               fault_plan="device_loss@1:reshard_to=4")
    done = eng.run(_reqs())
    h = eng.health()
    assert eng.B == 4 and h["reshards"] == 1
    assert eng.cache_stats()["misses"] == 2   # one re-trace at B=4
    for uid in done:
        _assert_bitwise(done[uid], clean_done[uid])


def test_engine_slot_reusable_after_quarantine(clean_done):
    eng = StencilServingEngine(_dom(), batch_size=2,
                               fault_plan="nan_poison@1:slot=0")
    done = eng.run(_reqs())
    assert eng.health()["quarantines"] == 1
    assert not eng.slots.any_live()
    # the quarantined slot serves fresh work on the next run, clean
    done2 = eng.run([_req(10, X, Y, 2)])
    assert done2[10].status == "done"
    ref = StencilServingEngine(_dom(), batch_size=2).run([_req(10, X, Y, 2)])
    _assert_bitwise(done2[10], ref[10])


def test_health_surface_shape():
    eng = StencilServingEngine(_dom(), batch_size=2,
                               fault_plan="cache_evict@1")
    eng.run(_reqs())
    h = eng.health()
    for key in ("faults_injected", "faults_skipped", "device_losses",
                "quarantines", "rollbacks", "retries", "degradations",
                "reshards", "cache_evictions", "snapshots", "transitions",
                "plan", "exchange", "quarantined_uids", "cache"):
        assert key in h, key
    assert h["plan"] == "cache_evict@1"


# -- retry / ladder / injector units ---------------------------------------

def test_retry_with_backoff_discipline():
    sleeps, tries = [], []

    def flaky():
        tries.append(1)
        if len(tries) < 3:
            raise ExchangeStalled("transient")
        return "ok"

    assert retry_with_backoff(flaky, max_retries=3, backoff_s=0.1,
                              sleeper=sleeps.append) == "ok"
    assert len(tries) == 3 and sleeps == [0.1, 0.2]

    def always():
        raise ExchangeStalled("stuck")

    with pytest.raises(ExchangeStalled):
        retry_with_backoff(always, max_retries=2, backoff_s=0.0)

    def broken():
        raise RuntimeError("not a stall")

    with pytest.raises(RuntimeError, match="not a stall"):
        retry_with_backoff(broken, max_retries=5)
    with pytest.raises(ValueError, match="max_retries"):
        retry_with_backoff(flaky, max_retries=-1)


def test_degradation_ladder():
    lad = DegradationLadder()
    assert lad.rungs == DEFAULT_LADDER and lad.current == "remote_dma"
    assert lad.degrade("stall") == "collective"
    assert lad.transitions == ["remote_dma -> collective (stall)"]
    with pytest.raises(RecoveryExhausted):
        lad.degrade("stall again")
    assert "EXHAUSTED" in lad.transitions[-1]
    with pytest.raises(ValueError, match="start rung"):
        DegradationLadder(start="smoke_signals")
    with pytest.raises(ValueError, match="at least one"):
        DegradationLadder(rungs=())


def test_injector_stall_arming_and_counters():
    inj = FaultInjector(FaultPlan.parse(
        "exchange_stall@0:stalls=2,rung=remote_dma"))
    [(idx, f)] = inj.due(0)
    inj.arm_stall(idx, f)
    inj.mark_fired(idx)
    with pytest.raises(ExchangeStalled):
        inj.poll_stall("remote_dma")
    # degrading PAST the faulted transport clears the armed stall: the
    # fallback does not share the faulted engine's failure
    inj.poll_stall("collective")
    inj.poll_stall("remote_dma")
    with pytest.raises(KeyError, match="unknown health counter"):
        inj.record("optimism")
    assert inj.due(0) == []                   # fired faults are consumed


# -- the distributed-run layer ---------------------------------------------

def test_resilient_distributed_run_degrades_bitwise():
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import compat_make_mesh
    from repro.stencil.distributed import make_distributed_step

    Xd, Yd, Zd = 6, 20, 12
    u, v, w = stratus_fields(Xd, Yd, Zd, seed=3)
    p = default_params(Zd)
    mesh = compat_make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P(None, "data", None))
    uu, vv, ww = (np.asarray(a) for a in (u, v, w))

    step = make_distributed_step(mesh, p, T=1, dt=DT)
    cu, cv, cw = uu, vv, ww
    for _ in range(3):
        cu, cv, cw = step(*(jnp.asarray(a) for a in (cu, cv, cw)))

    inj = FaultInjector(FaultPlan.parse(
        "exchange_stall@1:stalls=5,rung=remote_dma;"
        "nan_poison@2:persistent=false"))
    (ru, rv, rw), inj = resilient_distributed_run(
        mesh, p, jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ww),
        n_blocks=3, T=1, dt=DT, injector=inj,
        ladder=DegradationLadder(start="remote_dma"), max_retries=1)
    h = inj.health()
    assert h["retries"] == 1 and h["degradations"] == 1
    # nan_poison is injected at the exchange layer now: the guard detects
    # the non-finite rows and the block replays clean from its snapshot
    assert h["faults_skipped"] == 0 and h["faults_injected"] == 2
    assert h["rollbacks"] == 1 and h["snapshots"] >= 1
    for got, ref in ((ru, cu), (rv, cv), (rw, cw)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    # a stall on the LAST rung exhausts the ladder and propagates
    with pytest.raises(RecoveryExhausted):
        resilient_distributed_run(
            mesh, p, jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ww),
            n_blocks=2, T=1, dt=DT, max_retries=0,
            injector=FaultInjector(FaultPlan.parse(
                "exchange_stall@0:stalls=9,rung=collective")),
            ladder=DegradationLadder(start="collective"))


def _one_shard_setup(seed=3):
    from repro.launch.mesh import compat_make_mesh
    from repro.stencil.distributed import make_distributed_step

    Xd, Yd, Zd = 6, 20, 12
    u, v, w = stratus_fields(Xd, Yd, Zd, seed=seed)
    p = default_params(Zd)
    mesh = compat_make_mesh((1,), ("data",))
    uu, vv, ww = (np.asarray(a) for a in (u, v, w))
    step = make_distributed_step(mesh, p, T=1, dt=DT)
    cu, cv, cw = uu, vv, ww
    for _ in range(3):
        cu, cv, cw = step(*(jnp.asarray(a) for a in (cu, cv, cw)))
    return mesh, p, (uu, vv, ww), (cu, cv, cw)


def test_resilient_run_persistent_poison_exhausts_replays():
    mesh, p, (uu, vv, ww), _ = _one_shard_setup()
    inj = FaultInjector(FaultPlan.parse("nan_poison@1"))  # persistent
    with pytest.raises(RecoveryExhausted, match="persists after"):
        resilient_distributed_run(
            mesh, p, jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ww),
            n_blocks=3, T=1, dt=DT, injector=inj, max_replays=2)
    h = inj.health()
    assert h["rollbacks"] == 2 and h["faults_injected"] == 3


def test_resilient_run_all_kinds_on_one_shard_bitwise(tmp_path):
    """Every FAULT_KINDS member is applied (never skipped) at the
    exchange layer, even on a 1-shard mesh where halo_corruption
    degenerates to an edge-row poison; disk-backed snapshots make the
    rollbacks atomic on-disk, and the final fields are bitwise-equal to
    the clean run."""
    mesh, p, (uu, vv, ww), (cu, cv, cw) = _one_shard_setup()
    inj = FaultInjector(FaultPlan.parse(
        "halo_corruption@0;nan_poison@1:persistent=false;"
        "cache_evict@1;device_loss@2:reshard_to=1;"
        "exchange_stall@2:stalls=1,rung=remote_dma"))
    (ru, rv, rw), inj = resilient_distributed_run(
        mesh, p, jnp.asarray(uu), jnp.asarray(vv), jnp.asarray(ww),
        n_blocks=3, T=1, dt=DT, injector=inj,
        ladder=DegradationLadder(start="remote_dma"),
        checkpoint_dir=str(tmp_path), max_retries=2)
    h = inj.health()
    assert h["faults_injected"] == 5 and h["faults_skipped"] == 0
    assert h["rollbacks"] == 2        # halo_corruption + nan_poison
    assert h["cache_evictions"] == 1 and h["reshards"] == 1
    assert h["device_losses"] == 1 and h["retries"] == 1
    for got, ref in ((ru, cu), (rv, cv), (rw, cw)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_retry_with_backoff_cap_and_jitter():
    def make_flaky(n):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] <= n:
                raise ExchangeStalled("transient")
            return "ok"

        return flaky

    sleeps = []
    assert retry_with_backoff(make_flaky(4), max_retries=5, backoff_s=0.1,
                              max_backoff_s=0.25,
                              sleeper=sleeps.append) == "ok"
    assert sleeps == [0.1, 0.2, 0.25, 0.25]     # capped, never unbounded

    seqs = []
    for _ in range(2):
        sleeps = []
        retry_with_backoff(make_flaky(3), max_retries=4, backoff_s=0.1,
                           jitter_seed=7, sleeper=sleeps.append)
        seqs.append(sleeps)
    assert seqs[0] == seqs[1]                   # seeded jitter: determinism
    rng = np.random.default_rng(7)
    expect = [0.1 * 2 ** k * (0.5 + 0.5 * float(rng.random()))
              for k in range(3)]
    assert seqs[0] == expect
    for k, s in enumerate(seqs[0]):             # jitter stays in [1/2, 1]x
        assert 0.05 * 2 ** k <= s <= 0.1 * 2 ** k

    with pytest.raises(ValueError, match="max_backoff_s"):
        retry_with_backoff(make_flaky(0), max_backoff_s=-1.0)


# -- FaultPlan property tests (hypothesis via the _prop shim) ---------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_fault_plan_random_roundtrips_for_any_seed(seed):
    plan = FaultPlan.random(seed, n_steps=7, batch=4, n_faults=5,
                            kinds=FAULT_KINDS)
    assert FaultPlan.parse(plan.describe()).describe() == plan.describe()
    assert all(f.kind in FAULT_KINDS for f in plan.faults)


@settings(max_examples=25, deadline=None)
@given(kind=st.sampled_from(FAULT_KINDS),
       at_step=st.integers(min_value=0, max_value=99),
       slot=st.integers(min_value=0, max_value=7),
       field=st.sampled_from(("u", "v", "w")),
       mode=st.sampled_from(("nan", "inf")),
       depth=st.integers(min_value=1, max_value=4),
       persistent=st.booleans())
def test_fault_describe_parse_roundtrip_all_kinds(kind, at_step, slot,
                                                  field, mode, depth,
                                                  persistent):
    f = Fault(kind=kind, at_step=at_step, slot=slot, field=field,
              mode=mode, depth=depth, persistent=persistent)
    plan = FaultPlan(faults=(f,))
    back = FaultPlan.parse(plan.describe())
    assert back.faults == plan.faults
    assert back.describe() == plan.describe()


@pytest.mark.parametrize("spec,token", [
    ("nan_poison", "nan_poison"),                   # missing @step
    ("nan_poison@soon", "'soon'"),                  # non-integer step
    ("nan_poison@1:slot", "'slot'"),                # option without =
    ("nan_poison@1:turbo=3", "'turbo'"),            # unknown key
    ("nan_poison@1:slot=much", "'much'"),           # bad value
    ("warp_core_breach@1", "warp_core_breach"),     # unknown kind
])
def test_fault_plan_parse_malformed_names_offending_token(spec, token):
    with pytest.raises(ValueError, match="expected|unknown|bad fault") as ei:
        FaultPlan.parse(spec)
    assert token in str(ei.value)


# -- the dataflow leak fix (core/dataflow.py) ------------------------------

def test_pipeline_leak_is_loud_not_silent(caplog):
    """A consumer stage that dies leaves its producer blocked forever on
    the bounded inter-stage queue (depth 1: one parked item fills it).
    The drain must re-raise the stage error AND log the leaked worker —
    never return as if the run were clean."""

    def dies(x):
        raise RuntimeError("consumer died")

    pipe = Pipeline([Stage("produce", lambda x: x, depth=8),
                     Stage("consume", dies, depth=1)], join_timeout=0.2)
    with caplog.at_level(logging.ERROR, logger="repro.core.dataflow"):
        with pytest.raises(RuntimeError, match="consumer died"):
            pipe.run([0, 1, 2])
    assert any("leaked" in rec.message and "produce" in str(rec.args)
               for rec in caplog.records)


def test_pipeline_join_timeout_validation_and_clean_run():
    with pytest.raises(ValueError, match="join_timeout"):
        Pipeline([Stage("a", lambda x: x)], join_timeout=0.0)
    out = Pipeline([Stage("a", lambda x: x + 1),
                    Stage("b", lambda x: x * 2)]).run([1, 2, 3])
    assert out == [4, 6, 8]


# -- SlotManager fault-path edges ------------------------------------------

def test_slot_manager_rejects_fault_path_misuse():
    sm = SlotManager(2)
    with pytest.raises(ValueError, match="not live"):
        sm.release(0)                         # release of a dead slot
    with pytest.raises(ValueError, match="not live"):
        sm.tick(1)
    sm.occupy(0, object(), 2)
    with pytest.raises(ValueError, match="already live"):
        sm.occupy(0, object(), 1)             # double occupy
    sm.release(0)
    with pytest.raises(ValueError, match="not live"):
        sm.release(0)                         # double release

"""HLO-text collective inventory (`core.hlo`): checked-in HLO fixtures
pin the ring-algorithm wire accounting, replica-group span classification
(ici vs dcn under `pod_size`), async -start/-done dedup, and the op
census — no device or compile needed, the module is pure text analysis.
"""
import pytest

from repro.core.hlo import (collective_summary, op_census,
                            parse_collectives, total_wire_bytes)

# A hand-written HLO module exercising all five collective kinds. Byte
# math: all-reduce f32[128,64] = 32768 B over a 4-group; all-gather
# f32[256] = 1024 B over iota [2,4]<=[8]; reduce-scatter f32[64] = 256 B
# over a 4-group; all-to-all f32[32,32] = 4096 B over a pair;
# collective-permute f32[8,12] = 384 B (no replica_groups -> unknown).
FIVE_KINDS = """\
HloModule jit_step

%sum {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[256]{0} all-gather(%p0), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[64]{0} reduce-scatter(%p0), replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%sum
  %a2a = f32[32,32]{1,0} all-to-all(%p0), replica_groups={{0,1}}, dimensions={0}
  %cp = f32[8,12]{1,0} collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
}
"""


def test_ring_accounting_per_kind():
    ops = {o.name: o for o in parse_collectives(FIVE_KINDS)}
    assert set(ops) == {"ar", "ag", "rs", "a2a", "cp"}
    # all-reduce: 2(n-1)/n * B = 2 * 3/4 * 32768
    assert ops["ar"].out_bytes == 32768 and ops["ar"].wire_bytes == 49152.0
    # all-gather: (n-1)/n * B_out = 3/4 * 1024, group size from iota form
    assert ops["ag"].group_size == 4 and ops["ag"].wire_bytes == 768.0
    # reduce-scatter: (n-1) * B_out = 3 * 256
    assert ops["rs"].wire_bytes == 768.0
    # all-to-all: (n-1)/n * B = 1/2 * 4096
    assert ops["a2a"].group_size == 2 and ops["a2a"].wire_bytes == 2048.0
    # collective-permute: B, and no replica_groups means span unknown
    assert ops["cp"].wire_bytes == 384.0
    assert ops["cp"].group_span == "unknown"


def test_span_classification_and_filtered_totals():
    # pod_size=0 (unknown topology): grouped ops default to ici
    assert all(o.group_span == "ici" for o in parse_collectives(FIVE_KINDS)
               if o.name != "cp")
    # pod_size=2: the 4-wide groups straddle pods, the pair {0,1} does not
    ops = parse_collectives(FIVE_KINDS, pod_size=2)
    spans = {o.name: o.group_span for o in ops}
    assert spans == {"ar": "dcn", "ag": "dcn", "rs": "dcn",
                     "a2a": "ici", "cp": "unknown"}
    assert total_wire_bytes(ops, span="ici") == 2048.0
    assert total_wire_bytes(ops, span="dcn") == 49152.0 + 768.0 + 768.0
    assert total_wire_bytes(ops) == pytest.approx(53120.0)


def test_iota_groups_pod_size_boundary():
    # iota [2,4]<=[8]: stride 1, span 4 — intra-pod iff pod_size >= 4
    line = ("%ag = f32[256]{0} all-gather(%p0), "
            "replica_groups=[2,4]<=[8], dimensions={0}\n")
    assert parse_collectives(line, pod_size=8)[0].group_span == "ici"
    assert parse_collectives(line, pod_size=4)[0].group_span == "ici"
    assert parse_collectives(line, pod_size=2)[0].group_span == "dcn"


def test_explicit_groups_pod_size_boundary():
    # {0,4} stays in one 8-chip pod but crosses 4-chip pods
    line = ("%ar2 = f32[16]{0} all-reduce(%p0), "
            "replica_groups={{0,4},{1,5}}, to_apply=%sum\n")
    ici = parse_collectives(line, pod_size=8)[0]
    dcn = parse_collectives(line, pod_size=4)[0]
    assert ici.group_span == "ici" and dcn.group_span == "dcn"
    assert ici.group_size == 2
    assert ici.wire_bytes == 64.0       # 2 * 1/2 * 64 B


def test_async_start_counted_done_skipped_and_name_dedup():
    text = """\
  %all-gather-start.3 = (f32[64]{0}, f32[256]{0}) all-gather-start(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %all-gather-done.3 = f32[256]{0} all-gather-done(%all-gather-start.3)
  %ar = f32[16]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%sum
  %ar = f32[16]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%sum
"""
    ops = parse_collectives(text)
    # -done carries no new bytes; the duplicated %ar line is deduped
    assert [o.name for o in ops] == ["all-gather-start.3", "ar"]
    # tuple output (f32[64], f32[256]) = 1280 B, 4-ring: 3/4 * 1280
    assert ops[0].out_bytes == 1280 and ops[0].wire_bytes == 960.0


def test_malformed_lines_are_ignored():
    text = """\
this line is not HLO at all
  %weird = all-reduce
  all-gather without an assignment
  %ok = f32[8]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%sum
"""
    ops = parse_collectives(text)
    assert [o.name for o in ops] == ["ok"]
    assert ops[0].wire_bytes == 32.0    # 2 * 1/2 * 32 B


def test_collective_summary_keys_and_counts():
    summary = collective_summary(parse_collectives(FIVE_KINDS, pod_size=2))
    assert summary["all-reduce/dcn"] == {"count": 1, "wire_bytes": 49152.0}
    assert summary["all-to-all/ici"] == {"count": 1, "wire_bytes": 2048.0}
    assert summary["collective-permute/unknown"]["count"] == 1
    assert sum(v["count"] for v in summary.values()) == 5


def test_op_census_counts_compute_and_layout_ops():
    text = """\
  %d = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}
  %f = f32[8,8]{1,0} fusion(%a), kind=kLoop, calls=%fused_computation
  %c = f32[8,8]{1,0} copy(%a)
  %t = f32[8,8]{1,0} transpose(%a), dimensions={1,0}
  %ar = f32[8,8]{1,0} all-reduce(%a), replica_groups={{0,1}}, to_apply=%sum
"""
    census = op_census(text)
    assert census["dot"] == 1
    assert census["fusion"] == 1
    assert census["layout_change"] == 2         # copy + transpose
    assert census["all-reduce"] == 1
    assert op_census("no ops here\n") == {}

"""Fault tolerance: checkpoint atomicity, auto-resume, elastic TP restore,
NaN-guard, deterministic data on restart."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pspec
from repro.config import RunShape
from repro.configs import get_smoke_config
from repro.data.pipeline import synth_batch
from repro.launch.train import train_loop
from repro.models import model as M
from repro.training import checkpoint as CKPT
from repro.training import step as TS
from repro.training.optimizer import OptConfig


def tiny_cfg():
    return get_smoke_config("qwen3_32b")


def test_save_restore_roundtrip(tmp_path):
    cfg = tiny_cfg()
    layout = M.make_layout(cfg, 1)
    state = TS.init_state(cfg, layout, jax.random.PRNGKey(0))
    CKPT.save(tmp_path, state, 7, cfg=cfg, layout=layout)
    assert CKPT.latest_step(tmp_path) == 7
    restored, step = CKPT.restore(tmp_path, state, cfg=cfg, layout=layout)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc(tmp_path):
    cfg = tiny_cfg()
    layout = M.make_layout(cfg, 1)
    state = TS.init_state(cfg, layout, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4, 5):
        CKPT.save(tmp_path, state, s, keep_last=2)
    dirs = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert dirs == ["step_000000004", "step_000000005"]
    assert CKPT.latest_step(tmp_path) == 5


def test_corrupt_tmp_never_visible(tmp_path):
    """A crashed save (leftover tmp dir) must not affect LATEST."""
    cfg = tiny_cfg()
    layout = M.make_layout(cfg, 1)
    state = TS.init_state(cfg, layout, jax.random.PRNGKey(0))
    CKPT.save(tmp_path, state, 3)
    (tmp_path / ".tmp_step_000000009_999").mkdir()
    assert CKPT.latest_step(tmp_path) == 3
    restored, step = CKPT.restore(tmp_path, state)
    assert step == 3


def test_truncated_checkpoint_raises_corrupted_naming_path(tmp_path):
    """A torn arrays.npz (half-written before a crash) must surface as
    CheckpointCorrupted naming the path — never a raw zipfile/EOF
    traceback the on-call has to reverse-engineer."""
    state = {"x": np.arange(12, dtype=np.float32)}
    CKPT.save(tmp_path, state, 4)
    npz = tmp_path / "step_000000004" / "arrays.npz"
    blob = npz.read_bytes()
    npz.write_bytes(blob[:len(blob) // 2])        # simulate a torn write
    with pytest.raises(CKPT.CheckpointCorrupted, match="truncated") as ei:
        CKPT.restore(tmp_path, state)
    assert str(npz) in str(ei.value)

    # a checkpoint missing its arrays file entirely: FileNotFoundError
    # naming the path (it is absent, not damaged)
    npz.unlink()
    with pytest.raises(FileNotFoundError, match="arrays"):
        CKPT.restore(tmp_path, state, step=4)


def test_latest_step_ignores_partial_writes(tmp_path):
    """`latest_step` only ever returns COMPLETE checkpoints: a foreign
    step_* directory without both published files is skipped, and a
    stale LATEST pointer at such a directory falls back to the newest
    complete step instead of None."""
    state = {"x": np.arange(4, dtype=np.float32)}
    CKPT.save(tmp_path, state, 2)
    # a partial copy: directory exists, manifest only (no arrays.npz)
    partial = tmp_path / "step_000000008"
    partial.mkdir()
    (partial / "manifest.json").write_text("{}")
    assert CKPT.latest_step(tmp_path) == 2
    # point LATEST at the partial dir: scan fallback still finds step 2
    (tmp_path / "LATEST").write_text(partial.name)
    assert CKPT.latest_step(tmp_path) == 2
    restored, step = CKPT.restore(tmp_path, state)
    assert step == 2
    np.testing.assert_array_equal(restored["x"], state["x"])
    # nothing complete at all -> None
    import shutil
    shutil.rmtree(tmp_path / "step_000000002")
    assert CKPT.latest_step(tmp_path) is None


def test_elastic_restore_across_tp(tmp_path):
    """Save under tp=1, restore under tp=4 (padded heads): loss identical."""
    cfg = tiny_cfg()
    lo1, lo4 = M.make_layout(cfg, 1), M.make_layout(cfg, 4)
    state1 = TS.init_state(cfg, lo1, jax.random.PRNGKey(0))
    CKPT.save(tmp_path, state1, 1, cfg=cfg, layout=lo1)
    like4 = jax.tree.map(
        lambda s: np.zeros(s.shape, s.dtype),
        pspec.abstract_params(TS.state_specs(cfg, lo4)))
    state4, _ = CKPT.restore(tmp_path, like4, cfg=cfg, layout=lo4)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 33)))
    batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
    l1, _ = M.loss_fn(state1["params"], batch, cfg, lo1)
    l4, _ = M.loss_fn(jax.tree.map(jnp.asarray, state4["params"]), batch, cfg, lo4)
    assert abs(float(l1) - float(l4)) < 1e-4


def test_resume_equals_uninterrupted(tmp_path):
    """Train 6 steps straight == train 3, 'crash', resume 3 (same data/state)."""
    cfg = tiny_cfg()
    opt = OptConfig(peak_lr=1e-3, warmup_steps=0, total_steps=6)
    _, hist_full, _ = train_loop(cfg, steps=6, batch=2, seq=32, opt=opt,
                                 log_every=0, seed=99)
    d = tmp_path / "ck"
    _, h1, _ = train_loop(cfg, steps=3, batch=2, seq=32, opt=opt,
                          ckpt_dir=d, ckpt_every=3, log_every=0, seed=99)
    _, h2, _ = train_loop(cfg, steps=6, batch=2, seq=32, opt=opt,
                          ckpt_dir=d, ckpt_every=3, log_every=0, seed=99)
    resumed = h1 + h2
    np.testing.assert_allclose(hist_full, resumed, rtol=2e-4, atol=2e-4)


def test_nan_guard_skips_poisoned_step():
    # vlm smoke config: float embeds input, so the batch poisoning hook bites
    cfg = get_smoke_config("qwen2_vl_72b")
    opt = OptConfig(peak_lr=1e-3, warmup_steps=0, total_steps=5)
    state, hist, info = train_loop(cfg, steps=5, batch=2, seq=32, opt=opt,
                                   log_every=0, inject_nan_at=2)
    assert info["skipped"] == 1
    assert len(hist) == 4
    assert all(np.isfinite(h) for h in hist)
    # training state survived the poisoned batch
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(state["params"]))


def test_synth_batch_deterministic():
    cfg = tiny_cfg()
    shape = RunShape("t", "train", 32, 4)
    a = synth_batch(cfg, shape, 17, seed=5)
    b = synth_batch(cfg, shape, 17, seed=5)
    c = synth_batch(cfg, shape, 18, seed=5)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    assert not np.array_equal(a["inputs"], c["inputs"])

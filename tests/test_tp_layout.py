"""TP head-layout equivalence + hypothesis properties of HeadLayout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro import pspec
from repro.configs import get_smoke_config
from repro.distributed.sharding import make_head_layout
from repro.models import model as M
from repro.models import relayout as R


@settings(max_examples=200, deadline=None)
@given(kv=st.integers(1, 32), mult=st.integers(1, 16),
       tp=st.sampled_from([1, 2, 4, 8, 16, 32]))
def test_head_layout_invariants(kv, mult, tp):
    n_q = kv * mult
    lo = make_head_layout(n_q, kv, tp)
    assert lo.n_kv_stored % tp == 0 or tp == 1
    assert lo.n_q_stored == lo.n_kv_stored * lo.q_per_group
    mask = lo.q_head_mask()
    assert int(mask.sum()) == n_q                      # all logical heads live
    idx = lo.q_gather_index()
    live = idx[mask.astype(bool)]
    assert sorted(live.tolist()) == list(range(n_q))   # exactly once each
    kvi = lo.kv_gather_index()
    assert (kvi[: lo.n_kv_stored - lo.n_kv_dead] < kv).all()


@pytest.mark.parametrize("arch,tp", [("qwen2_5_14b", 4), ("qwen3_32b", 4),
                                     ("whisper_large_v3", 4),
                                     ("recurrentgemma_9b", 4),
                                     ("arctic_480b", 2)])
def test_forward_equivalence_across_tp(arch, tp):
    cfg = get_smoke_config(arch)
    lo1 = M.make_layout(cfg, 1)
    loN = M.make_layout(cfg, tp)
    p1 = pspec.init_params(M.param_specs(cfg, lo1), jax.random.PRNGKey(0))
    pN = R.from_logical(p1, cfg, loN)
    # stored shapes match the tp-layout specs
    sN = M.param_specs(cfg, loN)
    for a, s in zip(jax.tree.leaves(pN),
                    jax.tree.leaves(sN, is_leaf=pspec.is_spec)):
        assert tuple(a.shape) == tuple(s.shape)
    rng = np.random.default_rng(2)
    B, S = 2, 32
    if cfg.family == "encdec":
        batch = {"enc_embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                 "dec_inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)}
    else:
        batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    f1, _, _ = M.forward(p1, batch, cfg, lo1)
    fN, _, _ = M.forward(pN, batch, cfg, loN)
    V = cfg.vocab_size
    err = float(jnp.max(jnp.abs(f1[..., :V] - fN[..., :V])))
    assert err < 1e-4, (arch, err)
    # roundtrip is exact
    back = R.to_logical(pN, cfg, loN)
    rt = max(float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(back)))
    assert rt == 0.0

"""Launch-layer unit tests: input specs, exec policy, shardings — all 40
(arch x shape) cells, no compilation (structural invariants only)."""
import math

import jax
import numpy as np
import pytest

from repro import pspec
from repro.config import ALL_SHAPES, SHAPES, supports
from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import make_rules, spec_for
from repro.launch import specs as SP
from repro.launch.dryrun import _cost_cfg, _layer_multiplier, exec_policy
from repro.models import model as M


class FakeMesh:
    shape = {"pod": 2, "data": 16, "model": 16}


CELLS = [(a, s.name) for a in ARCH_IDS for s in ALL_SHAPES]


@pytest.mark.parametrize("arch,shape", CELLS)
def test_input_specs_shardable(arch, shape):
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if not supports(cfg, sh):
        assert shape == "long_500k" and not cfg.sub_quadratic
        return
    specs, axes = SP.input_specs(cfg, sh)
    assert specs, (arch, shape)
    rules = make_rules(multi_pod=True)
    for k, s in specs.items():
        spec = spec_for(s.shape, axes[k], rules, FakeMesh())
        # every sharded dim must divide evenly (jit-input requirement)
        for dim, p in zip(s.shape, spec):
            if p is None:
                continue
            parts = p if isinstance(p, tuple) else (p,)
            total = int(np.prod([FakeMesh.shape[a] for a in parts]))
            assert dim % total == 0, (arch, shape, k, dim, p)


@pytest.mark.parametrize("arch,shape", CELLS)
def test_param_and_cache_specs_shardable(arch, shape):
    cfg0 = get_config(arch)
    sh = SHAPES[shape]
    if not supports(cfg0, sh):
        return
    cfg = exec_policy(cfg0, sh)
    layout = M.make_layout(cfg, 16)
    rules = make_rules(multi_pod=False, seq_parallel=cfg.seq_parallel)
    trees = [M.param_specs(cfg, layout)]
    if sh.kind == "decode":
        trees.append(M.cache_specs(cfg, layout, sh.global_batch, sh.seq_len))
    for tree in trees:
        for s in jax.tree.leaves(tree, is_leaf=pspec.is_spec):
            spec = spec_for(s.shape, s.axes, rules, FakeMesh())
            for dim, p in zip(s.shape, spec):
                if p is None:
                    continue
                parts = p if isinstance(p, tuple) else (p,)
                total = int(np.prod([FakeMesh.shape[a] for a in parts]))
                assert dim % total == 0, (arch, shape, s.shape, s.axes)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cost_cfg_differential_consistency(arch):
    """_cost_cfg(n) must scale layer counts so that differential costing's
    unit x multiplier reconstructs the full stack."""
    cfg = get_config(arch)
    c1, c2 = _cost_cfg(cfg, 1), _cost_cfg(cfg, 2)
    mult = _layer_multiplier(cfg)
    if cfg.family == "encdec":
        per_unit = (c2.encdec.enc_layers - c1.encdec.enc_layers)
        total = cfg.encdec.enc_layers
    else:
        per_unit = c2.n_layers - c1.n_layers
        total = cfg.n_layers
    assert per_unit > 0
    assert abs(per_unit * mult - total) < per_unit, (arch, per_unit, mult)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exec_policy_train_serving_split(arch):
    cfg = get_config(arch)
    tr = exec_policy(cfg, SHAPES["train_4k"])
    assert tr.remat == "full" and tr.seq_parallel
    de = exec_policy(cfg, SHAPES["decode_32k"])
    assert de.remat == "none" and not de.seq_parallel
    cost = exec_policy(_cost_cfg(cfg, 1), SHAPES["train_4k"], for_cost=True)
    assert not cost.scan_layers and cost.attention_impl == "dense"


def test_long_500k_skip_rules():
    runs = [a for a in ARCH_IDS if supports(get_config(a), SHAPES["long_500k"])]
    assert sorted(runs) == ["falcon_mamba_7b", "recurrentgemma_9b"]


def test_make_batch_matches_specs():
    cfg = get_config("qwen3_32b")
    for sh in ALL_SHAPES:
        if not supports(cfg, sh):
            continue
        b = SP.make_batch(cfg, sh, batch=2, seq=64)
        specs, _ = SP.input_specs(cfg, sh)
        assert set(b) == set(specs)

"""Flash-attention Pallas kernel + custom-VJP JAX mirror: sweeps vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.attention import flash_attention, vmem_bytes
from repro.kernels.attention.ref import mha_ref
from repro.models import layers as L

CASES = [
    # B, H, Hkv, S, D, causal, dtype
    (2, 4, 2, 256, 64, True, jnp.float32),
    (1, 8, 1, 128, 32, True, jnp.bfloat16),
    (2, 4, 4, 512, 64, False, jnp.float32),
    (1, 2, 2, 384, 128, True, jnp.float32),
    (1, 6, 2, 256, 64, True, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,Hkv,S,D,causal,dt", CASES)
def test_flash_kernel_vs_ref(B, H, Hkv, S, D, causal, dt):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), dt)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dt)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dt)
    out = flash_attention(q, k, v, causal=causal)
    ref = mha_ref(q, k, v, causal=causal)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
    assert err < tol, err


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_block_shapes(bq, bk):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = mha_ref(q, k, v)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_vmem_budget():
    """Default blocks fit comfortably in v5e VMEM (16 MiB)."""
    assert vmem_bytes(128, 128, 128) < 4 * 2**20


def test_flash_vjp_matches_dense():
    rng = np.random.default_rng(2)
    B, S, K, G, D = 2, 64, 2, 3, 16
    q = jnp.asarray(rng.normal(size=(B, S, K, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    pos = jnp.arange(S)

    def f_ref(q, k, v):
        return (L.attn_dense(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                             scale=0.25) ** 2).sum()

    def f_flash(q, k, v):
        return (L.attn_flash(q, k, v, pos, pos, True, 0.25, 16) ** 2).sum()

    g1 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4


def test_chunked_equals_dense_forward():
    rng = np.random.default_rng(3)
    B, S, K, G, D = 1, 96, 2, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, K, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    pos = jnp.arange(S)
    a = L.attn_dense(q, k, v, q_pos=pos, kv_pos=pos, causal=True, scale=0.3)
    b = L.attn_chunked(q, k, v, q_pos=pos, kv_pos=pos, causal=True, scale=0.3,
                       chunk=32)
    c = L.attn_chunked(q, k, v, q_pos=pos, kv_pos=pos, causal=True, scale=0.3,
                       chunk=32, unroll=True)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
    assert float(jnp.max(jnp.abs(b - c))) < 1e-6


def test_local_window_attention_exact():
    """Blocked sliding window == dense with a band mask."""
    rng = np.random.default_rng(4)
    B, S, K, G, D, W = 1, 64, 1, 2, 8, 16
    q = jnp.asarray(rng.normal(size=(B, S, K, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    pos = jnp.arange(S)
    a = L.attn_dense(q, k, v, q_pos=pos, kv_pos=pos, causal=True, scale=0.3,
                     window=W)
    b = L.attn_local(q, k, v, q_pos=pos, kv_pos=pos, scale=0.3, window=W)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
    # non-multiple S exercises the padding path
    S2 = 56
    a2 = L.attn_dense(q[:, :S2], k[:, :S2], v[:, :S2], q_pos=pos[:S2],
                      kv_pos=pos[:S2], causal=True, scale=0.3, window=W)
    b2 = L.attn_local(q[:, :S2], k[:, :S2], v[:, :S2], q_pos=pos[:S2],
                      kv_pos=pos[:S2], scale=0.3, window=W)
    assert float(jnp.max(jnp.abs(a2 - b2))) < 1e-5


def test_ops_wrapper_gqa_layout():
    from repro.kernels.attention.ops import gqa_layout_attention
    rng = np.random.default_rng(9)
    B, S, K, G, D = 1, 128, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, K, G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, D)), jnp.float32)
    pos = jnp.arange(S)
    out = gqa_layout_attention(q, k, v)
    ref = L.attn_dense(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                       scale=D ** -0.5)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5

"""The remote-DMA exchange engine vs the collective oracle: interpret-mode
outputs must be BITWISE equal across (nx, ny, T, dtype, overlap,
local_kernel) — the two engines assemble the same extended slab through
different transports — and the engine's counted wire bytes must match
`halo_wire_bytes_model` exactly. Multi-device sweeps use the subprocess
idiom (`tests/_subproc.run_ok`, JAX_PLATFORMS=cpu pinned); fast-tier cases
cover wiring, ring-neighbour math and the multi-hop trace contract of the
compiled DMA kernel (one `make_async_remote_copy` per `_band_schedule`
hop; the pipelined K-block driver rides tests/test_pipeline_driver.py).
"""
import textwrap

import pytest

from _subproc import run_ok as _run


# --- fast tier: wiring + pure helpers --------------------------------------

def test_remote_dma_wiring_single_device():
    """(1, 1) 'mesh': the engine dispatch, masks and trim run with no
    exchange; both engines must agree with the global oracle and each
    other. Covers both dma_block_index parities."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (make_distributed_step,
                                           reference_global_step)

    X, Y, Z = 6, 10, 8
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = make_stencil_mesh(1, 1)
    sh = NamedSharding(mesh, P("x", "y", None))
    args = [jax.device_put(t, sh) for t in (u, v, w)]
    ref = reference_global_step(u, v, w, p, T=2, dt=0.01)
    for block in (0, 1):
        fn = make_distributed_step(mesh, p, axis="y", x_axis="x", T=2,
                                   dt=0.01, local_kernel="fused",
                                   overlap=True, exchange="remote_dma",
                                   dma_block_index=block)
        out = fn(*args)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(out, ref))
        assert err < 1e-5, (block, err)


def test_band_schedule_partitions_halo():
    """Hop offsets/counts tile the hi and lo halo regions exactly —
    the recv-slab addresses the DMA kernel and emulation share."""
    from repro.stencil.distributed import _band_schedule

    for L, depth in ((8, 3), (4, 4), (4, 6), (4, 10), (3, 14), (5, 1)):
        sched = _band_schedule(L, depth)
        hi = sorted((off, off + cnt) for _, cnt, off, _ in sched)
        lo = sorted((off, off + cnt) for _, cnt, _, off in sched)
        covered = [r for span in hi for r in range(*span)]
        assert covered == list(range(depth)), (L, depth, hi)
        covered = [r for span in lo for r in range(*span)]
        assert covered == list(range(depth + L, 2 * depth + L)), (L, depth)
        assert sum(cnt for _, cnt, _, _ in sched) == depth


def test_ring_neighbor_math():
    from repro.launch.mesh import dma_neighbor_coords, ring_neighbor

    assert ring_neighbor(0, 4, -1) == 3
    assert ring_neighbor(3, 4, 1) == 0
    assert ring_neighbor(2, 4, 2) == 0
    with pytest.raises(ValueError):
        ring_neighbor(0, 0, 1)
    coords = dma_neighbor_coords(("x", "y"), (1, 3), "y", 1, 4)
    assert coords == (1, 0)
    coords = dma_neighbor_coords(("x", "y"), (0, 2), "x", -1, 2)
    assert coords == (1, 2)
    with pytest.raises(ValueError, match="not in mesh axes"):
        dma_neighbor_coords(("x",), (0,), "z", 1, 2)


def test_dma_kernel_validates_args():
    """Argument validation fails fast on any backend, before any Pallas
    construction. Depth beyond the local extent is NOT an error any more
    (multi-hop landed — `test_dma_kernel_traces_under_shard_map` traces
    it); the only remaining depth bound, T > global extent - 2, lives in
    the step/run drivers."""
    import jax.numpy as jnp

    from repro.kernels.advection.advection import halo_band_exchange_dma

    f = jnp.zeros((4, 8, 16), jnp.float32)
    with pytest.raises(ValueError, match="dim"):
        halo_band_exchange_dma(f, f, f, axis="x", mesh_axes=("x",), n=2,
                               depth=2, dim=2)
    with pytest.raises(ValueError, match="depth"):
        halo_band_exchange_dma(f, f, f, axis="x", mesh_axes=("x",), n=2,
                               depth=0, dim=0)


def test_dma_kernel_traces_under_shard_map():
    """Abstract tracing of the real `make_async_remote_copy` kernel (both
    phases, both slot parities, single- AND multi-hop depths) must succeed
    on any backend — Mosaic lowering is TPU-only, but a trace regression
    would break the compiled path silently until the next TPU run."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.advection.advection import halo_band_exchange_dma
    from repro.launch.mesh import make_stencil_mesh

    mesh = make_stencil_mesh(1, 1)
    spec = P("x", "y", None)
    # depth 10 > L=8 rows (2 hops), depth 14 > L=6 planes (3 hops)
    for dim, depth, block in ((0, 2, 0), (1, 2, 1), (1, 10, 0),
                              (0, 14, 1)):
        def local(u, v, w, dim=dim, depth=depth, block=block):
            bands = halo_band_exchange_dma(
                u, v, w, axis=("x", "y")[dim], mesh_axes=mesh.axis_names,
                n=4, depth=depth, dim=dim, block_index=block,
                collective_id=dim)
            (uh, ul), _, _ = bands
            return uh + ul
        fn = shard_map(local, mesh=mesh, in_specs=(spec,) * 3,
                       out_specs=spec, check_rep=False)
        jax.make_jaxpr(fn)(*[jnp.zeros((6, 8, 16), jnp.float32)] * 3)


def test_dma_kernel_traces_with_traced_block_index():
    """The dynamic-parity bugfix: a TRACED block counter (the pipelined
    driver's fori_loop induction variable) must flow through the recv-slot
    selection — Python-level `o[slot]` indexing would raise a
    TracerIntegerConversionError here."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.advection.advection import halo_band_exchange_dma
    from repro.launch.mesh import make_stencil_mesh

    mesh = make_stencil_mesh(1, 1)
    spec = P("x", "y", None)

    def local(u, v, w, k):
        bands = halo_band_exchange_dma(
            u, v, w, axis="y", mesh_axes=mesh.axis_names, n=4, depth=10,
            dim=1, block_index=k, collective_id=1)
        (uh, ul), _, _ = bands
        return uh + ul

    fn = shard_map(local, mesh=mesh, in_specs=(spec,) * 3 + (P(),),
                   out_specs=spec, check_rep=False)
    jax.make_jaxpr(fn)(*[jnp.zeros((6, 8, 16), jnp.float32)] * 3,
                       jnp.int32(3))


# --- slow tier: multi-device bitwise equivalence ---------------------------

BITWISE_SWEEP_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.roofline import halo_wire_bytes_model
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh

    X, Y, Z = 8, 12, 10
    p = default_params(Z)
    for dtype in (jnp.float32, jnp.bfloat16):
        u, v, w = stratus_fields(X, Y, Z, dtype=dtype)
        for nx, ny in ((2, 2), (1, 4), (4, 1)):
            mesh = make_stencil_mesh(nx, ny)
            sh = NamedSharding(mesh, P("x", "y", None))
            args = [jax.device_put(t, sh) for t in (u, v, w)]
            for T in (1, 2, 3):
                for lk, ov in (("reference", False), ("reference", True),
                               ("fused", True), ("fused", False)):
                    kw = dict(axis="y", x_axis="x", T=T, dt=0.01,
                              local_kernel=lk, overlap=ov)
                    fc = make_distributed_step(mesh, p,
                                               exchange="collective", **kw)
                    fr = make_distributed_step(mesh, p,
                                               exchange="remote_dma", **kw)
                    oc, orr = fc(*args), fr(*args)
                    # BITWISE: both engines assemble the same extended slab
                    diff = max(float(jnp.max(jnp.abs(
                        jnp.asarray(a, jnp.float32)
                        - jnp.asarray(b, jnp.float32))))
                        for a, b in zip(oc, orr))
                    assert diff == 0.0, (dtype, nx, ny, T, lk, ov, diff)
                    got = count_exchange_wire_bytes(fr, u, v, w)
                    model = halo_wire_bytes_model(X, Y, Z, u.dtype.itemsize,
                                                  nx=nx, ny=ny, T=T)
                    assert got == model, (dtype, nx, ny, T, lk, got, model)
                # against the global oracle too (f32 only: bf16 tolerance
                # is the dtype sweep's business in test_distributed_2d)
                if dtype == jnp.float32:
                    fr = make_distributed_step(mesh, p, axis="y",
                                               x_axis="x", T=T, dt=0.01,
                                               local_kernel="fused",
                                               exchange="remote_dma")
                    ref = reference_global_step(u, v, w, p, T=T, dt=0.01)
                    err = max(float(jnp.max(jnp.abs(a - b)))
                              for a, b in zip(fr(*args), ref))
                    assert err < 1e-5, (nx, ny, T, err)
    print("OK")
""")


@pytest.mark.slow
def test_remote_dma_bitwise_equals_collective_sweep():
    _run(BITWISE_SWEEP_CODE)


MULTIHOP_EMULATION_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.roofline import halo_wire_bytes_model
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import compat_make_mesh

    # Yl = 4 per shard: T=6 takes 2 band messages (hops) per side, T=10
    # takes 3 — the emulation's per-hop recv-slab offsets must reproduce
    # the collective's multi-hop concatenation bitwise, and the per-hop
    # messages must still sum to exactly the modelled wire bytes.
    X, Y, Z = 6, 16, 12
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = compat_make_mesh((4,), ("data",))
    sh = NamedSharding(mesh, P(None, "data", None))
    args = [jax.device_put(t, sh) for t in (u, v, w)]
    for T in (6, 10, 14):
        fc = make_distributed_step(mesh, p, T=T, dt=0.005,
                                   exchange="collective")
        fr = make_distributed_step(mesh, p, T=T, dt=0.005,
                                   exchange="remote_dma")
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(fc(*args), fr(*args)))
        assert diff == 0.0, (T, diff)
        got = count_exchange_wire_bytes(fr, u, v, w)
        model = halo_wire_bytes_model(X, Y, Z, 4, ny=4, T=T)
        assert got == model, (T, got, model)
        ref = reference_global_step(u, v, w, p, T=T, dt=0.005)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(fr(*args), ref))
        assert err < 1e-5, (T, err)
    print("OK")
""")


@pytest.mark.slow
def test_remote_dma_emulation_multi_hop():
    _run(MULTIHOP_EMULATION_CODE)

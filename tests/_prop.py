"""Property-testing shim: hypothesis when available, fixed-seed sweep otherwise.

`hypothesis` is an optional dependency. When it is installed, this module
re-exports the real ``given`` / ``settings`` / ``st`` unchanged, so property
tests keep their full shrinking/fuzzing behaviour. On a clean environment the
fallback degrades each ``@given(...)`` into a deterministic
``pytest.mark.parametrize`` sweep: a fixed number of examples, each drawn from
a per-example fixed-seed ``numpy`` RNG, so the suite still exercises the same
invariants (reproducibly) without the dependency.

Usage in test modules (instead of importing hypothesis directly)::

    from _prop import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as _np
    import pytest as _pytest

    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        """A draw function over a seeded numpy Generator."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: "_np.random.Generator"):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*elements: _Strategy) -> _Strategy:
            """Fixed-arity tuple of per-position strategies (hypothesis
            `st.tuples` compatible)."""
            return _Strategy(
                lambda rng: tuple(e.example(rng) for e in elements))

    st = _Strategies()

    def settings(*_args, **_kwargs):
        """No-op stand-in for hypothesis.settings (example budget is fixed)."""

        def deco(fn):
            return fn

        return deco

    def given(**strategies):
        """Degrade @given to a fixed-seed parametrize over drawn examples."""

        def deco(fn):
            names = sorted(strategies)
            cases = []
            for ex in range(_FALLBACK_EXAMPLES):
                rng = _np.random.default_rng(ex)
                drawn = tuple(strategies[k].example(rng) for k in names)
                cases.append(drawn[0] if len(names) == 1 else drawn)
            ids = [f"ex{i}" for i in range(_FALLBACK_EXAMPLES)]
            return _pytest.mark.parametrize(",".join(names), cases, ids=ids)(fn)

        return deco

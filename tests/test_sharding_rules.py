"""Sharding rules + spec_for divisibility guard + loss/layer properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.distributed.sharding import make_rules, spec_for
from repro.models import layers as L
from repro.models.model import lm_loss


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping (enough for spec_for)."""
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_rules_basic():
    r = make_rules(multi_pod=True)
    assert r["batch"] == ("pod", "data")
    assert r["ffn"] == ("model",)
    spec = spec_for((256, 4096), ("batch", None), r, MESH)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"), None)


def test_divisibility_guard_drops_axis():
    r = make_rules(multi_pod=False)
    # 40 heads don't divide 16 -> axis dropped, replicated instead of error
    spec = spec_for((40, 128), ("heads", None), r, MESH)
    assert spec == jax.sharding.PartitionSpec(None, None)
    spec2 = spec_for((64, 128), ("heads", None), r, MESH)
    assert spec2 == jax.sharding.PartitionSpec("model", None)


def test_axis_used_once():
    r = make_rules(multi_pod=False)
    # both dims map to model -> second use dropped
    spec = spec_for((64, 64), ("heads", "ffn"), r, MESH)
    assert spec == jax.sharding.PartitionSpec("model", None)


@settings(max_examples=100, deadline=None)
@given(dims=st.lists(st.integers(1, 512), min_size=1, max_size=4),
       names=st.lists(st.sampled_from(["batch", "heads", "ffn", "embed",
                                       "vocab", None]), min_size=1, max_size=4))
def test_spec_for_never_crashes_and_divides(dims, names):
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    r = make_rules(multi_pod=True)
    spec = spec_for(tuple(dims), tuple(names), r, MESH)
    for d, p in zip(dims, spec):
        if p is None:
            continue
        axes = p if isinstance(p, tuple) else (p,)
        total = int(np.prod([MESH.shape[a] for a in axes]))
        assert d % total == 0


# --- loss & layer properties ------------------------------------------------

def test_lm_loss_uniform_logits():
    V = 128
    logits = jnp.zeros((2, 8, V))
    tgt = jnp.zeros((2, 8), jnp.int32)
    loss = lm_loss(logits, tgt, z_loss=0.0)
    assert abs(float(loss) - np.log(V)) < 1e-5


def test_lm_loss_masking():
    V = 64
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(1, 6, V)), jnp.float32)
    tgt = jnp.asarray([[1, 2, 3, -1, -1, -1]], jnp.int32)
    full = lm_loss(logits, tgt, z_loss=0.0)
    half = lm_loss(logits[:, :3], tgt[:, :3], z_loss=0.0)
    assert abs(float(full) - float(half)) < 1e-6


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 500))
def test_rope_preserves_norm(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    y = L.apply_rope(x, pos, 10_000.0)
    nx = jnp.linalg.norm(x.reshape(-1, 16), axis=-1)
    ny = jnp.linalg.norm(y.reshape(-1, 16), axis=-1)
    np.testing.assert_allclose(np.asarray(nx), np.asarray(ny), rtol=1e-4)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(16,)), jnp.float32)

    def dot_at(i, j):
        qq = L.apply_rope(q.reshape(1, 1, 1, 16),
                          jnp.array([[i]]), 100.0)
        kk = L.apply_rope(k.reshape(1, 1, 1, 16),
                          jnp.array([[j]]), 100.0)
        return float(jnp.sum(qq * kk))

    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-5  # actually varies


def test_mrope_collapses_to_rope_on_equal_positions():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    pos3 = jnp.broadcast_to(pos[..., None], (1, 8, 3))
    a = L.apply_rope(x, pos, 10_000.0, mrope=False)
    b = L.apply_rope(x, pos3, 10_000.0, mrope=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

"""Selective-scan Pallas kernel: shape/dtype/chunk sweeps vs the sequential
oracle, plus integration with the mamba block's math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssm.ops import mamba_scan, pick_chunk
from repro.kernels.ssm.ref import selective_scan_ref
from repro.kernels.ssm.ssm import selective_scan, vmem_bytes

CASES = [
    # B, S, D, N, chunk
    (2, 64, 16, 8, 16),
    (1, 128, 32, 4, 32),
    (2, 96, 8, 16, 48),
    (1, 64, 16, 16, 64),   # single chunk
]


def make(B, S, D, N, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(B, S, D)), dtype),
            jnp.asarray(np.abs(rng.normal(size=(B, S, D))) * 0.1, dtype),
            jnp.asarray(rng.normal(size=(B, S, N)), dtype),
            jnp.asarray(rng.normal(size=(B, S, N)), dtype),
            jnp.asarray(-np.abs(rng.normal(size=(D, N))), jnp.float32),
            jnp.asarray(rng.normal(size=(B, D, N)) * 0.1, jnp.float32))


@pytest.mark.parametrize("B,S,D,N,chunk", CASES)
def test_matches_sequential_oracle(B, S, D, N, chunk):
    args = make(B, S, D, N)
    y, h = selective_scan(*args, chunk=chunk)
    yr, hr = selective_scan_ref(*args)
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-4
    assert float(jnp.max(jnp.abs(h - hr))) < 1e-4


def test_bf16_inputs():
    args = make(1, 64, 16, 8, seed=3, dtype=jnp.bfloat16)
    y, h = selective_scan(*args, chunk=16)
    yr, hr = selective_scan_ref(*args)
    assert float(jnp.max(jnp.abs(y - yr))) < 5e-2


def test_state_carries_across_chunks():
    """Running two half-length scans chained == one full scan."""
    args = make(1, 64, 8, 4, seed=5)
    xc, dt, Bm, Cm, A, h0 = args
    y_full, h_full = selective_scan(xc, dt, Bm, Cm, A, h0, chunk=16)
    y1, h1 = selective_scan(xc[:, :32], dt[:, :32], Bm[:, :32], Cm[:, :32],
                            A, h0, chunk=16)
    y2, h2 = selective_scan(xc[:, 32:], dt[:, 32:], Bm[:, 32:], Cm[:, 32:],
                            A, h1, chunk=16)
    assert float(jnp.max(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full))) < 1e-4
    assert float(jnp.max(jnp.abs(h2 - h_full))) < 1e-4


def test_ops_wrapper_and_chunk_picker():
    args = make(1, 64, 16, 8)
    y, h = mamba_scan(*args, chunk=32)
    yr, _ = selective_scan_ref(*args)
    assert float(jnp.max(jnp.abs(y - yr))) < 1e-4
    # falcon-mamba production dims fit VMEM at the picked chunk
    c = pick_chunk(512, 16)   # per-device D after TP
    assert c >= 64
    assert vmem_bytes(c, 512, 16) <= 12 * 2**20


def test_pallas_attention_impl_in_model():
    """attention_impl='pallas' (the TPU kernel path) matches chunked."""
    from repro import pspec
    from repro.configs import get_smoke_config
    from repro.models import model as M
    cfg_c = get_smoke_config("qwen3_32b").replace(compute_dtype="float32",
                                                  attn_chunk=32)
    cfg_p = cfg_c.replace(attention_impl="pallas")
    layout = M.make_layout(cfg_c, 1)
    params = pspec.init_params(M.param_specs(cfg_c, layout),
                               jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg_c.vocab_size, (2, 64)), jnp.int32)
    batch = {"inputs": toks}
    fc, _, _ = M.forward(params, batch, cfg_c, layout)
    fp, _, _ = M.forward(params, batch, cfg_p, layout)
    assert float(jnp.max(jnp.abs(fc - fp))) < 1e-3

"""Serving engine: continuous batching, determinism, MoE properties."""
import pytest as _pytest

pytestmark = _pytest.mark.slow  # multi-minute module; -m "slow or not slow"

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro import pspec
from repro.configs import get_smoke_config
from repro.models import blocks as B
from repro.models import model as M
from repro.models.blocks import Ctx
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("qwen3_32b")
    layout = M.make_layout(cfg, tp=1)
    params = pspec.init_params(M.param_specs(cfg, layout),
                               jax.random.PRNGKey(0))
    return cfg, layout, params


def test_continuous_batching_completes_all(engine_setup):
    cfg, layout, params = engine_setup
    eng = ServingEngine(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 6 + i).astype(np.int32),
                    max_new_tokens=5) for i in range(5)]
    done = eng.run(reqs)
    assert set(done) == set(range(5))
    assert all(len(v) == 5 for v in done.values())


def test_greedy_determinism_same_batch(engine_setup):
    """Same requests, same batch: byte-identical outputs."""
    cfg, layout, params = engine_setup
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    reqs = lambda: [Request(uid=0, prompt=prompt, max_new_tokens=6),
                    Request(uid=1, prompt=prompt[:4], max_new_tokens=6)]
    a = ServingEngine(cfg, params, batch_size=3, max_len=64).run(reqs())
    b = ServingEngine(cfg, params, batch_size=3, max_len=64).run(reqs())
    assert a == b


def test_batch_composition_invariance_logits(engine_setup):
    """Decode logits for a row are independent of the other batch rows
    (up to BLAS gemv/gemm rounding — checked at tolerance, not argmax:
    an untrained model's near-uniform logits make argmax tie-flippy).
    f32 compute isolates the row-independence claim from bf16 noise."""
    cfg, layout, params = engine_setup
    cfg = cfg.replace(compute_dtype="float32")
    import jax.numpy as jnp
    from repro.models import model as M
    from repro.serving.engine import init_decode_cache, prefill_to_decode_cache
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 10)), jnp.int32)
    _, _, c1 = M.forward(params, {"inputs": prompt}, cfg, layout, mode="prefill")
    c1 = prefill_to_decode_cache(cfg, c1, 10, 32)
    l1, _ = M.decode_step(params, c1,
                          {"token": jnp.asarray([5]), "pos": jnp.asarray([10])},
                          cfg, layout)
    # same row embedded in a batch of 3 (other rows zero-cache garbage);
    # caches are layer-stacked: (L, B, len, K, D), so batch is axis 1
    c3 = init_decode_cache(cfg, layout, 3, 32)
    c3 = jax.tree.map(lambda d, s: d.at[:, 0].set(s[:, 0].astype(d.dtype)),
                      c3, c1)
    l3, _ = M.decode_step(params, c3,
                          {"token": jnp.asarray([5, 0, 0]),
                           "pos": jnp.asarray([10, 0, 0])},
                          cfg, layout)
    np.testing.assert_allclose(np.asarray(l1[0]), np.asarray(l3[0]),
                               rtol=1e-3, atol=1e-4)


# --- MoE routing properties --------------------------------------------------

def _moe_ctx(cfg):
    return Ctx(cfg=cfg, layout=M.make_layout(cfg, 1))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 200))
def test_moe_output_finite_and_gates_normalised(seed):
    cfg = get_smoke_config("arctic_480b")
    layout = M.make_layout(cfg, 1)
    p = pspec.init_params(M.param_specs(cfg, layout), jax.random.PRNGKey(0))
    moe_p = jax.tree.map(lambda a: a[0], p["layers"]["moe"])
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)) * 0.3, jnp.float32)
    out, aux = B.moe_apply(moe_p, x, _moe_ctx(cfg))
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens():
    """With capacity_factor ~0, (almost) all tokens drop -> output ~ dense
    residual only; aux stays finite."""
    import dataclasses
    cfg = get_smoke_config("arctic_480b")
    small = dataclasses.replace(cfg.moe, capacity_factor=1e-6)
    cfg_drop = cfg.replace(moe=small)
    layout = M.make_layout(cfg, 1)
    p = pspec.init_params(M.param_specs(cfg, layout), jax.random.PRNGKey(0))
    moe_p = jax.tree.map(lambda a: a[0], p["layers"]["moe"])
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    full, _ = B.moe_apply(moe_p, x, _moe_ctx(cfg))
    dropped, _ = B.moe_apply(moe_p, x, _moe_ctx(cfg_drop))
    # with cap=4 floor some tokens still route; outputs must differ from full
    assert float(jnp.max(jnp.abs(full - dropped))) > 1e-6
    assert bool(jnp.isfinite(dropped).all())

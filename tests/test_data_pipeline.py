"""Data pipeline: prefetcher ordering/termination, batch determinism."""
import numpy as np

from repro.config import RunShape
from repro.configs import get_smoke_config
from repro.data.pipeline import Prefetcher, synth_batch


def test_prefetcher_order_and_close():
    calls = []

    def mk(step):
        calls.append(step)
        return {"x": np.full((2,), step)}

    pf = Prefetcher(mk, start_step=5, depth=2)
    got = [next(pf) for _ in range(6)]
    pf.close()
    steps = [s for s, _ in got]
    assert steps == list(range(5, 11))
    for s, b in got:
        assert b["x"][0] == s


def test_synth_batch_families():
    for arch in ("qwen3_32b", "whisper_large_v3", "qwen2_vl_72b",
                 "falcon_mamba_7b"):
        cfg = get_smoke_config(arch)
        sh = RunShape("t", "train", 32, 2)
        b = synth_batch(cfg, sh, 0)
        assert "targets" in b
        for k, v in b.items():
            assert np.isfinite(v).all() if v.dtype.kind == "f" else True
        if not cfg.embeds_input and cfg.family != "encdec":
            assert b["inputs"].max() < cfg.vocab_size

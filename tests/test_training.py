"""Optimizer + training-step invariants."""
import pytest

pytestmark = pytest.mark.slow  # multi-minute module; run with -m "slow or not slow"

import jax
import jax.numpy as jnp
import numpy as np

from repro import pspec
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.training import optimizer as O
from repro.training import step as TS


def test_adamw_converges_quadratic():
    oc = O.OptConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                     weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = O.init_opt_state(params)
    target = jnp.array([1.0, 2.0])
    loss_fn = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = O.adamw_update(params, g, opt, oc)
    assert float(loss_fn(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = O.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-5
    assert abs(float(O.global_norm(clipped)) - 1.0) < 1e-5
    g2 = {"a": jnp.full((4,), 0.01)}
    same, _ = O.clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, rtol=1e-6)


def test_lr_schedule_shape():
    oc = O.OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                     min_lr_frac=0.1)
    lrs = [float(O.lr_at(jnp.asarray(s), oc)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6            # warmup rises
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] >= 0.1 - 1e-6                    # floor respected
    assert lrs[20] > lrs[80]                        # cosine decays


def test_grad_accum_equivalence():
    """accum=2 must match accum=1 on the same global batch (clip disabled).
    f32 compute isolates the algorithm from bf16 reduction-order noise."""
    cfg = get_smoke_config("qwen3_32b").replace(grad_accum=1,
                                                compute_dtype="float32")
    layout = M.make_layout(cfg, 1)
    oc = O.OptConfig(peak_lr=1e-3, warmup_steps=0, total_steps=10,
                     clip_norm=1e9, weight_decay=0.0)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 33)))
    batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
    s0 = TS.init_state(cfg, layout, jax.random.PRNGKey(0))

    s1, m1 = TS.make_train_step(cfg, layout, opt=oc)(s0, batch)
    cfg2 = cfg.replace(grad_accum=2)
    s0b = TS.init_state(cfg2, layout, jax.random.PRNGKey(0))
    s2, m2 = TS.make_train_step(cfg2, layout, opt=oc)(s0b, batch)
    # microbatch-mean ~ full-batch mean for equal micro sizes; bf16 forward
    # + AdamW's rsqrt(v) amplify reduction-order noise, hence loose rtol
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=2e-4)


def test_flash_impl_matches_chunked_train():
    """attention_impl=flash and =chunked give the same loss and grads."""
    cfg_c = get_smoke_config("qwen3_32b").replace(attention_impl="chunked",
                                                  compute_dtype="float32")
    cfg_f = cfg_c.replace(attention_impl="flash")
    layout = M.make_layout(cfg_c, 1)
    params = pspec.init_params(M.param_specs(cfg_c, layout), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg_c.vocab_size, (2, 49)))
    batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
    lc, _ = M.loss_fn(params, batch, cfg_c, layout)
    lf, _ = M.loss_fn(params, batch, cfg_f, layout)
    assert abs(float(lc) - float(lf)) < 1e-3
    gc = jax.grad(lambda p: M.loss_fn(p, batch, cfg_c, layout)[0])(params)
    gf = jax.grad(lambda p: M.loss_fn(p, batch, cfg_f, layout)[0])(params)
    for a, b in zip(jax.tree.leaves(gc), jax.tree.leaves(gf)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=5e-4)


def test_scan_group_matches_flat_scan():
    """sqrt-remat grouped scan computes the same loss/grads as flat scan."""
    cfg_flat = get_smoke_config("qwen3_32b").replace(n_layers=4, scan_group=0)
    cfg_grp = cfg_flat.replace(scan_group=2)
    layout = M.make_layout(cfg_flat, 1)
    params = pspec.init_params(M.param_specs(cfg_flat, layout),
                               jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg_flat.vocab_size, (2, 33)))
    batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
    lf, _ = M.loss_fn(params, batch, cfg_flat, layout)
    lg, _ = M.loss_fn(params, batch, cfg_grp, layout)
    assert abs(float(lf) - float(lg)) < 1e-5
    gf = jax.grad(lambda p: M.loss_fn(p, batch, cfg_flat, layout)[0])(params)
    gg = jax.grad(lambda p: M.loss_fn(p, batch, cfg_grp, layout)[0])(params)
    for a, b in zip(jax.tree.leaves(gf), jax.tree.leaves(gg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)

"""Core library: chunk scheduler, dataflow pipeline, roofline, HLO parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import hlo as H
from repro.core import roofline as R
from repro.core.chunking import ChunkScheduler, overlap_model
from repro.core.dataflow import Pipeline, Stage, pipeline_model


# --- chunk scheduler -------------------------------------------------------

def test_chunk_scheduler_results_identical():
    kernel = jax.jit(lambda x: jnp.tanh(x) @ x.T)
    chunks = [np.random.default_rng(i).normal(size=(32, 32)).astype(np.float32)
              for i in range(12)]
    s = ChunkScheduler(kernel, depth=4)
    a = s.run_serial(chunks)
    b = s.run_overlapped(chunks)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 64), depth=st.integers(1, 8))
def test_chunk_scheduler_order_preserved(n, depth):
    kernel = jax.jit(lambda x: x + 1.0)
    chunks = [np.full((2, 2), i, np.float32) for i in range(n)]
    out = ChunkScheduler(kernel, depth=depth).run_overlapped(chunks)
    for i, o in enumerate(out):
        assert float(o[0, 0]) == i + 1.0


@settings(max_examples=100, deadline=None)
@given(total=st.floats(1e6, 1e12), compute=st.floats(1e-4, 10.0),
       bw=st.floats(1e9, 1e12), n=st.integers(1, 256))
def test_overlap_model_invariants(total, compute, bw, n):
    m = overlap_model(total, compute, bw, n)
    assert m["overlapped_s"] <= m["serial_s"] + 1e-9
    assert m["speedup"] >= 1.0 - 1e-9
    assert 0.0 <= m["dma_overhead_overlapped"] <= 1.0 + 1e-9


# --- dataflow pipeline -----------------------------------------------------

def test_pipeline_thread_correctness():
    stages = [Stage("load", lambda x: x * 2),
              Stage("prep", lambda x: x + 1),
              Stage("compute", lambda x: x ** 2),
              Stage("store", lambda x: x - 3)]
    out = Pipeline(stages).run(list(range(50)))
    expect = [((i * 2 + 1) ** 2 - 3) for i in range(50)]
    assert out == expect


@settings(max_examples=100, deadline=None)
@given(stage_times=st.lists(st.floats(1e-4, 1.0), min_size=2, max_size=6),
       n=st.integers(1, 1000))
def test_pipeline_model_bounds(stage_times, n):
    stages = {f"s{i}": t for i, t in enumerate(stage_times)}
    m = pipeline_model(stages, n)
    assert m["pipelined_s"] <= m["serial_s"] + 1e-9
    assert m["speedup"] <= len(stage_times) + 1e-9


def test_pipeline_model_fig4_shape():
    """Dataflow region keeps pipelines filled: compute share rises."""
    stages = {"load": 3.0, "prepare": 0.5, "compute": 1.0, "store": 2.0}
    serial = pipeline_model(stages, 100, overlapped=False)
    flow = pipeline_model(stages, 100)
    assert flow["compute_share"] > serial["compute_share"]
    assert flow["bottleneck"] == "load"


# --- roofline --------------------------------------------------------------

def test_roofline_terms():
    t = R.RooflineTerms(flops_per_dev=197e12, hbm_bytes_per_dev=819e9,
                        ici_wire_bytes=0.0, dcn_wire_bytes=0.0, n_chips=256,
                        model_flops_global=197e12 * 256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert t.bound in ("compute", "memory")
    assert abs(t.mfu - 1.0) < 1e-6


@settings(max_examples=100, deadline=None)
@given(c1=st.floats(0, 1e15), c2=st.floats(0, 1e15), n=st.integers(1, 128))
def test_differential_costing(c1, c2, n):
    lo, hi = min(c1, c2), max(c1, c2)
    total = R.differential({"f": lo}, {"f": hi}, n, "f")
    per_layer = hi - lo
    assert total >= n * per_layer - 1e-6
    # exact on affine costs: c(n) = const + n*per_layer
    const = 7.0
    t2 = R.differential({"f": const + per_layer}, {"f": const + 2 * per_layer},
                        n, "f")
    assert abs(t2 - (const + n * per_layer)) < max(1e-6 * max(t2, 1), 1e-6)


# --- HLO parser ------------------------------------------------------------

SAMPLE = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), replica_groups=[16,16]<=[256], to_apply=%add
  %all-gather.3 = bf16[64,2048]{1,0} all-gather(bf16[64,128]{1,0} %y), replica_groups=[16,16]<=[256], dimensions={1}
  %collective-permute.2 = f32[8,8]{1,0} collective-permute(f32[8,8]{1,0} %z), source_target_pairs={{0,1},{1,0}}
  %reduce-scatter.9 = f32[64,32]{1,0} reduce-scatter(f32[64,512]{1,0} %w), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={1}
"""


def test_parse_collectives():
    ops = H.parse_collectives(SAMPLE, pod_size=256)
    kinds = {o.kind for o in ops}
    assert kinds == {"all-reduce", "all-gather", "collective-permute",
                     "reduce-scatter"}
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.out_bytes == 1024 * 512 * 4
    assert ar.group_size == 16
    assert ar.wire_bytes == pytest.approx(2 * 15 / 16 * ar.out_bytes)
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.out_bytes == 64 * 2048 * 2
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    assert rs.wire_bytes == pytest.approx(15 * 64 * 32 * 4)


def test_parse_real_compiled_module():
    """End-to-end: sharded matmul over a small mesh yields collectives."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, sys
        sys.path.insert(0, "src")
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import hlo as H
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((8,), ("model",))
        s = NamedSharding(mesh, P(None, "model"))
        f = lambda a, b: (a @ b).sum()
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 512), jnp.float32)
        comp = jax.jit(f, in_shardings=(None, s)).lower(a, b).compile()
        ops = H.parse_collectives(comp.as_text(), pod_size=8)
        assert len(ops) >= 1, comp.as_text()[:2000]
        print("OK", len(ops))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout

"""Distributed halo-exchange advection == single-device oracle (4-way mesh)."""
import subprocess
import sys
import textwrap

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.stencil.distributed import make_distributed_advect, reference_global
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params

    mesh = jax.make_mesh((4,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    for (X, Y, Z) in [(8, 32, 16), (5, 16, 24)]:
        u, v, w = stratus_fields(X, Y, Z)
        p = default_params(Z)
        fn = make_distributed_advect(mesh, p)
        sh = NamedSharding(mesh, P(None, "data", None))
        out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref = reference_global(u, v, w, p)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(out, ref))
        assert err < 1e-5, (X, Y, Z, err)
    # collective-permutes present (halo exchange, not gather)
    txt = jax.jit(fn).lower(*(jax.device_put(t, sh) for t in (u, v, w))
                            ).compile().as_text()
    assert txt.count("collective-permute") >= 6
    print("OK")
""")


def test_halo_exchange_matches_oracle():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, cwd=".", timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout

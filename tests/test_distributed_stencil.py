"""Distributed halo-exchange advection == single-device oracle (4-way mesh),
plus the T-fused distributed step (one depth-T halo exchange per T substeps).
"""
import pytest

pytestmark = pytest.mark.slow  # multi-minute module; -m "slow or not slow"

import textwrap

from _subproc import run_ok

CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.stencil.distributed import make_distributed_advect, reference_global
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params

    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((4,), ("data",))
    for (X, Y, Z) in [(8, 32, 16), (5, 16, 24)]:
        u, v, w = stratus_fields(X, Y, Z)
        p = default_params(Z)
        fn = make_distributed_advect(mesh, p)
        sh = NamedSharding(mesh, P(None, "data", None))
        out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref = reference_global(u, v, w, p)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(out, ref))
        assert err < 1e-5, (X, Y, Z, err)
    # collective-permutes present (halo exchange, not gather)
    txt = jax.jit(fn).lower(*(jax.device_put(t, sh) for t in (u, v, w))
                            ).compile().as_text()
    assert txt.count("collective-permute") >= 6
    print("OK")
""")


def test_halo_exchange_matches_oracle():
    run_ok(CODE, timeout=300)


FUSED_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.stencil.distributed import (make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import compat_make_mesh

    mesh = compat_make_mesh((4,), ("data",))
    sh_done = False
    for (X, Y, Z) in [(6, 16, 12), (5, 24, 16)]:
        for T in (1, 2, 4):
            u, v, w = stratus_fields(X, Y, Z)
            p = default_params(Z)
            fn = make_distributed_step(mesh, p, T=T, dt=0.01)
            sh = NamedSharding(mesh, P(None, "data", None))
            out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
            ref = reference_global_step(u, v, w, p, T=T, dt=0.01)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(out, ref))
            assert err < 1e-5, (X, Y, Z, T, err)
            if T == 4 and not sh_done:
                # ONE depth-T exchange per T substeps: 6 permutes (3 fields
                # x 2 directions), independent of T
                txt = jax.jit(fn).lower(
                    *(jax.device_put(t, sh) for t in (u, v, w))
                    ).compile().as_text()
                n_perm = txt.count("collective-permute-start") or \
                    txt.count("collective-permute(")
                assert n_perm == 6, (T, n_perm)
                sh_done = True
    print("OK")
""")


def test_fused_distributed_step_matches_oracle():
    run_ok(FUSED_CODE, timeout=300)


KERNEL_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.stencil.distributed import (make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import compat_make_mesh

    # local_kernel="fused": the per-shard slab streams through the v4
    # Pallas kernel (global-interior mask freezing the wrapped rows),
    # composed with the kernel's in-grid (y_tile, x) tiling.
    mesh = compat_make_mesh((4,), ("data",))
    sh = NamedSharding(mesh, P(None, "data", None))
    for (X, Y, Z) in [(6, 16, 12), (5, 24, 16)]:
        for T in (1, 2, 4):
            for y_tile in (None, 3):
                u, v, w = stratus_fields(X, Y, Z)
                p = default_params(Z)
                fn = make_distributed_step(mesh, p, T=T, dt=0.01,
                                           local_kernel="fused",
                                           y_tile=y_tile)
                out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
                ref = reference_global_step(u, v, w, p, T=T, dt=0.01)
                err = max(float(jnp.max(jnp.abs(a - b)))
                          for a, b in zip(out, ref))
                assert err < 1e-5, (X, Y, Z, T, y_tile, err)
    print("OK")
""")


def test_distributed_step_fused_local_kernel_matches_oracle():
    run_ok(KERNEL_CODE, timeout=300)

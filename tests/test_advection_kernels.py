"""Pallas advection kernels: shape/dtype sweeps vs the jnp + f64 oracles,
plus hypothesis physics properties of the PW scheme."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels.advection.advection import (advect_blocked, advect_dataflow,
                                               advect_wide, hbm_bytes_model)
from repro.kernels.advection.ref import (AdvectParams, default_params,
                                         flops_per_cell, pw_advect_ref)

SHAPES = [(4, 8, 8), (8, 16, 16), (6, 24, 40),
          pytest.param((12, 32, 128), marks=pytest.mark.slow),
          pytest.param((5, 8, 256), marks=pytest.mark.slow)]
VARIANTS = [("blocked", advect_blocked), ("dataflow", advect_dataflow)]


def fields(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(rng.normal(size=shape), dtype) for _ in range(3))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("name,fn", VARIANTS)
def test_kernel_matches_ref_f32(shape, name, fn):
    u, v, w = fields(shape, jnp.float32)
    p = default_params(shape[2])
    ref = pw_advect_ref(u, v, w, p)
    out = fn(u, v, w, p)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(ref, out))
    assert err < 1e-5, (name, shape, err)


@pytest.mark.parametrize("name,fn", VARIANTS)
def test_kernel_bf16(name, fn):
    u, v, w = fields((6, 16, 32), jnp.bfloat16)
    p = default_params(32)
    ref = pw_advect_ref(u.astype(jnp.float32), v.astype(jnp.float32),
                        w.astype(jnp.float32), p)
    out = fn(u, v, w, p)
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
              for a, b in zip(ref, out))
    assert err < 0.15, (name, err)  # bf16 stencil tolerance


def test_wide_requires_alignment():
    u, v, w = fields((4, 16, 64), jnp.float32)
    with pytest.raises(ValueError):
        advect_wide(u, v, w, default_params(64))
    u, v, w = fields((4, 16, 128), jnp.float32)
    out = advect_wide(u, v, w, default_params(128))
    assert out[0].shape == (4, 16, 128)
    # HOST-tiled blocks (tile+halo rows) can never satisfy the sublane
    # contract; the in-grid path keeps it per-tile (sublane-rounded halo)
    # but still rejects non-sublane tile sizes
    with pytest.raises(ValueError):
        advect_wide(u, v, w, default_params(128), y_tile=8, tiling="host")
    with pytest.raises(ValueError):
        advect_wide(u, v, w, default_params(128), y_tile=12)
    # y_tile=8 on Y=16 cannot fit a slab (8 + 2*8 > 16): falls back untiled
    tiled = advect_wide(u, v, w, default_params(128), y_tile=8)
    for a, b in zip(out, tiled):
        assert float(jnp.max(jnp.abs(a - b))) == 0.0


def test_f64_oracle_bounds_f32_error():
    """f32 kernel vs f64 numpy oracle: error within stencil tolerance."""
    shape = (6, 12, 24)
    rng = np.random.default_rng(5)
    u64, v64, w64 = (rng.normal(size=shape) for _ in range(3))
    Z = shape[2]
    k = np.arange(Z, dtype=np.float64)
    rdz = 1.0 / (40.0 * (1.0 + 0.001 * k))
    t1 = 0.25 * rdz * (1.0 - 0.002 * k)
    t2 = 0.25 * rdz * (1.0 + 0.002 * k)

    def ref64(u, v, w):
        def sh(f, di, dj, dk):
            return f[1 + di:f.shape[0] - 1 + di, 1 + dj:f.shape[1] - 1 + dj,
                     1 + dk:f.shape[2] - 1 + dk]
        out = []
        for f in (u, v, w):
            fx = 0.25 / 100.0 * (sh(u, -1, 0, 0) * (sh(f, 0, 0, 0) + sh(f, -1, 0, 0))
                                 - sh(u, 1, 0, 0) * (sh(f, 0, 0, 0) + sh(f, 1, 0, 0)))
            fy = 0.25 / 100.0 * (sh(v, 0, -1, 0) * (sh(f, 0, 0, 0) + sh(f, 0, -1, 0))
                                 - sh(v, 0, 1, 0) * (sh(f, 0, 0, 0) + sh(f, 0, 1, 0)))
            fz = (t1[1:-1] * sh(w, 0, 0, -1) * (sh(f, 0, 0, 0) + sh(f, 0, 0, -1))
                  - t2[1:-1] * sh(w, 0, 0, 1) * (sh(f, 0, 0, 0) + sh(f, 0, 0, 1)))
            out.append(np.pad(fx + fy + fz, 1))
        return out

    oracle = ref64(u64, v64, w64)
    p = default_params(Z)
    out = advect_dataflow(jnp.asarray(u64, jnp.float32),
                          jnp.asarray(v64, jnp.float32),
                          jnp.asarray(w64, jnp.float32), p)
    err = max(float(np.max(np.abs(np.asarray(a, np.float64) - b)))
              for a, b in zip(out, oracle))
    assert err < 1e-5, err


@settings(max_examples=25, deadline=None)
@given(cu=st.floats(-5, 5), cv=st.floats(-5, 5), cw=st.floats(-5, 5))
def test_constant_fields_give_zero_sources_on_uniform_grid(cu, cv, cw):
    """PW flux form: uniform flow on a uniform grid has zero divergence."""
    Z = 16
    rdz = np.full(Z, 1.0 / 40.0)
    p = AdvectParams(jnp.float32(0.25 / 100), jnp.float32(0.25 / 100),
                     jnp.asarray(0.25 * rdz, jnp.float32),
                     jnp.asarray(0.25 * rdz, jnp.float32))
    shape = (5, 6, Z)
    u = jnp.full(shape, cu, jnp.float32)
    v = jnp.full(shape, cv, jnp.float32)
    w = jnp.full(shape, cw, jnp.float32)
    out = advect_dataflow(u, v, w, p)
    assert max(float(jnp.max(jnp.abs(s))) for s in out) < 1e-4


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(0.1, 3.0), seed=st.integers(0, 100))
def test_quadratic_scaling(alpha, seed):
    """Momentum advection is quadratic: advect(a*U) == a^2 * advect(U)."""
    u, v, w = fields((5, 8, 8), jnp.float32, seed)
    p = default_params(8)
    base = pw_advect_ref(u, v, w, p)
    scaled = pw_advect_ref(alpha * u, alpha * v, alpha * w, p)
    err = max(float(jnp.max(jnp.abs(s - alpha * alpha * b)))
              for s, b in zip(scaled, base))
    assert err < 1e-2 * max(alpha * alpha, 1.0)


def test_boundary_is_zero():
    u, v, w = fields((6, 10, 12), jnp.float32)
    for s in advect_dataflow(u, v, w, default_params(12)):
        assert float(jnp.abs(s[0]).max()) == 0.0
        assert float(jnp.abs(s[-1]).max()) == 0.0
        assert float(jnp.abs(s[:, 0]).max()) == 0.0
        assert float(jnp.abs(s[:, :, -1]).max()) == 0.0


def test_traffic_model_ladder():
    """The Fig. 3 ladder: each stage strictly reduces modelled HBM traffic."""
    X, Y, Z = 512, 512, 64
    b_point = hbm_bytes_model(X, Y, Z, 4, "pointwise")
    b_block = hbm_bytes_model(X, Y, Z, 4, "blocked")
    b_flow = hbm_bytes_model(X, Y, Z, 4, "dataflow")
    b_wide = hbm_bytes_model(X, Y, 128, 4, "wide")
    assert b_point > b_block > b_flow
    # wide at z=128 moves fewer bytes per cell than dataflow at z=64
    assert b_wide / (X * Y * 128) < b_flow / (X * Y * 64)


@pytest.mark.parametrize("tiling", ["grid", "host"])
@pytest.mark.parametrize("name,fn", VARIANTS)
def test_source_kernels_ytiled_match_untiled(name, fn, tiling):
    """Y-tiling — in-grid (2D (y_tile, x) grid) and host-side (halo-1
    blocks) alike — restitches to the exact untiled sources, including a
    tile size that does not divide Y."""
    shape = (5, 14, 16)
    u, v, w = fields(shape, jnp.float32, seed=7)
    p = default_params(shape[2])
    full = fn(u, v, w, p)
    for y_tile in (4, 5):
        tiled = fn(u, v, w, p, y_tile=y_tile, tiling=tiling)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(full, tiled))
        assert err == 0.0, (name, tiling, y_tile, err)


def test_flops_per_cell_measured():
    n = flops_per_cell()
    assert 50 <= n <= 70  # paper: 53 (21 add/sub + 32 mul); ours measured


def test_ops_wrapper_variants():
    from repro.kernels.advection.ops import pw_advect
    u, v, w = fields((6, 16, 16), jnp.float32)
    p = default_params(16)
    ref = pw_advect(u, v, w, p, variant="reference")
    for variant in ("blocked", "dataflow"):
        out = pw_advect(u, v, w, p, variant=variant)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(ref, out))
        assert err < 1e-5, (variant, err)

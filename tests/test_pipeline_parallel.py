"""Pipeline parallelism: GPipe schedule == sequential stack, exact."""
import subprocess
import sys
import textwrap

CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np, sys
    from repro.distributed.pipeline import pipeline_apply, bubble_fraction

    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((4,), ("pod",))
    rng = np.random.default_rng(0)
    L, D = 8, 16           # 8 layers -> 2 per stage
    W = jnp.asarray(rng.normal(size=(L, D, D)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.normal(size=(L, D)) * 0.1, jnp.float32)
    params = {"w": W, "b": b}
    block = lambda p, x: jnp.tanh(x @ p["w"] + p["b"])

    n_micro, B = 6, 4
    xs = jnp.asarray(rng.normal(size=(n_micro, B, D)), jnp.float32)

    # sequential oracle
    def seq(x):
        for i in range(L):
            x = block(jax.tree.map(lambda a: a[i], params), x)
        return x
    ref = jnp.stack([seq(xs[i]) for i in range(n_micro)])

    out = pipeline_apply(params, xs, block, mesh, axis="pod")
    err = float(jnp.max(jnp.abs(out - ref)))
    print("pipeline vs sequential max err:", err)
    assert err < 1e-6, err
    # the schedule really is a pipeline: collective-permutes present
    txt = jax.jit(lambda p, x: pipeline_apply(p, x, block, mesh)).lower(params, xs).compile().as_text()
    assert "collective-permute" in txt
    print("bubble:", bubble_fraction(4, n_micro))
    print("OK")
""")


def test_gpipe_schedule_exact():
    r = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                       text=True, cwd=".", timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            # without this the scrubbed env lets jax probe a
                            # TPU backend: ~2 min of libtpu metadata retries
                            # before the CPU fallback — the old timeout flake
                            "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout

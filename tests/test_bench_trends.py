"""Trend-gate script hardening (`scripts/check_bench_trends.py`): every
failure mode of a hand-edited baselines.json or an interrupted sweep must
be a SystemExit that NAMES the offending artifact — never a bare
KeyError/JSONDecodeError traceback. Loaded via importlib from the
scripts/ path (the file is a script, not a package module).
"""
import importlib.util
import json
import os

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench_trends",
    os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                 "check_bench_trends.py"))
bt = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bt)

DOC = {"ledger": [{"categories": {"pallas_hbm": 64, "note": "text"}}],
       "scalar": 7}


def test_resolve_walks_dicts_and_lists():
    assert bt.resolve(DOC, "scalar") == 7
    assert bt.resolve(DOC, "ledger.0.categories.pallas_hbm",
                      "BENCH_x.json") == 64


@pytest.mark.parametrize("path,needle", [
    ("ledger.9.categories", "does not index the list"),
    ("ledger.nope.categories", "does not index the list"),
    ("missing_key", "missing"),
    ("scalar.deeper", "descends into a leaf"),
    ("ledger.0.categories.note", "not a number"),
])
def test_resolve_errors_name_the_artifact(path, needle):
    with pytest.raises(SystemExit) as ei:
        bt.resolve(DOC, path, "BENCH_x.json")
    msg = str(ei.value)
    assert "BENCH_x.json" in msg and needle in msg


def test_entry_fields_validates_schema():
    assert bt.entry_fields("a.json", {"path": "p", "value": 1,
                                      "direction": "eq"}) == ("p", 1, "eq")
    with pytest.raises(SystemExit, match="a.json.*not an object"):
        bt.entry_fields("a.json", ["path", "value"])
    with pytest.raises(SystemExit) as ei:
        bt.entry_fields("a.json", {"path": "p", "value": 1})
    assert "a.json" in str(ei.value) and "direction" in str(ei.value)


def test_load_artifact_names_file_on_malformed_json(tmp_path):
    good = tmp_path / "BENCH_ok.json"
    good.write_text(json.dumps({"x": 1}))
    assert bt.load_artifact(str(good), "BENCH_ok.json") == {"x": 1}
    bad = tmp_path / "BENCH_trunc.json"
    bad.write_text('{"x": [1, 2')        # an interrupted sweep's artifact
    with pytest.raises(SystemExit) as ei:
        bt.load_artifact(str(bad), "BENCH_trunc.json")
    msg = str(ei.value)
    assert "BENCH_trunc.json" in msg and "not valid JSON" in msg
    assert "rerun" in msg


def test_check_directions_pass_and_fail():
    doc = {"v": 10}
    mk = lambda d, want, **kw: dict({"path": "v", "direction": d,
                                     "value": want}, **kw)
    assert bt.check("a.json", [mk("eq", 10), mk("le", 10),
                               mk("ge", 10)], doc) == []
    assert bt.check("a.json", [mk("le", 9, rtol=0.2)], doc) == []
    fails = bt.check("a.json", [mk("eq", 9), mk("le", 8), mk("ge", 11)],
                     doc)
    assert len(fails) == 3 and all("a.json:v" in f for f in fails)
    with pytest.raises(SystemExit, match="bad direction"):
        bt.check("a.json", [mk("lt", 9)], doc)


def test_main_gate_and_update_roundtrip(tmp_path, monkeypatch, capsys):
    baselines = tmp_path / "baselines.json"
    baselines.write_text(json.dumps(
        {"BENCH_t.json": [{"path": "v", "direction": "eq", "value": 3}]}))
    monkeypatch.setattr(bt, "BASELINES", str(baselines))
    monkeypatch.chdir(tmp_path)

    # missing artifact: named, with the remedy
    with pytest.raises(SystemExit, match="BENCH_t.json not found"):
        bt.main([])
    (tmp_path / "BENCH_t.json").write_text(json.dumps({"v": 3}))
    bt.main([])                                         # gate passes
    assert "1 baselines hold" in capsys.readouterr().out

    # regression -> SystemExit listing the failing path
    (tmp_path / "BENCH_t.json").write_text(json.dumps({"v": 4}))
    with pytest.raises(SystemExit, match="BENCH_t.json:v"):
        bt.main([])
    # --update rewrites the baseline to the current value
    bt.main(["--update"])
    assert json.loads(baselines.read_text())["BENCH_t.json"][0]["value"] == 4
    bt.main([])                                         # and now it gates

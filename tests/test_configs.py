"""Exact published-config checks + analytic parameter counts."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config

EXPECTED = {
    "nemotron_4_340b": dict(n_layers=96, d_model=18432, n_heads=96,
                            n_kv_heads=8, d_ff=73728, vocab_size=256000,
                            mlp="sq_relu"),
    "qwen2_5_14b": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
                        d_ff=13824, vocab_size=152064, qkv_bias=True),
    "qwen3_32b": dict(n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
                      d_ff=25600, vocab_size=151936, qk_norm=True),
    "nemotron_4_15b": dict(n_layers=32, d_model=6144, n_heads=48,
                           n_kv_heads=8, d_ff=24576, vocab_size=256000),
    "qwen2_vl_72b": dict(n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
                         d_ff=29568, vocab_size=152064, pos="mrope"),
    "whisper_large_v3": dict(d_model=1280, n_heads=20, n_kv_heads=20,
                             d_ff=5120, vocab_size=51866, family="encdec"),
    "arctic_480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                        d_ff=4864, vocab_size=32000, family="moe"),
    "llama4_maverick_400b_a17b": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv_heads=8, d_ff=8192,
                                      vocab_size=202048, family="moe"),
    "falcon_mamba_7b": dict(n_layers=64, d_model=4096, vocab_size=65024,
                            family="ssm"),
    "recurrentgemma_9b": dict(n_layers=38, d_model=4096, n_heads=16,
                              n_kv_heads=1, d_ff=12288, vocab_size=256000,
                              family="hybrid"),
}

# published total parameter counts (rough, for the analytic count sanity band)
PARAM_BAND = {
    "nemotron_4_340b": (300e9, 380e9),
    "qwen2_5_14b": (12e9, 16e9),
    "qwen3_32b": (28e9, 36e9),
    "nemotron_4_15b": (13e9, 18e9),
    "qwen2_vl_72b": (65e9, 80e9),
    "whisper_large_v3": (1.2e9, 1.9e9),
    "arctic_480b": (400e9, 520e9),
    "llama4_maverick_400b_a17b": (330e9, 440e9),
    "falcon_mamba_7b": (6e9, 8.5e9),
    "recurrentgemma_9b": (7.5e9, 11e9),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_config(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_band(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    lo, hi = PARAM_BAND[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]B"


def test_moe_active_counts():
    arctic = get_config("arctic_480b")
    llama4 = get_config("llama4_maverick_400b_a17b")
    assert arctic.active_param_count() < 0.2 * arctic.param_count()
    # llama4-maverick: ~17B active of ~400B
    assert 12e9 < llama4.active_param_count() < 25e9


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_same_family(arch):
    full, smoke = get_config(arch), get_smoke_config(arch)
    assert full.family == smoke.family
    assert smoke.d_model <= 128 and smoke.n_layers <= 4

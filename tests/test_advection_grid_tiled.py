"""In-grid (y_tile, x) 2D tiling: the equivalence + budget suite.

Grid-tiled outputs must be BITWISE equal to the untiled kernel and to the
retained host-tiled path (`_y_tiled_host`, `tiling="host"`) across
(y_tile, T, dtype, edge-remainder Y) sweeps; the fused-update v1-v3 rungs
must reproduce sources + host Euler exactly; `advect_wide` gains a
lane-aligned tiled path; and the VMEM register stays inside the budget the
updated `fused_register_bytes` promises.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.advection.advection import (_y_tiled_host, advect_blocked,
                                               advect_dataflow, advect_fused,
                                               advect_wide,
                                               fused_register_bytes,
                                               hbm_bytes_model,
                                               vmem_halo_bytes_model)
from repro.kernels.advection.ref import default_params, pw_advect_ref

DT = 0.01


def fields(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(rng.normal(size=shape), dtype) for _ in range(3))


def assert_bitwise(a_tuple, b_tuple, ctx):
    for a, b in zip(a_tuple, b_tuple):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=str(ctx))


# --- grid == untiled == host, across the sweep -----------------------------

SOURCE_KERNELS = [("blocked", advect_blocked), ("dataflow", advect_dataflow)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("y_tile", [3, 4, 5])
@pytest.mark.parametrize("name,fn", SOURCE_KERNELS)
def test_grid_tiled_sources_bitmatch_untiled_and_host(name, fn, y_tile,
                                                      dtype):
    """Y=14 is not a multiple of any swept tile except 7-adjacent sizes, so
    every sweep exercises the edge-remainder tile."""
    shape = (5, 14, 16)
    u, v, w = fields(shape, dtype, seed=11)
    p = default_params(shape[2])
    full = fn(u, v, w, p)
    grid = fn(u, v, w, p, y_tile=y_tile, tiling="grid")
    host = fn(u, v, w, p, y_tile=y_tile, tiling="host")
    assert_bitwise(grid, full, (name, y_tile, dtype, "grid vs untiled"))
    assert_bitwise(grid, host, (name, y_tile, dtype, "grid vs host"))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,y_tile", [(1, 4), (2, 5), (2, 7), (4, 3)])
def test_grid_tiled_fused_bitmatch_untiled_and_host(T, y_tile, dtype):
    shape = (5, 17, 12)   # 17 = prime: every y_tile leaves a remainder tile
    u, v, w = fields(shape, dtype, seed=12)
    p = default_params(shape[2])
    full = advect_fused(u, v, w, p, T=T, dt=DT)
    grid = advect_fused(u, v, w, p, T=T, dt=DT, y_tile=y_tile, tiling="grid")
    host = advect_fused(u, v, w, p, T=T, dt=DT, y_tile=y_tile, tiling="host")
    assert_bitwise(grid, full, (T, y_tile, dtype, "grid vs untiled"))
    assert_bitwise(grid, host, (T, y_tile, dtype, "grid vs host"))


def test_host_tiler_retained_under_new_name():
    """The renamed `_y_tiled_host` is the same halo-overlap/trim/concat loop
    the kernels' `tiling="host"` dispatches to."""
    shape = (5, 14, 16)
    u, v, w = fields(shape, seed=13)
    p = default_params(shape[2])
    direct = _y_tiled_host(lambda a, b, c: advect_dataflow(a, b, c, p),
                           u, v, w, y_tile=4, halo=1)
    via_kw = advect_dataflow(u, v, w, p, y_tile=4, tiling="host")
    assert_bitwise(direct, via_kw, "host path dispatch")


def test_rejects_bad_tiling_and_y_tile():
    u, v, w = fields((4, 8, 8))
    p = default_params(8)
    with pytest.raises(ValueError):
        advect_dataflow(u, v, w, p, tiling="diagonal")
    with pytest.raises(ValueError):
        advect_fused(u, v, w, p, y_tile=0)


# --- fuse_update: the Euler step folded into the v1-v3 kernels -------------

@pytest.mark.parametrize("y_tile", [None, 4, 5])
@pytest.mark.parametrize("name,fn", SOURCE_KERNELS)
def test_fuse_update_equals_sources_plus_euler(name, fn, y_tile):
    shape = (6, 14, 16)
    u, v, w = fields(shape, seed=9)
    p = default_params(shape[2])
    su, sv, sw = fn(u, v, w, p)
    expect = (u + DT * su, v + DT * sv, w + DT * sw)
    out = fn(u, v, w, p, fuse_update=True, dt=DT, y_tile=y_tile)
    assert_bitwise(out, expect, (name, y_tile))


def test_fuse_update_wide():
    u, v, w = fields((4, 16, 128), seed=10)
    p = default_params(128)
    su, sv, sw = advect_wide(u, v, w, p)
    expect = (u + DT * su, v + DT * sv, w + DT * sw)
    out = advect_wide(u, v, w, p, fuse_update=True, dt=DT)
    assert_bitwise(out, expect, "wide fuse_update")


def test_ops_wrapper_fuse_update_and_tiling():
    from repro.kernels.advection.ops import pw_advect
    u, v, w = fields((5, 14, 16), seed=14)
    p = default_params(16)
    base = pw_advect(u, v, w, p, variant="dataflow")
    tiled = pw_advect(u, v, w, p, variant="dataflow", y_tile=4,
                      tiling="grid")
    assert_bitwise(base, tiled, "ops grid tiling")
    stepped = pw_advect(u, v, w, p, variant="dataflow", fuse_update=True,
                        dt=DT)
    expect = tuple(f + DT * s for f, s in zip((u, v, w), base))
    assert_bitwise(stepped, expect, "ops fuse_update")
    ref_step = pw_advect(u, v, w, p, variant="reference", fuse_update=True,
                         dt=DT)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(ref_step, expect))
    assert err < 1e-6


# --- wide: the lane-aligned tiled path (previously raised) -----------------

def test_wide_grid_tiled_y1024_class():
    """Fig. 8 shapes (Y=1024) now tile under the (8,128) contract: the
    in-grid slab carries a sublane-rounded (8-row) halo, so tile row counts
    and element offsets stay multiples of 8."""
    u, v, w = fields((3, 1024, 128), seed=4)
    p = default_params(128)
    full = advect_wide(u, v, w, p)
    tiled = advect_wide(u, v, w, p, y_tile=256)
    assert_bitwise(tiled, full, "wide Y=1024 y_tile=256")


def test_wide_tiling_contract_checks():
    u, v, w = fields((4, 32, 128), seed=5)
    p = default_params(128)
    with pytest.raises(ValueError):           # host path: contract-breaking
        advect_wide(u, v, w, p, y_tile=8, tiling="host")
    with pytest.raises(ValueError):           # non-sublane tile
        advect_wide(u, v, w, p, y_tile=12)
    full = advect_wide(u, v, w, p)
    tiled = advect_wide(u, v, w, p, y_tile=8)  # 8 + 2*8 <= 32: tiles
    assert_bitwise(tiled, full, "wide y_tile=8 on Y=32")


# --- y_interior_mask: the distributed-composition hook ---------------------

def test_fused_y_interior_mask_matches_masked_reference_loop():
    """The kernel's per-substep row mask reproduces the distributed halo
    semantics: masked rows are frozen walls; grid tiling does not change a
    bit of it."""
    X, Y, Z, T = 6, 20, 12, 3
    u, v, w = fields((X, Y, Z), seed=6)
    p = default_params(Z)
    gy = -T + np.arange(Y)
    mask = ((gy >= 1) & (gy <= 40)).astype(np.float32)
    us, vs, ws = u, v, w
    m = jnp.asarray(mask)[None, :, None] > 0
    for _ in range(T):
        su, sv, sw = pw_advect_ref(us, vs, ws, p)
        us = us + DT * jnp.where(m, su, 0.0)
        vs = vs + DT * jnp.where(m, sv, 0.0)
        ws = ws + DT * jnp.where(m, sw, 0.0)
    ref = (us, vs, ws)
    base = advect_fused(u, v, w, p, T=T, dt=DT,
                        y_interior_mask=jnp.asarray(mask))
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(ref, base))
    assert err < 1e-6, err          # kernel vs jnp loop: FMA-level noise
    for y_tile in (6, 7):
        tiled = advect_fused(u, v, w, p, T=T, dt=DT, y_tile=y_tile,
                             y_interior_mask=jnp.asarray(mask))
        assert_bitwise(tiled, base, ("masked grid tiling", y_tile))
    with pytest.raises(ValueError):  # host tiling cannot slice the mask
        advect_fused(u, v, w, p, T=T, dt=DT, y_tile=6, tiling="host",
                     y_interior_mask=jnp.asarray(mask))
    with pytest.raises(ValueError):  # mask shape must match Y
        advect_fused(u, v, w, p, T=T, dt=DT,
                     y_interior_mask=jnp.ones((Y + 1,)))


def test_distributed_step_fused_local_kernel_single_shard():
    """Cheap in-process wiring check of local_kernel="fused" (the 4-shard
    equivalence lives in the slow distributed suite): one self-wrapping
    shard must match the global T-substep oracle."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import compat_make_mesh
    from repro.stencil.distributed import (make_distributed_step,
                                           reference_global_step)
    X, Y, Z = 6, 20, 12
    u, v, w = fields((X, Y, Z), seed=7)
    p = default_params(Z)
    mesh = compat_make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P(None, "data", None))
    for T, y_tile in ((1, None), (2, 6)):
        fn = make_distributed_step(mesh, p, T=T, dt=DT,
                                   local_kernel="fused", y_tile=y_tile)
        out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref = reference_global_step(u, v, w, p, T=T, dt=DT)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(out, ref))
        assert err < 1e-5, (T, y_tile, err)
    with pytest.raises(ValueError):
        make_distributed_step(mesh, p, local_kernel="magic")


# --- VMEM budget: the in-grid register honours fused_register_bytes --------

VMEM_BUDGET_BYTES = 8 * 1024 * 1024


@pytest.mark.parametrize("Y", [1024, 65536])
@pytest.mark.parametrize("T", [1, 4, 8])
def test_grid_tiled_register_budget(Y, T):
    """The in-grid slab ring is (T, 3, y_tile+2T, Z) x 3 fields — exactly
    what fused_register_bytes prices, flat in Y and under budget."""
    Z, item, y_tile = 64, 4, 128
    b = fused_register_bytes(T, Y, Z, item, y_tile=y_tile)
    assert b == 3 * 3 * T * (y_tile + 2 * T) * Z * item
    assert b == fused_register_bytes(T, 8 * Y, Z, item, y_tile=y_tile)
    assert b <= VMEM_BUDGET_BYTES, (Y, T, b)
    # wide's grid-tiled ring carries the sublane-rounded 8-row halo instead
    bw = fused_register_bytes(1, Y, 128, item, y_tile=y_tile, halo=8)
    assert bw == 3 * 3 * (y_tile + 16) * 128 * item
    assert bw <= VMEM_BUDGET_BYTES


def test_domain_grid_tiling_accounting():
    from repro.stencil.advection import AdvectionDomain
    dom = AdvectionDomain(16, 65536, 64, variant="fused", fuse_T=4,
                          y_tile=128)
    host = AdvectionDomain(16, 65536, 64, variant="fused", fuse_T=4,
                          y_tile=128, tiling="host")
    assert dom.tiling == "grid"
    assert dom.hbm_bytes_per_step() < host.hbm_bytes_per_step()
    assert dom.hbm_bytes_per_step() == hbm_bytes_model(16, 65536, 64, 4,
                                                       "fused", T=4)
    assert dom.vmem_halo_bytes_per_step() > 0
    assert host.vmem_halo_bytes_per_step() == 0
    assert dom.vmem_register_bytes() <= VMEM_BUDGET_BYTES
    wide = AdvectionDomain(16, 1024, 128, variant="wide", y_tile=128)
    assert wide.vmem_register_bytes() \
        == fused_register_bytes(1, 1024, 128, 4, y_tile=128, halo=8)


def test_domain_fuse_update_fast_path():
    from repro.stencil.advection import AdvectionDomain
    dom = AdvectionDomain(5, 14, 16, variant="dataflow", fuse_update=True,
                          dt=DT, y_tile=4)
    u, v, w = dom.init()
    base = AdvectionDomain(5, 14, 16, variant="dataflow", dt=DT)
    expect = base.step(u, v, w)
    out = dom.step(u, v, w)
    assert_bitwise(out, expect, "domain fuse_update")
    with pytest.raises(ValueError):
        dom.sources(u, v, w)
    with pytest.raises(ValueError):
        dom.step(u, v, w, dt=0.5)   # dt is baked into the fused-update kernel
    # the unfused-update model charges the extra full-field pass
    assert dom.hbm_bytes_per_step() < base.hbm_bytes_per_step()

"""2D (x, y) mesh decomposition == single-device oracle: equivalence sweeps
over (nx, ny, T, local_kernel, y_tile, overlap, dtype), the 4-device
corner-exchange regression (the x-then-y two-phase contract), and the
multi-hop depth-T exchange that lifts the old T <= local-extent limit.

Subprocess idiom (`tests/_subproc.run_ok`): meshes come from
`launch.mesh.compat_make_mesh` on 4 forced host devices, and the child env
pins JAX_PLATFORMS=cpu so jax never probes libtpu (the old timeout flake).
A cheap single-device wiring test stays in the fast tier.
"""
import textwrap

import pytest

from _subproc import run_ok as _run


SWEEP_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.stencil.distributed import (make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh

    X, Y, Z = 8, 12, 10
    p = default_params(Z)
    # (local_kernel, y_tile, overlap): y_tile=5 does NOT divide any shard's
    # local Y (12, 6 or 3 rows + 2T halo) — the non-divisible tile shapes
    for nx, ny in ((2, 2), (1, 4), (4, 1)):
        mesh = make_stencil_mesh(nx, ny)
        sh = NamedSharding(mesh, P("x", "y", None))
        for T in (1, 2, 3):
            for lk, yt, ov in (("reference", None, False),
                               ("reference", None, True),
                               ("fused", None, True),
                               ("fused", 5, False)):
                u, v, w = stratus_fields(X, Y, Z)
                fn = make_distributed_step(mesh, p, axis="y", x_axis="x",
                                           T=T, dt=0.01, local_kernel=lk,
                                           y_tile=yt, overlap=ov)
                out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
                ref = reference_global_step(u, v, w, p, T=T, dt=0.01)
                err = max(float(jnp.max(jnp.abs(a - b)))
                          for a, b in zip(out, ref))
                assert err < 1e-5, (nx, ny, T, lk, yt, ov, err)
    # dtype sweep: bfloat16 end-to-end (kernel + exchange + oracle all
    # bf16; looser tolerance bounds the accumulated rounding)
    mesh = make_stencil_mesh(2, 2)
    sh = NamedSharding(mesh, P("x", "y", None))
    for lk in ("reference", "fused"):
        u, v, w = stratus_fields(X, Y, Z, dtype=jnp.bfloat16)
        fn = make_distributed_step(mesh, p, axis="y", x_axis="x", T=2,
                                   dt=0.01, local_kernel=lk)
        out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref = reference_global_step(u, v, w, p, T=2, dt=0.01)
        err = max(float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                                        - jnp.asarray(b, jnp.float32))))
                  for a, b in zip(out, ref))
        assert err < 0.1, (lk, err)
    print("OK")
""")


@pytest.mark.slow
def test_2d_decomposition_matches_oracle_sweep():
    _run(SWEEP_CODE)


CORNER_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.roofline import halo_wire_bytes_model
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh

    # 2x2 mesh, T=2: the four cells within T of BOTH interior cuts depend
    # on the diagonal-neighbour shard; they only come out right if the
    # y-phase exchanges the x-EXTENDED slab (corners ride phase 2)
    X, Y, Z, T = 8, 8, 12, 2
    u, v, w = stratus_fields(X, Y, Z, seed=5)
    p = default_params(Z)
    mesh = make_stencil_mesh(2, 2)
    sh = NamedSharding(mesh, P("x", "y", None))
    fn = make_distributed_step(mesh, p, axis="y", x_axis="x", T=T, dt=0.01,
                               local_kernel="fused", overlap=True)
    out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
    ref = reference_global_step(u, v, w, p, T=T, dt=0.01)
    cut_x, cut_y = X // 2, Y // 2
    win_x = slice(cut_x - T, cut_x + T)
    win_y = slice(cut_y - T, cut_y + T)
    for a, b in zip(out, ref):
        corner = np.abs(np.asarray(a)[win_x, win_y]
                        - np.asarray(b)[win_x, win_y])
        assert float(corner.max()) < 1e-5, float(corner.max())
    # the corner bytes are priced: counted ppermute operands must include
    # the 2T extra columns of the x-extended phase-2 rows (reordering the
    # phases would shrink the count and break the cells above)
    got = count_exchange_wire_bytes(fn, u, v, w)
    model = halo_wire_bytes_model(X, Y, Z, 4, nx=2, ny=2, T=T)
    assert got == model, (got, model)
    # a phase ordering that exchanged y on the UNextended slab would send
    # exactly 2T*2T*Z fewer elements per field — the corner blocks
    no_corner = 3 * 4 * (2 * T * (Y // 2) * Z + 2 * T * (X // 2) * Z)
    assert got == no_corner + 3 * 4 * 2 * T * 2 * T * Z, (got, no_corner)
    print("OK")
""")


@pytest.mark.slow
def test_corner_exchange_regression_2x2():
    _run(CORNER_CODE)


MULTIHOP_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.stencil.distributed import (make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import compat_make_mesh

    # Yl = 4 per shard: T=6 needs 2 ppermute hops, T=10 needs 3; T=14 is
    # the global bound (Y-2), T=15 must raise. Both local kernels.
    X, Y, Z = 6, 16, 12
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = compat_make_mesh((4,), ("data",))
    sh = NamedSharding(mesh, P(None, "data", None))
    # overlap=True composed with multi-hop: the interior/boundary select
    # must hold when the T-deep bands swallow whole shards (T > Yl)
    for T in (6, 10, 14):
        for lk in ("reference", "fused"):
            fn = make_distributed_step(mesh, p, T=T, dt=0.005,
                                       local_kernel=lk, overlap=(T == 10))
            out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
            ref = reference_global_step(u, v, w, p, T=T, dt=0.005)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(out, ref))
            assert err < 1e-5, (T, lk, err)
    try:
        fn = make_distributed_step(mesh, p, T=15)
        fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        raise SystemExit("T=15 on Y=16 should have raised")
    except ValueError as e:
        assert "exceeds the decomposable global Y" in str(e), e
    # multi-hop along x too: Xl=2 per shard on a (4, 1) mesh, T=3 -> 2 hops
    X2 = 8
    u2, v2, w2 = stratus_fields(X2, Y, Z)
    mesh2 = compat_make_mesh((4, 1), ("x", "y"))
    sh2 = NamedSharding(mesh2, P("x", "y", None))
    fn = make_distributed_step(mesh2, p, axis="y", x_axis="x", T=3, dt=0.01,
                               local_kernel="fused")
    out = fn(*(jax.device_put(t, sh2) for t in (u2, v2, w2)))
    ref = reference_global_step(u2, v2, w2, p, T=3, dt=0.01)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(out, ref))
    assert err < 1e-5, err
    print("OK")
""")


@pytest.mark.slow
def test_multi_hop_depth_T_exchange():
    _run(MULTIHOP_CODE)


def test_2d_wiring_single_device():
    """Fast-tier wiring check: a (1, 1) 'mesh' exercises the 2D code path
    (specs, masks, trim) without any exchange; full multi-device coverage
    lives in the slow subprocess sweeps above."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (make_distributed_step,
                                           reference_global_step)

    X, Y, Z = 6, 10, 8
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = make_stencil_mesh(1, 1)
    sh = NamedSharding(mesh, P("x", "y", None))
    for lk in ("reference", "fused"):
        fn = make_distributed_step(mesh, p, axis="y", x_axis="x", T=2,
                                   dt=0.01, local_kernel=lk, overlap=True)
        out = fn(*(jax.device_put(t, sh) for t in (u, v, w)))
        ref = reference_global_step(u, v, w, p, T=2, dt=0.01)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(out, ref))
        assert err < 1e-5, (lk, err)

"""Differential oracle suite for the stencil-spec frontend.

Two gate families keep the generalised engine honest:

  * BITWISE — the spec-driven `stencil_fused` must reproduce the
    hand-written `advect_fused` bit for bit when given the
    Piacsek-Williams spec (swept over T, y_tile, dtype), and the
    spec-driven distributed step must reproduce the legacy 3-field
    distributed path bit for bit. The frontend is a generalisation of the
    v4 ladder, not a fork.
  * f64 ORACLE — every new operator (tracer advection, 3D diffusion) and
    the in-ring RK2 integrator, differenced against
    `spec_multistep_ref_f64` (genuine float64 intermediates) under a
    per-dtype tolerance ladder.

`benchmarks/stencil_sweep.py` re-runs the same gates as explicit
SystemExit raises and prices counted-vs-modelled bytes per operator.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_ok

from repro.kernels.advection.advection import (advect_fused, stencil_fused,
                                               stencil_fused_batched)
from repro.kernels.advection.ref import default_params
from repro.stencil import spec as SP
from repro.stencil.advection import stratus_fields

DT = 0.01
SHAPE = (8, 10, 8)

# per-dtype tolerance ladder (relative to the operator's field scale)
TOL_REL = {"float32": 2e-5, "bfloat16": 0.02}


def _max_err_f64(out, oracle):
    return max(float(np.max(np.abs(np.asarray(a, np.float64) - b)))
               for a, b in zip(out, oracle))


def _bitwise(a_fields, b_fields, ctx=""):
    for a, b in zip(a_fields, b_fields):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(ctx))


def _operator(key, dtype=jnp.float32):
    X, Y, Z = SHAPE
    if key in ("pw", "pw_rk2"):
        spec = SP.pw_advection_spec("rk2" if key.endswith("rk2")
                                    else "euler")
        return spec, default_params(Z), stratus_fields(X, Y, Z,
                                                       dtype=dtype), DT
    if key in ("tracer", "tracer_rk2"):
        spec = SP.tracer_advection_spec("rk2" if key.endswith("rk2")
                                        else "euler")
        fields = stratus_fields(X, Y, Z, dtype=dtype) + (
            SP.tracer_field(X, Y, Z, dtype=dtype),)
        return spec, default_params(Z), fields, DT
    spec = SP.diffusion_spec("rk2" if key.endswith("rk2") else "euler")
    return spec, SP.default_diffusion_params(Z), (
        SP.diffusion_field(X, Y, Z, dtype=dtype),), 1e-3


# ---------------------------------------------------------------------------
# bitwise: spec frontend == hand-written v4 kernel for the PW spec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T", [1, 2, 3])
@pytest.mark.parametrize("y_tile", [None, 5])
def test_pw_spec_bitwise_vs_advect_fused(T, y_tile):
    X, Y, Z = SHAPE
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    ref = advect_fused(u, v, w, p, T=T, dt=DT, y_tile=y_tile)
    got = stencil_fused((u, v, w), p, SP.pw_advection_spec(), T=T, dt=DT,
                        y_tile=y_tile)
    _bitwise(got, ref, (T, y_tile))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pw_spec_bitwise_dtype_sweep(dtype):
    X, Y, Z = SHAPE
    u, v, w = stratus_fields(X, Y, Z, dtype=dtype)
    p = default_params(Z)
    ref = advect_fused(u, v, w, p, T=2, dt=DT, y_tile=4)
    got = stencil_fused((u, v, w), p, SP.pw_advection_spec(), T=2, dt=DT,
                        y_tile=4)
    _bitwise(got, ref, dtype)


def test_pw_spec_bitwise_with_interior_masks():
    """The distributed rung's mask arguments thread through identically."""
    X, Y, Z = SHAPE
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    xm = (np.arange(X) % 5 != 0).astype(np.float32)
    ym = (np.arange(Y) % 4 != 0).astype(np.float32)
    ref = advect_fused(u, v, w, p, T=2, dt=DT, x_interior_mask=xm,
                       y_interior_mask=ym)
    got = stencil_fused((u, v, w), p, SP.pw_advection_spec(), T=2, dt=DT,
                        x_interior_mask=xm, y_interior_mask=ym)
    _bitwise(got, ref)


# ---------------------------------------------------------------------------
# f64 oracle ladder: the new operators and the in-ring RK2
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("key", ["tracer", "diffusion", "pw_rk2",
                                 "tracer_rk2", "diffusion_rk2"])
def test_operator_matches_f64_oracle(key, dtype):
    T = 2
    spec, params, fields, dt = _operator(key, dtype)
    oracle = SP.spec_multistep_ref_f64(fields, params, spec, T, dt)
    out = stencil_fused(fields, params, spec, T=T, dt=dt)
    scale = max(1.0, max(float(np.max(np.abs(b))) for b in oracle))
    tol = TOL_REL[jnp.dtype(dtype).name] * scale
    err = _max_err_f64(out, oracle)
    assert err <= tol, (key, jnp.dtype(dtype).name, err, tol)


@pytest.mark.parametrize("key", ["tracer", "diffusion_rk2"])
def test_operator_tiled_matches_untiled_bitwise(key):
    """In-grid y-tiling restitches to the exact untiled result for the
    generalised ring too (deeper rk2 halos included)."""
    T = 2
    spec, params, fields, dt = _operator(key)
    full = stencil_fused(fields, params, spec, T=T, dt=dt)
    for y_tile in (3, 5, 64):
        tiled = stencil_fused(fields, params, spec, T=T, dt=dt,
                              y_tile=y_tile)
        _bitwise(tiled, full, (key, y_tile))


def test_tracer_velocities_bitwise_equal_pw():
    """The tracer spec's u/v/w outputs are the PW spec's outputs exactly:
    the fourth field rides the rings without perturbing the carriers."""
    spec, params, fields, dt = _operator("tracer")
    out4 = stencil_fused(fields, params, spec, T=2, dt=dt)
    out3 = stencil_fused(fields[:3], params, SP.pw_advection_spec(), T=2,
                         dt=dt)
    _bitwise(out4[:3], out3)


def test_spec_boundary_cells_frozen():
    """zero_source walls: the outermost `radius` cells never change, for
    every operator and integrator."""
    for key in ("tracer", "diffusion_rk2"):
        spec, params, fields, dt = _operator(key)
        out = stencil_fused(fields, params, spec, T=3, dt=dt)
        r = spec.radius
        for f0, fT in zip(fields, out):
            f0, fT = np.asarray(f0), np.asarray(fT)
            np.testing.assert_array_equal(fT[:r], f0[:r])
            np.testing.assert_array_equal(fT[-r:], f0[-r:])
            np.testing.assert_array_equal(fT[:, :r], f0[:, :r])
            np.testing.assert_array_equal(fT[:, :, -r:], f0[:, :, -r:])


def test_spec_batched_matches_sequential_bitwise():
    X, Y, Z = SHAPE
    B = 3
    spec, params, _, dt = _operator("tracer")
    rng = np.random.default_rng(11)
    fields = tuple(jnp.asarray(rng.normal(size=(B, X, Y, Z)), jnp.float32)
                   for _ in range(spec.n_fields))
    batched = stencil_fused_batched(fields, params, spec, T=2, dt=dt)
    for b in range(B):
        one = stencil_fused(tuple(f[b] for f in fields), params, spec,
                            T=2, dt=dt)
        _bitwise([g[b] for g in batched], one, b)


# ---------------------------------------------------------------------------
# build-time contracts
# ---------------------------------------------------------------------------


def test_stencil_fused_rejects_bad_args():
    spec, params, fields, dt = _operator("tracer")
    with pytest.raises(ValueError, match="T must be"):
        stencil_fused(fields, params, spec, T=0)
    with pytest.raises(ValueError, match="got 3 arrays"):
        stencil_fused(fields[:3], params, spec, T=1)
    with pytest.raises(ValueError, match="shape"):
        bad = fields[:3] + (fields[3][:, :-1],)
        stencil_fused(bad, params, spec, T=1)


# ---------------------------------------------------------------------------
# distributed: spec path bitwise vs the legacy 3-field path (4 host devices)
# ---------------------------------------------------------------------------

DIST_CODE = """
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax.numpy as jnp
from repro.launch.mesh import make_stencil_mesh, compat_make_mesh
from repro.stencil import spec as SP
from repro.stencil import distributed as D
from repro.stencil.advection import stratus_fields
from repro.kernels.advection.ref import default_params

X, Y, Z = 8, 12, 8
p = default_params(Z)
u, v, w = stratus_fields(X, Y, Z)
q = SP.tracer_field(X, Y, Z)
mesh = make_stencil_mesh(2, 2)
pw = SP.pw_advection_spec()
for T in (1, 2):
    legacy = D.make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                                     dt=0.01)(u, v, w)
    via_spec = D.make_distributed_step(mesh, p, axis="y", x_axis="x", T=T,
                                       dt=0.01, spec=pw,
                                       spec_params=p)(u, v, w)
    for a, b in zip(legacy, via_spec):
        assert np.array_equal(np.asarray(a), np.asarray(b)), T

# tracer: fused local kernel bitwise vs reference; run == sequential steps
tr = SP.tracer_advection_spec()
st_r = D.make_distributed_step(mesh, p, axis="y", x_axis="x", T=2, dt=0.01,
                               spec=tr, spec_params=p)
st_f = D.make_distributed_step(mesh, p, axis="y", x_axis="x", T=2, dt=0.01,
                               spec=tr, spec_params=p,
                               local_kernel="fused", y_tile=4)
outr, outf = st_r(u, v, w, q), st_f(u, v, w, q)
for a, b in zip(outr, outf):
    assert np.array_equal(np.asarray(a), np.asarray(b))
run = D.make_distributed_run(mesh, p, n_blocks=2, axis="y", x_axis="x",
                             T=2, dt=0.01, spec=tr, spec_params=p)
seq = st_r(*st_r(u, v, w, q))
for a, b in zip(run(u, v, w, q), seq):
    assert np.array_equal(np.asarray(a), np.asarray(b))

# rk2 diffusion: deeper exchange vs the single-device oracle
mesh1 = compat_make_mesh((4,), ("data",))
dspec = SP.diffusion_spec("rk2")
dp = SP.default_diffusion_params(Z)
phi = SP.diffusion_field(X, Y, Z)
out = D.make_distributed_step(mesh1, p, axis="data", T=2, dt=1e-3,
                              spec=dspec, spec_params=dp)(phi)
ref = D.reference_global_spec_step((phi,), dp, dspec, T=2, dt=1e-3)
err = float(jnp.max(jnp.abs(out[0] - ref[0])))
assert err < 1e-5, err
print("OK")
"""


def test_distributed_spec_path_bitwise_and_oracle():
    run_ok(DIST_CODE)

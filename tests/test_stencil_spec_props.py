"""Property tests for the stencil-spec frontend (via the `tests/_prop.py`
shim: hypothesis when installed, fixed-seed sweep otherwise).

  * random well-formed `StencilSpec`s round-trip validation and report
    the radius/stages/halo the offsets imply;
  * malformed specs are rejected with errors NAMING the offending field
    and offset (the error is the API — callers debug specs through it);
  * the halo invariant: `exchange depth == max|offset| * stages * T`,
    checked against `_band_schedule`'s partition of the exchanged bands
    (the per-hop counts must sum to exactly `spec.halo(T)` and tile the
    hi/lo halo regions gaplessly for ANY local extent).
"""
import jax.numpy as jnp
import pytest

from _prop import given, settings, st

from repro.kernels.advection.advection import _band_schedule
from repro.stencil import spec as SP

OFF = st.tuples(st.integers(-2, 2), st.integers(-2, 2), st.integers(-2, 2))


def _src_one(sh, pv):
    return (sh(0, 0, 0, 0),)


def _make_spec(offs, integrator="euler", fields=("a",)):
    return SP.StencilSpec(
        name="prop", fields=tuple(fields),
        offsets={f: tuple(offs) for f in fields},
        source=_src_one, pack_params=lambda p: (p,),
        integrator=integrator)


# ---------------------------------------------------------------------------
# round-trip: well-formed specs validate and expose the implied geometry
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(offs=st.lists(OFF, min_size=1, max_size=6),
       integrator=st.sampled_from(["euler", "rk2"]),
       T=st.integers(1, 5),
       n_fields=st.integers(1, 4))
def test_spec_roundtrip_and_halo_formula(offs, integrator, T, n_fields):
    offs = [tuple(int(c) for c in o) for o in offs]
    if not any(c != 0 for o in offs for c in o):
        offs.append((0, 1, 0))
    fields = tuple(f"f{i}" for i in range(n_fields))
    spec = _make_spec(offs, integrator, fields)
    r = max(abs(c) for o in offs for c in o)
    s = 2 if integrator == "rk2" else 1
    assert spec.radius == r
    assert spec.stages == s
    assert spec.n_fields == n_fields
    assert spec.halo(T) == r * s * T
    with pytest.raises(ValueError, match="T must be"):
        spec.halo(0)


# ---------------------------------------------------------------------------
# rejection: the error names the offending field / offset
# ---------------------------------------------------------------------------


def test_rejects_malformed_offset_naming_field_and_offset():
    with pytest.raises(ValueError, match=r"'a'.*\(1, 0\).*3-tuple"):
        _make_spec([(1, 0)])
    with pytest.raises(ValueError, match=r"'a'.*True.*bool"):
        _make_spec([(True, 0, 0)])
    with pytest.raises(ValueError, match=r"'a'.*1\.5.*float"):
        _make_spec([(1.5, 0, 0)])


def test_rejects_structural_spec_errors():
    with pytest.raises(ValueError, match="duplicate field name 'a'"):
        _make_spec([(1, 0, 0)], fields=("a", "a"))
    with pytest.raises(ValueError, match="'b' has no stencil offsets"):
        SP.StencilSpec(name="x", fields=("a", "b"),
                       offsets={"a": ((1, 0, 0),)},
                       source=_src_one, pack_params=lambda p: (p,))
    with pytest.raises(ValueError, match="unknown field 'ghost'"):
        SP.StencilSpec(name="x", fields=("a",),
                       offsets={"a": ((1, 0, 0),), "ghost": ((1, 0, 0),)},
                       source=_src_one, pack_params=lambda p: (p,))
    with pytest.raises(ValueError, match="'a': offsets must be non-empty"):
        _make_spec([])
    with pytest.raises(ValueError, match="integrator must be one of"):
        _make_spec([(1, 0, 0)], integrator="rk9")
    with pytest.raises(ValueError, match="radius >= 1"):
        _make_spec([(0, 0, 0)])
    with pytest.raises(ValueError, match="boundary must be one of"):
        SP.StencilSpec(name="x", fields=("a",),
                       offsets={"a": ((1, 0, 0),)}, source=_src_one,
                       pack_params=lambda p: (p,), boundary="periodic")


def test_accessor_rejects_reads_beyond_declared_radius():
    """A source reaching past the declared star is a spec bug; the error
    names the field and the offending offset."""

    def greedy(sh, pv):
        return (sh(0, 2, 0, 0),)

    spec = SP.StencilSpec(name="x", fields=("a",),
                          offsets={"a": ((1, 0, 0),)}, source=greedy,
                          pack_params=lambda p: ())
    with pytest.raises(ValueError, match=r"'a'.*\(2, 0, 0\).*radius 1"):
        SP.spec_sources((jnp.zeros((6, 6, 6)),), None, spec)


# ---------------------------------------------------------------------------
# halo invariant vs the band schedule's partition
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(radius=st.integers(1, 3), T=st.integers(1, 6), L=st.integers(1, 8),
       integrator=st.sampled_from(["euler", "rk2"]))
def test_band_schedule_partitions_spec_halo(radius, T, L, integrator):
    spec = _make_spec([(radius, 0, 0)], integrator)
    D = spec.halo(T)
    sched = _band_schedule(L, D)
    # exchanged rows sum to exactly the spec's halo depth, in ceil(D/L) hops
    assert sum(cnt for _, cnt, _, _ in sched) == D
    assert len(sched) == -(-D // L)
    assert all(1 <= cnt <= L for _, cnt, _, _ in sched)
    # the hi bands tile [0, D) and the lo bands tile [D+L, D+L+D) of the
    # extended slab — gapless, non-overlapping, in ring order
    hi = sorted((off, off + cnt) for _, cnt, off, _ in sched)
    lo = sorted((off, off + cnt) for _, cnt, _, off in sched)
    assert hi[0][0] == 0 and hi[-1][1] == D
    assert lo[0][0] == D + L and lo[-1][1] == D + L + D
    for (a0, a1), (b0, b1) in zip(hi, hi[1:]):
        assert a1 == b0
    for (a0, a1), (b0, b1) in zip(lo, lo[1:]):
        assert a1 == b0

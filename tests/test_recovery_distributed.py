"""Checksummed halo exchange + checkpoint/resume for the distributed run.

Fast tier (1-device wiring, runs under `-m "not slow"`):
  * `roofline.integrity_bytes_model` values + validation (hop-count
    dependent, payload-size independent: one uint32 word per band
    message);
  * a verified step on an undecomposed mesh is BITWISE-equal to the
    unchecked step, reports zero mismatch flags, and counts zero
    integrity bytes == the model (on the hand-written AND the
    stencil-spec path — the integrity layer rides `spec=` builds too);
  * the integrity layer's build-time config errors (compiled Mosaic DMA
    has no checksum channel / injection hook);
  * `make_distributed_run(checkpoint_every=, checkpoint_dir=)` +
    `resume_distributed_run`: interrupted-and-resumed == uninterrupted
    BITWISE, and a tampered snapshot (wrong parity, wrong block index)
    is REFUSED with an error naming the inconsistency.

Slow tier (4-device subprocess sweeps, the bench-gate contracts at test
size): counted integrity bytes == model EXACTLY on both ppermute
engines (hand-written advection AND the spec-driven tracer operator, at
`n_fields=spec.n_fields` / `depth=spec.halo(T)`), checksummed clean run
bitwise == unchecked, injected corruption
detected (`HaloCorrupted`), multi-device checkpoint/resume bitwise, the
resilient driver's clean plan == `make_distributed_run` (the
dma_block_index parity regression), and elastic shrink/regrow bitwise.
"""
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from _subproc import run_ok
from repro.core import roofline as R
from repro.kernels.advection.advection import band_checksum
from repro.kernels.advection.ref import default_params
from repro.launch.mesh import compat_make_mesh, resize_stencil_mesh
from repro.stencil import distributed as D
from repro.stencil.advection import stratus_fields

X, Y, Z, T = 6, 16, 12, 2
DT = 0.005


# --- fast tier: the roofline model ------------------------------------------

def test_integrity_bytes_model_values():
    # ny=4, Yl=4, T=2 -> 1 hop; 2 sides * 3 fields * 1 hop * 4 bytes = 24
    assert R.integrity_bytes_model(X, Y, Z, ny=4, T=2) == 24
    # T=6 over Yl=4 -> ceil(6/4)=2 hops
    assert R.integrity_bytes_model(X, Y, Z, ny=4, T=6) == 48
    # both axes decomposed: hops add
    assert R.integrity_bytes_model(8, 16, Z, nx=2, ny=4, T=2) == \
        2 * 3 * (1 + 1) * R.INTEGRITY_WORD_ITEMSIZE
    # undecomposed mesh: no wire, no checksum words
    assert R.integrity_bytes_model(X, Y, Z) == 0
    # payload-size independent: same T/mesh, bigger Z, same bytes
    assert (R.integrity_bytes_model(X, Y, 4 * Z, ny=4, T=2)
            == R.integrity_bytes_model(X, Y, Z, ny=4, T=2))
    assert R.integrity_bytes_model(X, Y, Z, ny=4, T=2, n_fields=1) == 8


def test_integrity_bytes_model_validation():
    with pytest.raises(ValueError, match="mesh shape"):
        R.integrity_bytes_model(X, Y, Z, ny=0)
    with pytest.raises(ValueError, match="T must be"):
        R.integrity_bytes_model(X, Y, Z, T=0)
    with pytest.raises(ValueError, match="not divisible"):
        R.integrity_bytes_model(X, Y + 1, Z, ny=4)


def test_band_checksum_contract():
    g = jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4)
    ck = band_checksum(g)
    assert ck.shape == (1,) and ck.dtype == jnp.uint32
    # order-independent exact sum: permuting rows leaves it unchanged
    assert np.asarray(band_checksum(g[::-1])) == np.asarray(ck)
    # a single flipped bit changes it
    assert np.asarray(band_checksum(g.at[0, 0, 0].add(1.0))) != np.asarray(ck)
    with pytest.raises(TypeError, match="32-bit"):
        band_checksum(g.astype(jnp.float16))


# --- fast tier: 1-device wiring ---------------------------------------------

def _setup():
    u, v, w = stratus_fields(X, Y, Z, seed=0)
    return compat_make_mesh((1,), ("data",)), default_params(Z), (u, v, w)


def test_verified_step_one_device_bitwise_and_priced():
    mesh, p, (u, v, w) = _setup()
    kw = dict(axis="data", x_axis=None, T=T, dt=DT)
    for ex in ("collective", "remote_dma"):
        step0 = D.make_distributed_step(mesh, p, exchange=ex, **kw)
        stepv = D.make_distributed_step(mesh, p, exchange=ex,
                                        verify_integrity=True, **kw)
        o0 = step0(u, v, w)
        *ov, flags = stepv(u, v, w)
        for a, b in zip(o0, ov):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        D.check_integrity(flags)                      # clean: no raise
        assert int(np.sum(np.asarray(flags))) == 0
        # undecomposed: zero checksum words, and counted == model == 0
        assert D.count_integrity_bytes(stepv, u, v, w) == 0
        assert R.integrity_bytes_model(X, Y, Z, nx=1, ny=1, T=T) == 0


def test_check_integrity_raises_on_nonzero_flags():
    flags = np.zeros((4,), np.uint32)
    D.check_integrity(flags)
    flags[2] = 1
    with pytest.raises(D.HaloCorrupted, match="checksum"):
        D.check_integrity(flags)


def test_integrity_config_build_time_errors():
    mesh, p, _ = _setup()
    kw = dict(axis="data", x_axis=None, T=T, dt=DT, exchange="remote_dma",
              interpret=False)
    with pytest.raises(RuntimeError, match="checksum"):
        D.make_distributed_step(mesh, p, verify_integrity=True, **kw)
    with pytest.raises(RuntimeError, match="injection"):
        D.make_distributed_step(mesh, p, corrupt_halo=(0, 1, float("nan")),
                                **kw)
    with pytest.raises(ValueError, match="field index"):
        D.make_distributed_step(mesh, p, axis="data", x_axis=None, T=T,
                                dt=DT, corrupt_halo=(7, 1, float("nan")))
    with pytest.raises(ValueError, match="depth"):
        D.make_distributed_step(mesh, p, axis="data", x_axis=None, T=T,
                                dt=DT, corrupt_halo=(0, 0, float("nan")))


def test_spec_verified_step_one_device_bitwise_and_priced():
    # the integrity layer rides the SPEC path too: n_fields slabs, one
    # extra uint32 flag output, fields bitwise-identical to unchecked
    from repro.stencil.spec import tracer_advection_spec
    mesh, p, _ = _setup()
    spec = tracer_advection_spec()
    fields = stratus_fields(X, Y, Z, seed=1)
    fields = tuple(fields) + tuple(
        f * 0.5 for f in fields[:spec.n_fields - 3])
    kw = dict(axis="data", x_axis=None, T=T, dt=DT, spec=spec,
              spec_params=p)
    step0 = D.make_distributed_step(mesh, p, **kw)
    stepv = D.make_distributed_step(mesh, p, verify_integrity=True, **kw)
    o0 = step0(*fields)
    *ov, flags = stepv(*fields)
    assert len(ov) == spec.n_fields
    for a, b in zip(o0, ov):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    D.check_integrity(flags)
    assert int(np.sum(np.asarray(flags))) == 0
    # undecomposed: zero words counted == modelled at the spec's field
    # count and halo depth
    assert D.count_integrity_bytes(stepv, *fields) == 0
    assert R.integrity_bytes_model(X, Y, Z, nx=1, ny=1, T=T,
                                   n_fields=spec.n_fields,
                                   depth=spec.halo(T)) == 0
    # the spec path validates corrupt_halo against spec.n_fields
    with pytest.raises(ValueError, match="field index"):
        D.make_distributed_step(
            mesh, p, corrupt_halo=(spec.n_fields, 1, float("nan")), **kw)


def test_resize_stencil_mesh_validates():
    with pytest.raises(ValueError, match="mesh shape"):
        resize_stencil_mesh(1, 0)
    with pytest.raises(ValueError, match="devices"):
        resize_stencil_mesh(64, 64)
    m = resize_stencil_mesh(1, 1, y_axis="data")
    assert m.shape["data"] == 1


# --- fast tier: checkpoint / resume ----------------------------------------

def test_checkpoint_kwargs_come_together():
    mesh, p, _ = _setup()
    for kw in (dict(checkpoint_every=2), dict(checkpoint_dir="/tmp/x")):
        with pytest.raises(ValueError, match="together"):
            D.make_distributed_run(mesh, p, n_blocks=2, axis="data",
                                   x_axis=None, T=T, dt=DT, **kw)


def test_checkpointed_run_and_resume_bitwise(tmp_path):
    mesh, p, (u, v, w) = _setup()
    kw = dict(axis="data", x_axis=None, T=T, dt=DT, exchange="remote_dma")
    full = D.make_distributed_run(mesh, p, n_blocks=5, **kw)(u, v, w)

    ck = tmp_path / "ck"
    out = D.make_distributed_run(mesh, p, n_blocks=5, checkpoint_every=2,
                                 checkpoint_dir=str(ck), **kw)(u, v, w)
    for a, b in zip(full, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # interrupted at block 3 (separate dir), resumed to 5: bitwise == full
    part = tmp_path / "part"
    D.make_distributed_run(mesh, p, n_blocks=3, checkpoint_every=2,
                           checkpoint_dir=str(part), **kw)(u, v, w)
    res = D.resume_distributed_run(mesh, p, u, v, w, n_blocks=5,
                                   checkpoint_dir=str(part),
                                   checkpoint_every=2, **kw)
    for a, b in zip(full, res):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the resume wrote its own checkpoints: resuming again is a no-op
    # that returns the finished block-5 fields
    done = D.resume_distributed_run(mesh, p, u, v, w, n_blocks=5,
                                    checkpoint_dir=str(part), **kw)
    for a, b in zip(full, done):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpointed_run_with_verify_carries_flags(tmp_path):
    mesh, p, (u, v, w) = _setup()
    kw = dict(axis="data", x_axis=None, T=T, dt=DT, exchange="collective",
              verify_integrity=True)
    *full, ffl = D.make_distributed_run(mesh, p, n_blocks=4, **kw)(u, v, w)
    D.make_distributed_run(mesh, p, n_blocks=2, checkpoint_every=1,
                           checkpoint_dir=str(tmp_path), **kw)(u, v, w)
    *res, rfl = D.resume_distributed_run(mesh, p, u, v, w, n_blocks=4,
                                         checkpoint_dir=str(tmp_path), **kw)
    for a, b in zip(full, res):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.sum(np.asarray(rfl))) == 0


def test_resume_refuses_tampered_snapshots(tmp_path):
    from repro.training import checkpoint as CKPT

    mesh, p, (u, v, w) = _setup()
    kw = dict(axis="data", x_axis=None, T=T, dt=DT)
    uu, vv, ww = (np.asarray(a) for a in (u, v, w))

    # parity that contradicts the stored block index
    bad = {"u": uu, "v": vv, "w": ww, "block": np.int64(1),
           "parity": np.int64(0)}
    d1 = tmp_path / "parity"
    CKPT.save(d1, bad, 1)
    with pytest.raises(ValueError, match="parity"):
        D.resume_distributed_run(mesh, p, u, v, w, n_blocks=4,
                                 checkpoint_dir=str(d1), **kw)

    # step directory number that contradicts the stored block index
    bad = {"u": uu, "v": vv, "w": ww, "block": np.int64(1),
           "parity": np.int64(1)}
    d2 = tmp_path / "step"
    CKPT.save(d2, bad, 2)
    with pytest.raises(ValueError, match="block index"):
        D.resume_distributed_run(mesh, p, u, v, w, n_blocks=4,
                                 checkpoint_dir=str(d2), **kw)

    with pytest.raises(FileNotFoundError, match="no checkpoint"):
        D.resume_distributed_run(mesh, p, u, v, w, n_blocks=4,
                                 checkpoint_dir=str(tmp_path / "void"), **kw)


# --- slow tier: 4-device subprocess sweeps ----------------------------------

_PRELUDE = textwrap.dedent("""
    import os, tempfile
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_stencil_mesh
    from repro.kernels.advection.ref import default_params
    from repro.stencil.advection import stratus_fields
    from repro.stencil import distributed as D
    from repro.core import roofline as RL

    X, Y, Z, T, DT = 6, 16, 12, 2, 0.005
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = make_stencil_mesh(1, 4)
    kw = dict(axis="y", x_axis=None, T=T, dt=DT)

    def bw(a, b):
        assert all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(a, b))
""")

INTEGRITY_CODE = _PRELUDE + textwrap.dedent("""
    for ex in ("collective", "remote_dma"):
        step0 = D.make_distributed_step(mesh, p, exchange=ex, **kw)
        stepv = D.make_distributed_step(mesh, p, exchange=ex,
                                        verify_integrity=True, **kw)
        o0 = step0(u, v, w)
        *ov, fl = stepv(u, v, w)
        bw(o0, ov)                                  # checksums change nothing
        assert int(np.sum(np.asarray(fl))) == 0, ex
        counted = D.count_integrity_bytes(stepv, u, v, w)
        model = RL.integrity_bytes_model(X, Y, Z, nx=1, ny=4, T=T)
        assert counted == model == 24, (ex, counted, model)
        # the FIELD wire bytes are verify-invariant; unchecked = 0 words
        assert (D.count_exchange_wire_bytes(step0, u, v, w)
                == D.count_exchange_wire_bytes(stepv, u, v, w)), ex
        assert D.count_integrity_bytes(step0, u, v, w) == 0, ex
        # injected wire damage trips the receiver-side checksum
        stepc = D.make_distributed_step(mesh, p, exchange=ex,
                                        verify_integrity=True,
                                        corrupt_halo=(0, 1, float("nan")),
                                        **kw)
        *oc, flc = stepc(u, v, w)
        assert int(np.sum(np.asarray(flc))) > 0, ex
        try:
            D.check_integrity(flc)
            raise SystemExit("corruption not raised")
        except D.HaloCorrupted:
            pass
    # multi-hop: T=6 over Yl=4 -> 2 hops -> 2x the words
    stepm = D.make_distributed_step(mesh, p, axis="y", x_axis=None, T=6,
                                    dt=DT, verify_integrity=True)
    assert (D.count_integrity_bytes(stepm, u, v, w)
            == RL.integrity_bytes_model(X, Y, Z, nx=1, ny=4, T=6) == 48)
    print("OK")
""")

CKPT_CODE = _PRELUDE + textwrap.dedent("""
    full = D.make_distributed_run(mesh, p, n_blocks=5,
                                  exchange="remote_dma", **kw)(u, v, w)
    with tempfile.TemporaryDirectory() as d:
        out = D.make_distributed_run(mesh, p, n_blocks=5, checkpoint_every=2,
                                     checkpoint_dir=d, exchange="remote_dma",
                                     **kw)(u, v, w)
        bw(full, out)
    with tempfile.TemporaryDirectory() as d:
        D.make_distributed_run(mesh, p, n_blocks=3, checkpoint_every=2,
                               checkpoint_dir=d, exchange="remote_dma",
                               **kw)(u, v, w)
        res = D.resume_distributed_run(mesh, p, u, v, w, n_blocks=5,
                                       checkpoint_dir=d, checkpoint_every=2,
                                       exchange="remote_dma", **kw)
        bw(full, res)
    print("OK")
""")

RESILIENT_CODE = _PRELUDE + textwrap.dedent("""
    from repro.serving import faults as F

    rkw = dict(n_blocks=4, T=T, dt=DT, axis="y", x_axis=None)
    clean = D.make_distributed_run(mesh, p, exchange="remote_dma",
                                   **rkw)(u, v, w)
    # the dma_block_index parity regression: clean plan == the pipelined run
    out, inj = F.resilient_distributed_run(mesh, p, u, v, w, **rkw)
    bw(clean, out)
    assert inj.health()["rollbacks"] == 0

    # injected halo corruption: detected by the band checksums, one
    # bounded replay from the last snapshot, final fields bitwise
    plan = F.FaultPlan.parse("halo_corruption@2:field=v")
    out, inj = F.resilient_distributed_run(mesh, p, u, v, w,
                                           injector=F.FaultInjector(plan),
                                           **rkw)
    h = inj.health()
    bw(clean, out)
    assert h["rollbacks"] == 1 and h["faults_skipped"] == 0
    assert any("checksum" in t for t in h["transitions"])

    # elastic: lose devices (4->2), regrow (2->4); fused kernel with a
    # fixed y_tile keeps per-tile arithmetic shard-shape independent,
    # so the whole trajectory is bitwise vs the never-interrupted run
    fkw = dict(n_blocks=4, T=T, dt=DT, axis="y", x_axis=None,
               local_kernel="fused", y_tile=2)
    cleanf = D.make_distributed_run(mesh, p, exchange="remote_dma",
                                    **fkw)(u, v, w)
    plan = F.FaultPlan.parse(
        "device_loss@1:reshard_to=2;device_loss@3:reshard_to=4")
    out, inj = F.resilient_distributed_run(mesh, p, u, v, w,
                                           injector=F.FaultInjector(plan),
                                           **fkw)
    h = inj.health()
    bw(cleanf, out)
    assert h["device_losses"] == 2 and h["reshards"] == 2
    print("OK")
""")


SPEC_INTEGRITY_CODE = _PRELUDE + textwrap.dedent("""
    from repro.stencil.spec import tracer_advection_spec

    spec = tracer_advection_spec()
    mesh2 = make_stencil_mesh(2, 2)
    GX, GY = 8, 8
    key = jax.random.PRNGKey(3)
    fields = tuple(jax.random.normal(jax.random.fold_in(key, i),
                                     (GX, GY, Z), jnp.float32) * 0.01
                   for i in range(spec.n_fields))
    skw = dict(axis="y", x_axis="x", T=1, dt=DT, spec=spec, spec_params=p)
    model = RL.integrity_bytes_model(GX, GY, Z, nx=2, ny=2, T=1,
                                     n_fields=spec.n_fields,
                                     depth=spec.halo(1))
    for ex in ("collective", "remote_dma"):
        step0 = D.make_distributed_step(mesh2, p, exchange=ex, **skw)
        stepv = D.make_distributed_step(mesh2, p, exchange=ex,
                                        verify_integrity=True, **skw)
        o0 = step0(*fields)
        *ov, fl = stepv(*fields)
        bw(o0, ov)                       # checksums change nothing
        assert int(np.sum(np.asarray(fl))) == 0, ex
        counted = D.count_integrity_bytes(stepv, *fields)
        assert counted == model > 0, (ex, counted, model)
        # field wire bytes are verify-invariant at the spec's depth
        assert (D.count_exchange_wire_bytes(step0, *fields)
                == D.count_exchange_wire_bytes(stepv, *fields)), ex
        # injected wire damage on the LAST (tracer) field is caught
        stepc = D.make_distributed_step(
            mesh2, p, exchange=ex, verify_integrity=True,
            corrupt_halo=(spec.n_fields - 1, 1, float("nan")), **skw)
        *oc, flc = stepc(*fields)
        assert int(np.sum(np.asarray(flc))) > 0, ex
        try:
            D.check_integrity(flc)
            raise SystemExit("spec corruption not raised")
        except D.HaloCorrupted:
            pass
    # the verified RUN accumulates flags across blocks and stays bitwise
    run0 = D.make_distributed_run(mesh2, p, n_blocks=3, **skw)
    runv = D.make_distributed_run(mesh2, p, n_blocks=3,
                                  verify_integrity=True, **skw)
    o0 = run0(*fields)
    *ov, fl = runv(*fields)
    bw(o0, ov)
    assert int(np.sum(np.asarray(fl))) == 0
    # ONE traced block: the run's per-block words == the step's
    assert D.count_integrity_bytes(runv, *fields) == model
    print("OK")
""")


@pytest.mark.slow
def test_integrity_counted_equals_model_multidevice():
    run_ok(INTEGRITY_CODE, timeout=600)


@pytest.mark.slow
def test_spec_integrity_counted_equals_model_multidevice():
    run_ok(SPEC_INTEGRITY_CODE, timeout=600)


@pytest.mark.slow
def test_checkpoint_resume_multidevice_bitwise():
    run_ok(CKPT_CODE, timeout=600)


@pytest.mark.slow
def test_resilient_run_parity_corruption_elastic_multidevice():
    run_ok(RESILIENT_CODE, timeout=600)

"""Seed-determinism regression: the initial-condition generators are part
of the reproducibility contract.

Every differential gate in this repo (bitwise kernel parity, distributed
vs oracle, the BENCH trend baselines) assumes `stratus_fields` /
`tracer_field` / `diffusion_field` produce the SAME bytes on every run
and every machine. A silent RNG or init-formula change would shift every
downstream number while each individual gate kept passing against its
own freshly generated inputs — so the content hashes are pinned here.

If one of these fails after an intentional init change: regenerate the
hashes (the assert message prints the new value), update the pins, and
expect to re-baseline `benchmarks/baselines.json` in the same commit.
"""
import hashlib

import jax.numpy as jnp
import numpy as np

from repro.stencil import spec as SP
from repro.stencil.advection import stratus_fields

SHAPE = (8, 10, 8)

PINNED = {
    "u": "195d0ce8471c66833b113445574b08d05b053fd7410e0a1f75e4badee85cb349",
    "v": "51a5d1872a214ab1ab5170b406f91e67f12a9e8acaaf37a608ede91fcb6441b5",
    "w": "a56ca1671aa89d367ab70e0b12a0c1f03c67d80633f74cff11df93d0da6a8b37",
    "q": "0c6e5ce4c464a7b0a694a93de6db212ce0292c723a14ba1eaf9da61cd73fdffe",
    "phi": "6779ad1c4b2cfcf0756335d0c28d3dce729495618672c79d3f896b44b09479df",
    "u_bf16":
        "c36924470754f775807d047a9b46472b18872373423bd4492d9110d6ff972513",
}


def _sha(a) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(np.asarray(a)).tobytes()).hexdigest()


def _check(name, arr):
    got = _sha(arr)
    assert got == PINNED[name], (
        f"init field {name!r} changed content hash: expected "
        f"{PINNED[name]}, got {got}. If the init change is intentional, "
        f"update the pin AND re-baseline benchmarks/baselines.json.")


def test_stratus_fields_content_pinned():
    X, Y, Z = SHAPE
    u, v, w = stratus_fields(X, Y, Z)
    _check("u", u)
    _check("v", v)
    _check("w", w)


def test_spec_operator_fields_content_pinned():
    X, Y, Z = SHAPE
    _check("q", SP.tracer_field(X, Y, Z))
    _check("phi", SP.diffusion_field(X, Y, Z))


def test_dtype_cast_is_deterministic_too():
    """The bf16 ladder rungs cast at init; that cast is pinned as well."""
    X, Y, Z = SHAPE
    u, _, _ = stratus_fields(X, Y, Z, dtype=jnp.bfloat16)
    _check("u_bf16", u)


def test_generators_are_call_stable():
    """Two calls in the same process agree bitwise (no hidden global RNG
    state), and distinct seeds actually differ."""
    X, Y, Z = SHAPE
    a = SP.tracer_field(X, Y, Z)
    b = SP.tracer_field(X, Y, Z)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = SP.tracer_field(X, Y, Z, seed=99)
    assert not np.array_equal(np.asarray(a), np.asarray(c))

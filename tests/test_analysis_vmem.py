"""VMEM budget pass: every shipped config's on-chip buffers statically
summed against `roofline.VMEM_PER_CORE` BEFORE anything compiles.

All fast tier (1-device): plan arithmetic pinned to the kernel sizing
formulas (`fused_register_bytes`, `dma_slab_bytes`), `check()` raising a
`VmemBudgetExceeded` that NAMES the largest buffer, `plan_max_batch` ==
`roofline.serving_max_batch` (the pass and the serving-only bound can
never drift), and the two trace/alloc-time integration points: an
over-budget fused distributed config refused while TRACING (before
compile), and the serving engine's `_alloc` refusing an over-budget
batch at construction.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (VmemBudgetExceeded, VmemBuffer, VmemPlan,
                            plan_max_batch)
from repro.analysis.vmem import (distributed_block_plan, fused_ring_plan,
                                 serving_ring_plan)
from repro.core import roofline as R
from repro.kernels.advection.advection import (dma_slab_bytes,
                                               fused_register_bytes)
from repro.kernels.advection.ref import default_params
from repro.launch.mesh import make_stencil_mesh
from repro.serving.stencil_engine import StencilServingEngine
from repro.stencil.advection import AdvectionDomain
from repro.stencil.distributed import make_distributed_step
from repro.stencil.spec import tracer_advection_spec


def test_plan_arithmetic_and_table():
    plan = VmemPlan((VmemBuffer("a", 100), VmemBuffer("b", 50, "why")),
                    budget=200, context="unit")
    assert plan.total() == 150
    assert plan.headroom() == 50
    assert plan.fits()
    assert plan.check() is plan
    assert "TOTAL" in plan.table() and "why" in plan.table()


def test_check_raises_naming_largest_buffer():
    plan = VmemPlan((VmemBuffer("small ring", 100),
                     VmemBuffer("huge recv slab", 10 ** 9, "depth=64")),
                    budget=2 ** 20, context="unit-overflow")
    assert not plan.fits() and plan.headroom() < 0
    with pytest.raises(VmemBudgetExceeded) as ei:
        plan.check()
    msg = str(ei.value)
    assert "huge recv slab" in msg and "unit-overflow" in msg
    assert "small ring" in msg            # full table rides the error


def test_fused_ring_plan_matches_register_bytes():
    plan = fused_ring_plan(64, 128, T=4, y_tile=8, halo=4)
    assert plan.total() == fused_register_bytes(4, 64, 128, 4, 8, 4)
    # batch multiplies the slot ring
    b4 = fused_ring_plan(64, 128, T=4, y_tile=8, halo=4, batch=4)
    assert b4.total() == 4 * plan.total()
    assert "batch=4" in b4.buffers[0].name


def test_serving_ring_plan_and_max_batch_agree():
    Y, Z, T = 64, 128, 4
    per_slot = fused_register_bytes(T, Y, Z, 4, None)
    assert serving_ring_plan(Y, Z, batch=1, T=T).total() == per_slot
    mb = plan_max_batch(Y, Z, T=T)
    assert mb == R.serving_max_batch(per_slot, vmem_budget=R.VMEM_PER_CORE)
    # the plan at max batch fits; one slot past it does not
    assert serving_ring_plan(Y, Z, batch=mb, T=T).fits()
    assert not serving_ring_plan(Y, Z, batch=mb + 1, T=T).fits()


def test_distributed_block_plan_fused_and_dma_slabs():
    shard = (8, 16, 128)
    # fused local kernel on a y-decomposed mesh: ring over the
    # halo-extended rows
    p = distributed_block_plan(shard, T=2, local_kernel="fused",
                               exchange="collective", interpret=True, ny=4)
    assert p.total() == fused_register_bytes(2, 16 + 2 * 2, 128, 4, None)
    # compiled remote-DMA on a 2D mesh adds stage+recv slabs per phase
    d = distributed_block_plan(shard, T=2, local_kernel="reference",
                               exchange="remote_dma", interpret=False,
                               nx=2, ny=2)
    sx, rx = dma_slab_bytes(shard, 2, 0, 4)
    sy, ry = dma_slab_bytes((8 + 4, 16, 128), 2, 1, 4)
    assert p.buffers[0].name.startswith("fused shift-register ring")
    assert d.total() == sx + rx + sy + ry
    assert len(d.buffers) == 4
    # interpret-mode DMA emulation stages nothing in VMEM
    i = distributed_block_plan(shard, T=2, local_kernel="reference",
                               exchange="remote_dma", interpret=True,
                               nx=2, ny=2)
    assert i.total() == 0


def test_distributed_block_plan_spec_geometry():
    spec = tracer_advection_spec()
    shard = (8, 16, 128)
    T = 2
    p = distributed_block_plan(shard, T=T, local_kernel="fused",
                               exchange="collective", interpret=True,
                               ny=4, spec=spec)
    depth = spec.halo(T)
    want = fused_register_bytes(T, 16 + 2 * depth, 128, 4, None,
                                depth, n_fields=spec.n_fields,
                                n_slots=2 * spec.radius + 1,
                                n_levels=spec.stages * T)
    assert p.total() == want


def test_oversized_distributed_build_refused_at_trace_time():
    # an untiled fused ring over a tall shard must be refused while
    # TRACING the step — before compile, naming the ring buffer
    mesh = make_stencil_mesh(1, 1)
    p = default_params(128)
    big = jnp.zeros((8, 16384, 128), jnp.float32)
    step = make_distributed_step(mesh, p, axis="y", x_axis=None, T=8,
                                 local_kernel="fused")
    with pytest.raises(VmemBudgetExceeded, match="shift-register ring"):
        jax.make_jaxpr(lambda u, v, w: step(u, v, w))(big, big, big)
    # the tiled equivalent of the same config traces fine
    tiled = make_distributed_step(mesh, p, axis="y", x_axis=None, T=8,
                                  local_kernel="fused", y_tile=8)
    jax.make_jaxpr(lambda u, v, w: tiled(u, v, w))(big, big, big)


def test_serving_engine_alloc_checks_budget():
    # a modest domain constructs fine...
    eng = StencilServingEngine(
        AdvectionDomain(6, 16, 12, variant="fused", fuse_T=2, dt=0.005),
        batch_size=2)
    assert eng is not None
    # ...an over-budget slot ring is refused at construction, naming the
    # batched rings (the untiled Y makes each slot ring Y-proportional)
    with pytest.raises(VmemBudgetExceeded, match="slot rings"):
        StencilServingEngine(
            AdvectionDomain(8, 65536, 128, variant="fused", fuse_T=8,
                            dt=0.005),
            batch_size=8)

"""Overlap accounting: `RooflineTerms` exposed-vs-hidden collective
seconds, the engine overlap-efficiency model, and the remote-DMA schedule's
wire bytes — all pinned to `halo_wire_bytes_model` across (nx, ny, T) —
plus the clear-error contract for `exchange="remote_dma"` on non-TPU
backends in compiled mode.
"""
import pytest

from repro.core import roofline as R
from repro.core.roofline import (RooflineTerms, halo_wire_bytes_model,
                                 interior_compute_fraction,
                                 overlap_efficiency_model,
                                 pipeline_efficiency_model)
from repro.stencil.advection import AdvectionDomain
from repro.stencil.distributed import remote_dma_schedule_wire_bytes


def _terms(wire_bytes, eff, flops=1e12, hbm=1e9):
    return RooflineTerms(flops_per_dev=flops, hbm_bytes_per_dev=hbm,
                         ici_wire_bytes=wire_bytes, dcn_wire_bytes=0.0,
                         n_chips=4, overlap_efficiency=eff)


# --- RooflineTerms hidden/exposed split ------------------------------------

@pytest.mark.parametrize("eff", [0.0, 0.25, 0.5, 1.0])
def test_hidden_plus_exposed_is_collective(eff):
    t = _terms(3e9, eff)
    assert t.collective_hidden_s + t.collective_exposed_s == \
        pytest.approx(t.collective_s)
    assert t.collective_hidden_s >= 0.0
    assert t.collective_exposed_s >= 0.0


def test_zero_efficiency_exposes_everything():
    t = _terms(3e9, 0.0)
    assert t.collective_hidden_s == 0.0
    assert t.collective_exposed_s == pytest.approx(t.collective_s)
    assert t.overlapped_step_time_s == pytest.approx(
        max(t.compute_s, t.memory_s) + t.collective_s)


def test_hidden_bounded_by_onchip_work():
    """A huge exchange over tiny compute cannot hide more than the on-chip
    time, even at efficiency 1."""
    t = _terms(1e12, 1.0, flops=1e9, hbm=1e6)
    onchip = max(t.compute_s, t.memory_s)
    assert t.collective_hidden_s == pytest.approx(onchip)
    assert t.collective_exposed_s == pytest.approx(t.collective_s - onchip)


def test_exposed_monotone_decreasing_in_efficiency():
    exposed = [_terms(3e9, e).collective_exposed_s
               for e in (0.0, 0.3, 0.6, 1.0)]
    assert exposed == sorted(exposed, reverse=True)
    assert exposed[0] > exposed[-1]


def test_overlapped_step_time_between_bounds():
    t = _terms(3e9, 0.5)
    assert t.step_time_s <= t.overlapped_step_time_s <= t.no_overlap_s


def test_overlap_efficiency_validation():
    with pytest.raises(ValueError, match="overlap_efficiency"):
        _terms(1e9, 1.5)
    with pytest.raises(ValueError, match="overlap_efficiency"):
        _terms(1e9, -0.1)


def test_overlapped_bound_ranks_exposed_seconds():
    """`bound` ranks the raw collective_s; `overlapped_bound` ranks what
    is actually left on the critical path — a well-hidden exchange must
    stop reporting 'collective'-bound."""
    # wire time dominates raw (collective_s = 1.5x memory_s > compute_s),
    # but 95% of the hideable part is hidden -> exposed falls below the
    # memory term (hidden = 0.95 * memory_s, exposed = 0.55 * memory_s)
    t = _terms(1e12 * R.ICI_BW / R.HBM_BW * 1.5, 0.95,
               flops=1e6, hbm=1e12)
    assert t.bound == "collective"
    assert t.collective_exposed_s < t.memory_s
    assert t.overlapped_bound == "memory"
    # nothing hidden: the two rankings agree
    t0 = _terms(3e9, 0.0, flops=1e6, hbm=1e3)
    assert t0.overlapped_bound == t0.bound == "collective"
    d = t.as_dict()
    assert d["overlapped_bound"] == "memory"
    assert d["bound"] == "collective"


# --- pipelined multi-block efficiency model ---------------------------------

def test_pipeline_efficiency_collective_is_k_independent():
    frac = 0.8
    for K in (1, 2, 16):
        assert pipeline_efficiency_model(
            n_blocks=K, overlap=True, exchange="collective",
            interior_fraction=frac) == pytest.approx(
                frac * R.XLA_OVERLAP_DISCOUNT)


def test_pipeline_efficiency_remote_dma_fill_penalty():
    """K=1 hides nothing (the kernel serialises its own waits); K blocks
    pay exactly one fill block; the steady state approaches the
    single-block interior-fraction figure from below."""
    frac = 0.8
    assert pipeline_efficiency_model(
        n_blocks=1, overlap=True, exchange="remote_dma",
        interior_fraction=frac) == 0.0
    effs = [pipeline_efficiency_model(
        n_blocks=K, overlap=True, exchange="remote_dma",
        interior_fraction=frac) for K in (2, 4, 16, 1024)]
    assert effs == sorted(effs)
    assert effs[0] == pytest.approx(frac / 2)
    assert effs[-1] < frac
    assert effs[-1] == pytest.approx(frac, rel=1e-2)


def test_pipeline_efficiency_validation_and_no_overlap():
    with pytest.raises(ValueError, match="n_blocks"):
        pipeline_efficiency_model(n_blocks=0, overlap=True)
    with pytest.raises(ValueError, match="exchange engine"):
        pipeline_efficiency_model(n_blocks=2, overlap=True,
                                  exchange="carrier_pigeon")
    for ex in ("collective", "remote_dma"):
        assert pipeline_efficiency_model(n_blocks=8, overlap=False,
                                         exchange=ex,
                                         interior_fraction=0.9) == 0.0


# --- engine efficiency model -----------------------------------------------

def test_efficiency_model_no_overlap_is_zero():
    for ex in ("collective", "remote_dma"):
        assert overlap_efficiency_model(overlap=False, exchange=ex,
                                        interior_fraction=0.9) == 0.0


def test_efficiency_model_remote_dma_beats_collective():
    frac = 0.8
    coll = overlap_efficiency_model(overlap=True, exchange="collective",
                                    interior_fraction=frac)
    dma = overlap_efficiency_model(overlap=True, exchange="remote_dma",
                                   interior_fraction=frac)
    assert dma == pytest.approx(frac)
    assert coll == pytest.approx(frac * R.XLA_OVERLAP_DISCOUNT)
    assert dma > coll


def test_efficiency_model_validation():
    with pytest.raises(ValueError, match="exchange engine"):
        overlap_efficiency_model(overlap=True, exchange="carrier_pigeon")
    with pytest.raises(ValueError, match="interior_fraction"):
        overlap_efficiency_model(overlap=True, interior_fraction=1.2)


@pytest.mark.parametrize("Xl,Yl,T,nx,ny,expect", [
    (256, 64, 8, 16, 16, (240 / 256) * (48 / 64)),
    (256, 64, 8, 1, 16, 48 / 64),       # undecomposed x: no x band
    (256, 64, 8, 16, 1, 240 / 256),
    (8, 8, 4, 2, 2, 0.0),               # bands swallow the shard
    (100, 100, 1, 1, 1, 1.0),
])
def test_interior_compute_fraction(Xl, Yl, T, nx, ny, expect):
    assert interior_compute_fraction(Xl, Yl, T, nx=nx, ny=ny) == \
        pytest.approx(expect)


def test_interior_compute_fraction_validation():
    with pytest.raises(ValueError):
        interior_compute_fraction(0, 8, 1)
    with pytest.raises(ValueError):
        interior_compute_fraction(8, 8, 0)


# --- consistency with the wire model across (nx, ny, T) --------------------

SWEEP = [(nx, ny, T) for nx, ny in ((1, 4), (4, 1), (2, 2), (4, 4), (16, 16))
         for T in (1, 4, 8)]


@pytest.mark.parametrize("nx,ny,T", SWEEP)
def test_exposed_seconds_consistent_with_wire_model(nx, ny, T):
    """The split prices exactly the modelled wire bytes: exposed + hidden
    reconstruct wire/bw, and overlap strictly cuts the exposed time vs the
    overlap=False baseline whenever there is an exchange to hide."""
    X, Y, Z = 4096, 1024, 64
    wire = halo_wire_bytes_model(X, Y, Z, 4, nx=nx, ny=ny, T=T)
    base = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T,
                           mesh_nx=nx, mesh_ny=ny)
    assert base.roofline_terms().ici_wire_bytes == wire
    t_off = base.roofline_terms()
    assert t_off.collective_exposed_s == pytest.approx(wire / t_off.ici_bw)
    for ex in ("collective", "remote_dma"):
        t_on = AdvectionDomain(
            X, Y, Z, variant="fused", fuse_T=T, mesh_nx=nx, mesh_ny=ny,
            exchange=ex, overlap=True).roofline_terms()
        assert (t_on.collective_hidden_s + t_on.collective_exposed_s
                == pytest.approx(t_on.collective_s))
        assert t_on.collective_exposed_s < t_off.collective_exposed_s
    dma = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T, mesh_nx=nx,
                          mesh_ny=ny, exchange="remote_dma",
                          overlap=True).roofline_terms()
    coll = AdvectionDomain(X, Y, Z, variant="fused", fuse_T=T, mesh_nx=nx,
                           mesh_ny=ny, exchange="collective",
                           overlap=True).roofline_terms()
    assert dma.collective_exposed_s < coll.collective_exposed_s


@pytest.mark.parametrize("nx,ny,T", SWEEP + [(4, 4, 40), (2, 8, 70)])
def test_dma_schedule_bytes_match_model_exactly(nx, ny, T):
    """The remote-DMA engine's per-hop band messages (summed independently
    of the closed form, multi-hop included) put EXACTLY the modelled bytes
    on the wire — the schedule and the pricing can never drift apart."""
    X, Y, Z = 256, 128, 64
    sched = remote_dma_schedule_wire_bytes(X // nx, Y // ny, Z, 4,
                                           nx=nx, ny=ny, T=T)
    model = halo_wire_bytes_model(X, Y, Z, 4, nx=nx, ny=ny, T=T)
    assert sched == model


# --- AdvectionDomain plumbing ----------------------------------------------

def test_domain_overlap_efficiency_values():
    kw = dict(variant="fused", fuse_T=8, mesh_nx=16, mesh_ny=16)
    dom = AdvectionDomain(4096, 1024, 64, **kw)
    assert dom.overlap_efficiency() == 0.0          # overlap=False default
    frac = interior_compute_fraction(256, 64, 8, nx=16, ny=16)
    on = AdvectionDomain(4096, 1024, 64, overlap=True, **kw)
    assert on.overlap_efficiency() == pytest.approx(
        frac * R.XLA_OVERLAP_DISCOUNT)
    dma = AdvectionDomain(4096, 1024, 64, overlap=True,
                          exchange="remote_dma", **kw)
    assert dma.overlap_efficiency() == pytest.approx(frac)
    single = AdvectionDomain(64, 64, 64, variant="fused", overlap=True)
    assert single.overlap_efficiency() == 0.0       # nothing to exchange


def test_domain_rejects_unknown_exchange():
    with pytest.raises(ValueError, match="exchange"):
        AdvectionDomain(16, 16, 16, exchange="smoke_signals")


def test_domain_pipeline_efficiency_plumbing():
    """n_blocks threads the pipelined model into roofline_terms (n_blocks
    > 1), while n_blocks=1 keeps the single-block figure — BENCH_overlap
    back-compat."""
    kw = dict(variant="fused", fuse_T=8, mesh_nx=16, mesh_ny=16,
              overlap=True, exchange="remote_dma")
    frac = interior_compute_fraction(256, 64, 8, nx=16, ny=16)
    one = AdvectionDomain(4096, 1024, 64, **kw)
    assert one.pipeline_efficiency() == 0.0
    assert one.roofline_terms().overlap_efficiency == pytest.approx(frac)
    k8 = AdvectionDomain(4096, 1024, 64, n_blocks=8, **kw)
    assert k8.pipeline_efficiency() == pytest.approx(frac * 7 / 8)
    assert k8.roofline_terms().overlap_efficiency == pytest.approx(
        frac * 7 / 8)
    coll = AdvectionDomain(4096, 1024, 64, variant="fused", fuse_T=8,
                           mesh_nx=16, mesh_ny=16, overlap=True,
                           n_blocks=8)
    assert coll.pipeline_efficiency() == pytest.approx(
        frac * R.XLA_OVERLAP_DISCOUNT)
    single = AdvectionDomain(64, 64, 64, variant="fused", overlap=True,
                             n_blocks=8)
    assert single.pipeline_efficiency() == 0.0  # nothing to exchange
    with pytest.raises(ValueError, match="n_blocks"):
        AdvectionDomain(16, 16, 16, n_blocks=0)


# --- compiled-mode backend gate --------------------------------------------

def test_remote_dma_compiled_requires_tpu():
    """On this (CPU) backend, building the compiled remote-DMA step must
    fail loudly at build time — not at first call — and say why."""
    import jax
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.distributed import make_distributed_step

    if jax.default_backend() == "tpu":
        pytest.skip("this asserts the NON-TPU error path")
    mesh = make_stencil_mesh(1, 1)
    with pytest.raises(RuntimeError, match="TPU backend"):
        make_distributed_step(mesh, default_params(8), axis="y", x_axis="x",
                              T=2, exchange="remote_dma", interpret=False)


def test_unknown_exchange_engine_rejected():
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.distributed import make_distributed_step

    with pytest.raises(ValueError, match="exchange"):
        make_distributed_step(make_stencil_mesh(1, 1), default_params(8),
                              exchange="telepathy")

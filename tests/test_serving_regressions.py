"""Tier-1 ServingEngine regressions: budget off-by-one, prompt-length
guard, dead-slot masking / slot reuse.

Unlike tests/test_serving.py (slow tier), these run in the fast tier —
they pin the three correctness fixes:

  * ``max_new_tokens=1`` completes AT PRIME TIME with exactly one token
    (the prefill argmax); the old engine decoded one token past budget.
  * a prompt with ``len >= max_len`` must raise ValueError naming the
    limit — JAX's clipped scatter would otherwise silently drop the
    out-of-bounds cache tail and corrupt decode.
  * a re-primed slot is unaffected by its previous occupant: priming
    overwrites the whole cache slot and dead slots are masked out of the
    decode feed.
"""
import jax
import numpy as np
import pytest

from repro import pspec
from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.slots import SlotManager


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("qwen3_32b")
    layout = M.make_layout(cfg, tp=1)
    params = pspec.init_params(M.param_specs(cfg, layout),
                               jax.random.PRNGKey(0))
    return cfg, params


def _prompt(n, seed=0, vocab=128):
    return np.random.default_rng(seed).integers(0, vocab, n).astype(np.int32)


def test_max_new_tokens_one_emits_exactly_one(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, batch_size=2, max_len=32)
    done = eng.run([Request(uid=0, prompt=_prompt(6), max_new_tokens=1)])
    assert len(done[0]) == 1
    # complete-at-prime: the request never occupied a slot
    assert not eng.slots.any_live()


def test_budget_exact_for_small_counts(engine_setup):
    cfg, params = engine_setup
    for n in (1, 2, 3):
        eng = ServingEngine(cfg, params, batch_size=1, max_len=32)
        done = eng.run([Request(uid=0, prompt=_prompt(5), max_new_tokens=n)])
        assert len(done[0]) == n, f"max_new_tokens={n} produced {len(done[0])}"


def test_max_new_tokens_zero_rejected(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, batch_size=1, max_len=32)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.run([Request(uid=0, prompt=_prompt(4), max_new_tokens=0)])


def test_oversized_prompt_raises_naming_limit(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, batch_size=1, max_len=16)
    with pytest.raises(ValueError, match=r"max_len is 16"):
        eng.run([Request(uid=0, prompt=_prompt(16), max_new_tokens=2)])
    with pytest.raises(ValueError, match=r"max_len"):
        eng.run([Request(uid=1, prompt=_prompt(40), max_new_tokens=2)])


def test_slot_reuse_unaffected_by_previous_occupant(engine_setup):
    """A request decoded in a reused slot matches the same request decoded
    in a fresh engine: no stale cache from the previous occupant leaks."""
    cfg, params = engine_setup
    pa, pb = _prompt(10, seed=1), _prompt(7, seed=2)
    # batch_size=1 forces B to reuse the slot A just released
    eng = ServingEngine(cfg, params, batch_size=1, max_len=64)
    done = eng.run([Request(uid=0, prompt=pa, max_new_tokens=6),
                    Request(uid=1, prompt=pb, max_new_tokens=6)])
    fresh = ServingEngine(cfg, params, batch_size=1, max_len=64)
    alone = fresh.run([Request(uid=1, prompt=pb, max_new_tokens=6)])
    assert done[1] == alone[1]


def test_next_token_initialised_at_construction(engine_setup):
    cfg, params = engine_setup
    eng = ServingEngine(cfg, params, batch_size=3, max_len=32)
    assert eng.next_token.shape == (3,)
    assert eng.next_token.dtype == np.int32
    assert not eng.next_token.any()


# -- SlotManager unit behaviour (shared by both serving tiers) -------------

def test_slot_manager_rejects_zero_budget():
    sm = SlotManager(2)
    with pytest.raises(ValueError, match="budget"):
        sm.occupy(0, "req", 0)


def test_slot_manager_lifecycle():
    sm = SlotManager(2)
    sm.occupy(1, "req", 2)
    assert sm.live_slots() == [1] and sm.idle_slots() == [0]
    with pytest.raises(ValueError):
        sm.occupy(1, "other", 3)      # already live
    assert sm.tick(1) is False        # budget 2 -> 1
    assert sm.tick(1) is True         # budget 1 -> 0: complete
    sm.release(1)
    assert not sm.any_live()
    with pytest.raises(ValueError):
        sm.tick(1)                    # not live any more

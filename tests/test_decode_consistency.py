"""prefill + single-token decode must equal the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pspec
from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.serving.engine import prefill_to_decode_cache

TOL = {"ssm": 5e-2, "hybrid": 5e-2, "encdec": 5e-2}


# heaviest decode archs go to the slow tier; the cheap ones keep per-family
# decode coverage fast (same policy as test_arch_smoke.HEAVY_ARCHS)
_HEAVY = {"recurrentgemma_9b", "whisper_large_v3", "llama4_maverick_400b_a17b"}


@pytest.mark.parametrize("arch",
                         [pytest.param(a, marks=pytest.mark.slow)
                          if a in _HEAVY else a for a in ARCH_IDS])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    layout = M.make_layout(cfg, tp=1)
    params = pspec.init_params(M.param_specs(cfg, layout), jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    B, S = 2, 32
    if cfg.family == "encdec":
        enc = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        dec = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)
        full, _, _ = M.forward(params, {"enc_embeds": enc, "dec_inputs": dec},
                               cfg, layout)
        _, _, caches = M.forward(params,
                                 {"enc_embeds": enc, "dec_inputs": dec[:, :7]},
                                 cfg, layout, mode="prefill")
        caches = prefill_to_decode_cache(cfg, caches, 7, cfg.encdec.max_dec_len)
        logits, _ = M.decode_step(
            params, caches,
            {"token": dec[:, 7], "pos": jnp.full((B,), 7, jnp.int32)},
            cfg, layout)
    elif cfg.embeds_input:
        emb = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        pos3 = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
        full, _, _ = M.forward(params, {"embeds": emb, "positions": pos3},
                               cfg, layout)
        _, _, caches = M.forward(params, {"embeds": emb[:, :S - 1],
                                          "positions": pos3[:, :S - 1]},
                                 cfg, layout, mode="prefill")
        caches = prefill_to_decode_cache(cfg, caches, S - 1, S + 4)
        logits, _ = M.decode_step(
            params, caches,
            {"embeds": emb[:, S - 1:S], "token": jnp.zeros((B,), jnp.int32),
             "pos": jnp.full((B,), S - 1, jnp.int32)},
            cfg, layout)
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        full, _, _ = M.forward(params, {"inputs": toks}, cfg, layout)
        _, _, caches = M.forward(params, {"inputs": toks[:, :S - 1]}, cfg,
                                 layout, mode="prefill")
        caches = prefill_to_decode_cache(cfg, caches, S - 1, S + 4)
        logits, _ = M.decode_step(
            params, caches,
            {"token": toks[:, S - 1], "pos": jnp.full((B,), S - 1, jnp.int32)},
            cfg, layout)
    err = float(jnp.max(jnp.abs(logits - full[:, -1])))
    assert err < TOL.get(cfg.family, 1e-3), (arch, err)


def test_multi_token_decode_chain():
    """Decode 8 tokens sequentially == slices of the full forward logits."""
    cfg = get_smoke_config("qwen3_32b")
    layout = M.make_layout(cfg, tp=1)
    params = pspec.init_params(M.param_specs(cfg, layout), jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    B, S, T = 2, 24, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + T)), jnp.int32)
    full, _, _ = M.forward(params, {"inputs": toks}, cfg, layout)
    _, _, caches = M.forward(params, {"inputs": toks[:, :S]}, cfg, layout,
                             mode="prefill")
    caches = prefill_to_decode_cache(cfg, caches, S, S + T + 2)
    errs = []
    for t in range(T):
        logits, caches = M.decode_step(
            params, caches,
            {"token": toks[:, S + t], "pos": jnp.full((B,), S + t, jnp.int32)},
            cfg, layout)
        errs.append(float(jnp.max(jnp.abs(logits - full[:, S + t]))))
    assert max(errs) < 1e-3, errs

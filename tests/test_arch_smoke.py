"""Required per-arch smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import pspec
from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.training import optimizer as O
from repro.training import step as TS


def make_smoke_batch(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.default_rng(0)
    if cfg.family == "encdec":
        Td = cfg.encdec.dec_len
        return {"enc_embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "dec_inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Td)), jnp.int32),
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Td)), jnp.int32)}
    if cfg.embeds_input:
        pos = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3)).astype(jnp.int32)
        return {"embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "positions": pos,
                "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    return {"inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    layout = M.make_layout(cfg, tp=1)
    params = pspec.init_params(M.param_specs(cfg, layout), jax.random.PRNGKey(0))
    batch = make_smoke_batch(cfg)
    logits, aux, _ = M.forward(params, batch, cfg, layout)
    B = batch["targets"].shape[0]
    T = batch["targets"].shape[1]
    assert logits.shape[:2] == (B, T)
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch


# the multi-minute tail of the fast tier lives in a handful of heavy archs;
# their forward-pass coverage stays fast, the optimiser step goes slow-tier
HEAVY_ARCHS = {"recurrentgemma_9b", "whisper_large_v3",
               "llama4_maverick_400b_a17b", "falcon_mamba_7b", "arctic_480b"}


def _train_params(ids):
    return [pytest.param(a, marks=pytest.mark.slow) if a in HEAVY_ARCHS
            else a for a in ids]


@pytest.mark.parametrize("arch", _train_params(ARCH_IDS))
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    layout = M.make_layout(cfg, tp=1)
    state = TS.init_state(cfg, layout, jax.random.PRNGKey(1))
    step = jax.jit(TS.make_train_step(cfg, layout,
                                      opt=O.OptConfig(warmup_steps=1,
                                                      total_steps=10)))
    batch = make_smoke_batch(cfg)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert bool(jnp.isfinite(metrics["grad_norm"])), arch
    assert float(metrics["grad_norm"]) > 0.0, f"{arch}: zero gradients"
    assert int(new_state["opt"]["step"]) == 1
    # params actually moved
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(state["params"]),
                            jax.tree.leaves(new_state["params"])))
    assert d > 0.0, arch


@pytest.mark.parametrize("arch", _train_params(["qwen3_32b",
                                                "falcon_mamba_7b",
                                                "recurrentgemma_9b",
                                                "arctic_480b"]))
def test_two_steps_loss_decreases(arch):
    """Overfit two steps on one batch: loss must drop (lr sane, grads real)."""
    cfg = get_smoke_config(arch)
    layout = M.make_layout(cfg, tp=1)
    state = TS.init_state(cfg, layout, jax.random.PRNGKey(2))
    step = jax.jit(TS.make_train_step(
        cfg, layout, opt=O.OptConfig(peak_lr=1e-2, warmup_steps=0,
                                     total_steps=100, weight_decay=0.0)))
    batch = make_smoke_batch(cfg)
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], (arch, losses)

"""Opt-in compiled-mode (interpret=False) smoke: does Mosaic accept the
grid-tiled kernels' `pl.Unblocked` element offsets on real TPU tiling?
(ROADMAP open item — everything else in the suite runs interpret-mode.)

Off by default everywhere: set ``REPRO_COMPILED=1`` on a TPU host to run
(`REPRO_COMPILED=1 python -m pytest -m compiled`). Without the env var, or
on a non-TPU backend, the tests skip cleanly — so the fast tier stays
green on CPU CI and the cases light up the moment a TPU is attached.
"""
import os

import numpy as np
import pytest

pytestmark = pytest.mark.compiled

_opted_in = pytest.mark.skipif(
    os.environ.get("REPRO_COMPILED") != "1",
    reason="compiled-mode smoke is opt-in: set REPRO_COMPILED=1 on a TPU "
           "host")


def _require_tpu():
    import jax
    if jax.default_backend() != "tpu":
        pytest.skip("compiled-mode smoke needs a TPU backend (Mosaic); "
                    f"got {jax.default_backend()!r}")


@_opted_in
@pytest.mark.parametrize("y_tile", [None, 8])
def test_compiled_fused_grid_tiled_matches_interpret(y_tile):
    """One tiny grid-tiled v4 launch with interpret=False: Mosaic must
    lower the Unblocked element-offset BlockSpecs and reproduce the
    interpret-mode result."""
    _require_tpu()
    import jax.numpy as jnp

    from repro.kernels.advection.advection import advect_fused
    from repro.kernels.advection.ref import default_params
    from repro.stencil.advection import stratus_fields

    X, Y, Z, T = 6, 24, 128, 2   # lane-aligned Z; slab fits VMEM easily
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    ref = advect_fused(u, v, w, p, T=T, dt=0.01, y_tile=y_tile,
                       interpret=True)
    out = advect_fused(u, v, w, p, T=T, dt=0.01, y_tile=y_tile,
                       interpret=False)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


@_opted_in
def test_compiled_remote_dma_exchange_matches_collective():
    """The real §IV endgame: the in-kernel `make_async_remote_copy` band
    exchange (double-buffered recv slabs, barrier + DMA semaphores) on an
    actual TPU ring must reproduce the collective engine's step. Needs >= 2
    TPU devices; skips on a single-chip host."""
    _require_tpu()
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import make_distributed_step

    n = len(jax.devices())
    if n < 2:
        pytest.skip("remote-DMA smoke needs >= 2 TPU devices")
    ny = 2
    X, Y, Z, T = 6, 16 * ny, 128, 2
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = make_stencil_mesh(1, ny)
    sh = NamedSharding(mesh, P("x", "y", None))
    args = [jax.device_put(t, sh) for t in (u, v, w)]
    kw = dict(axis="y", x_axis="x", T=T, dt=0.01, local_kernel="fused",
              interpret=False, overlap=True)
    ref = make_distributed_step(mesh, p, exchange="collective", **kw)(*args)
    for block in (0, 1):   # both recv-slab slots
        out = make_distributed_step(mesh, p, exchange="remote_dma",
                                    dma_block_index=block, **kw)(*args)
        for a, b in zip(out, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


@_opted_in
def test_compiled_pipelined_multiblock_multihop_remote_dma():
    """The pipelined endgame on real hardware: ONE compiled program runs
    K blocks with the remote-DMA engine's recv-slot parity alternating on
    the traced block counter, at a T DEEPER than the local extent (2-hop
    `make_async_remote_copy` schedule). Must match the collective run and
    K sequential alternating-parity steps. Needs >= 2 TPU devices."""
    _require_tpu()
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (make_distributed_run,
                                           make_distributed_step)

    n = len(jax.devices())
    if n < 2:
        pytest.skip("pipelined remote-DMA smoke needs >= 2 TPU devices")
    ny, K = 2, 3
    X, Y, Z = 6, 8 * ny, 128
    T = 10                      # Yl = 8 -> 2 hops per side
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = make_stencil_mesh(1, ny)
    sh = NamedSharding(mesh, P("x", "y", None))
    args = [jax.device_put(t, sh) for t in (u, v, w)]
    kw = dict(axis="y", x_axis="x", T=T, dt=0.01, local_kernel="fused",
              interpret=False, overlap=True)
    ref = make_distributed_run(mesh, p, n_blocks=K,
                               exchange="collective", **kw)(*args)
    out = make_distributed_run(mesh, p, n_blocks=K,
                               exchange="remote_dma", **kw)(*args)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    seq = args
    for k in range(K):
        seq = make_distributed_step(mesh, p, exchange="remote_dma",
                                    dma_block_index=k, **kw)(*seq)
    for a, b in zip(out, seq):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@_opted_in
def test_compiled_dataflow_grid_tiled_smoke():
    _require_tpu()
    from repro.kernels.advection.advection import advect_dataflow
    from repro.kernels.advection.ref import default_params
    from repro.stencil.advection import stratus_fields

    X, Y, Z = 5, 16, 128
    u, v, w = stratus_fields(X, Y, Z, seed=1)
    p = default_params(Z)
    ref = advect_dataflow(u, v, w, p, y_tile=4, interpret=True)
    out = advect_dataflow(u, v, w, p, y_tile=4, interpret=False)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

"""Tiling-contract linter: every Pallas block mapping checked statically
against the (8, 128) tile, Unblocked bounds, and in-place alias windows.

All fast tier (1-device): the repo's own kernels lint error-free (the
lane/sublane warnings on deliberately-tiny interpret grids are warnings,
not errors); a fabricated Unblocked kernel whose index map walks past
the operand extent is flagged "unblocked-oob" with the offending grid
point and dim; aliased in-place windows that diverge are flagged
"alias-window"; a lane-aligned kernel produces no lane warnings. The
linter only TRACES (`jax.make_jaxpr`) — the broken fixtures never run.
"""
import functools

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import SUBLANE, LANE, lint_tiling
from repro.kernels.advection.advection import advect_fused
from repro.kernels.advection.ref import default_params

X, Y, Z = 4, 16, 128


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def _unblocked_copy(x, *, n, stride, block, base=0):
    """`n` grid steps, each copying a `block` window read at Unblocked
    element offset ``base + g * stride``. A stride (or base) walking
    past the operand extent fabricates the OOB the linter must catch;
    the program is traced, never run."""
    spec = pl.BlockSpec(block,
                        lambda g: (base + g * stride, 0),
                        indexing_mode=pl.Unblocked())
    out_spec = pl.BlockSpec(block, lambda g: (0, 0),
                            indexing_mode=pl.Unblocked())
    return pl.pallas_call(
        _copy_kernel, grid=(n,),
        in_specs=[spec], out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(block, x.dtype),
        interpret=True)(x)


def test_repo_fused_kernel_is_error_free():
    p = default_params(Z)
    f = jnp.zeros((X, Y, Z), jnp.float32)
    report = lint_tiling(
        lambda u, v, w: advect_fused(u, v, w, p, T=2, interpret=True,
                                     y_tile=8), f, f, f)
    assert report.kernels >= 1
    assert not report.errors
    report.raise_if_errors()            # no-op when green


def test_lane_aligned_kernel_has_no_lane_warnings():
    x = jnp.zeros((64, LANE), jnp.float32)
    report = lint_tiling(
        lambda a: _unblocked_copy(a, n=2, stride=SUBLANE,
                                  block=(SUBLANE, LANE)), x)
    assert not report.errors
    assert not [w for w in report.warnings
                if w.kind in ("lane", "sublane")]


def test_misaligned_block_warns_not_errors():
    x = jnp.zeros((64, LANE), jnp.float32)
    report = lint_tiling(
        lambda a: _unblocked_copy(a, n=1, stride=0, block=(3, 100)), x)
    assert not report.errors
    kinds = {w.kind for w in report.warnings}
    assert "lane" in kinds and "sublane" in kinds


def test_unblocked_oob_is_an_error():
    x = jnp.zeros((64, LANE), jnp.float32)
    # grid point 1 reads rows [60, 68) of a 64-row operand
    report = lint_tiling(
        lambda a: _unblocked_copy(a, n=2, stride=60,
                                  block=(SUBLANE, LANE)), x)
    errs = [e for e in report.errors if e.kind == "unblocked-oob"]
    assert errs, report.issues
    assert "extent 64" in errs[0].detail and "(1,)" in errs[0].detail
    with pytest.raises(AssertionError, match="unblocked-oob"):
        report.raise_if_errors()
    # a negative element offset is equally out of bounds
    neg = lint_tiling(
        lambda a: _unblocked_copy(a, n=1, stride=0, base=-8,
                                  block=(SUBLANE, LANE)), x)
    assert any(e.kind == "unblocked-oob" for e in neg.errors)


def _aliased_shift(x, *, shift):
    """In-place update whose write window is `shift` rows away from its
    read window — `shift != 0` fabricates the alias-window violation."""
    n = x.shape[0] // SUBLANE

    def kernel(src_ref, dst_ref):
        dst_ref[...] = src_ref[...] * 2.0

    return pl.pallas_call(
        kernel, grid=(n,),
        in_specs=[pl.BlockSpec((SUBLANE, LANE),
                               lambda g: (g * SUBLANE, 0),
                               indexing_mode=pl.Unblocked())],
        out_specs=pl.BlockSpec((SUBLANE, LANE),
                               functools.partial(
                                   lambda g, s: (g * SUBLANE + s, 0),
                                   s=shift),
                               indexing_mode=pl.Unblocked()),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={0: 0},
        interpret=True)(x)


def test_alias_window_divergence_is_an_error():
    x = jnp.zeros((64, LANE), jnp.float32)
    clean = lint_tiling(lambda a: _aliased_shift(a, shift=0), x)
    assert not clean.errors
    bad = lint_tiling(lambda a: _aliased_shift(a, shift=SUBLANE), x)
    errs = [e for e in bad.errors if e.kind == "alias-window"]
    assert errs, bad.issues
    assert "in[0]<->out[0]" in errs[0].operand


def test_grid_cap_falls_back_to_corners():
    # a grid bigger than max_grid_points still catches a corner OOB:
    # only the LAST grid point (g=39, rows [78, 86)) exceeds 64 rows
    x = jnp.zeros((64, LANE), jnp.float32)
    report = lint_tiling(
        lambda a: _unblocked_copy(a, n=40, stride=2,
                                  block=(SUBLANE, LANE)),
        x, max_grid_points=4)
    assert any(e.kind == "unblocked-oob" for e in report.errors)

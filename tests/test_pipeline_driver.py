"""The pipelined multi-block driver `make_distributed_run`: K substep
blocks in ONE traced program, block counter threaded as a traced
`fori_loop` induction variable into the exchange engine's recv-slot
parity. Fast tier pins the trace-once contract (no per-block retrace) and
single-device wiring; the slow tier runs the multi-device K-block bitwise
sweep (vs K sequential alternating-parity steps, vs the collective run,
multi-hop T included) through the subprocess idiom. `_band_schedule`'s
invariants are property-tested via the `tests/_prop` shim.
"""
import textwrap

import pytest

from _prop import given, settings, st
from _subproc import run_ok as _run


# --- fast tier: _band_schedule property invariants --------------------------

@settings(max_examples=60, deadline=None)
@given(L=st.integers(1, 64), depth=st.integers(1, 64))
def test_band_schedule_invariants(L, depth):
    """For any local extent L and halo depth: hop counts sum exactly to
    `depth`, hop distances are 1..ceil(depth/L) ascending, and the
    `hi_off`/`lo_off` bands partition the hi halo [0, depth) and the lo
    halo [depth+L, depth+L+depth) of the extended slab with no overlap
    or gap — the recv-slab addresses every engine shares."""
    from repro.stencil.distributed import _band_schedule

    sched = _band_schedule(L, depth)
    hops = -(-depth // L)
    assert [k for k, _, _, _ in sched] == list(range(1, hops + 1))
    assert all(1 <= cnt <= L for _, cnt, _, _ in sched)
    assert sum(cnt for _, cnt, _, _ in sched) == depth
    hi_rows = sorted(r for _, cnt, hi_off, _ in sched
                     for r in range(hi_off, hi_off + cnt))
    assert hi_rows == list(range(depth)), (L, depth, sched)
    lo_rows = sorted(r for _, cnt, _, lo_off in sched
                     for r in range(lo_off, lo_off + cnt))
    assert lo_rows == list(range(depth + L, 2 * depth + L)), (L, depth,
                                                              sched)


def test_band_schedule_reexported_from_kernel_layer():
    """The schedule the DMA kernel issues IS the schedule the emulation
    and the wire pricing address through — one object, no drift."""
    from repro.kernels.advection import advection as K
    from repro.stencil import distributed as D

    assert D._band_schedule is K._band_schedule


# --- fast tier: driver wiring + trace-once regression -----------------------

def test_run_driver_rejects_bad_config():
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.distributed import make_distributed_run

    mesh = make_stencil_mesh(1, 1)
    p = default_params(8)
    with pytest.raises(ValueError, match="n_blocks"):
        make_distributed_run(mesh, p, n_blocks=0)
    with pytest.raises(ValueError, match="exchange"):
        make_distributed_run(mesh, p, n_blocks=2, exchange="telepathy")
    with pytest.raises(ValueError, match="T must be"):
        make_distributed_run(mesh, p, n_blocks=2, T=0)


def test_run_driver_single_device_matches_sequential_and_oracle():
    """(1, 1) 'mesh': K blocks of the run driver == K sequential step
    calls (alternating dma_block_index parity) == the global oracle at
    K*T substeps, for both engines."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil.advection import stratus_fields
    from repro.stencil.distributed import (make_distributed_run,
                                           make_distributed_step,
                                           reference_global_step)

    X, Y, Z, T, K = 6, 10, 8, 2, 3
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    mesh = make_stencil_mesh(1, 1)
    sh = NamedSharding(mesh, P("x", "y", None))
    args = [jax.device_put(t, sh) for t in (u, v, w)]
    ref = reference_global_step(u, v, w, p, T=K * T, dt=0.01)
    for ex in ("collective", "remote_dma"):
        kw = dict(axis="y", x_axis="x", T=T, dt=0.01,
                  local_kernel="fused", overlap=True, exchange=ex)
        out = make_distributed_run(mesh, p, n_blocks=K, **kw)(*args)
        seq = args
        for k in range(K):
            seq = make_distributed_step(mesh, p, dma_block_index=k,
                                        **kw)(*seq)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(out, seq))
        assert diff == 0.0, (ex, diff)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(out, ref))
        assert err < 1e-5, (ex, err)


@pytest.mark.parametrize("n_blocks", [3, 5])
def test_run_driver_traces_step_body_exactly_once(monkeypatch, n_blocks):
    """The regression the driver exists to fix: K blocks must NOT retrace
    (let alone recompile) the step body per block. The reference local
    kernel calls `pw_advect_ref` exactly T times per traced block body —
    a driver that unrolled or rebuilt per block would trace K*T calls."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh
    from repro.stencil import distributed as dist
    from repro.stencil.advection import stratus_fields

    T = 2
    calls = {"n": 0}
    real = dist.pw_advect_ref

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(dist, "pw_advect_ref", counting)
    mesh = make_stencil_mesh(1, 1)
    p = default_params(8)
    u, v, w = stratus_fields(6, 10, 8)
    sh = NamedSharding(mesh, P("x", "y", None))
    args = [jax.device_put(t, sh) for t in (u, v, w)]
    run = dist.make_distributed_run(mesh, p, n_blocks=n_blocks, axis="y",
                                    x_axis="x", T=T, dt=0.01,
                                    local_kernel="reference")
    jax.block_until_ready(run(*args))
    assert calls["n"] == T, (n_blocks, calls["n"])


# --- slow tier: multi-device K-block bitwise + trace-once wire count --------

RUN_SWEEP_CODE = textwrap.dedent("""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.roofline import halo_wire_bytes_model
    from repro.stencil.distributed import (count_exchange_wire_bytes,
                                           make_distributed_run,
                                           make_distributed_step,
                                           reference_global_step)
    from repro.stencil.advection import stratus_fields
    from repro.kernels.advection.ref import default_params
    from repro.launch.mesh import make_stencil_mesh

    X, Y, Z, K = 6, 16, 12, 3
    u, v, w = stratus_fields(X, Y, Z)
    p = default_params(Z)
    # (nx, ny, T): Yl = 4 on the (1, 4) mesh, so T=2/6/10 is 1/2/3 hops
    # per side — T both <= and > the local extent, the acceptance sweep;
    # (2, 2) runs two-phase with multi-hop x (Xl=3 < T=4).
    for nx, ny, T, lk in ((1, 4, 2, "fused"), (1, 4, 6, "reference"),
                          (1, 4, 10, "reference"), (2, 2, 4, "fused")):
        mesh = make_stencil_mesh(nx, ny)
        sh = NamedSharding(mesh, P("x", "y", None))
        args = [jax.device_put(t, sh) for t in (u, v, w)]
        kw = dict(axis="y", x_axis="x", T=T, dt=0.005, local_kernel=lk,
                  overlap=True)
        runs = {ex: make_distributed_run(mesh, p, n_blocks=K, exchange=ex,
                                         **kw)
                for ex in ("collective", "remote_dma")}
        outs = {ex: fn(*args) for ex, fn in runs.items()}
        diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                   zip(outs["collective"], outs["remote_dma"]))
        assert diff == 0.0, (nx, ny, T, lk, diff)
        seq = args
        for k in range(K):
            seq = make_distributed_step(mesh, p, exchange="remote_dma",
                                        dma_block_index=k, **kw)(*seq)
        diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in
                   zip(outs["remote_dma"], seq))
        assert diff == 0.0, (nx, ny, T, lk, diff)
        # trace-once: the fori_loop body jaxpr carries ONE block's
        # ppermutes, so the K-block count equals the one-block model
        got = count_exchange_wire_bytes(runs["remote_dma"], u, v, w)
        model = halo_wire_bytes_model(X, Y, Z, 4, nx=nx, ny=ny, T=T)
        assert got == model, (nx, ny, T, got, model)
        ref = reference_global_step(u, v, w, p, T=K * T, dt=0.005)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(outs["remote_dma"], ref))
        assert err < 1e-4, (nx, ny, T, lk, err)
    print("OK")
""")


@pytest.mark.slow
def test_run_driver_multi_device_bitwise_sweep():
    _run(RUN_SWEEP_CODE)
